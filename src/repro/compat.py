"""Version-portability shims for JAX API drift (non-Pallas surface).

The repo targets the jax.shard_map-era API, but must run on any jax from
0.4.3x upward.  Every module that needs an API whose home has moved imports
it from here instead of feature-testing locally, so there is exactly one
place that knows about the drift.  (Pallas-specific drift lives in
``repro.kernels.pallas_compat`` — the kernel layer's single import point.)

Currently papered over:

* ``jax.shard_map`` — promoted from ``jax.experimental.shard_map`` to the
  top-level namespace in jax 0.6; older versions only have the
  experimental path (whose extra ``check_rep`` knob we disable: the
  replication checker in the 0.4.x line rejects some valid
  collective-in-loop patterns that the promoted version accepts).
"""

from __future__ import annotations

import inspect

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """`jax.shard_map` on new jax, `jax.experimental.shard_map` on old.

    ``check_vma``: the varying-axes checker toggle, named ``check_vma`` on
    new jax and ``check_rep`` on the experimental version.  ``None`` means
    "whatever the version's default is" (new jax) / disabled (old jax).
    """
    if hasattr(jax, "shard_map"):
        kwargs = {}
        if check_vma is not None:
            params = inspect.signature(jax.shard_map).parameters
            for name in ("check_vma", "check_rep"):
                if name in params:
                    kwargs[name] = check_vma
                    break
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
