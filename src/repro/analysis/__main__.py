"""``python -m repro.analysis`` — the invariant-checker CLI CI gates on.

Default run (no arguments): Layer 1 lints the installed ``repro``
package tree and Layer 2 abstractly verifies every registered kernel
form under every advertised capability combination.  Explicit paths
restrict the run to Layer 1 over those paths (fixture checking, editor
integration).  ``--state-dir`` additionally runs the Layer-3
determinism auditor over a ``DurableStore`` directory.

Exit status 0 means every checked invariant holds; 1 means violations
were printed (one ``RULE path:line message`` per line); 2 means the
checker itself could not run.
"""

from __future__ import annotations

import argparse
import os
import sys

from repro.analysis.violations import RULES, render


def _default_tree() -> str:
    # the repro package directory itself: works both from a src checkout
    # and an installed package
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Check the repo's kernel/service invariants "
                    "(see repro.analysis.RULES for rule IDs).")
    parser.add_argument(
        "paths", nargs="*",
        help="files/directories to lint (default: the repro package "
             "tree, plus the kernel contract layer)")
    parser.add_argument(
        "--state-dir", action="append", default=[],
        help="DurableStore state dir to audit (repeatable)")
    parser.add_argument(
        "--skip-contracts", action="store_true",
        help="skip the jaxpr contract layer (no jax import)")
    parser.add_argument(
        "--list-rules", action="store_true",
        help="print every rule ID and the contract it enforces")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in sorted(RULES):
            print(f"{rule}  {RULES[rule]}")
        return 0

    violations = []
    notes = []

    from repro.analysis import boundary
    lint_paths = args.paths or [_default_tree()]
    violations.extend(boundary.check_paths(lint_paths))
    notes.append(f"boundary: linted {lint_paths}")

    run_contracts = not args.skip_contracts and not args.paths
    if run_contracts:
        from repro.analysis import contracts
        from repro.kernels import registry
        violations.extend(contracts.check_registered_forms())
        forms = registry.forms()
        combos = sum(len(contracts._combos(f)) for f in forms)
        notes.append(f"contracts: {len(forms)} form(s), "
                     f"{combos} capability combo(s) traced")

    from repro.analysis import streams
    for state_dir in args.state_dir:
        report = streams.audit_state_dir(state_dir)
        violations.extend(report.violations)
        notes.append(report.summary())

    if violations:
        print(render(violations))
    for note in notes:
        print(f"[analysis] {note}", file=sys.stderr)
    status = "FAIL" if violations else "OK"
    print(f"[analysis] {status}: {len(violations)} violation(s)",
          file=sys.stderr)
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
