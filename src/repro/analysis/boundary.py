"""Layer 1: repo-specific AST lint over the whole tree.

Four rules, each enforcing an invariant the ROADMAP used to state only
in prose:

* **BND001** — ``jax.experimental.*`` (Pallas, shard_map's old home,
  anything unstable) may be imported or referenced only from the two
  version-drift shims, ``repro/kernels/pallas_compat.py`` and
  ``repro/compat.py``.  Everything else rides the shims, so a jax bump
  is a two-file change.
* **BND002** — ``jax.shard_map`` (the new-API name) likewise: only
  ``repro/compat.py`` may touch it, because the floor jax (0.4.37)
  doesn't have it.
* **PUR001** — modules under ``repro/kernels/`` and ``repro/core/``
  hold eval bodies and counter plumbing whose outputs must be a pure
  function of (key, counters, params): no wall-clock (``time``),
  stateful RNG (``random``, ``np.random``), ``datetime``, or host I/O
  (``open``/``input``).  Host-side drivers (``launch/``, ``service/``,
  benchmarks) are out of scope.
* **F64001** — no ``jnp.float64`` (or ``astype``/``dtype='float64'``)
  in ``repro/kernels/`` / ``repro/core/``: accumulators are f32 by
  contract (TPU has no fast f64, and the WAL journals exact f32 bits).
  Host-side ``np.float64`` (analytic references, static metadata) is
  fine and not flagged.
* **OBS001** — modules under ``repro/service/`` and ``repro/obs/``
  read the wall clock only through the ``repro/obs/clock.py`` shim (no
  direct ``time`` import or ``time.*`` call): trace timestamps, metric
  latencies and fake-clock tests must all observe the same clock.
  Kernels/core stay wholly clock-free under the stricter PUR001;
  standalone launchers and ``distributed/`` are out of scope.
* **RES001** — modules under ``repro/service/`` retry, back off and
  sleep only through ``repro/service/resilience.py``: importing the
  ad-hoc ``run_with_restarts`` loop or calling any ``.sleep(...)``
  elsewhere in the service is flagged.  One policy object owns attempt
  budgets, deterministic jitter and deadline clamping — scattered retry
  loops are exactly how tickets end up hanging past their deadline.

Escape hatch: append ``# analysis: ignore[RULE]`` (comma-separate for
several rules) to the offending line.  Use it to *document* a deliberate
exception, never to silence a rule you don't understand — the rule ID
makes every exemption greppable.

The linter is pure ``ast`` + stdlib: it never imports the files it
checks, so fixture files seeded with violations are safe to scan.
"""

from __future__ import annotations

import ast
import os
import re
from pathlib import Path

from repro.analysis.violations import Violation

# Files allowed to touch jax.experimental.* / jax.shard_map (BND001/2).
BOUNDARY_ALLOWED = (
    "repro/kernels/pallas_compat.py",
    "repro/compat.py",
)

# Path fragments marking purity-scoped modules (PUR001/F64001).  A
# segment match (not a suffix match) so test fixtures laid out under
# ``fixtures/kernels/`` / ``fixtures/core/`` are scoped identically.
PURE_SCOPE_SEGMENTS = ("kernels", "core")

# Modules whose import into a pure scope is a PUR001 violation.
_IMPURE_MODULES = ("time", "random", "datetime")

# Path fragments marking clock-shim-scoped modules (OBS001), and the
# one file allowed to touch ``time`` inside them.  Segment match, like
# PURE_SCOPE_SEGMENTS, so ``fixtures/service/`` fixtures scope too.
OBS_SCOPE_SEGMENTS = ("service", "obs")
CLOCK_SHIM_SUFFIX = "obs/clock.py"

# Path fragments marking retry-policy-scoped modules (RES001), and the
# one file allowed to run retry loops and sleep inside them.
RES_SCOPE_SEGMENTS = ("service",)
RESILIENCE_SUFFIX = "service/resilience.py"

# The ad-hoc retry entry point RES001 bans outside the policy module.
_ADHOC_RETRY = "run_with_restarts"

# Builtin calls that do host I/O.
_IO_CALLS = ("open", "input")

# Seed model-config data modules (chatglm/deepseek/...) kept only for
# the model-stack smoke tests; lint-exempt so the clean-tree gate
# reflects the integration service we actually ship.  Mirrored by the
# ruff exclude in pyproject.toml.
DEFAULT_EXCLUDES = ("repro/configs/",)

_IGNORE_RE = re.compile(r"#\s*analysis:\s*ignore\[([A-Za-z0-9_,\s]+)\]")


def _posix(path) -> str:
    return str(path).replace(os.sep, "/")


def _is_boundary_shim(path: str) -> bool:
    return any(path.endswith(suffix) for suffix in BOUNDARY_ALLOWED)


def _in_pure_scope(path: str) -> bool:
    parts = path.split("/")
    return any(seg in parts[:-1] for seg in PURE_SCOPE_SEGMENTS)


def _in_obs_scope(path: str) -> bool:
    if path.endswith(CLOCK_SHIM_SUFFIX):
        return False     # the shim itself wraps ``time``
    parts = path.split("/")
    return any(seg in parts[:-1] for seg in OBS_SCOPE_SEGMENTS)


def _in_res_scope(path: str) -> bool:
    if path.endswith(RESILIENCE_SUFFIX):
        return False     # the policy module itself retries and sleeps
    parts = path.split("/")
    return any(seg in parts[:-1] for seg in RES_SCOPE_SEGMENTS)


def _dotted(node: ast.AST) -> str | None:
    """'a.b.c' for an Attribute/Name chain, None for anything else."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def _ignored_rules(lines: list[str], lineno: int) -> set[str]:
    if not 1 <= lineno <= len(lines):
        return set()
    m = _IGNORE_RE.search(lines[lineno - 1])
    if not m:
        return set()
    return {r.strip() for r in m.group(1).split(",") if r.strip()}


class _Checker(ast.NodeVisitor):
    def __init__(self, path: str):
        self.path = path
        self.shim = _is_boundary_shim(path)
        self.pure = _in_pure_scope(path)
        self.obs_scope = _in_obs_scope(path)
        self.res_scope = _in_res_scope(path)
        self.found: list[Violation] = []

    def _flag(self, rule: str, node: ast.AST, message: str) -> None:
        self.found.append(Violation(rule=rule, path=self.path,
                                    line=node.lineno, message=message))

    # -- imports --------------------------------------------------------------
    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            self._check_module(node, alias.name)
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        mod = node.module or ""
        self._check_module(node, mod)
        if mod == "jax" and not self.shim:
            for alias in node.names:
                if alias.name == "shard_map":
                    self._flag("BND002", node,
                               "import jax.shard_map via repro.compat, "
                               "not directly")
        if self.res_scope:
            for alias in node.names:
                if alias.name == _ADHOC_RETRY:
                    self._flag("RES001", node,
                               f"import of {_ADHOC_RETRY!r} in a service "
                               "module: retries go through "
                               "repro.service.resilience.run_with_policy "
                               "(one policy, deterministic jitter, "
                               "deadline-aware)")
        self.generic_visit(node)

    def _check_module(self, node: ast.AST, mod: str) -> None:
        if (mod == "jax.experimental"
                or mod.startswith("jax.experimental.")) and not self.shim:
            self._flag("BND001", node,
                       f"import of {mod!r} outside the compat shims "
                       "(use repro.kernels.pallas_compat / repro.compat)")
        if self.pure and (mod in _IMPURE_MODULES
                          or any(mod.startswith(m + ".")
                                 for m in _IMPURE_MODULES)):
            self._flag("PUR001", node,
                       f"import of {mod!r} in a purity-scoped module "
                       "(eval outputs must be a pure function of "
                       "key/counters/params)")
        if self.obs_scope and (mod == "time" or mod.startswith("time.")):
            self._flag("OBS001", node,
                       f"import of {mod!r} in a service/obs module: go "
                       "through repro.obs.clock (the single wall-clock "
                       "shim) so trace timestamps and fake-clock tests "
                       "stay consistent")

    # -- attribute chains -----------------------------------------------------
    def visit_Attribute(self, node: ast.Attribute) -> None:
        chain = _dotted(node)
        if chain is not None:
            if (chain.startswith("jax.experimental")
                    and not self.shim):
                self._flag("BND001", node,
                           f"reference to {chain!r} outside the compat "
                           "shims")
            elif chain == "jax.shard_map" and not self.shim:
                self._flag("BND002", node,
                           "use repro.compat.shard_map, not "
                           "jax.shard_map (absent on the floor jax)")
            if self.pure:
                if chain in ("np.random", "numpy.random") or chain.startswith(
                        ("np.random.", "numpy.random.")):
                    self._flag("PUR001", node,
                               f"stateful host RNG {chain!r} in a "
                               "purity-scoped module (use counter-based "
                               "repro.core.rng)")
                if chain in ("jnp.float64", "jax.numpy.float64"):
                    self._flag("F64001", node,
                               "float64 on an accumulator path "
                               "(deposits are exact f32; TPU has no "
                               "fast f64)")
            if self.obs_scope and chain.startswith("time."):
                self._flag("OBS001", node,
                           f"wall-clock read {chain!r} in a service/obs "
                           "module: use repro.obs.clock")
            if self.res_scope and chain.endswith("." + _ADHOC_RETRY):
                self._flag("RES001", node,
                           f"reference to {chain!r} in a service module: "
                           "retries go through "
                           "repro.service.resilience.run_with_policy")
            # a complete chain is all Names/Attributes: recursing would
            # re-flag its sub-chains (jax.experimental.pallas AND
            # jax.experimental) on the same line
            return
        self.generic_visit(node)

    # -- calls ----------------------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        if (self.res_scope and isinstance(node.func, ast.Attribute)
                and node.func.attr == "sleep"):
            self._flag("RES001", node,
                       "ad-hoc sleep in a service module: backoff pauses "
                       "belong to repro.service.resilience (jittered, "
                       "clamped to the request deadline)")
        if self.pure:
            if isinstance(node.func, ast.Name) and node.func.id in _IO_CALLS:
                self._flag("PUR001", node,
                           f"host I/O call {node.func.id}() in a "
                           "purity-scoped module")
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "astype"
                    and any(_is_f64_const(a) for a in node.args)):
                self._flag("F64001", node,
                           "astype('float64') on an accumulator path")
            for kw in node.keywords:
                if kw.arg == "dtype" and _is_f64_const(kw.value):
                    self._flag("F64001", node,
                               "dtype='float64' on an accumulator path")
        self.generic_visit(node)


def _is_f64_const(node: ast.AST) -> bool:
    return isinstance(node, ast.Constant) and node.value == "float64"


def check_source(source: str, path: str) -> list[Violation]:
    """Lint one file's source; ``path`` scopes the rules (see module
    docstring) and labels the violations."""
    path = _posix(path)
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        return [Violation(rule="BND001", path=path, line=exc.lineno or 0,
                          message=f"unparseable file: {exc.msg}")]
    checker = _Checker(path)
    checker.visit(tree)
    lines = source.splitlines()
    return [v for v in checker.found
            if v.rule not in _ignored_rules(lines, v.line)]


def check_file(path) -> list[Violation]:
    with open(path, encoding="utf-8") as f:
        return check_source(f.read(), _posix(path))


def iter_python_files(root):
    root = Path(root)
    if root.is_file():
        yield root
        return
    for path in sorted(root.rglob("*.py")):
        if any(part.startswith(".") for part in path.parts):
            continue
        yield path


def check_paths(paths, *, excludes: tuple[str, ...] = DEFAULT_EXCLUDES
                ) -> list[Violation]:
    """Lint every ``*.py`` under each path (files or directories)."""
    found: list[Violation] = []
    for root in paths:
        for path in iter_python_files(root):
            posix = _posix(path)
            if any(ex in posix for ex in excludes):
                continue
            found.extend(check_file(path))
    return found
