"""Layer 3: determinism/race analyzer over durable stream state.

The cache's bit-identity promise (a top-up equals an uninterrupted run,
``tests/core/test_resume.py``) rests on three structural invariants of
the counter-stream bookkeeping:

* every stream owns a **pairwise-disjoint** counter-space range
  ``[fn_offset, fn_offset + n_fn)`` — overlap means two streams draw the
  same Threefry counters (STR001, STR004 for the allocator high-water
  mark that guards future allocations);
* per-stream deposit rounds are **gap-free and monotone** — the f32
  accumulators are left-folded strictly in round order, so a skipped or
  reordered round changes association order and breaks bit-identity
  (STR002), and every round's delta must match the stream's shape and
  round quantum (STR003, STR005);
* every deposit references an **allocated** stream — a dep whose alloc
  never made it to disk is dropped on replay and silently recomputed
  (STR006);
* adapted streams' **grid epochs chain contiguously** — each ``grid``
  record extends its parent stream's epoch by exactly one (or opens
  epoch 1 on a base stream), duplicate records for one child agree, and
  the grid is journaled *before* the child stream's alloc, so a resumed
  engine always rebuilds the adapted family from the recorded edges
  rather than refitting a different grid (STR007).

This module proves them two ways from ONE set of predicates:

* :func:`audit_state_dir` — an offline auditor over a ``DurableStore``
  state dir (``python -m repro.analysis --state-dir ...``), used by
  operators (``serve_integrals --audit-state``) and by
  ``benchmarks/persistence_bench`` to show a post-SIGKILL dir still
  satisfies every invariant;
* cheap **debug-mode assertion hooks** the live service calls at its
  mutation points (``ResultCache.get_or_allocate``,
  ``RoundBatcher._spans_of``, ``IntegrationEngine._retire_items``),
  enabled via ``REPRO_ANALYSIS_ASSERTS=1`` or :func:`enable_asserts` —
  off by default so the hot path pays one predicate call's ``if``.

No jax anywhere in this module: the auditor must run in processes that
never touch a device (benchmark orchestrators, operator shells).
"""

from __future__ import annotations

import dataclasses
import os

from repro.analysis.violations import Violation

# -- debug-mode assertion switch ----------------------------------------------

_ASSERTS: bool | None = None


def asserts_enabled() -> bool:
    """Debug assertions on?  Env ``REPRO_ANALYSIS_ASSERTS`` (1/true/on)
    unless overridden by :func:`enable_asserts`."""
    if _ASSERTS is not None:
        return _ASSERTS
    return os.environ.get("REPRO_ANALYSIS_ASSERTS", "").lower() in (
        "1", "true", "on", "yes")


def enable_asserts(flag: bool | None) -> None:
    """Force debug assertions on/off (``None`` restores env control)."""
    global _ASSERTS
    _ASSERTS = flag


# -- shared predicates (auditor + live hooks) ---------------------------------

def find_overlaps(ranges):
    """Overlapping pairs among ``(label, start, n)`` counter ranges.

    Sort-and-sweep: only adjacent-in-start ranges can newly overlap, so
    this is O(n log n) — cheap enough for the live allocation hook.
    Empty ranges (n == 0) cannot overlap anything.
    """
    ordered = sorted(((start, start + n, label)
                      for label, start, n in ranges if n > 0))
    overlaps = []
    prev_end, prev_label = None, None
    for start, end, label in ordered:
        if prev_end is not None and start < prev_end:
            overlaps.append((prev_label, label))
        if prev_end is None or end > prev_end:
            prev_end, prev_label = end, label
    return overlaps


def classify_round(frontier: int, round_index: int) -> str:
    """'fold' (the next in-order round), 'replay' (already folded — an
    exact recomputation, skippable), or 'gap' (beyond the frontier —
    folding it would skip samples)."""
    if round_index < frontier:
        return "replay"
    if round_index == frontier:
        return "fold"
    return "gap"


# -- live debug hooks ---------------------------------------------------------

def assert_disjoint_allocation(existing_ranges, label: str, start: int,
                               n: int) -> None:
    """STR001 as a live check: a fresh allocation must not overlap any
    existing stream's counter range.  ``existing_ranges`` iterates
    ``(label, start, n)`` of already-placed streams."""
    end = start + n
    for other_label, other_start, other_n in existing_ranges:
        if start < other_start + other_n and other_start < end:
            raise AssertionError(
                f"[STR001] counter range [{start}, {end}) allocated to "
                f"{label} overlaps [{other_start}, {other_start + other_n}) "
                f"owned by {other_label}")


def assert_wave_consistent(rounds_by_label: dict) -> None:
    """STR002 as a live check on one dispatched wave: each stream's
    rounds must be strictly consecutive ascending — a duplicate round
    is a double-deposit in the making, a gap would wedge the fold
    frontier.  (Cross-wave ordering is enforced by the cache's
    admission rules; this guards the batcher's own emission contract.)
    """
    for label, rounds in rounds_by_label.items():
        if list(rounds) != list(range(rounds[0], rounds[0] + len(rounds))):
            raise AssertionError(
                f"[STR002] wave deposits rounds {list(rounds)} for "
                f"{label}: per-stream rounds must be consecutive "
                "ascending (duplicates double-deposit, gaps wedge the "
                "fold frontier)")


def assert_inflight_consistent(label: str, count: int) -> None:
    """In-flight accounting must never go negative — a negative count
    means a wave was retired twice (the double-deposit precursor)."""
    if count < 0:
        raise AssertionError(
            f"[STR002] in-flight round count for {label} went negative "
            f"({count}): a wave was retired twice")


# -- offline auditor ----------------------------------------------------------

@dataclasses.dataclass
class AuditReport:
    """What :func:`audit_state_dir` proved (or disproved)."""

    state_dir: str
    violations: list[Violation]
    streams: int = 0
    journal_records: int = 0
    deposits_folded: int = 0
    deposits_replayed: int = 0
    truncated_tail_bytes: int = 0   # expected after SIGKILL: informational

    @property
    def ok(self) -> bool:
        return not self.violations

    def summary(self) -> str:
        status = "clean" if self.ok else f"{len(self.violations)} violation(s)"
        return (f"audit {self.state_dir}: {status} — {self.streams} "
                f"stream(s), {self.journal_records} journal record(s), "
                f"{self.deposits_folded} folded / "
                f"{self.deposits_replayed} replayed deposit(s), "
                f"{self.truncated_tail_bytes} torn tail byte(s)")


@dataclasses.dataclass
class _Stream:
    fn_offset: int
    n_fn: int
    round_samples: int
    frontier: int


def audit_state_dir(state_dir: str) -> AuditReport:
    """Prove the STR invariants over one DurableStore state dir.

    Reads meta.json, snapshot.npz and journal.bin read-only (never
    truncates — auditing must not mutate evidence) and replays the
    journal against the same admission rules the store applies, flagging
    every record that breaks a determinism invariant.  A torn journal
    tail is *reported* but is not a violation: that is exactly the
    artifact a SIGKILL is allowed to leave.
    """
    import json

    # lazy: pulls numpy (npz decoding) but stays off any jax path
    from repro.service.store import (DurableStore, _decode_f32,
                                     read_journal, read_snapshot)

    report = AuditReport(state_dir=str(state_dir), violations=[])
    found = report.violations
    meta_path = os.path.join(state_dir, DurableStore.META)
    snap_path = os.path.join(state_dir, DurableStore.SNAPSHOT)
    journal_path = os.path.join(state_dir, DurableStore.JOURNAL)

    quantum = None          # round_samples consensus across sources
    quantum_src = None
    if os.path.exists(meta_path):
        with open(meta_path, encoding="utf-8") as f:
            meta = json.load(f)
        if "round_samples" in meta:
            quantum = int(meta["round_samples"])
            quantum_src = "meta.json"

    streams: dict[str, _Stream] = {}
    # child chash -> (parent chash, epoch, source path, line) of every
    # grid record seen; chain contiguity is proven at the end so the
    # verdict is independent of snapshot/journal interleaving
    grids: dict[str, tuple[str, int, str, int]] = {}
    journal_allocs: set[str] = set()
    hwm = 0
    if os.path.exists(snap_path):
        snap_meta, _ = read_snapshot(snap_path)
        hwm = int(snap_meta["next_id"])
        snap_quantum = int(snap_meta["round_samples"])
        if quantum is not None and snap_quantum != quantum:
            found.append(Violation(
                rule="STR005", path=snap_path, line=0,
                message=f"snapshot round_samples={snap_quantum} disagrees "
                        f"with {quantum_src} round_samples={quantum}"))
        quantum = quantum if quantum is not None else snap_quantum
        quantum_src = quantum_src or "snapshot"
        for i, ent in enumerate(snap_meta["entries"]):
            chash = ent["chash"]
            st = _Stream(fn_offset=int(ent["fn_offset"]),
                         n_fn=int(ent["n_fn"]),
                         round_samples=int(ent["round_samples"]),
                         frontier=int(ent["rounds_done"]))
            streams[chash] = st
            if st.round_samples != quantum:
                found.append(Violation(
                    rule="STR005", path=snap_path, line=i + 1,
                    message=f"stream {chash[:16]} quantized into rounds of "
                            f"{st.round_samples}; state dir uses {quantum}"))
            if st.fn_offset + st.n_fn > hwm:
                found.append(Violation(
                    rule="STR004", path=snap_path, line=i + 1,
                    message=f"stream {chash[:16]} range "
                            f"[{st.fn_offset}, {st.fn_offset + st.n_fn}) "
                            f"exceeds the allocator high-water mark {hwm}: "
                            "a future allocation could collide"))
        for a, b in find_overlaps(
                (c, s.fn_offset, s.n_fn) for c, s in streams.items()):
            found.append(Violation(
                rule="STR001", path=snap_path, line=0,
                message=f"streams {a[:16]} and {b[:16]} own overlapping "
                        "counter ranges"))
        for i, g in enumerate(snap_meta.get("grids", []), start=1):
            grids[g["chash"]] = (g["parent"], int(g["epoch"]),
                                 snap_path, i)

    records, bad_tail = read_journal(journal_path)
    report.truncated_tail_bytes = bad_tail
    report.journal_records = len(records)
    for lineno, record in enumerate(records, start=1):
        kind = record.get("t")
        if kind == "alloc":
            chash = record["chash"]
            journal_allocs.add(chash)
            fn_offset = int(record["fn_offset"])
            n_fn = int(record["n_fn"])
            rs = int(record["round_samples"])
            if quantum is None:
                quantum, quantum_src = rs, "journal"
            elif rs != quantum:
                found.append(Violation(
                    rule="STR005", path=journal_path, line=lineno,
                    message=f"alloc of {chash[:16]} carries "
                            f"round_samples={rs}; {quantum_src} says "
                            f"{quantum}"))
            known = streams.get(chash)
            if known is not None:
                if (known.fn_offset, known.n_fn) != (fn_offset, n_fn):
                    found.append(Violation(
                        rule="STR001", path=journal_path, line=lineno,
                        message=f"stream {chash[:16]} re-allocated at "
                                f"[{fn_offset}, {fn_offset + n_fn}); it "
                                f"already owns [{known.fn_offset}, "
                                f"{known.fn_offset + known.n_fn})"))
                continue
            overlap = [c for c, s in streams.items()
                       if fn_offset < s.fn_offset + s.n_fn
                       and s.fn_offset < fn_offset + n_fn]
            if overlap:
                found.append(Violation(
                    rule="STR001", path=journal_path, line=lineno,
                    message=f"alloc of {chash[:16]} at [{fn_offset}, "
                            f"{fn_offset + n_fn}) overlaps stream(s) "
                            f"{', '.join(c[:16] for c in overlap)}"))
            elif fn_offset < hwm:
                found.append(Violation(
                    rule="STR004", path=journal_path, line=lineno,
                    message=f"alloc of {chash[:16]} at {fn_offset} is "
                            f"below the allocator high-water mark {hwm}: "
                            "the bump allocator never goes backwards"))
            streams[chash] = _Stream(fn_offset=fn_offset, n_fn=n_fn,
                                     round_samples=rs, frontier=0)
            hwm = max(hwm, fn_offset + n_fn)
        elif kind == "dep":
            chash = record["chash"]
            st = streams.get(chash)
            if st is None:
                found.append(Violation(
                    rule="STR006", path=journal_path, line=lineno,
                    message=f"deposit for {chash[:16]} has no allocation "
                            "anywhere in snapshot or journal: it is "
                            "dropped on replay and silently recomputed"))
                continue
            round_index = int(record["round"])
            s1 = _decode_f32(record["s1"])
            s2 = _decode_f32(record["s2"])
            if s1.shape != (st.n_fn,) or s2.shape != (st.n_fn,):
                found.append(Violation(
                    rule="STR003", path=journal_path, line=lineno,
                    message=f"deposit for {chash[:16]} carries "
                            f"{s1.shape[0]}/{s2.shape[0]} function sums; "
                            f"the stream has n_fn={st.n_fn}"))
                continue
            if quantum is not None and int(record["n"]) != quantum:
                found.append(Violation(
                    rule="STR003", path=journal_path, line=lineno,
                    message=f"deposit for {chash[:16]} folds "
                            f"{record['n']} samples; the round quantum "
                            f"is {quantum}"))
            verdict = classify_round(st.frontier, round_index)
            if verdict == "gap":
                found.append(Violation(
                    rule="STR002", path=journal_path, line=lineno,
                    message=f"deposit round {round_index} for "
                            f"{chash[:16]} is beyond the fold frontier "
                            f"{st.frontier}: rounds "
                            f"[{st.frontier}, {round_index}) are missing"))
            elif verdict == "replay":
                report.deposits_replayed += 1
            else:
                st.frontier += 1
                report.deposits_folded += 1
        elif kind == "grid":
            chash = record["chash"]
            parent = record["parent"]
            epoch = int(record["epoch"])
            if chash in journal_allocs:
                found.append(Violation(
                    rule="STR007", path=journal_path, line=lineno,
                    message=f"grid record for {chash[:16]} arrives after "
                            "its stream's alloc: the WAL must journal an "
                            "adapted stream's grid before the stream "
                            "itself, or a crash in between strands the "
                            "child without its edges"))
            known = grids.get(chash)
            if known is not None:
                if (known[0], known[1]) != (parent, epoch):
                    found.append(Violation(
                        rule="STR007", path=journal_path, line=lineno,
                        message=f"duplicate grid record for {chash[:16]} "
                                f"disagrees: parent {parent[:16]} epoch "
                                f"{epoch} vs recorded parent "
                                f"{known[0][:16]} epoch {known[1]}"))
            else:
                grids[chash] = (parent, epoch, journal_path, lineno)
        else:
            found.append(Violation(
                rule="STR003", path=journal_path, line=lineno,
                message=f"unknown journal record type {kind!r}"))

    # STR007 chain contiguity, order-independently over every grid seen:
    # epoch k's parent must hold a grid record at epoch k-1, and epoch 1
    # must chain to a base stream (no grid record of its own)
    for chash in sorted(grids):
        parent, epoch, src, line = grids[chash]
        parent_grid = grids.get(parent)
        expect = parent_grid[1] + 1 if parent_grid is not None else 1
        if epoch != expect:
            holds = (f"holds a grid record at epoch {parent_grid[1]}"
                     if parent_grid is not None
                     else "has no grid record (a base stream)")
            found.append(Violation(
                rule="STR007", path=src, line=line,
                message=f"grid for {chash[:16]} opens epoch {epoch}, but "
                        f"its parent {parent[:16]} {holds} — the epoch "
                        f"chain must be contiguous (expected epoch "
                        f"{expect})"))

    report.streams = len(streams)
    return report
