"""Layer 2: jaxpr contract checker for registered kernel forms.

The fused launch path makes three promises it cannot check cheaply at
launch time:

* eval bodies are **pure** — a body that hides a host callback or debug
  print would make per-round sums depend on execution order, breaking
  the WAL's bit-exact replay (KCT001);
* bodies accumulate in **float32** — the ``(s1, s2)`` deposit dtype the
  journal stores exactly, and the only dtype the TPU reduction path is
  fast at (KCT002);
* all bodies fused into one ``(dim, sampler)`` bucket produce
  **identical output avals** — ``lax.switch`` in the fused kernel
  (``template._fused_kernel``) selects between them per function block
  and silently requires matching branch signatures (KCT003);
* a form advertising ``supports_compactified=True`` really does compose
  with ``template.compactified_body`` — otherwise infinite-domain
  families fall back (or worse, miscompute the Jacobian) at launch time
  (KCT004);
* a form declaring ``sweep_cols`` really does compose with
  ``template.swept_body`` — the declared column map must substitute
  cleanly into the packed row (and through the compactified wrapper),
  or parameter sweeps would fail at first launch (KCT005);
* a form advertising ``supports_adapted=True`` really does compose with
  ``template.adapted_body`` — the VEGAS importance-map stage must read
  its packed edge columns and fold the Jacobian cleanly (including
  through the compactified wrapper), or adapted streams would fail (or
  bias the estimate) at their first post-pilot launch (KCT006).

This module proves all six **abstractly**: each registered
:class:`~repro.kernels.registry.KernelForm` body is traced with
``jax.make_jaxpr`` on zero-filled probe operands
(:func:`repro.kernels.template.probe_operands`) for every capability
combination it advertises (sampler × finite/compactified ×
plain/swept × plain/adapted, over a probe dim sweep; the engine never
builds swept+adapted streams, so that combination is not probed).  No
kernel is launched and no device is needed — this runs in CI on CPU in
milliseconds.

:func:`validate_form_registration` packages the same predicates for
eager use at registration time (``registry.register_form``), so a
contract-breaking form raises a named exception where it is defined
instead of failing deep inside ``lax.switch`` at first launch.
"""

from __future__ import annotations

import functools
import inspect

import jax

from repro.analysis.violations import Violation
from repro.kernels import template

# Dimensions each form is probed at: the low dims the paper's example
# suite lives in plus one mid-size dim; each is clipped to the form's
# advertised max_dim (and the Sobol table limit for sampler="sobol").
PROBE_DIMS = (1, 2, 4)

# Importance-grid bins used when probing adapted combos (KCT006).  The
# adapted wrapper unrolls a static per-axis bin loop, so a small probe
# count keeps registration-time traces fast; composition is bin-count
# independent (the column layout is the only thing that scales).
PROBE_BINS = 4

# jaxpr primitive-name fragments that mean "talks to the host".  The
# ``effects`` set catches modern versions of these; the name scan keeps
# the check meaningful across the jax floor (0.4.37) where some effects
# plumbing differs.
_SIDE_EFFECT_FRAGMENTS = ("callback", "infeed", "outfeed", "debug")


def _body_location(body) -> tuple[str, int]:
    """(file, line) of an eval body, for violation labelling."""
    try:
        code = getattr(body, "__wrapped__", body).__code__
        return code.co_filename.replace("\\", "/"), code.co_firstlineno
    except AttributeError:
        try:
            path = inspect.getsourcefile(body) or "<unknown>"
            _, line = inspect.getsourcelines(body)
            return path.replace("\\", "/"), line
        except (OSError, TypeError):
            return "<unknown>", 0


def _iter_eqns(jaxpr):
    """All equations in a jaxpr, descending into sub-jaxprs (scan/cond/
    switch/pjit bodies)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for param in eqn.params.values():
            for sub in _sub_jaxprs(param):
                yield from _iter_eqns(sub)


def _sub_jaxprs(param):
    if hasattr(param, "jaxpr"):        # ClosedJaxpr
        yield param.jaxpr
    elif hasattr(param, "eqns"):       # raw Jaxpr
        yield param
    elif isinstance(param, (list, tuple)):
        for item in param:
            yield from _sub_jaxprs(item)


@functools.lru_cache(maxsize=512)
def _trace_body(body, dim: int, n_cols: int):
    """(out_avals, closed_jaxpr) of ``body`` on zero probe operands.

    lru_cached on body identity: registration-time validation re-traces
    each registered body against every newcomer sharing a bucket, and
    ``compactified_body`` wrappers are themselves cached, so repeat
    traces are pure cache hits.
    """
    draws, packed = template.probe_operands(dim, n_cols)

    def probe(draws, packed):
        return body(lambda d: draws[d], packed, 0, dim)

    closed = jax.make_jaxpr(probe)(draws, packed)
    return tuple(closed.out_avals), closed


def _probe_dims(form, sampler: str) -> list[int]:
    dims = []
    for dim in PROBE_DIMS:
        if form.supports(dim=dim, sampler=sampler):
            dims.append(dim)
    return dims


def _full_sweep(form, dim: int) -> tuple[str, ...]:
    """The widest sweep the form advertises at ``dim`` — every name in
    its ``sweep_cols`` map, sorted (the order ``swept_over`` produces).
    Probing the full set subsumes every subset: subsets substitute fewer
    columns through the identical wrapper machinery."""
    if form.sweep_cols is None:
        return ()
    return tuple(sorted(form.sweep_cols(dim)))


def _combos(form):
    """Every advertised capability combination: (sampler, compactified,
    swept, adapted, dim) tuples the form claims to support.  ``swept``
    probes the form's full ``sweep_cols`` name set (or stays ``()``);
    ``adapted`` is probed only for non-swept combos, mirroring the
    engine (adapted streams are never swept)."""
    out = []
    for sampler in form.samplers:
        for compact in (False, True):
            if compact and not form.supports_compactified:
                continue
            for dim in _probe_dims(form, sampler):
                for swept in ({(), _full_sweep(form, dim)} if
                              form.supports_swept else {()}):
                    adapt_axis = ((False, True) if
                                  form.supports_adapted and not swept
                                  else (False,))
                    for adapted in adapt_axis:
                        if form.supports(dim=dim, sampler=sampler,
                                         compactified=compact, sweep=swept,
                                         adapted=adapted):
                            out.append((sampler, compact, swept, adapted,
                                        dim))
    return sorted(out)


def _body_for(form, compact: bool, dim: int, swept: tuple[str, ...] = (),
              adapt_bins: int = 0):
    """(body, n_cols) the launch path would use for this combo — the
    sweep wrapper grows one table column per swept parameter column,
    the importance-map wrapper ``dim * (adapt_bins + 1)`` edge columns
    after that, and the compactified wrapper 2*dim transform columns
    last, exactly mirroring ``template.body_and_packed``'s
    ``[base][sweep][adapt][transform]`` composition and layout."""
    body, n_cols = form.body, form.n_cols(dim)
    if swept:
        cols = form.sweep_cols(dim)
        col_map = tuple(int(c) for name in swept for c in cols[name])
        body = template.swept_body(body, n_cols, col_map)
        n_cols += len(col_map)
    adapt_len = dim * (adapt_bins + 1) if adapt_bins else 0
    if compact:
        body = template.compactified_body(body, n_cols + adapt_len)
    if adapt_bins:
        body = template.adapted_body(body, n_cols, adapt_bins)
    n_cols += adapt_len
    if compact:
        n_cols += 2 * dim
    return body, n_cols


def check_form(form) -> list[Violation]:
    """KCT001/KCT002/KCT004/KCT005/KCT006 for one form, over every
    advertised combo."""
    found: list[Violation] = []
    path, line = _body_location(form.body)
    seen: set[tuple] = set()
    for sampler, compact, swept, adapted, dim in _combos(form):
        combo_key = (compact, swept, adapted, dim)  # bodies are sampler-independent
        if combo_key in seen:
            continue
        seen.add(combo_key)
        adapt_bins = PROBE_BINS if adapted else 0
        body, n_cols = _body_for(form, compact, dim, swept, adapt_bins)
        label = (f"{form.name}[dim={dim}"
                 + (", compactified" if compact else "")
                 + (f", swept={','.join(swept)}" if swept else "")
                 + (", adapted" if adapted else "") + "]")
        try:
            out_avals, closed = _trace_body(body, dim, n_cols)
        except Exception as exc:  # noqa: BLE001 - any trace failure is the finding
            rule = ("KCT006" if adapted else
                    "KCT005" if swept else
                    "KCT004" if compact else "KCT001")
            found.append(Violation(
                rule=rule, path=path, line=line,
                message=f"{label} fails to trace: {exc}"))
            continue

        effects = getattr(closed, "effects", frozenset())
        if effects:
            found.append(Violation(
                rule="KCT001", path=path, line=line,
                message=f"{label} jaxpr carries effects {sorted(map(str, effects))}"))
        for eqn in _iter_eqns(closed.jaxpr):
            prim = eqn.primitive.name
            if any(frag in prim for frag in _SIDE_EFFECT_FRAGMENTS):
                found.append(Violation(
                    rule="KCT001", path=path, line=line,
                    message=f"{label} jaxpr contains side-effecting "
                            f"primitive {prim!r}"))

        for aval in out_avals:
            dtype = getattr(aval, "dtype", None)
            if dtype is not None and str(dtype) != "float32":
                found.append(Violation(
                    rule="KCT002", path=path, line=line,
                    message=f"{label} accumulates in {dtype} (the (s1, s2) "
                            "deposit contract is float32)"))
        shapes = [getattr(a, "shape", None) for a in out_avals]
        if shapes != [(template.S_ROWS, template.S_LANES)]:
            found.append(Violation(
                rule=("KCT006" if adapted else
                      "KCT005" if swept else
                      "KCT004" if compact else "KCT002"),
                path=path, line=line,
                message=f"{label} returns avals shaped {shapes}, expected "
                        f"one ({template.S_ROWS}, {template.S_LANES}) tile"))
    return found


def bucket_avals(form, sampler: str, dim: int):
    """Output avals of the (body, packed-width) the fused planner would
    put in the (dim, sampler) bucket for this form's *finite* families.
    Returns None if the form doesn't trace (check_form reports that)."""
    body, n_cols = _body_for(form, False, dim)
    try:
        out_avals, _ = _trace_body(body, dim, n_cols)
    except Exception:  # noqa: BLE001
        return None
    return tuple((getattr(a, "shape", None), str(getattr(a, "dtype", "?")))
                 for a in out_avals)


def check_bucket_uniformity(forms) -> list[Violation]:
    """KCT003: identical output avals across all forms sharing a
    (dim, sampler) bucket — the ``lax.switch`` branch precondition."""
    found: list[Violation] = []
    buckets: dict[tuple, list] = {}
    for form in forms:
        for sampler in form.samplers:
            for dim in _probe_dims(form, sampler):
                buckets.setdefault((dim, sampler), []).append(form)
    for (dim, sampler), members in sorted(buckets.items()):
        sigs = []
        for form in members:
            avals = bucket_avals(form, sampler, dim)
            if avals is not None:
                sigs.append((form, avals))
        if len({avals for _, avals in sigs}) <= 1:
            continue
        majority = max({avals for _, avals in sigs},
                       key=lambda a: sum(1 for _, x in sigs if x == a))
        for form, avals in sigs:
            if avals != majority:
                path, line = _body_location(form.body)
                found.append(Violation(
                    rule="KCT003", path=path, line=line,
                    message=f"{form.name} produces avals {avals} in the "
                            f"(dim={dim}, sampler={sampler!r}) bucket; "
                            f"other bucket members produce {majority} — "
                            "lax.switch branches must match"))
    return found


def check_forms(forms) -> list[Violation]:
    """All Layer-2 rules over an explicit form collection."""
    found: list[Violation] = []
    for form in forms:
        found.extend(check_form(form))
    found.extend(check_bucket_uniformity(forms))
    return found


def check_registered_forms() -> list[Violation]:
    """All Layer-2 rules over every registered form (CI entry point).

    Coverage is total by construction: :func:`check_form` enumerates
    every (sampler, compactified, probe-dim) combination each form
    advertises, and :func:`check_bucket_uniformity` visits every
    (dim, sampler) bucket those combinations induce.
    """
    from repro.kernels import registry
    return check_forms(registry.forms())


def validate_form_registration(form, existing) -> None:
    """Eager registration-time gate: raise ValueError if ``form`` breaks
    a kernel contract on its own or against already-registered forms.

    Called by ``registry.register_form`` before the registry mutates, so
    a bad form never becomes visible.  ``existing`` is the iterable of
    already-registered KernelForms to check bucket uniformity against.
    """
    own = check_form(form)
    if own:
        raise ValueError(
            f"kernel form {form.name!r} violates kernel contracts:\n"
            + "\n".join(str(v) for v in own))
    for sampler in form.samplers:
        for dim in _probe_dims(form, sampler):
            new_avals = bucket_avals(form, sampler, dim)
            if new_avals is None:
                continue
            for other in existing:
                if sampler not in other.samplers or not other.supports(
                        dim=dim, sampler=sampler):
                    continue
                other_avals = bucket_avals(other, sampler, dim)
                if other_avals is not None and other_avals != new_avals:
                    raise ValueError(
                        f"kernel form {form.name!r} produces output avals "
                        f"{new_avals} in the (dim={dim}, "
                        f"sampler={sampler!r}) bucket, but registered form "
                        f"{other.name!r} produces {other_avals}: lax.switch "
                        "fusion requires identical branch signatures "
                        "[KCT003]")
