# Static analysis for the repo's kernel/service contracts.
#
#   violations - rule registry + the Violation record all layers emit
#   boundary   - Layer 1: AST lint (import boundary, purity, f32-only)
#   contracts  - Layer 2: jaxpr contract checker over registered forms
#   streams    - Layer 3: determinism auditor over durable stream state
#                + live debug assertion hooks
#   __main__   - the CLI CI gates on: python -m repro.analysis
#
# This package root stays import-light (no jax): the Layer-3 auditor and
# the live hooks run in processes that never touch a device.  Layer 2
# (contracts) imports jax and is pulled lazily by the CLI.

from repro.analysis.violations import RULES, Violation, render

__all__ = ["RULES", "Violation", "render"]
