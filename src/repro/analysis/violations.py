"""The one violation currency every analysis layer trades in.

A :class:`Violation` is a rule ID plus a location plus a message.  Rule
IDs are stable, greppable, and documented in :data:`RULES`; CI output,
the pytest fixtures and the ROADMAP "Standing invariants" section all
refer to them.  Formatting is uniform (``RULE path:line message``) so a
failing CI job reads like a compiler error list.

This module is dependency-free (no jax, no numpy): every layer —
including the journal auditor that runs in processes that never import
jax — can afford it.
"""

from __future__ import annotations

import dataclasses

# Rule registry: ID -> one-line contract it enforces.  Layer 1 (AST
# lint) rules are prefixed BND/PUR/F64, Layer 2 (jaxpr contracts) KCT,
# Layer 3 (durable-state determinism) STR.
RULES: dict[str, str] = {
    "BND001": ("jax.experimental.* is importable only from "
               "repro/kernels/pallas_compat.py and repro/compat.py"),
    "BND002": ("jax.shard_map is referenced only from repro/compat.py "
               "(and the pallas shim)"),
    "PUR001": ("no wall-clock, stateful RNG or host I/O inside "
               "repro/kernels/ or repro/core/ modules"),
    "F64001": ("no float64 on kernel/core accumulator paths (TPU MC "
               "reductions are f32-only)"),
    "OBS001": ("service/obs layers read the wall clock only through "
               "repro/obs/clock.py (one shim: fake-clock tests and "
               "trace timestamps stay consistent)"),
    "RES001": ("service-layer retries, backoff sleeps and deadlines go "
               "only through repro/service/resilience.py (no ad-hoc "
               "run_with_restarts or .sleep() calls: one policy, "
               "deterministic jitter, budget-aware)"),
    "KCT001": ("kernel eval bodies must trace to a side-effect-free "
               "jaxpr (no callbacks, debug prints, infeed/outfeed)"),
    "KCT002": ("kernel eval bodies must accumulate in float32 — the "
               "(s1, s2) deposit dtype the WAL replays bit-exactly"),
    "KCT003": ("all bodies sharing a (dim, sampler) bucket must produce "
               "identical output avals (the lax.switch precondition)"),
    "KCT004": ("forms advertising supports_compactified=True must trace "
               "through template.compactified_body"),
    "KCT005": ("forms advertising sweep capability (sweep_cols) must "
               "trace through template.swept_body"),
    "KCT006": ("forms advertising supports_adapted=True must trace "
               "through template.adapted_body (the VEGAS importance-map "
               "stage)"),
    "STR001": ("cached streams own pairwise-disjoint counter-space "
               "ranges"),
    "STR002": ("per-stream deposit rounds are gap-free and monotone "
               "(the in-order left-fold bit-identity precondition)"),
    "STR003": ("deposit deltas are shape- and size-consistent with the "
               "stream's allocation and round quantum"),
    "STR004": ("the allocator high-water mark covers every allocated "
               "counter range"),
    "STR005": ("meta.json, snapshot and alloc records agree on the "
               "round quantum"),
    "STR006": ("every deposit references an allocated stream (a dep "
               "without its alloc is dropped on replay)"),
    "STR007": ("adapted-stream grid epochs form a contiguous chain — "
               "each grid record's epoch extends its parent by one, "
               "duplicate children agree, and the grid record precedes "
               "the child stream's alloc in the journal"),
}


@dataclasses.dataclass(frozen=True)
class Violation:
    """One broken invariant at one location."""

    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.rule} {self.path}:{self.line} {self.message}"


def render(violations) -> str:
    """Stable, sorted, one-per-line rendering for CLI / CI output."""
    return "\n".join(
        str(v) for v in sorted(violations,
                               key=lambda v: (v.path, v.line, v.rule)))
