"""Fused Monte-Carlo sample+eval+reduce Pallas TPU kernel (harmonic family).

This is the TPU re-think of ZMCintegral's Numba CUDA evaluation loop.  The
CUDA version assigns one GPU thread per sample chunk, draws xoroshiro128+
numbers from global-memory state, evaluates the integrand and accumulates
with atomics.  On TPU we instead:

* tile the (function x sample) space with a grid of
  ``(n_fn_blocks, n_sample_blocks)`` kernel instances,
* generate the uniforms *inside* VMEM with counter-based Threefry-2x32 on
  (8, 128) vector tiles — random bits never touch HBM,
* evaluate ``f(x) = a cos(k.x) + b sin(k.x)`` on the tiles (VPU
  transcendentals; phase accumulation is a ``dim``-step fused
  multiply-add), and
* reduce each block to per-function partial (sum f, sum f^2) pairs,
  accumulated *in place* across the sample-block grid axis (the output
  BlockSpec maps every ``j`` to the same block, so the kernel revisits its
  f32 accumulator — the canonical TPU reduction pattern).

Per grid cell the kernel reads ``O(F_BLK * dim)`` parameter floats and
writes ``O(F_BLK)`` floats while performing
``F_BLK * dim * ~130`` uint32/f32 vector ops per (8, 128) tile — i.e. the
kernel is wholly compute-bound (arithmetic intensity ~10^4 flop/byte),
which is the correct roofline regime for MC integration.

VMEM budget per instance (defaults F_BLK=16, S_BLK=2048, dim<=8):
  params  16*(2 + 3*8)*4 B           ~ 1.7 KiB
  tiles   ~6 live (16, 128) u32/f32  ~ 48 KiB
  out     16*2*4 B                   ~ 0.1 KiB
comfortably below the ~16 MiB VMEM of a v5e core; S_BLK can grow to 2^15
before VMEM pressure matters — the sweep in §Perf picks the block shape.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import rng as rng_lib

# Sample tile: 16 sublanes x 128 lanes = 2048 samples per grid step.
S_ROWS = 16
S_LANES = 128
S_BLK = S_ROWS * S_LANES
# Functions per grid step.
F_BLK = 16


def _mc_harmonic_kernel(scalars_ref, fn_ids_ref, a_ref, b_ref, k_ref,
                        lo_ref, hi_ref, out_ref, *, dim: int):
    """One (function-block, sample-block) grid cell.

    scalars_ref: SMEM uint32[4] = (k0, k1, sample_offset, n_valid)
    fn_ids_ref:  SMEM uint32[F_BLK] global function ids (RNG counters)
    a/b_ref:     VMEM f32[F_BLK, 1] harmonic coefficients
    k/lo/hi_ref: VMEM f32[F_BLK, dim]
    out_ref:     VMEM f32[F_BLK, 2] running (sum f, sum f^2) accumulator
    """
    j = pl.program_id(1)
    k0 = scalars_ref[0]
    k1 = scalars_ref[1]
    sample_offset = scalars_ref[2]
    n_valid = scalars_ref[3]

    row = jax.lax.broadcasted_iota(jnp.uint32, (S_ROWS, S_LANES), 0)
    col = jax.lax.broadcasted_iota(jnp.uint32, (S_ROWS, S_LANES), 1)
    local = row * jnp.uint32(S_LANES) + col
    local_idx = jnp.uint32(j) * jnp.uint32(S_BLK) + local   # call-local index
    c0 = sample_offset + local_idx                          # global counter
    valid = local_idx < n_valid

    parts = []
    for f in range(F_BLK):
        fid = fn_ids_ref[f]
        phase = jnp.zeros((S_ROWS, S_LANES), jnp.float32)
        for d in range(dim):
            c1 = fid * jnp.uint32(rng_lib.DIM_STRIDE) + jnp.uint32(d)
            bits = rng_lib.random_bits(k0, k1, c0, c1)
            u = rng_lib.bits_to_uniform(bits)
            x = lo_ref[f, d] + u * (hi_ref[f, d] - lo_ref[f, d])
            phase = phase + k_ref[f, d] * x
        val = a_ref[f, 0] * jnp.cos(phase) + b_ref[f, 0] * jnp.sin(phase)
        val = jnp.where(valid, val, 0.0)
        parts.append(jnp.stack([jnp.sum(val), jnp.sum(val * val)]))
    part = jnp.stack(parts)  # (F_BLK, 2)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = part

    @pl.when(j > 0)
    def _acc():
        out_ref[...] = out_ref[...] + part


@functools.partial(jax.jit, static_argnames=("dim", "n_sample_blocks", "interpret"))
def mc_harmonic_pallas(scalars, fn_ids, a, b, k, lo, hi, *,
                       dim: int, n_sample_blocks: int, interpret: bool):
    """pallas_call wrapper. All function arrays pre-padded to F_BLK multiple.

    Args:
      scalars: uint32[4] (k0, k1, sample_offset, n_valid).
      fn_ids: uint32[n_fn_pad].
      a, b: f32[n_fn_pad, 1]; k, lo, hi: f32[n_fn_pad, dim].
    Returns:
      f32[n_fn_pad, 2] of (sum f, sum f^2) per function.
    """
    n_fn_pad = fn_ids.shape[0]
    assert n_fn_pad % F_BLK == 0
    grid = (n_fn_pad // F_BLK, n_sample_blocks)

    fn_blk = lambda i, j: (i, 0)
    return pl.pallas_call(
        functools.partial(_mc_harmonic_kernel, dim=dim),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                # scalars
            pl.BlockSpec((F_BLK,), lambda i, j: (i,),
                         memory_space=pltpu.SMEM),                # fn_ids
            pl.BlockSpec((F_BLK, 1), fn_blk),                     # a
            pl.BlockSpec((F_BLK, 1), fn_blk),                     # b
            pl.BlockSpec((F_BLK, dim), fn_blk),                   # k
            pl.BlockSpec((F_BLK, dim), fn_blk),                   # lo
            pl.BlockSpec((F_BLK, dim), fn_blk),                   # hi
        ],
        out_specs=pl.BlockSpec((F_BLK, 2), fn_blk),
        out_shape=jax.ShapeDtypeStruct((n_fn_pad, 2), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            # function blocks are independent; sample axis revisits the
            # accumulator block and must stay sequential
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="mc_eval_harmonic",
    )(scalars, fn_ids, a, b, k, lo, hi)
