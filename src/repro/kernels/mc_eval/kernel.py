"""Fused Monte-Carlo sample+eval+reduce kernel — harmonic family.

This is the TPU re-think of ZMCintegral's Numba CUDA evaluation loop.  The
CUDA version assigns one GPU thread per sample chunk, draws xoroshiro128+
numbers from global-memory state, evaluates the integrand and accumulates
with atomics.  On TPU we instead tile the (function x sample) space,
generate uniforms inside VMEM with counter-based Threefry-2x32, evaluate
``f(x) = a cos(k.x) + b sin(k.x)`` on (S_ROWS, S_LANES) vector tiles (VPU
transcendentals; phase accumulation is a ``dim``-step fused multiply-add)
and reduce each block to per-function (sum f, sum f^2) partials
accumulated in place across the sample-block grid axis.

All of that scaffolding now lives in :mod:`repro.kernels.template`; this
module contributes only the harmonic **eval body** and **param packing**
(cols = [a, b, k_0..k_{dim-1}]) plus the historical
:func:`mc_harmonic_pallas` entry point the oracle tests drive directly.

Per grid cell the kernel reads ``O(F_BLK * dim)`` parameter floats and
writes ``O(F_BLK)`` floats while performing ``F_BLK * dim * ~130``
uint32/f32 vector ops per (16, 128) tile — wholly compute-bound
(arithmetic intensity ~10^4 flop/byte), the correct roofline regime for
MC integration.

VMEM budget per instance (defaults F_BLK=16, S_BLK=2048, dim<=8):
  params  16*(2 + 3*8)*4 B           ~ 1.7 KiB
  tiles   ~6 live (16, 128) u32/f32  ~ 48 KiB
  out     16*2*4 B                   ~ 0.1 KiB
comfortably below the ~16 MiB VMEM of a v5e core; S_BLK can grow to 2^15
before VMEM pressure matters — the sweep in §Perf picks the block shape.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.template import (F_BLK, S_BLK, S_LANES, S_ROWS,  # noqa: F401
                                    fused_mc_pallas)


def harmonic_body(draw, p, f, dim: int):
    """f(x) = a cos(k.x) + b sin(k.x); packed cols [a, b, k_0..k_{dim-1}]."""
    phase = jnp.zeros((S_ROWS, S_LANES), jnp.float32)
    for d in range(dim):
        phase = phase + p[f, 2 + d] * draw(d)
    return p[f, 0] * jnp.cos(phase) + p[f, 1] * jnp.sin(phase)


def pack_harmonic(family):
    """f32[n_fn, 2 + dim] packed (a, b, k) parameters."""
    prm = family.params
    if not {"a", "b", "k"} <= set(prm):
        raise ValueError("harmonic kernel needs params {'a','b','k'}")
    n_fn, dim = family.n_fn, family.dim
    return jnp.concatenate([
        jnp.asarray(prm["a"], jnp.float32).reshape(n_fn, 1),
        jnp.asarray(prm["b"], jnp.float32).reshape(n_fn, 1),
        jnp.asarray(prm["k"], jnp.float32).reshape(n_fn, dim),
    ], axis=1)


def mc_harmonic_pallas(scalars, fn_ids, a, b, k, lo, hi, *,
                       dim: int, n_sample_blocks: int, interpret: bool):
    """Historical entry point (oracle tests). Arrays pre-padded to F_BLK.

    Args:
      scalars: uint32[4] (k0, k1, sample_offset, n_valid).
      fn_ids: uint32[n_fn_pad].
      a, b: f32[n_fn_pad, 1]; k, lo, hi: f32[n_fn_pad, dim].
    Returns:
      f32[n_fn_pad, 2] of (sum f, sum f^2) per function.
    """
    packed = jnp.concatenate(
        [jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32),
         jnp.asarray(k, jnp.float32)], axis=1)
    return fused_mc_pallas(
        scalars, fn_ids, packed, jnp.asarray(lo, jnp.float32),
        jnp.asarray(hi, jnp.float32), dim=dim,
        n_sample_blocks=n_sample_blocks, bodies=(harmonic_body,),
        sampler="mc", interpret=interpret, name="mc_eval_harmonic")[0]
