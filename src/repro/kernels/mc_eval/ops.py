"""jit'd public wrapper for the fused MC harmonic kernel.

Conforms to the :mod:`repro.kernels.registry` fast-path signature so
``IntegrandFamily(kernel="mc_eval_harmonic")`` families dispatch here from
the direct-MC engine (single-device and shard_map paths alike).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.direct_mc import SumsState
from repro.kernels import registry
from repro.kernels.mc_eval.kernel import F_BLK, S_BLK, mc_harmonic_pallas
from repro.kernels.mc_eval.sobol_kernel import mc_sobol_harmonic_pallas


def _should_interpret() -> bool:
    # Real Mosaic lowering only exists on TPU; everywhere else (this CPU
    # container included) the kernel body runs in interpret mode.
    return jax.default_backend() != "tpu"


def _pad_rows(x, n_pad):
    if n_pad == 0:
        return x
    return jnp.pad(x, [(0, n_pad)] + [(0, 0)] * (x.ndim - 1))


@registry.register("mc_eval_harmonic")
def mc_eval_harmonic(family, n_samples: int, key, *, fn_offset: int = 0,
                     sample_offset=0, fn_ids=None,
                     interpret: bool | None = None) -> SumsState:
    """Fused-kernel (s1, s2) sums for a harmonic family.

    Matches ``direct_mc.family_sums`` semantics: same counters, same
    uniforms, same estimates (up to f32 association order).
    """
    p = family.params
    if not {"a", "b", "k"} <= set(p):
        raise ValueError("mc_eval_harmonic needs params {'a','b','k'}")
    n_fn = family.n_fn
    dim = family.dim
    if fn_ids is None:
        fn_ids = jnp.uint32(fn_offset) + jnp.arange(n_fn, dtype=jnp.uint32)
    if interpret is None:
        interpret = _should_interpret()

    n_fn_pad = math.ceil(n_fn / F_BLK) * F_BLK
    pad = n_fn_pad - n_fn
    a = _pad_rows(jnp.asarray(p["a"], jnp.float32).reshape(n_fn, 1), pad)
    b = _pad_rows(jnp.asarray(p["b"], jnp.float32).reshape(n_fn, 1), pad)
    k = _pad_rows(jnp.asarray(p["k"], jnp.float32).reshape(n_fn, dim), pad)
    lo = _pad_rows(jnp.asarray(family.domains[..., 0], jnp.float32), pad)
    hi = _pad_rows(jnp.asarray(family.domains[..., 1], jnp.float32), pad)
    fn_ids = _pad_rows(jnp.asarray(fn_ids, jnp.uint32), pad)

    n_sample_blocks = max(1, math.ceil(int(n_samples) / S_BLK))
    scalars = jnp.stack([
        jnp.asarray(key[0], jnp.uint32).reshape(()),
        jnp.asarray(key[1], jnp.uint32).reshape(()),
        jnp.asarray(sample_offset, jnp.uint32).reshape(()),
        jnp.asarray(n_samples, jnp.uint32).reshape(()),
    ])

    out = mc_harmonic_pallas(scalars, fn_ids, a, b, k, lo, hi, dim=dim,
                             n_sample_blocks=n_sample_blocks,
                             interpret=bool(interpret))
    return SumsState(s1=out[:n_fn, 0], s2=out[:n_fn, 1],
                     n=jnp.float32(n_samples))


@registry.register("mc_eval_harmonic@sobol")
def mc_eval_sobol_harmonic(family, n_samples: int, key, *, fn_offset: int = 0,
                           sample_offset=0, fn_ids=None,
                           interpret: bool | None = None) -> SumsState:
    """RQMC fast path: fused Sobol sampling + harmonic eval + reduction."""
    from repro.core.sobol import direction_vectors
    p = family.params
    n_fn, dim = family.n_fn, family.dim
    if fn_ids is None:
        fn_ids = jnp.uint32(fn_offset) + jnp.arange(n_fn, dtype=jnp.uint32)
    if interpret is None:
        interpret = _should_interpret()
    n_fn_pad = math.ceil(n_fn / F_BLK) * F_BLK
    pad = n_fn_pad - n_fn
    a = _pad_rows(jnp.asarray(p["a"], jnp.float32).reshape(n_fn, 1), pad)
    b = _pad_rows(jnp.asarray(p["b"], jnp.float32).reshape(n_fn, 1), pad)
    k = _pad_rows(jnp.asarray(p["k"], jnp.float32).reshape(n_fn, dim), pad)
    lo = _pad_rows(jnp.asarray(family.domains[..., 0], jnp.float32), pad)
    hi = _pad_rows(jnp.asarray(family.domains[..., 1], jnp.float32), pad)
    fn_ids = _pad_rows(jnp.asarray(fn_ids, jnp.uint32), pad)
    dirvecs = jnp.asarray(direction_vectors(dim))
    n_sample_blocks = max(1, math.ceil(int(n_samples) / S_BLK))
    scalars = jnp.stack([
        jnp.asarray(key[0], jnp.uint32).reshape(()),
        jnp.asarray(key[1], jnp.uint32).reshape(()),
        jnp.asarray(sample_offset, jnp.uint32).reshape(()),
        jnp.asarray(n_samples, jnp.uint32).reshape(()),
    ])
    out = mc_sobol_harmonic_pallas(scalars, fn_ids, dirvecs, a, b, k, lo, hi,
                                   dim=dim, n_sample_blocks=n_sample_blocks,
                                   interpret=bool(interpret))
    return SumsState(s1=out[:n_fn, 0], s2=out[:n_fn, 1],
                     n=jnp.float32(n_samples))
