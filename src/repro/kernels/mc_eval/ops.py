"""Registered kernel forms for the direct-MC engine.

Each form is an eval body + param packer + capability metadata
(:class:`repro.kernels.registry.KernelForm`); registration generates the
single-family fast-path impls (``"mc_eval_<form>"`` and
``"mc_eval_<form>@sobol"``) from the shared template, and the fused
multi-family planner (:mod:`repro.kernels.mc_eval.multi`) picks the forms
up when a whole ``MultiFunctionSpec`` runs with ``use_kernel=True``.

``IntegrandFamily(kernel="mc_eval_harmonic")``-style families dispatch
here from the direct-MC engine (single-device and shard_map paths alike)
via ``registry.lookup``.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.mc_eval.kernel import harmonic_body, pack_harmonic
from repro.kernels.registry import KernelForm
from repro.kernels.template import S_LANES, S_ROWS


def abs_sum_body(draw, p, f, dim: int):
    """g(x) = c * |sum_d s_d x_d|; packed cols [c, s_0..s_{dim-1}]."""
    acc = jnp.zeros((S_ROWS, S_LANES), jnp.float32)
    for d in range(dim):
        acc = acc + p[f, 1 + d] * draw(d)
    return p[f, 0] * jnp.abs(acc)


def pack_abs_sum(family):
    prm = family.params
    if not {"c", "s"} <= set(prm):
        raise ValueError("abs_sum kernel needs params {'c','s'}")
    n_fn, dim = family.n_fn, family.dim
    return jnp.concatenate([
        jnp.asarray(prm["c"], jnp.float32).reshape(n_fn, 1),
        jnp.asarray(prm["s"], jnp.float32).reshape(n_fn, dim),
    ], axis=1)


def genz_osc_body(draw, p, f, dim: int):
    """Genz oscillatory cos(2 pi u_1 + sum a_d x_d); cols [u_1, a_0..]."""
    phase = jnp.full((S_ROWS, S_LANES), 2.0 * jnp.pi, jnp.float32) * p[f, 0]
    for d in range(dim):
        phase = phase + p[f, 1 + d] * draw(d)
    return jnp.cos(phase)


def pack_genz_osc(family):
    prm = family.params
    if not {"a", "u"} <= set(prm):
        raise ValueError("genz oscillatory kernel needs params {'a','u'}")
    n_fn, dim = family.n_fn, family.dim
    return jnp.concatenate([
        jnp.asarray(prm["u"], jnp.float32).reshape(n_fn, dim)[:, :1],
        jnp.asarray(prm["a"], jnp.float32).reshape(n_fn, dim),
    ], axis=1)


def genz_corner_body(draw, p, f, dim: int):
    """Genz corner peak (1 + sum a_d x_d)^-(dim+1); cols [a_0..a_{dim-1}].

    The base is >= 1 on [0,1]^d with a >= 0, so the power is computed as
    exp(-(dim+1) log(base)) — branch-free and safe for padded zero rows.
    """
    acc = jnp.ones((S_ROWS, S_LANES), jnp.float32)
    for d in range(dim):
        acc = acc + p[f, d] * draw(d)
    return jnp.exp(-(dim + 1.0) * jnp.log(acc))


def pack_genz_corner(family):
    prm = family.params
    if "a" not in prm:
        raise ValueError("genz corner-peak kernel needs params {'a'}")
    return jnp.asarray(prm["a"], jnp.float32).reshape(family.n_fn, family.dim)


def gaussian_body(draw, p, f, dim: int):
    """f(x) = exp(-0.5 ||x||^2 / sigma^2); packed cols [sigma]."""
    r2 = jnp.zeros((S_ROWS, S_LANES), jnp.float32)
    for d in range(dim):
        x = draw(d)
        r2 = r2 + x * x
    return jnp.exp(-0.5 * r2 / (p[f, 0] * p[f, 0]))


def pack_gaussian(family):
    prm = family.params
    if "sigma" not in prm:
        raise ValueError("gaussian kernel needs params {'sigma'}")
    return jnp.asarray(prm["sigma"], jnp.float32).reshape(family.n_fn, 1)


# sweep_cols maps each sweepable template parameter to the base packed
# columns it occupies (see ``template.sweep_col_map``); genz_osc's "u" is
# deliberately absent — its packer keeps only u[:, :1] of a dim-wide
# leaf, so a per-point table could not round-trip through the columns.
HARMONIC = registry.register_form(KernelForm(
    name="mc_eval_harmonic",
    body=harmonic_body,
    pack_params=pack_harmonic,
    n_cols=lambda dim: 2 + dim,
    sweep_cols=lambda dim: {"a": (0,), "b": (1,),
                            "k": tuple(range(2, 2 + dim))},
))

ABS_SUM = registry.register_form(KernelForm(
    name="mc_eval_abs_sum",
    body=abs_sum_body,
    pack_params=pack_abs_sum,
    n_cols=lambda dim: 1 + dim,
    sweep_cols=lambda dim: {"c": (0,), "s": tuple(range(1, 1 + dim))},
))

GAUSSIAN = registry.register_form(KernelForm(
    name="mc_eval_gaussian",
    body=gaussian_body,
    pack_params=pack_gaussian,
    n_cols=lambda dim: 1,
    sweep_cols=lambda dim: {"sigma": (0,)},
))

GENZ_OSC = registry.register_form(KernelForm(
    name="mc_eval_genz_osc",
    body=genz_osc_body,
    pack_params=pack_genz_osc,
    n_cols=lambda dim: 1 + dim,
    sweep_cols=lambda dim: {"a": tuple(range(1, 1 + dim))},
))

GENZ_CORNER = registry.register_form(KernelForm(
    name="mc_eval_genz_corner",
    body=genz_corner_body,
    pack_params=pack_genz_corner,
    n_cols=lambda dim: dim,
    sweep_cols=lambda dim: {"a": tuple(range(dim))},
))

# Directly-importable fast paths (historical public names).
mc_eval_harmonic = registry.impl("mc_eval_harmonic")
mc_eval_sobol_harmonic = registry.impl("mc_eval_harmonic@sobol")
mc_eval_abs_sum = registry.impl("mc_eval_abs_sum")
mc_eval_gaussian = registry.impl("mc_eval_gaussian")
