"""Pure-jnp oracle for the fused MC harmonic kernel.

Mirrors the kernel's exact blocking and accumulation order so the test
sweeps can assert tight f32 agreement (same Threefry counters, same
(8,128)-tile partial sums, same sequential block accumulation).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import rng as rng_lib
from repro.kernels.mc_eval.kernel import S_BLK


def mc_harmonic_ref(scalars, fn_ids, a, b, k, lo, hi, *,
                    dim: int, n_sample_blocks: int):
    """Reference (sum f, sum f^2) per function; same layout as the kernel.

    Args match :func:`repro.kernels.mc_eval.kernel.mc_harmonic_pallas`.
    """
    k0, k1, sample_offset, n_valid = (scalars[i] for i in range(4))
    n_fn = fn_ids.shape[0]

    def block(carry, j):
        s = carry
        local_idx = jnp.uint32(j) * jnp.uint32(S_BLK) + jnp.arange(S_BLK, dtype=jnp.uint32)
        c0 = sample_offset + local_idx
        valid = local_idx < n_valid
        d = jnp.arange(dim, dtype=jnp.uint32)
        c1 = (fn_ids[:, None, None] * jnp.uint32(rng_lib.DIM_STRIDE)
              + d[None, None, :])
        shape = (n_fn, S_BLK, dim)
        bits = rng_lib.random_bits(
            k0, k1,
            jnp.broadcast_to(c0[None, :, None], shape),
            jnp.broadcast_to(c1, shape))
        u = rng_lib.bits_to_uniform(bits)
        x = lo[:, None, :] + u * (hi - lo)[:, None, :]
        phase = jnp.sum(x * k[:, None, :], axis=-1)
        val = a * jnp.cos(phase) + b * jnp.sin(phase)
        val = jnp.where(valid[None, :], val, 0.0)
        part = jnp.stack([jnp.sum(val, -1), jnp.sum(val * val, -1)], axis=-1)
        return s + part, None

    init = jnp.zeros((n_fn, 2), jnp.float32)
    out, _ = jax.lax.scan(block, init, jnp.arange(n_sample_blocks))
    return out
