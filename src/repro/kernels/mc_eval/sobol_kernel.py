"""Fused RQMC (Sobol) sample+eval+reduce kernel — the QMC upgrade at
kernel speed.

Identical tiling/reduction to the Threefry kernel, but the uniforms come
from the digitally-shifted Sobol sequence.  Cheaper per sample than
Threefry: the Sobol point for (sample, dim) is shared by every function in
the block, so the 32-step Gray-code XOR runs once per (tile, dim) and each
function only pays one XOR (its digital shift) + the affine map — vs 20
Threefry rounds per (function, sample, dim).

Direction vectors arrive as a (dim, 32) uint32 VMEM operand; per-function
shifts are recomputed in-kernel with the same Threefry call as the oracle
(`core/sobol.shifts_for`), keeping bit-parity between kernel and engine.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import rng as rng_lib
from repro.kernels.mc_eval.kernel import F_BLK, S_BLK, S_LANES, S_ROWS


def _sobol_tiles(idx, v_ref, dim: int):
    """Sobol points for one index tile: list of dim uint32 tiles."""
    gray = idx ^ (idx >> jnp.uint32(1))
    outs = [jnp.zeros(idx.shape, jnp.uint32) for _ in range(dim)]
    for j in range(32):
        bit = ((gray >> jnp.uint32(j)) & jnp.uint32(1)).astype(bool)
        for d in range(dim):
            outs[d] = outs[d] ^ jnp.where(bit, v_ref[d, j], jnp.uint32(0))
    return outs


def _mc_sobol_kernel(scalars_ref, fn_ids_ref, v_ref, a_ref, b_ref, k_ref,
                     lo_ref, hi_ref, out_ref, *, dim: int):
    j = pl.program_id(1)
    k0 = scalars_ref[0]
    k1 = scalars_ref[1]
    sample_offset = scalars_ref[2]
    n_valid = scalars_ref[3]

    row = jax.lax.broadcasted_iota(jnp.uint32, (S_ROWS, S_LANES), 0)
    col = jax.lax.broadcasted_iota(jnp.uint32, (S_ROWS, S_LANES), 1)
    local = row * jnp.uint32(S_LANES) + col
    local_idx = jnp.uint32(j) * jnp.uint32(S_BLK) + local
    sample_ids = sample_offset + local_idx
    valid = local_idx < n_valid

    pts = _sobol_tiles(sample_ids, v_ref, dim)      # dim x (S_ROWS,S_LANES)

    parts = []
    for f in range(F_BLK):
        fid = fn_ids_ref[f]
        phase = jnp.zeros((S_ROWS, S_LANES), jnp.float32)
        for d in range(dim):
            # per-(fn, dim) digital shift: same counter plane as the oracle
            c1 = fid * jnp.uint32(rng_lib.DIM_STRIDE) + jnp.uint32(d)
            shift = rng_lib.random_bits(k0, k1, jnp.uint32(0x50B01), c1)
            u = rng_lib.bits_to_uniform(pts[d] ^ shift)
            x = lo_ref[f, d] + u * (hi_ref[f, d] - lo_ref[f, d])
            phase = phase + k_ref[f, d] * x
        val = a_ref[f, 0] * jnp.cos(phase) + b_ref[f, 0] * jnp.sin(phase)
        val = jnp.where(valid, val, 0.0)
        parts.append(jnp.stack([jnp.sum(val), jnp.sum(val * val)]))
    part = jnp.stack(parts)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = part

    @pl.when(j > 0)
    def _acc():
        out_ref[...] = out_ref[...] + part


@functools.partial(jax.jit, static_argnames=("dim", "n_sample_blocks",
                                             "interpret"))
def mc_sobol_harmonic_pallas(scalars, fn_ids, dirvecs, a, b, k, lo, hi, *,
                             dim: int, n_sample_blocks: int, interpret: bool):
    n_fn_pad = fn_ids.shape[0]
    assert n_fn_pad % F_BLK == 0
    grid = (n_fn_pad // F_BLK, n_sample_blocks)
    fn_blk = lambda i, j: (i, 0)
    return pl.pallas_call(
        functools.partial(_mc_sobol_kernel, dim=dim),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),               # scalars
            pl.BlockSpec((F_BLK,), lambda i, j: (i,),
                         memory_space=pltpu.SMEM),               # fn_ids
            pl.BlockSpec((dim, 32), lambda i, j: (0, 0)),        # dirvecs
            pl.BlockSpec((F_BLK, 1), fn_blk),                    # a
            pl.BlockSpec((F_BLK, 1), fn_blk),                    # b
            pl.BlockSpec((F_BLK, dim), fn_blk),                  # k
            pl.BlockSpec((F_BLK, dim), fn_blk),                  # lo
            pl.BlockSpec((F_BLK, dim), fn_blk),                  # hi
        ],
        out_specs=pl.BlockSpec((F_BLK, 2), fn_blk),
        out_shape=jax.ShapeDtypeStruct((n_fn_pad, 2), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="mc_eval_sobol_harmonic",
    )(scalars, fn_ids, dirvecs, a, b, k, lo, hi)
