"""Fused RQMC (Sobol) sample+eval+reduce kernel — the QMC upgrade at
kernel speed.

Identical tiling/reduction to the Threefry kernel (both are instances of
:mod:`repro.kernels.template` with ``sampler="sobol"`` vs ``"mc"``), but
the uniforms come from the digitally-shifted Sobol sequence.  Cheaper per
sample than Threefry: the Sobol point for (sample, dim) is shared by every
function in the block, so the 32-step Gray-code XOR runs once per
(tile, dim) and each function only pays one XOR (its digital shift) + the
affine map — vs 20 Threefry rounds per (function, sample, dim).

Direction vectors arrive as a (dim, 32) uint32 VMEM operand; per-function
shifts are recomputed in-kernel with the same Threefry call as the oracle
(`core/sobol.shifts_for`), keeping bit-parity between kernel and engine.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.mc_eval.kernel import harmonic_body
from repro.kernels.template import (F_BLK, S_BLK, S_LANES, S_ROWS,  # noqa: F401
                                    fused_mc_pallas, sobol_tiles)  # noqa: F401


def mc_sobol_harmonic_pallas(scalars, fn_ids, dirvecs, a, b, k, lo, hi, *,
                             dim: int, n_sample_blocks: int, interpret: bool):
    """Historical entry point; see :func:`...kernel.mc_harmonic_pallas`."""
    packed = jnp.concatenate(
        [jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32),
         jnp.asarray(k, jnp.float32)], axis=1)
    return fused_mc_pallas(
        scalars, fn_ids, packed, jnp.asarray(lo, jnp.float32),
        jnp.asarray(hi, jnp.float32), dirvecs=jnp.asarray(dirvecs, jnp.uint32),
        dim=dim, n_sample_blocks=n_sample_blocks, bodies=(harmonic_body,),
        sampler="sobol", interpret=interpret,
        name="mc_eval_sobol_harmonic")[0]
