"""Fused multi-family dispatch: one pallas_call per (dim, sampler) bucket.

The per-family loop in ``ZMCMultiFunctions._trial_sums`` launches one
kernel per family — fine for a handful of families, but the paper's
headline workload (>10^3 integrands, mixed forms and dimensions) wants
the original ZMCintegral property of splitting the *whole* batch across
the device in a single launch.  This module plans that:

1. every family whose ``kernel`` names a registered form that supports
   (dim, sampler) is **fusable** — compactified infinite-domain families
   included, via the transform wrapper stage and extra packed columns of
   ``template.body_and_packed``; the rest fall back to the chunked JAX
   path (the caller handles them);
2. fusable families are bucketed by integrand dimension (the kernel's
   sample-drawing loop is specialised on ``dim``);
3. within a bucket each family is padded to an F_BLK multiple (so every
   function block is homogeneous in form), packed parameters are padded
   to the bucket's widest form, and everything is concatenated into one
   operand set;
4. the whole bucket runs in a single ``pallas_call`` with per-block form
   ids driving ``lax.switch`` body selection (elided when the bucket has
   one distinct body);
5. results are sliced back out per family, in global-fn-id counter space
   — bit-identical to what the per-family launches would produce, since
   the Threefry/Sobol counters depend only on (global fn id, sample id).

The plan depends only on the spec (shapes, forms, dims) — callers build
it once and re-run it per trial/round with different keys/offsets.

Compile-cache keying: a bucket's kernel ``name`` (a static argument of
the jitted ``template.fused_mc_pallas``) encodes only the bucket's
**shape signature** — (sampler, dim, padded rows, packed cols) — never
which families produced it.  Two different request mixes that bucket to
the same shapes and the same body tuple therefore hit the same compiled
executable instead of retracing; only genuinely new shapes pay a
compile.

Multi-round plans: :func:`eval_plan_rounds` (and its mesh sibling
:func:`sharded_eval_plan_rounds`) evaluate R consecutive fixed-size
counter rounds of every bucket in ONE launch each — a refinement wave of
R rounds costs B launches instead of R x B.  Per-family ``start_rounds``
ride in a per-function-block SMEM operand, so streams parked at
different refinement depths still share the launch; per-round sums are
bit-identical to the R single-round launches they replace (the service
cache's in-order fold and resume invariants depend on this).
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.kernels import registry, template
from repro.kernels.pallas_compat import resolve_interpret
from repro.kernels.template import F_BLK, S_BLK


@dataclasses.dataclass(frozen=True)
class _Slice:
    """Where one family's functions live inside a bucket's padded rows."""
    family_index: int
    row_start: int
    n_fn: int


@dataclasses.dataclass(frozen=True)
class _Bucket:
    """One fused launch: all same-dim fusable families, concatenated."""
    dim: int
    bodies: tuple            # distinct eval bodies, switch order
    packed: jnp.ndarray      # f32[n_fn_pad, n_cols_max]
    lo: jnp.ndarray          # f32[n_fn_pad, dim]
    hi: jnp.ndarray          # f32[n_fn_pad, dim]
    fn_ids: jnp.ndarray      # u32[n_fn_pad] global function ids
    form_ids: jnp.ndarray | None   # i32[n_fn_pad // F_BLK] or None
    slices: tuple[_Slice, ...]
    name: str


@dataclasses.dataclass(frozen=True)
class FusionPlan:
    buckets: tuple[_Bucket, ...]
    unfused: tuple[int, ...]   # family indices left to the chunked path
    sampler: str

    @property
    def n_launches(self) -> int:
        return len(self.buckets)


def plan_spec(spec, *, sampler: str = "mc",
              fn_offsets=None) -> FusionPlan:
    """Bucket a MultiFunctionSpec's fusable families by dimension.

    Args:
      spec: ``repro.core.integrand.MultiFunctionSpec``.
      sampler: "mc" | "sobol" — a family fuses only if its form supports
        this sampler at its dimension.
      fn_offsets: optional per-family global fn-id offsets (defaults to
        ``spec.offsets()``, the engine's counter layout).
    """
    families = spec.families
    if fn_offsets is None:
        fn_offsets = spec.offsets()

    by_dim: dict[int, list[int]] = {}
    unfused: list[int] = []
    for idx, fam in enumerate(families):
        form = registry.form(fam.kernel) if fam.kernel else None
        if form is None or not form.supports(
                dim=fam.dim, sampler=sampler, compactified=fam.compact,
                sweep=fam.swept, adapted=bool(fam.adapt_bins)):
            unfused.append(idx)
            continue
        by_dim.setdefault(fam.dim, []).append(idx)

    buckets = []
    for dim in sorted(by_dim):
        idxs = by_dim[dim]
        bodies: list = []
        packed_parts, lo_parts, hi_parts, id_parts = [], [], [], []
        block_forms: list[int] = []
        slices: list[_Slice] = []
        n_cols = max(template.packed_cols(registry.form(families[i].kernel),
                                          families[i]) for i in idxs)
        row = 0
        for idx in idxs:
            fam = families[idx]
            form = registry.form(fam.kernel)
            body, packed = template.body_and_packed(form, fam)
            if body not in bodies:
                bodies.append(body)
            body_ix = bodies.index(body)

            n_fn = fam.n_fn
            n_fn_pad = math.ceil(n_fn / F_BLK) * F_BLK
            pad = n_fn_pad - n_fn
            packed = template.pad_rows(packed, pad)
            if packed.shape[1] < n_cols:
                packed = jnp.pad(
                    packed, ((0, 0), (0, n_cols - packed.shape[1])))
            packed_parts.append(packed)
            lo_parts.append(template.pad_rows(
                jnp.asarray(fam.domains[..., 0], jnp.float32), pad))
            hi_parts.append(template.pad_rows(
                jnp.asarray(fam.domains[..., 1], jnp.float32), pad))
            id_parts.append(template.pad_rows(
                jnp.uint32(fn_offsets[idx])
                + jnp.arange(n_fn, dtype=jnp.uint32), pad))
            block_forms += [body_ix] * (n_fn_pad // F_BLK)
            slices.append(_Slice(idx, row, n_fn))
            row += n_fn_pad

        form_ids = (jnp.asarray(np.asarray(block_forms, np.int32))
                    if len(bodies) > 1 else None)
        buckets.append(_Bucket(
            dim=dim,
            bodies=tuple(bodies),
            packed=jnp.concatenate(packed_parts),
            lo=jnp.concatenate(lo_parts),
            hi=jnp.concatenate(hi_parts),
            fn_ids=jnp.concatenate(id_parts),
            form_ids=form_ids,
            slices=tuple(slices),
            # shape-signature name: identical for every entry mix that
            # buckets to these shapes, so the jit compile cache is keyed
            # by what the compiler actually sees, not by which families
            # happened to arrive (see module docstring)
            name=f"mc_eval_fused_{sampler}_d{dim}f{row}c{n_cols}",
        ))
    return FusionPlan(buckets=tuple(buckets), unfused=tuple(unfused),
                      sampler=sampler)


def eval_plan(plan: FusionPlan, n_samples: int, key, *,
              sample_offset=0, interpret: bool | None = None):
    """Run every bucket of a plan; returns {family_index: SumsState}.

    Same counter space as the per-family path: family ``i``'s sums are
    identical (up to f32 association order) to
    ``family_sums(families[i], ..., use_kernel=True)``.
    """
    from repro.core.direct_mc import SumsState

    interpret = resolve_interpret(interpret)
    n_sample_blocks = max(1, math.ceil(int(n_samples) / S_BLK))
    scalars = template.pack_scalars(key, sample_offset, n_samples)

    out: dict[int, SumsState] = {}
    for bucket in plan.buckets:
        dirvecs = None
        if plan.sampler == "sobol":
            from repro.core.sobol import direction_vectors
            dirvecs = jnp.asarray(direction_vectors(bucket.dim))
        template.record_launch()
        sums = template.fused_mc_pallas(
            scalars, bucket.fn_ids, bucket.packed, bucket.lo, bucket.hi,
            form_ids=bucket.form_ids, dirvecs=dirvecs, dim=bucket.dim,
            n_sample_blocks=n_sample_blocks, bodies=bucket.bodies,
            sampler=plan.sampler, interpret=interpret, name=bucket.name)[0]
        for sl in bucket.slices:
            rows = sums[sl.row_start:sl.row_start + sl.n_fn]
            out[sl.family_index] = SumsState(
                s1=rows[:, 0], s2=rows[:, 1], n=jnp.float32(n_samples))
    return out


def _round_base_for(bucket: _Bucket, start_rounds, round_samples: int):
    """u32 per-function-block window starts for a multi-round launch.

    ``start_rounds`` maps family_index -> absolute index of the first
    round this launch evaluates for that family.  Blocks are per-family
    by construction (families are padded to F_BLK multiples), so the
    per-block value is exact; shard-padding blocks keep offset 0 (their
    rows are sliced off anyway).
    """
    n_blocks = bucket.fn_ids.shape[0] // F_BLK
    base = np.zeros(n_blocks, np.uint32)
    for sl in bucket.slices:
        b0 = sl.row_start // F_BLK
        nb = math.ceil(sl.n_fn / F_BLK)
        # counters are u32: streams wrap at 2^32 samples, exactly like
        # the scalar sample_offset path
        start = (int(start_rounds[sl.family_index]) * int(round_samples))
        base[b0:b0 + nb] = np.uint32(start & 0xFFFFFFFF)
    return jnp.asarray(base)


def eval_plan_rounds(plan: FusionPlan, round_samples: int, n_rounds: int,
                     key, *, start_rounds, interpret: bool | None = None):
    """R consecutive fixed-size rounds of every bucket, ONE launch each.

    Args:
      round_samples: samples per round (every round is full-size; the
        service cache's round quantum).
      n_rounds: consecutive rounds to evaluate per family.
      start_rounds: family_index -> absolute first round index; families
        may start at different depths (fused top-ups).
    Returns:
      {family_index: (SumsState, ...)} — ``n_rounds`` states in round
      order, each bit-identical to the single-round
      :func:`eval_plan` call at ``sample_offset = round * round_samples``.
    """
    from repro.core.direct_mc import SumsState

    interpret = resolve_interpret(interpret)
    n_sample_blocks = max(1, math.ceil(int(round_samples) / S_BLK))
    scalars = template.pack_scalars(key, 0, round_samples,
                                    round_stride=round_samples)

    out: dict[int, tuple] = {}
    for bucket in plan.buckets:
        dirvecs = None
        if plan.sampler == "sobol":
            from repro.core.sobol import direction_vectors
            dirvecs = jnp.asarray(direction_vectors(bucket.dim))
        round_base = _round_base_for(bucket, start_rounds, round_samples)
        template.record_launch()
        sums = template.fused_mc_pallas(
            scalars, bucket.fn_ids, bucket.packed, bucket.lo, bucket.hi,
            form_ids=bucket.form_ids, round_base=round_base,
            dirvecs=dirvecs, dim=bucket.dim,
            n_sample_blocks=n_sample_blocks, bodies=bucket.bodies,
            n_rounds=n_rounds, sampler=plan.sampler, interpret=interpret,
            name=f"{bucket.name}_r{n_rounds}")
        for sl in bucket.slices:
            rows = sums[:, sl.row_start:sl.row_start + sl.n_fn]
            out[sl.family_index] = tuple(
                SumsState(s1=rows[r, :, 0], s2=rows[r, :, 1],
                          n=jnp.float32(round_samples))
                for r in range(n_rounds))
    return out


def _shard_bucket(bucket: _Bucket, fn_par: int) -> _Bucket:
    """Pad a bucket so its F_BLK blocks divide evenly over ``fn_par``.

    Padded rows are zeros (sliced off by the caller, exactly like the
    per-family padding) and padded blocks carry body index 0.
    """
    blocks = bucket.fn_ids.shape[0] // F_BLK
    tgt_blocks = math.ceil(blocks / fn_par) * fn_par
    extra = (tgt_blocks - blocks) * F_BLK
    if extra == 0:
        return bucket
    form_ids = bucket.form_ids
    if form_ids is not None:
        form_ids = jnp.concatenate(
            [form_ids, jnp.zeros(tgt_blocks - blocks, jnp.int32)])
    return dataclasses.replace(
        bucket,
        packed=template.pad_rows(bucket.packed, extra),
        lo=template.pad_rows(bucket.lo, extra),
        hi=template.pad_rows(bucket.hi, extra),
        fn_ids=template.pad_rows(bucket.fn_ids, extra),
        form_ids=form_ids,
    )


def sharded_eval_plan(plan: FusionPlan, n_samples: int, key, mesh, *,
                      fn_axis: str = "model", sample_axes=("data",),
                      sample_offset=0, interpret: bool | None = None):
    """Mesh variant of :func:`eval_plan`: one fused launch per bucket,
    *inside* ``shard_map``.

    The bucketed operands are built once on the host (same planner as the
    single-device path), then function rows shard over ``fn_axis`` and
    each sample-axis shard draws a disjoint counter range; a single
    ``psum`` over the sample axes merges the (s1, s2) partials — the same
    communication shape as ``direct_mc.sharded_family_sums``, but one
    launch per (dim, sampler) bucket instead of one per family.

    Returns {family_index: SumsState} with ``n`` *exactly* ``n_samples``:
    unlike the per-family sharded path, the last shard masks its tail
    instead of rounding the total up, so counter ranges of consecutive
    windows (``sample_offset`` advancing by ``n_samples``) never overlap
    — the invariant the service cache's top-up fold relies on.
    """
    from repro.compat import shard_map
    from repro.core.direct_mc import SumsState

    interpret = resolve_interpret(interpret)
    sample_axes = tuple(sample_axes)
    fn_par = mesh.shape[fn_axis]
    sample_par = int(np.prod([mesh.shape[a] for a in sample_axes]))
    per_shard = math.ceil(int(n_samples) / sample_par)
    n_sample_blocks = max(1, math.ceil(per_shard / S_BLK))
    k0, k1 = key
    fs = P(fn_axis)

    out: dict[int, SumsState] = {}
    for bucket in plan.buckets:
        sb = _shard_bucket(bucket, fn_par)
        dirvecs = None
        if plan.sampler == "sobol":
            from repro.core.sobol import direction_vectors
            dirvecs = jnp.asarray(direction_vectors(sb.dim))

        def local(fn_ids, packed, lo, hi, form_ids, *, _bucket=sb,
                  _dirvecs=dirvecs):
            idx = jnp.uint32(0)
            mult = 1
            for a in reversed(sample_axes):
                idx = idx + jnp.uint32(jax.lax.axis_index(a)) * jnp.uint32(mult)
                mult *= mesh.shape[a]
            # exact split: the last shard masks the tail so the call draws
            # precisely n_samples counters in total
            start = jnp.minimum(idx * jnp.uint32(per_shard),
                                jnp.uint32(n_samples))
            n_local = jnp.minimum(jnp.uint32(n_samples) - start,
                                  jnp.uint32(per_shard))
            shard_offset = jnp.uint32(sample_offset) + start
            scalars = template.pack_scalars((k0, k1), shard_offset, n_local)
            sums = template.fused_mc_pallas(
                scalars, fn_ids, packed, lo, hi, form_ids=form_ids,
                dirvecs=_dirvecs, dim=_bucket.dim,
                n_sample_blocks=n_sample_blocks, bodies=_bucket.bodies,
                sampler=plan.sampler, interpret=interpret,
                name=_bucket.name + "_sharded")[0]
            return jax.lax.psum(sums, sample_axes)

        in_specs = [fs, fs, fs, fs]
        args = [sb.fn_ids, sb.packed, sb.lo, sb.hi]
        if sb.form_ids is not None:
            in_specs.append(fs)
            args.append(sb.form_ids)
        else:
            local = functools.partial(local, form_ids=None)
        template.record_launch()
        sums = shard_map(local, mesh=mesh, in_specs=tuple(in_specs),
                         out_specs=fs)(*args)
        n_actual = jnp.float32(int(n_samples))
        for sl in bucket.slices:
            rows = sums[sl.row_start:sl.row_start + sl.n_fn]
            out[sl.family_index] = SumsState(
                s1=rows[:, 0], s2=rows[:, 1], n=n_actual)
    return out


def sharded_eval_plan_rounds(plan: FusionPlan, round_samples: int,
                             n_rounds: int, key, mesh, *, start_rounds,
                             fn_axis: str = "model", sample_axes=("data",),
                             interpret: bool | None = None):
    """Mesh variant of :func:`eval_plan_rounds`: R rounds x B buckets in
    B launches, *inside* ``shard_map``.

    Each sample-axis shard evaluates its window of every round (the last
    shard masks the tail, so each round draws exactly ``round_samples``
    counters globally); one ``psum`` over the sample axes merges the
    whole (n_rounds, fn, 2) stack at once.  Per-round sums are
    bit-identical to ``n_rounds`` separate :func:`sharded_eval_plan`
    calls: same per-shard counters, same in-shard fold order, and the
    psum applies the same per-element association order regardless of
    how many rounds ride in the stack.
    """
    from repro.compat import shard_map
    from repro.core.direct_mc import SumsState

    interpret = resolve_interpret(interpret)
    sample_axes = tuple(sample_axes)
    fn_par = mesh.shape[fn_axis]
    sample_par = int(np.prod([mesh.shape[a] for a in sample_axes]))
    per_shard = math.ceil(int(round_samples) / sample_par)
    n_sample_blocks = max(1, math.ceil(per_shard / S_BLK))
    k0, k1 = key
    fs = P(fn_axis)

    out: dict[int, tuple] = {}
    for bucket in plan.buckets:
        sb = _shard_bucket(bucket, fn_par)
        round_base = _round_base_for(sb, start_rounds, round_samples)
        dirvecs = None
        if plan.sampler == "sobol":
            from repro.core.sobol import direction_vectors
            dirvecs = jnp.asarray(direction_vectors(sb.dim))

        def local(fn_ids, packed, lo, hi, round_base, form_ids, *,
                  _bucket=sb, _dirvecs=dirvecs):
            idx = jnp.uint32(0)
            mult = 1
            for a in reversed(sample_axes):
                idx = idx + jnp.uint32(jax.lax.axis_index(a)) * jnp.uint32(mult)
                mult *= mesh.shape[a]
            start = jnp.minimum(idx * jnp.uint32(per_shard),
                                jnp.uint32(round_samples))
            n_local = jnp.minimum(jnp.uint32(round_samples) - start,
                                  jnp.uint32(per_shard))
            scalars = template.pack_scalars((k0, k1), start, n_local,
                                            round_stride=round_samples)
            sums = template.fused_mc_pallas(
                scalars, fn_ids, packed, lo, hi, form_ids=form_ids,
                round_base=round_base, dirvecs=_dirvecs, dim=_bucket.dim,
                n_sample_blocks=n_sample_blocks, bodies=_bucket.bodies,
                n_rounds=n_rounds, sampler=plan.sampler,
                interpret=interpret,
                name=f"{_bucket.name}_r{n_rounds}_sharded")
            return jax.lax.psum(sums, sample_axes)

        in_specs = [fs, fs, fs, fs, fs]
        args = [sb.fn_ids, sb.packed, sb.lo, sb.hi, round_base]
        if sb.form_ids is not None:
            in_specs.append(fs)
            args.append(sb.form_ids)
        else:
            local = functools.partial(local, form_ids=None)
        template.record_launch()
        sums = shard_map(local, mesh=mesh, in_specs=tuple(in_specs),
                         out_specs=P(None, fn_axis))(*args)
        for sl in bucket.slices:
            rows = sums[:, sl.row_start:sl.row_start + sl.n_fn]
            out[sl.family_index] = tuple(
                SumsState(s1=rows[r, :, 0], s2=rows[r, :, 1],
                          n=jnp.float32(int(round_samples)))
                for r in range(n_rounds))
    return out
