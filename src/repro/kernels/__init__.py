"""Pallas kernel subsystem: version-portable fused MC evaluation.

Layout:

* ``pallas_compat`` — the single import point for ``pl``/``pltpu``.
  Papers over JAX API drift (``CompilerParams`` vs ``TPUCompilerParams``)
  and owns interpret-mode selection: compiled Mosaic on TPU, the Pallas
  interpreter everywhere else, so the whole subsystem runs (and is
  tested) on CPU-only hosts.
* ``template`` — the shared grid / in-VMEM sampling / accumulator
  scaffolding.  A registered form supplies only an eval body and a param
  packer and gets fused single-family and multi-family kernels for both
  samplers (Threefry MC, digitally-shifted Sobol RQMC).
* ``registry`` — named fast paths with capability metadata (supported
  samplers, max dimension, backends).  ``registry.lookup`` is
  capability-checked: the engine falls back to the chunked pure-JAX path
  for anything a kernel cannot serve, so ``use_kernel=True`` is always
  safe to request.
* ``mc_eval`` — the direct-MC eval kernels: registered forms (harmonic,
  |sum|, gaussian), the pure-jnp oracle, and ``mc_eval.multi`` — fused
  multi-family dispatch that evaluates an entire heterogeneous
  ``MultiFunctionSpec`` in one ``pallas_call`` per (dim, sampler) bucket
  with per-block ``lax.switch`` body selection.
* ``moments`` — the bandwidth-bound stratified-sampling reduction
  (Chan/Welford block merge), built on the same template accumulator.

``use_kernel`` semantics (engine-wide): a request, not a demand — every
family whose registered form supports its (dim, sampler) runs fused;
unregistered or unsupported forms silently take the chunked JAX path
with identical counters, so estimates never depend on which path ran.
"""
