"""jit'd wrapper for the stratum-moments kernel (pads + unpads)."""

from __future__ import annotations

import math

import jax.numpy as jnp

from repro.core.reduction import Moments
from repro.kernels.moments.kernel import C_BLK, R_BLK, moments_pallas
from repro.kernels.pallas_compat import resolve_interpret


def stratum_moments(values, *, interpret: bool | None = None) -> Moments:
    """Per-row Moments of a (n_strata, n_samples) value matrix.

    Columns are padded by *repeating the row mean estimate*? No — padding
    columns would bias the variance; instead we require the sample count to
    be a C_BLK multiple and pad only rows (with zeros, sliced off after).
    The stratified solver already draws per-stratum budgets in C_BLK
    multiples (see ``repro.core.stratified``).
    """
    values = jnp.asarray(values, jnp.float32)
    r, c = values.shape
    if c % C_BLK != 0:
        raise ValueError(
            f"n_samples per stratum must be a multiple of {C_BLK}; got {c}")
    interpret = resolve_interpret(interpret)
    r_pad = math.ceil(r / R_BLK) * R_BLK
    if r_pad != r:
        values = jnp.pad(values, ((0, r_pad - r), (0, 0)))
    out = moments_pallas(values, interpret=bool(interpret))[:r]
    return Moments(count=out[:, 0], mean=out[:, 1], m2=out[:, 2])
