"""Pure-jnp oracle for the stratum-moments kernel."""

from __future__ import annotations

import jax.numpy as jnp


def moments_ref(values):
    """(count, mean, M2) per row — direct two-pass formula.

    The kernel combines per-block Welford moments; mathematically the result
    equals this two-pass computation exactly, and in f32 they agree to
    ~1e-6 relative (asserted by the kernel sweep tests).
    """
    r, c = values.shape
    mean = jnp.mean(values, axis=1)
    m2 = jnp.sum(jnp.square(values - mean[:, None]), axis=1)
    count = jnp.full((r,), float(c), jnp.float32)
    return jnp.stack([count, mean, m2], axis=1)
