"""Per-row streaming-moments Pallas kernel (stratified-sampling reduction).

``ZMCintegral_normal`` ranks strata by their sample variance; computing
(mean, M2) for tens of thousands of strata is a bandwidth-bound reduction.
This kernel tiles a (n_strata, n_samples) value matrix and combines block
moments with the Chan/Welford parallel-update rule while the block is still
in VMEM, so each value is read from HBM exactly once and the output is
O(n_strata) — the minimum possible traffic.

Grid: (row_blocks, col_blocks); the column axis revisits the accumulator
block via the shared :func:`repro.kernels.template.accumulate` pattern
(sequential semantics), identical to the mc_eval reduction — only the
``combine`` rule differs (Welford merge instead of add).  Pallas symbols
come from :mod:`repro.kernels.pallas_compat` so the kernel runs under any
supported jax (compiled on TPU, interpret mode elsewhere).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.pallas_compat import compiler_params, pl
from repro.kernels.template import accumulate

R_BLK = 8     # strata rows per grid step
C_BLK = 512   # samples per grid step (4 x 128 lanes)


def _welford_combine(acc, part):
    """Chan/Welford parallel update of stacked (n, mean, M2) rows."""
    n_a, mean_a, m2_a = acc[:, 0], acc[:, 1], acc[:, 2]
    n_b, mean_b, m2_b = part[:, 0], part[:, 1], part[:, 2]
    n = n_a + n_b
    delta = mean_b - mean_a
    mean = mean_a + delta * (n_b / n)
    m2 = m2_a + m2_b + jnp.square(delta) * (n_a * n_b / n)
    return jnp.stack([n, mean, m2], axis=1)


def _moments_kernel(vals_ref, out_ref):
    j = pl.program_id(1)
    v = vals_ref[...]                       # (R_BLK, C_BLK) f32
    n_b = jnp.float32(C_BLK)
    mean_b = jnp.mean(v, axis=1)            # (R_BLK,)
    m2_b = jnp.sum(jnp.square(v - mean_b[:, None]), axis=1)
    part = jnp.stack([jnp.full_like(mean_b, n_b), mean_b, m2_b], axis=1)
    accumulate(j, out_ref, part, combine=_welford_combine)


@functools.partial(jax.jit, static_argnames=("interpret",))
def moments_pallas(values, *, interpret: bool):
    """(count, mean, M2) per row of ``values``.

    Args:
      values: f32[R, C] with R % R_BLK == 0 and C % C_BLK == 0 (ops.py pads).
    Returns:
      f32[R, 3].
    """
    r, c = values.shape
    assert r % R_BLK == 0 and c % C_BLK == 0, (r, c)
    grid = (r // R_BLK, c // C_BLK)
    return pl.pallas_call(
        _moments_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((R_BLK, C_BLK), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((R_BLK, 3), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, 3), jnp.float32),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="stratum_moments",
    )(values)
