"""Kernel fast-path registry with capability metadata.

Two registration levels:

* :func:`register` — a bare named callable (legacy fast path).  Registered
  impls must match the signature::

      impl(family, n_samples, key, *, fn_offset=0, sample_offset=0,
           fn_ids=None) -> SumsState

  and produce sums statistically identical to the pure-JAX path (same
  Threefry counters, same uniforms; asserted bit-tight by the kernel test
  sweeps).

* :func:`register_form` — a :class:`KernelForm`: an eval body + param
  packer + capability metadata (supported samplers, max dimension,
  backends).  Registration generates the single-family impls for every
  supported sampler from the shared template
  (``repro.kernels.template.make_family_impl``) and makes the form
  available to the fused multi-family planner
  (``repro.kernels.mc_eval.multi``).

Dispatch entry points:

* :func:`get` — name -> impl, raising on unknown names (test/debug use).
* :func:`lookup` — capability-checked: returns the impl only if the named
  form supports the requested (dim, sampler), else ``None`` so the engine
  falls back to the chunked pure-JAX path instead of crashing.  This is
  what ``direct_mc._sums_with_ids`` calls.

Sampler naming: the pseudo-random impl owns the bare form name; other
samplers get ``"<name>@<sampler>"`` (e.g. ``"mc_eval_harmonic@sobol"``).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

_REGISTRY: dict[str, Callable] = {}
_FORMS: dict[str, "KernelForm"] = {}

# dims addressable by the Threefry counter layout (rng.DIM_STRIDE)
_COUNTER_MAX_DIM = 256


@dataclasses.dataclass(frozen=True)
class KernelForm:
    """Capability record for one integrand form's fused kernel.

    Attributes:
      name: registry name (also the ``IntegrandFamily.kernel`` tag).
      body: eval body ``body(draw, p, f, dim) -> value tile`` (see
        ``repro.kernels.template``).
      pack_params: ``family -> f32[n_fn, n_cols(dim)]`` packed parameters.
      n_cols: ``dim -> int`` packed width (fused buckets pad to the max).
      max_dim: largest supported integrand dimension.
      samplers: supported samplers, subset of ("mc", "sobol").
      backends: where the kernel can run ("tpu" compiled, "interpret"
        everywhere else via the Pallas interpreter).
      supports_compactified: whether the eval body composes with the
        in-kernel compactification stage
        (``repro.kernels.template.compactified_body``) that serves
        infinite-domain families.  Bodies that consume every dimension
        through ``draw`` compose automatically (the wrapper hands them
        pre-transformed draws and folds the Jacobian into the value);
        set False for bodies that read domain geometry directly.
      sweep_cols: ``dim -> {param name: base packed column indices}`` —
        which template parameters the parameter-sweep stage
        (``repro.kernels.template.swept_body``) can override per grid
        point, and which of this form's packed columns each occupies.
        ``None`` (the default) means the form doesn't serve swept
        families.  Declared combos are contract-checked eagerly at
        registration (rule KCT005), so an inconsistent map fails at the
        definition site.
      supports_adapted: whether the eval body composes with the
        in-kernel VEGAS importance-map stage
        (``repro.kernels.template.adapted_body``) that serves adapted
        families (``IntegrandFamily.adapted``).  Like compactification,
        bodies consuming every dimension through ``draw`` compose
        automatically; set False for bodies that read domain geometry
        directly.  Declared combos are contract-checked eagerly at
        registration (rule KCT006).
    """

    name: str
    body: Callable
    pack_params: Callable
    n_cols: Callable[[int], int]
    max_dim: int = _COUNTER_MAX_DIM
    samplers: tuple[str, ...] = ("mc", "sobol")
    backends: tuple[str, ...] = ("tpu", "interpret")
    supports_compactified: bool = True
    sweep_cols: Callable[[int], dict[str, tuple[int, ...]]] | None = None
    supports_adapted: bool = True

    @property
    def supports_swept(self) -> bool:
        """Whether this form serves swept families at all."""
        return self.sweep_cols is not None

    def supports(self, *, dim: int, sampler: str = "mc",
                 compactified: bool = False,
                 sweep: tuple[str, ...] = (),
                 adapted: bool = False) -> bool:
        if sampler not in self.samplers:
            return False
        if dim > self.max_dim:
            return False
        if compactified and not self.supports_compactified:
            return False
        if adapted and not self.supports_adapted:
            return False
        if sweep:
            if self.sweep_cols is None:
                return False
            sweepable = self.sweep_cols(dim)
            if any(name not in sweepable for name in sweep):
                return False
        if sampler == "sobol":
            from repro.core.sobol import MAX_DIM
            return dim <= MAX_DIM
        return True


def register(name: str):
    """Register a bare callable under ``name`` (no capability metadata)."""
    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY:
            raise ValueError(f"kernel {name!r} already registered")
        _REGISTRY[name] = fn
        return fn
    return deco


def register_form(form: KernelForm, *, validate: bool = True) -> KernelForm:
    """Register a form and generate its per-sampler impls.

    By default the form's kernel contracts are proven eagerly BEFORE the
    registry mutates (``repro.analysis.contracts``): the eval body must
    trace to a pure f32 jaxpr under every advertised capability combo,
    and its output avals must match every already-registered form it
    would share a ``lax.switch`` bucket with — so a contract-breaking
    form raises a named ValueError here, at its definition site, instead
    of failing deep inside the fused kernel at first launch.  Tests
    exercising deliberately-broken forms pass ``validate=False``.
    """
    if form.name in _FORMS:
        raise ValueError(f"kernel form {form.name!r} already registered")
    from repro.kernels.template import make_family_impl
    if validate:
        from repro.analysis.contracts import validate_form_registration
        validate_form_registration(form, _FORMS.values())
    _FORMS[form.name] = form
    for sampler in form.samplers:
        key = form.name if sampler == "mc" else f"{form.name}@{sampler}"
        if key in _REGISTRY:
            raise ValueError(f"kernel {key!r} already registered")
        _REGISTRY[key] = make_family_impl(form, sampler)
    return form


def _load_builtin():
    # import for side effect: kernel modules self-register
    import repro.kernels.mc_eval.ops  # noqa: F401


def impl(name: str) -> Callable:
    """Plain dict lookup (no import side effect; registration-time use)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"no kernel impl registered under {name!r}; have "
            f"{sorted(_REGISTRY)} (sampler variants are named "
            f"'<form>@<sampler>')") from None


def get(name: str) -> Callable:
    _load_builtin()
    if name not in _REGISTRY:
        raise KeyError(f"no kernel named {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def form(name: str) -> KernelForm | None:
    """The KernelForm registered under (base) ``name``, or None."""
    _load_builtin()
    return _FORMS.get(name.split("@", 1)[0])


def _explain_miss(f: "KernelForm | None", name: str, *, dim: int,
                  sampler: str, compactified: bool,
                  sweep: tuple[str, ...], adapted: bool = False) -> str:
    """Human-readable reason a capability lookup missed, with the nearest
    combo the registry *does* serve."""
    asked = (f"dim={dim}, sampler={sampler!r}"
             + (", compactified" if compactified else "")
             + (f", sweep={sweep}" if sweep else "")
             + (", adapted" if adapted else ""))
    if f is None:
        hint = (f"no KernelForm named {name!r}; registered forms: "
                f"{sorted(_FORMS)}")
        if not compactified and not sweep:
            hint += (" (legacy bare callables serve finite non-swept "
                     "families only)")
        return f"kernel lookup missed for {name!r} ({asked}): {hint}"
    reasons = []
    if sampler not in f.samplers:
        reasons.append(f"sampler {sampler!r} not in {f.samplers}")
    if dim > f.max_dim:
        reasons.append(f"dim {dim} > max_dim {f.max_dim}")
    if sampler == "sobol":
        from repro.core.sobol import MAX_DIM
        if dim > MAX_DIM:
            reasons.append(f"dim {dim} > sobol direction-vector "
                           f"MAX_DIM {MAX_DIM}")
    if compactified and not f.supports_compactified:
        reasons.append("form does not compose with the compactification "
                       "stage (supports_compactified=False)")
    if adapted and not f.supports_adapted:
        reasons.append("form does not compose with the importance-map "
                       "stage (supports_adapted=False)")
    if sweep:
        if f.sweep_cols is None:
            reasons.append("form declares no sweep_cols (not sweepable)")
        else:
            bad = [n for n in sweep if n not in f.sweep_cols(dim)]
            if bad:
                reasons.append(
                    f"parameters {bad} not sweepable; form sweeps "
                    f"{sorted(f.sweep_cols(dim))} at dim={dim}")
    nearest = (f"nearest supported: dim<={f.max_dim}, "
               f"samplers={f.samplers}"
               + (", compactified ok" if f.supports_compactified else "")
               + (f", sweepable={sorted(f.sweep_cols(dim if dim <= f.max_dim else f.max_dim))}"
                  if f.sweep_cols is not None else ""))
    return (f"kernel form {f.name!r} cannot serve ({asked}): "
            + "; ".join(reasons) + f".  {nearest}")


def lookup(name: str, *, dim: int, sampler: str = "mc",
           compactified: bool = False, sweep: tuple[str, ...] = (),
           adapted: bool = False, required: bool = False) -> Callable | None:
    """Capability-checked dispatch: impl for the requested combo or None.

    Unknown names and unsupported (dim, sampler, compactified, sweep,
    adapted) combinations return None — callers fall back to the chunked
    pure-JAX path.  ``compactified`` marks families carrying the
    infinite-domain transform stage; ``sweep`` names the parameters a
    swept family's table overrides (forms opt in per parameter via
    ``sweep_cols``); ``adapted`` marks families carrying a VEGAS
    importance grid (``IntegrandFamily.adapt_bins``).  Legacy bare
    callables can pack no wrapper-stage columns, so they always miss
    those.

    ``required=True`` turns the silent None into a ``ValueError`` naming
    the form, the requested capabilities, and the nearest registered
    combo — for callers with no fallback path (the sweep engine).
    """
    _load_builtin()
    f = _FORMS.get(name)
    if f is not None:
        if not f.supports(dim=dim, sampler=sampler,
                          compactified=compactified, sweep=sweep,
                          adapted=adapted):
            if required:
                raise ValueError(_explain_miss(
                    f, name, dim=dim, sampler=sampler,
                    compactified=compactified, sweep=sweep,
                    adapted=adapted))
            return None
        key = name if sampler == "mc" else f"{name}@{sampler}"
        return _REGISTRY.get(key)
    if compactified or sweep or adapted:
        if required:
            raise ValueError(_explain_miss(
                None, name, dim=dim, sampler=sampler,
                compactified=compactified, sweep=sweep, adapted=adapted))
        return None
    # legacy bare callables: only the default sampler naming convention
    key = name if sampler == "mc" else f"{name}@{sampler}"
    found = _REGISTRY.get(key)
    if found is None and required:
        raise ValueError(_explain_miss(
            None, name, dim=dim, sampler=sampler,
            compactified=compactified, sweep=sweep, adapted=adapted))
    return found


def names() -> list[str]:
    _load_builtin()
    return sorted(_REGISTRY)


def forms() -> list[KernelForm]:
    _load_builtin()
    return [_FORMS[k] for k in sorted(_FORMS)]
