"""Kernel fast-path registry.

An :class:`~repro.core.integrand.IntegrandFamily` can name a registered
Pallas implementation (``family.kernel``); the direct-MC engine dispatches
to it when ``use_kernel=True``.  Registered impls must match the signature::

    impl(family, n_samples, key, *, fn_offset=0, sample_offset=0,
         fn_ids=None) -> SumsState

and produce sums statistically identical to the pure-JAX path (same Threefry
counters, same uniforms; asserted bit-tight by the kernel test sweeps).
"""

from __future__ import annotations

from typing import Callable

_REGISTRY: dict[str, Callable] = {}


def register(name: str):
    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY:
            raise ValueError(f"kernel {name!r} already registered")
        _REGISTRY[name] = fn
        return fn
    return deco


def get(name: str) -> Callable:
    # import for side effect: kernel modules self-register
    import repro.kernels.mc_eval.ops  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"no kernel named {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def names() -> list[str]:
    import repro.kernels.mc_eval.ops  # noqa: F401
    return sorted(_REGISTRY)
