"""Single import point for Pallas across the kernel subsystem.

Every kernel module imports ``pl``/``pltpu`` from here — never from
``jax.experimental`` directly — so JAX API drift is papered over exactly
once:

* ``CompilerParams``: the TPU compiler-params class was named
  ``TPUCompilerParams`` through the jax 0.4/0.5 line and renamed to
  ``CompilerParams`` in 0.6.  :func:`compiler_params` builds whichever
  exists (both accept ``dimension_semantics``).
* Interpret mode: real Mosaic lowering only exists on TPU.
  :func:`should_interpret` is the one place that decides when kernels run
  under the Pallas interpreter (CPU CI containers, GPU hosts without a
  Mosaic backend) vs compiled; ops-layer wrappers default their
  ``interpret`` argument from it.

If a future jax moves ``pl``/``pltpu`` themselves, only this module
changes.
"""

from __future__ import annotations

import jax
from jax.experimental import pallas as pl  # noqa: F401  (re-export)
from jax.experimental.pallas import tpu as pltpu  # noqa: F401  (re-export)

# jax >= 0.6 name, falling back to the 0.4/0.5 name.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def compiler_params(*, dimension_semantics=None, **kwargs):
    """Version-portable ``pltpu.CompilerParams`` constructor."""
    return _CompilerParams(dimension_semantics=dimension_semantics, **kwargs)


def should_interpret() -> bool:
    """True when pallas_call must run interpreted (no Mosaic backend)."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Ops-layer helper: explicit flag wins, else backend autodetect."""
    return should_interpret() if interpret is None else bool(interpret)
