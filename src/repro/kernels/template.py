"""Shared scaffolding for fused MC sample+eval+reduce Pallas kernels.

Every fused MC kernel in this repo has the same shape: a
``(n_fn_blocks, n_sample_blocks)`` grid, per-function parameters blocked
``F_BLK`` rows at a time, uniforms generated *inside* VMEM (counter-based
Threefry or digitally-shifted Sobol — random bits never touch HBM), an
integrand evaluated on (S_ROWS, S_LANES) vector tiles, and per-function
(sum f, sum f^2) partials accumulated in place across the sample-block
grid axis (the output BlockSpec maps every ``j`` to the same block — the
canonical TPU reduction pattern).

This module owns that scaffolding once.  A registered integrand form
(:class:`repro.kernels.registry.KernelForm`) supplies only

* an **eval body** ``body(draw, p, f, dim) -> (S_ROWS, S_LANES) tile``,
  where ``draw(d)`` yields the domain-mapped sample tile for dimension
  ``d`` of function ``f`` and ``p`` is the (F_BLK, n_cols) packed
  parameter block, and
* a **param packer** ``pack_params(family) -> f32[n_fn, n_cols]``,

and gets single-family *and* fused multi-family kernels for both samplers
for free (:func:`make_family_impl`, :mod:`repro.kernels.mc_eval.multi`).

Multi-form dispatch: when one launch covers families with different eval
bodies, each F_BLK function block is homogeneous in form (families are
padded to F_BLK multiples before concatenation) and carries a per-block
form id in SMEM; the kernel selects the body with ``jax.lax.switch`` once
per block.  Sampling, domain mapping and reduction are shared across
forms — this is what lets a heterogeneous ``MultiFunctionSpec`` run in
one ``pallas_call`` per (dim, sampler) bucket instead of one per family.

Infinite domains: a compactified family (``IntegrandFamily.compact``)
evaluates through the same machinery with a **wrapper stage** around its
form's body (:func:`compactified_body`): the per-axis transform kind and
shift ride as extra packed parameter columns, the wrapper maps every
draw through the tangent/rational compactification shared with the
chunked path (``repro.core.domains.apply_transform``) and folds the
Jacobian product into the value tile.  The wrapped body participates in
``lax.switch`` selection like any other, so finite and infinite-domain
families fuse into the same (dim, sampler) bucket launches.

Parameter sweeps: a swept family (``IntegrandFamily.swept``, built by
``swept_over``) runs a single-function template over a grid of parameter
points through a second **wrapper stage** (:func:`swept_body`), mirroring
the compactified one: the per-point table values ride as extra packed
columns after the form's base columns, and the wrapper substitutes them
into the template's packed row (static column indexing — no gather)
before the form's body reads it.  Every grid point is an ordinary
function row with its own global fn id and counter stream, so a whole
sweep chunk runs in ONE ``pallas_call`` per (dim, sampler) bucket while
staying bit-identical to evaluating each point as its own family.  The
stages compose — a compactified sweep packs
``[base cols][sweep cols][transform cols]`` and wraps
``compactified_body(swept_body(body))``.

Adaptive importance sampling: an adapted family
(``IntegrandFamily.adapt_bins``, built by ``IntegrandFamily.adapted``
from a VEGAS grid fit — :mod:`repro.core.adaptive`) samples the unit
cube and maps each draw through its per-axis inverse-CDF grid via a
third wrapper stage (:func:`adapted_body`): the ``dim * (n_bins + 1)``
bin-edge columns ride after the form's base (and sweep) columns, the
wrapper bin-selects with static unrolled column reads (no gather) and
folds the bin-width Jacobian product into the value tile.  The full
composition for an adapted compactified family is
``adapted_body(compactified_body(body))`` over a
``[base][sweep][adapt][transform]`` column layout — draws are uniforms,
the adapt stage maps them into the compactified box, the transform
stage maps onward to the original (possibly infinite) coordinates.
Adapted streams therefore fuse into the same (dim, sampler) bucket
launches as everything else, and their counters depend only on (global
fn id, sample id) exactly like an unadapted stream's.

Multi-round evaluation: the grid carries an optional **round axis**
(``n_rounds``) so one launch evaluates R consecutive counter-addressed
sample windows, emitting per-round ``(sum f, sum f^2)`` partials in an
``f32[n_rounds, n_fn_pad, 2]`` output.  Round ``r`` draws the counters
``base + r * round_stride + [0, n_valid)`` — exactly the counters a
separate launch with ``sample_offset = base + r * round_stride`` would
draw, and each round's accumulator folds its sample blocks in the same
order — so per-round sums are **bit-identical** to R single-round
launches.  An optional per-function-block ``round_base`` operand lets
function blocks start their windows at different offsets (the service
fuses cache streams sitting at different refinement depths into one
launch); blocks are per-family, so the Sobol point construction stays
shared per (tile, dim) exactly as in the single-round kernel.

All Pallas symbols come from :mod:`repro.kernels.pallas_compat` (the
version-drift shim); nothing here imports ``jax.experimental`` directly.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core import domains as domains_lib
from repro.core import rng as rng_lib
from repro.kernels.pallas_compat import (compiler_params, pl, pltpu,
                                         resolve_interpret)

# Sample tile: 16 sublanes x 128 lanes = 2048 samples per grid step.
S_ROWS = 16
S_LANES = 128
S_BLK = S_ROWS * S_LANES
# Functions per grid step.
F_BLK = 16

# c0 plane reserved for per-(function, dim) Sobol digital shifts; must
# match the pure-jnp oracle in repro.core.sobol.
SOBOL_SHIFT_C0 = 0x50B01

# Python-level pallas_call launch counter (incremented by the ops-layer
# wrappers each dispatch; launches made while tracing inside an outer jit
# count once at trace time).  benchmarks/kernel_bench.py uses this to show
# the fused path needs fewer launches than the per-family loop.
_LAUNCHES = 0


def record_launch() -> None:
    global _LAUNCHES
    _LAUNCHES += 1


def launch_count() -> int:
    return _LAUNCHES


def reset_launch_count() -> None:
    global _LAUNCHES
    _LAUNCHES = 0


def pad_rows(x, n_pad: int):
    """Zero-pad the leading (function) axis by ``n_pad`` rows."""
    if n_pad == 0:
        return x
    return jnp.pad(x, [(0, n_pad)] + [(0, 0)] * (x.ndim - 1))


def tile_sample_index(j):
    """Call-local sample index of each lane of the (S_ROWS, S_LANES) tile
    for sample-block ``j``."""
    row = jax.lax.broadcasted_iota(jnp.uint32, (S_ROWS, S_LANES), 0)
    col = jax.lax.broadcasted_iota(jnp.uint32, (S_ROWS, S_LANES), 1)
    local = row * jnp.uint32(S_LANES) + col
    return jnp.uint32(j) * jnp.uint32(S_BLK) + local


def accumulate(j, out_ref, part, combine=None):
    """In-place accumulator across the sequential grid axis ``j``.

    First visit stores ``part``; later visits fold it in with ``combine``
    (default: elementwise add).  The caller's output BlockSpec must map
    every ``j`` to the same block.
    """

    @pl.when(j == 0)
    def _init():
        out_ref[...] = part

    @pl.when(j > 0)
    def _acc():
        if combine is None:
            out_ref[...] = out_ref[...] + part
        else:
            out_ref[...] = combine(out_ref[...], part)


def sobol_tiles(idx, v, dim: int):
    """Unshifted Sobol points for one index tile: list of dim u32 tiles.

    Gray-code-by-index construction: point ``i`` is the XOR of the
    direction vectors selected by the bits of ``gray(i)`` — O(32) vector
    ops, shared by every function in the block.
    """
    gray = idx ^ (idx >> jnp.uint32(1))
    outs = [jnp.zeros(idx.shape, jnp.uint32) for _ in range(dim)]
    for j in range(32):
        bit = ((gray >> jnp.uint32(j)) & jnp.uint32(1)).astype(bool)
        for d in range(dim):
            outs[d] = outs[d] ^ jnp.where(bit, v[d, j], jnp.uint32(0))
    return outs


@functools.lru_cache(maxsize=None)
def compactified_body(body, base_cols: int):
    """Wrap an eval body with the infinite-domain compactification stage.

    A compactified family's packed parameters carry, after its form's
    ``base_cols`` columns, ``2 * dim`` transform columns:
    ``[kind_0..kind_{dim-1}, shift_0..shift_{dim-1}]`` (kind codes are
    ``repro.core.domains.TRANSFORM_*`` — exact small ints in f32).  The
    wrapper draws every dimension once, maps each tile through the
    tangent/rational transform shared with the chunked path
    (``domains.apply_transform``), hands the body pre-transformed draws,
    and folds the per-axis Jacobian product into the returned value tile.

    lru_cached so every plan of the same (body, base_cols) pair reuses
    ONE wrapper object: bucket body dedupe and the jit compile cache both
    key on body identity.
    """

    def wrapped(draw, p, f, dim: int):
        xs = []
        jac = None
        for d in range(dim):
            x, j = domains_lib.apply_transform(
                draw(d), p[f, base_cols + d], p[f, base_cols + dim + d])
            xs.append(x)
            jac = j if jac is None else jac * j
        val = body(lambda d: xs[d], p, f, dim)
        return val * jac

    wrapped.__name__ = f"compactified_{getattr(body, '__name__', 'body')}"
    return wrapped


def transform_cols(family):
    """f32[n_fn, 2 * dim] packed (kind, shift) columns of a compactified
    family, appended after its form's own parameter columns."""
    aux = family.params["aux"]
    return jnp.concatenate([
        jnp.asarray(aux["kind"], jnp.float32),
        jnp.asarray(aux["shift"], jnp.float32)], axis=1)


@functools.lru_cache(maxsize=None)
def swept_body(body, base_cols: int, col_map: tuple):
    """Wrap an eval body with the parameter-sweep substitution stage.

    A swept family's packed parameters carry, after its form's
    ``base_cols`` columns, one table column per swept parameter column;
    ``col_map[j]`` names the base column that table column ``j``
    overrides (:func:`sweep_col_map` derives it from
    ``KernelForm.sweep_cols``).  The wrapper redirects the body's
    parameter reads through a column-substitution view: ``p[f, c]``
    resolves to the table column when ``c`` is overridden and to the
    base column otherwise.  Substitution happens at the *read site*
    (static Python index arithmetic, no gather, no rebuilt block), so
    the traced kernel issues exactly the per-point program's scalar
    reads at shifted column constants — XLA sees a structurally
    identical computation and bit-identity to the per-point path is
    preserved through fusion/contraction choices, not just in exact
    arithmetic.  Counters depend only on (global fn id, sample id), so
    the values agree too.

    lru_cached for the same reason as :func:`compactified_body`: bucket
    body dedupe and the jit compile cache key on body identity.
    """
    subst = {col_map[j]: base_cols + j for j in range(len(col_map))}

    class _SubstView:
        """Redirects ``[f, c]`` parameter reads through the sweep map."""
        __slots__ = ("p",)

        def __init__(self, p):
            self.p = p

        def __getitem__(self, idx):
            f, c = idx
            return self.p[f, subst.get(c, c)]

    def wrapped(draw, p, f, dim: int):
        return body(draw, _SubstView(p), f, dim)

    wrapped.__name__ = f"swept_{getattr(body, '__name__', 'body')}"
    return wrapped


def sweep_col_map(form, family) -> tuple:
    """Base-column substitution map of a swept ``family`` under ``form``.

    Entry ``j`` is the base packed column that sweep table column ``j``
    overrides; table columns are laid out name-major in ``family.swept``
    order (sorted names), each name contributing its
    ``form.sweep_cols(dim)`` columns in declared order.  Takes the
    non-compact (:meth:`IntegrandFamily.inner`) swept view.  Raises if
    the form doesn't advertise the swept names or a table leaf's width
    disagrees with the form's column map.
    """
    if form.sweep_cols is None:
        raise ValueError(
            f"kernel form {form.name!r} does not support swept families")
    cols = form.sweep_cols(family.dim)
    table = family.params["table"]
    out = []
    for name in family.swept:
        if name not in cols:
            raise ValueError(
                f"kernel form {form.name!r} cannot sweep parameter "
                f"{name!r} at dim={family.dim}; sweepable: {sorted(cols)}")
        width = 1
        for s in jnp.shape(table[name])[1:]:
            width *= int(s)
        if width != len(cols[name]):
            raise ValueError(
                f"sweep axis {name!r} packs {width} column(s) per point "
                f"but form {form.name!r} maps it to {len(cols[name])} "
                f"base column(s) at dim={family.dim}")
        out.extend(int(c) for c in cols[name])
    return tuple(out)


def sweep_table_cols(family):
    """f32[n_fn, n_sweep_cols] packed per-point table columns of a swept
    family (non-compact view), appended after its form's base columns in
    :func:`sweep_col_map` order."""
    table = family.params["table"]
    return jnp.concatenate(
        [jnp.asarray(table[name], jnp.float32).reshape(family.n_fn, -1)
         for name in family.swept], axis=1)


@functools.lru_cache(maxsize=None)
def adapted_body(body, base_cols: int, n_bins: int):
    """Wrap an eval body with the VEGAS importance-map stage.

    An adapted family's packed parameters carry, after its form's (and
    sweep's) ``base_cols`` columns, ``dim * (n_bins + 1)`` bin-edge
    columns — axis-major, so axis ``d``'s edges sit at
    ``base_cols + d * (n_bins + 1)``.  The family's domain box is the
    unit cube, so ``draw(d)`` yields a raw uniform tile; the wrapper
    bin-selects with a static unrolled loop (scalar column reads +
    ``jnp.where`` — no gather, which Mosaic would reject), linearly
    interpolates inside the selected bin, hands the body the mapped
    draws, and folds the per-axis ``n_bins * bin_width`` Jacobian
    product into the returned value tile.  The arithmetic mirrors
    :func:`repro.core.adaptive.apply_map` expression for expression, so
    the fused and chunked paths agree on adapted streams exactly like
    they do on compactified ones.

    lru_cached for the same reason as :func:`compactified_body`: bucket
    body dedupe and the jit compile cache key on body identity.
    """

    def wrapped(draw, p, f, dim: int):
        xs = []
        jac = None
        for d in range(dim):
            u = draw(d)
            s = u * float(n_bins)
            idx = jnp.minimum(s.astype(jnp.int32), n_bins - 1)
            frac = s - idx.astype(jnp.float32)
            col = base_cols + d * (n_bins + 1)
            x = jnp.zeros_like(u)
            w = jnp.zeros_like(u)
            for b in range(n_bins):
                e0 = p[f, col + b]
                e1 = p[f, col + b + 1]
                sel = idx == b
                x = jnp.where(sel, e0 + frac * (e1 - e0), x)
                w = jnp.where(sel, (e1 - e0) * float(n_bins), w)
            xs.append(x)
            jac = w if jac is None else jac * w
        val = body(lambda d: xs[d], p, f, dim)
        return val * jac

    wrapped.__name__ = f"adapted_{getattr(body, '__name__', 'body')}"
    return wrapped


def adapt_grid_cols(family):
    """f32[n_fn, dim * (n_bins + 1)] packed bin-edge columns of an
    adapted family, appended after its form's base (and sweep) columns
    in axis-major order."""
    return jnp.asarray(family.params["grid"], jnp.float32).reshape(
        family.n_fn, -1)


def packed_cols(form, family) -> int:
    """Total packed width of ``family`` under ``form`` — the width
    :func:`body_and_packed` produces, sweep, adapt-grid and transform
    columns included.  The fused planner sizes its buckets with this so
    the column layout lives in one module."""
    adapt = family.dim * (family.adapt_bins + 1) if family.adapt_bins else 0
    extra = 2 * family.dim if family.compact else 0
    sweep = len(sweep_col_map(form, family.inner())) if family.swept else 0
    return form.n_cols(family.dim) + sweep + adapt + extra


def body_and_packed(form, family):
    """The (eval body, f32[n_fn, cols]) pair of one family under ``form``.

    The single place swept families grow their substitution wrapper and
    table columns, compactified families their transform wrapper and
    transform columns, and adapted families their importance-map wrapper
    and bin-edge columns — composed, in full, as
    ``adapted_body(compactified_body(swept_body(body)))`` over a
    ``[base][sweep][adapt][transform]`` column layout.  Finite non-swept
    non-adapted families pass through untouched.  Callers (the
    single-family impl and the fused planner) must have
    capability-checked ``form.supports(..., compactified=family.compact,
    sweep=family.swept, adapted=bool(family.adapt_bins))`` first.
    """
    adapt_bins = family.adapt_bins
    core = family.adapt_inner()
    base_cols = form.n_cols(family.dim)
    inner = core.inner()
    if family.swept:
        col_map = sweep_col_map(form, inner)
        body = swept_body(form.body, base_cols, col_map)
        packed = jnp.concatenate([
            jnp.asarray(form.pack_params(inner.sweep_base()), jnp.float32),
            sweep_table_cols(inner)], axis=1)
        core_cols = base_cols + len(col_map)
    else:
        body = form.body
        packed = jnp.asarray(form.pack_params(inner), jnp.float32)
        core_cols = base_cols
    adapt_len = family.dim * (adapt_bins + 1) if adapt_bins else 0
    if family.compact:
        # the transform stage reads past the adapt columns: [..][adapt][transform]
        body = compactified_body(body, core_cols + adapt_len)
    if adapt_bins:
        body = adapted_body(body, core_cols, adapt_bins)
        packed = jnp.concatenate([packed, adapt_grid_cols(family)], axis=1)
    if family.compact:
        packed = jnp.concatenate([packed, transform_cols(core)], axis=1)
    return body, packed


def _fused_kernel(*refs, dim: int, bodies: tuple, sampler: str,
                  has_forms: bool, has_round_base: bool, n_rounds: int):
    """One (function-block, round, sample-block) grid cell.

    Ref order: scalars, fn_ids, [form_ids], [round_base], [dirvecs],
    packed, lo, hi, out.
      scalars: SMEM u32[4|5] = (k0, k1, sample_offset, n_valid
               [, round_stride — required when n_rounds > 1])
      fn_ids:  SMEM u32[F_BLK] global function ids (RNG counters)
      form_ids: SMEM i32[1] body index of this function block (multi-form)
      round_base: SMEM u32[1] additional per-block sample offset (fused
               streams at different refinement depths)
      dirvecs: VMEM u32[dim, 32] Sobol direction vectors (sampler="sobol")
      packed:  VMEM f32[F_BLK, n_cols] form-packed parameters
      lo/hi:   VMEM f32[F_BLK, dim] domain boxes
      out:     VMEM f32[1, F_BLK, 2] this round's running (sum f, sum f^2)
    """
    it = iter(refs)
    scalars_ref = next(it)
    fn_ids_ref = next(it)
    form_ref = next(it) if has_forms else None
    rbase_ref = next(it) if has_round_base else None
    v_ref = next(it) if sampler == "sobol" else None
    packed_ref, lo_ref, hi_ref, out_ref = it

    j = pl.program_id(2)
    k0 = scalars_ref[0]
    k1 = scalars_ref[1]
    sample_offset = scalars_ref[2]
    n_valid = scalars_ref[3]
    if has_round_base:
        sample_offset = sample_offset + rbase_ref[0]
    if n_rounds > 1:
        # round r's window starts round_stride counters after round r-1's;
        # uint32 adds are exact, so this matches a single-round launch at
        # sample_offset + r * round_stride bit for bit
        r = pl.program_id(1)
        sample_offset = sample_offset + jnp.uint32(r) * scalars_ref[4]

    local_idx = tile_sample_index(j)
    c0 = sample_offset + local_idx          # global sample counter
    valid = local_idx < n_valid

    pts = sobol_tiles(c0, v_ref[...], dim) if sampler == "sobol" else None
    p = packed_ref[...]
    lo = lo_ref[...]
    hi = hi_ref[...]

    def eval_block(body):
        parts = []
        for f in range(F_BLK):
            fid = fn_ids_ref[f]

            def draw(d, f=f, fid=fid):
                c1 = fid * jnp.uint32(rng_lib.DIM_STRIDE) + jnp.uint32(d)
                if sampler == "sobol":
                    # per-(fn, dim) digital shift: same counter plane as
                    # the pure-jnp oracle (core/sobol.shifts_for)
                    shift = rng_lib.random_bits(
                        k0, k1, jnp.uint32(SOBOL_SHIFT_C0), c1)
                    bits = pts[d] ^ shift
                else:
                    bits = rng_lib.random_bits(k0, k1, c0, c1)
                u = rng_lib.bits_to_uniform(bits)
                return lo[f, d] + u * (hi[f, d] - lo[f, d])

            val = body(draw, p, f, dim)
            val = jnp.where(valid, val, 0.0)
            parts.append(jnp.stack([jnp.sum(val), jnp.sum(val * val)]))
        return jnp.stack(parts)            # (F_BLK, 2)

    if has_forms and len(bodies) > 1:
        part = jax.lax.switch(
            form_ref[0], [functools.partial(eval_block, b) for b in bodies])
    else:
        part = eval_block(bodies[0])

    accumulate(j, out_ref, part[None])     # (1, F_BLK, 2) round-r block


@functools.partial(jax.jit, static_argnames=(
    "dim", "n_sample_blocks", "n_rounds", "bodies", "sampler", "interpret",
    "name"))
def fused_mc_pallas(scalars, fn_ids, packed, lo, hi, form_ids=None,
                    round_base=None, dirvecs=None, *, dim: int,
                    n_sample_blocks: int, bodies: tuple, n_rounds: int = 1,
                    sampler: str = "mc", interpret: bool,
                    name: str = "mc_eval_fused"):
    """One pallas_call over a (padded) stack of functions x rounds.

    Args:
      scalars: u32[4] (k0, k1, sample_offset, n_valid) — or u32[5] with a
        trailing ``round_stride`` when ``n_rounds > 1`` (counters round r
        draws start at ``offset + r * round_stride``).
      fn_ids: u32[n_fn_pad] with n_fn_pad % F_BLK == 0.
      packed: f32[n_fn_pad, n_cols] form-packed parameters.
      lo, hi: f32[n_fn_pad, dim] domain boxes.
      form_ids: optional i32[n_fn_pad // F_BLK] per-block body index
        (required when len(bodies) > 1; blocks must be form-homogeneous).
      round_base: optional u32[n_fn_pad // F_BLK] per-block extra sample
        offset, added to ``scalars[2]`` — lets one launch fuse function
        blocks whose sample windows start at different stream depths.
      dirvecs: u32[dim, 32] Sobol direction vectors (sampler="sobol").
      bodies: static tuple of eval bodies (see module docstring).
      n_rounds: consecutive counter windows to evaluate in this launch.
    Returns:
      f32[n_rounds, n_fn_pad, 2] of per-round (sum f, sum f^2) per
      function; each round bit-identical to its own single-round launch.
    """
    n_fn_pad = fn_ids.shape[0]
    assert n_fn_pad % F_BLK == 0
    if len(bodies) > 1 and form_ids is None:
        raise ValueError(
            "multiple eval bodies need per-block form_ids; without them "
            "every block would silently run bodies[0]")
    if n_rounds > 1 and scalars.shape[0] < 5:
        raise ValueError(
            "multi-round launches need scalars[4] = round_stride "
            "(pack_scalars(..., round_stride=...))")
    grid = (n_fn_pad // F_BLK, n_rounds, n_sample_blocks)
    fn_blk = lambda i, r, j: (i, 0)

    in_specs = [
        pl.BlockSpec(memory_space=pltpu.SMEM),                    # scalars
        pl.BlockSpec((F_BLK,), lambda i, r, j: (i,),
                     memory_space=pltpu.SMEM),                    # fn_ids
    ]
    args = [scalars, fn_ids]
    has_forms = form_ids is not None
    if has_forms:
        in_specs.append(pl.BlockSpec((1,), lambda i, r, j: (i,),
                                     memory_space=pltpu.SMEM))    # form_ids
        args.append(form_ids)
    has_round_base = round_base is not None
    if has_round_base:
        in_specs.append(pl.BlockSpec((1,), lambda i, r, j: (i,),
                                     memory_space=pltpu.SMEM))    # round_base
        args.append(round_base)
    if sampler == "sobol":
        in_specs.append(pl.BlockSpec((dim, 32), lambda i, r, j: (0, 0)))
        args.append(dirvecs)
    n_cols = packed.shape[1]
    in_specs += [
        pl.BlockSpec((F_BLK, n_cols), fn_blk),                    # packed
        pl.BlockSpec((F_BLK, dim), fn_blk),                       # lo
        pl.BlockSpec((F_BLK, dim), fn_blk),                       # hi
    ]
    args += [packed, lo, hi]

    return pl.pallas_call(
        functools.partial(_fused_kernel, dim=dim, bodies=bodies,
                          sampler=sampler, has_forms=has_forms,
                          has_round_base=has_round_base, n_rounds=n_rounds),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, F_BLK, 2), lambda i, r, j: (r, i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rounds, n_fn_pad, 2), jnp.float32),
        compiler_params=compiler_params(
            # function blocks and rounds write independent output blocks;
            # the sample axis revisits its round's accumulator block and
            # must stay sequential
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name=name,
    )(*args)


def pack_scalars(key, sample_offset, n_samples, round_stride=None):
    """u32[4] SMEM operand shared by every fused MC kernel — u32[5] with
    the per-round counter stride when the launch is multi-round."""
    parts = [
        jnp.asarray(key[0], jnp.uint32).reshape(()),
        jnp.asarray(key[1], jnp.uint32).reshape(()),
        jnp.asarray(sample_offset, jnp.uint32).reshape(()),
        jnp.asarray(n_samples, jnp.uint32).reshape(()),
    ]
    if round_stride is not None:
        parts.append(jnp.asarray(round_stride, jnp.uint32).reshape(()))
    return jnp.stack(parts)


def probe_operands(dim: int, n_cols: int):
    """Zero-filled abstract-trace operands for one eval body.

    Returns ``(draws, packed)`` shaped exactly like what
    :func:`_fused_kernel` hands a body — ``draws`` is f32[dim, S_ROWS,
    S_LANES] (index ``draws[d]`` to get dimension ``d``'s sample tile)
    and ``packed`` is the f32[F_BLK, n_cols] parameter block.  The
    contract checker (:mod:`repro.analysis.contracts`) traces bodies on
    these to prove purity/dtype/aval invariants without a device.
    """
    return (jnp.zeros((dim, S_ROWS, S_LANES), jnp.float32),
            jnp.zeros((F_BLK, n_cols), jnp.float32))


def make_family_impl(form, sampler: str):
    """Build a registry fast-path callable for one form + sampler.

    The returned impl matches ``direct_mc.family_sums`` semantics exactly:
    same Threefry counters, same uniforms, same estimates (up to f32
    association order) — asserted by the kernel test sweeps.
    """
    from repro.core.direct_mc import SumsState

    def impl(family, n_samples: int, key, *, fn_offset: int = 0,
             sample_offset=0, fn_ids=None,
             interpret: bool | None = None) -> SumsState:
        n_fn, dim = family.n_fn, family.dim
        compact = family.compact
        if not form.supports(dim=dim, sampler=sampler, compactified=compact,
                             sweep=family.swept,
                             adapted=bool(family.adapt_bins)):
            raise ValueError(
                f"kernel {form.name!r} does not support dim={dim} with "
                f"sampler={sampler!r}"
                + (" on a compactified family" if compact else "")
                + (f" swept over {family.swept}" if family.swept else "")
                + (" with an importance grid" if family.adapt_bins else ""))
        if fn_ids is None:
            fn_ids = jnp.uint32(fn_offset) + jnp.arange(n_fn,
                                                        dtype=jnp.uint32)
        interpret = resolve_interpret(interpret)

        n_fn_pad = math.ceil(n_fn / F_BLK) * F_BLK
        pad = n_fn_pad - n_fn
        body, packed = body_and_packed(form, family)
        packed = pad_rows(packed, pad)
        lo = pad_rows(jnp.asarray(family.domains[..., 0], jnp.float32), pad)
        hi = pad_rows(jnp.asarray(family.domains[..., 1], jnp.float32), pad)
        fn_ids = pad_rows(jnp.asarray(fn_ids, jnp.uint32), pad)

        dirvecs = None
        if sampler == "sobol":
            from repro.core.sobol import direction_vectors
            dirvecs = jnp.asarray(direction_vectors(dim))

        n_sample_blocks = max(1, math.ceil(int(n_samples) / S_BLK))
        scalars = pack_scalars(key, sample_offset, n_samples)
        record_launch()
        out = fused_mc_pallas(
            scalars, fn_ids, packed, lo, hi, dirvecs=dirvecs, dim=dim,
            n_sample_blocks=n_sample_blocks, bodies=(body,),
            sampler=sampler, interpret=interpret,
            name=form.name if sampler == "mc" else f"{form.name}@{sampler}")[0]
        return SumsState(s1=out[:n_fn, 0], s2=out[:n_fn, 1],
                         n=jnp.float32(n_samples))

    impl.__name__ = form.name if sampler == "mc" else f"{form.name}@{sampler}"
    impl.form = form
    impl.sampler = sampler
    return impl
