from repro.optim.optimizers import (Optimizer, adafactor, adamw,
                                    make_optimizer, opt_state_specs)
from repro.optim.schedule import constant, warmup_cosine

__all__ = ["Optimizer", "adamw", "adafactor", "make_optimizer",
           "opt_state_specs", "warmup_cosine", "constant"]
