"""Optimizers from scratch (no optax): AdamW and Adafactor.

Both expose:
  init(params)            -> opt state (pytree)
  update(grads, state, params, step) -> (new_params, new_state)
plus the module-level :func:`opt_state_specs`, which derives the logical
sharding axes of the optimizer state from (abstract params, param axes) so
the dry-run shards optimizer memory along the same mesh axes as the
parameters (ZeRO-style; there is no replicated copy anywhere).

Adafactor (Shazeer & Stern 2018) keeps factored second moments — O(n+m)
per (n, m) matrix instead of O(n*m) — which is what lets deepseek-v3-671b's
optimizer state fit 512 x 16 GB chips (AdamW f32 moments would need ~5.4 TB
for the MoE weights alone; see EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    kind: str
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, Any], tuple[Any, Any]]


def _schedule_fn(lr):
    return lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr, *, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, moment_dtype=jnp.float32) -> Optimizer:
    sched = _schedule_fn(lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        stepf = jnp.asarray(step, jnp.float32) + 1.0
        lr_t = sched(step)
        c1 = 1.0 - jnp.power(b1, stepf)
        c2 = 1.0 - jnp.power(b2, stepf)

        def one(g, mu, nu, p):
            gf = g.astype(moment_dtype)
            mu = b1 * mu + (1 - b1) * gf
            nu = b2 * nu + (1 - b2) * jnp.square(gf)
            mu_hat = mu.astype(jnp.float32) / c1
            nu_hat = nu.astype(jnp.float32) / c2
            upd = mu_hat / (jnp.sqrt(nu_hat) + eps)
            upd = upd + weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr_t * upd
            return new_p.astype(p.dtype), mu, nu

        flat_g, treedef = jax.tree.flatten(grads)
        flat_mu = treedef.flatten_up_to(state["mu"])
        flat_nu = treedef.flatten_up_to(state["nu"])
        flat_p = treedef.flatten_up_to(params)
        out = [one(g, m, n, p)
               for g, m, n, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_mu = treedef.unflatten([o[1] for o in out])
        new_nu = treedef.unflatten([o[2] for o in out])
        return new_p, {"mu": new_mu, "nu": new_nu}

    return Optimizer(kind="adamw", init=init, update=update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments, no first moment by default)
# ---------------------------------------------------------------------------

def _axes_leaf(x):
    return isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x)


def adafactor(lr, *, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, weight_decay: float = 0.0,
              min_dim_size_to_factor: int = 128) -> Optimizer:
    sched = _schedule_fn(lr)

    def _factored(shape) -> bool:
        return (len(shape) >= 2 and shape[-1] >= min_dim_size_to_factor
                and shape[-2] >= min_dim_size_to_factor)

    def init(params):
        def one(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return jax.tree.map(one, params)

    def update(grads, state, params, step):
        stepf = jnp.asarray(step, jnp.float32) + 1.0
        lr_t = sched(step)
        beta = 1.0 - jnp.power(stepf, -decay)   # increasing decay schedule

        def one(g, s, p):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + eps
            if _factored(p.shape):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                row_mean = jnp.mean(vr, axis=-1, keepdims=True)
                precond = (vr / jnp.maximum(row_mean, eps))[..., None] * \
                    jnp.expand_dims(vc, -2)
                upd = gf / jnp.sqrt(jnp.maximum(precond, eps))
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                upd = gf / jnp.sqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-30)
            upd = upd / jnp.maximum(1.0, rms / clip_threshold)
            new_p = p.astype(jnp.float32) - lr_t * (
                upd + weight_decay * p.astype(jnp.float32))
            return new_p.astype(p.dtype), new_s

        flat_g, treedef = jax.tree.flatten(grads)
        flat_s = treedef.flatten_up_to(state)
        flat_p = treedef.flatten_up_to(params)
        out = [one(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        return (treedef.unflatten([o[0] for o in out]),
                treedef.unflatten([o[1] for o in out]))

    return Optimizer(kind="adafactor", init=init, update=update)


def opt_state_specs(kind: str, abstract_params, param_specs,
                    min_dim_size_to_factor: int = 128):
    """Logical-axes tree for the optimizer state of `kind`.

    Needs abstract params because Adafactor's factorisation depends on leaf
    shapes, not just axes.
    """
    leaves, treedef = jax.tree.flatten(abstract_params)
    axes_leaves = treedef.flatten_up_to(param_specs)

    if kind == "adamw":
        mu = treedef.unflatten(list(axes_leaves))
        nu = treedef.unflatten(list(axes_leaves))
        return {"mu": mu, "nu": nu}
    if kind == "adafactor":
        def one(p, axes):
            if (len(p.shape) >= 2 and p.shape[-1] >= min_dim_size_to_factor
                    and p.shape[-2] >= min_dim_size_to_factor):
                return {"vr": tuple(axes[:-1]), "vc": tuple(axes[:-2]) + (axes[-1],)}
            return {"v": tuple(axes)}
        out = [one(p, a) for p, a in zip(leaves, axes_leaves)]
        return treedef.unflatten(out)
    raise ValueError(kind)


def make_optimizer(kind: str, lr, **kw) -> Optimizer:
    if kind == "adamw":
        return adamw(lr, **kw)
    if kind == "adafactor":
        return adafactor(lr, **kw)
    raise ValueError(kind)
