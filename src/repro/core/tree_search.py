"""Heuristic tree search: adaptive stratum refinement (ZMCintegral_normal).

The original package repeatedly evaluates domain chunks, ranks them by the
standard deviation of repeated estimates, and recursively re-partitions the
worst chunks.  The TPU-native formulation below keeps the *heuristic* —
"spend samples where vol x sigma is largest" — but replaces Python recursion
with a bounded, statically-shaped refinement loop:

  repeat ``depth`` times:
    1. priority_k = vol_k * sqrt(var_k)          (active strata only)
    2. pick the top ``k_split`` strata
    3. bisect each along its widest dimension
    4. evaluate the 2*k_split children (fresh counter epoch)

Each iteration only evaluates the *new* strata, so the total work is
``n0 + 2 * depth * k_split`` stratum evaluations.  Everything is
``lax``-expressible and jit-compiles to a single program.

This is the escalation path of the service's variance-reduction stack
(exported from ``repro.core``): when
:func:`repro.core.adaptive.region_scores` shows an integrand's mass is
too non-separable for an axis-factorized VEGAS grid to help, per-region
refinement here spends samples where ``vol * sigma`` is largest instead.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import stratified


class TreeSearchResult(NamedTuple):
    integral: jax.Array
    stderr: jax.Array
    table: stratified.StratumTable
    n_evals: jax.Array  # total integrand evaluations spent


def refine(
    fn: Callable,
    table: stratified.StratumTable,
    key,
    *,
    n0: int,
    n_per: int,
    depth: int,
    k_split: int,
) -> stratified.StratumTable:
    """Run ``depth`` refinement iterations on an initialised table."""

    def step(it, tab: stratified.StratumTable) -> stratified.StratumTable:
        vol = stratified.stratum_volumes(tab)
        sigma = jnp.sqrt(tab.var)
        priority = jnp.where(tab.active, vol * sigma, -jnp.inf)
        _, idx = jax.lax.top_k(priority, k_split)

        parents = tab.boxes[idx]                      # (K, dim, 2)
        lo, hi = parents[..., 0], parents[..., 1]
        widths = hi - lo
        wd = jnp.argmax(widths, axis=-1)              # widest dim per parent
        onehot = jax.nn.one_hot(wd, tab.dim, dtype=lo.dtype)
        mid = lo + 0.5 * widths
        child_a = jnp.stack([lo, jnp.where(onehot > 0, mid, hi)], axis=-1)
        child_b = jnp.stack([jnp.where(onehot > 0, mid, lo), hi], axis=-1)

        slot_b = n0 + it * k_split + jnp.arange(k_split)
        boxes = tab.boxes.at[idx].set(child_a).at[slot_b].set(child_b)
        active = tab.active.at[slot_b].set(True)

        child_boxes = jnp.concatenate([child_a, child_b], axis=0)
        child_slots = jnp.concatenate([idx, slot_b], axis=0)
        # epoch it+2: epoch 0 (multiplier 1) was the initial grid evaluation
        mean_c, var_c = stratified.eval_strata(
            fn, child_boxes, child_slots, it + 2, n_per, key)
        mean = tab.mean.at[child_slots].set(mean_c)
        var = tab.var.at[child_slots].set(var_c)
        return stratified.StratumTable(boxes=boxes, mean=mean, var=var,
                                       active=active)

    return jax.lax.fori_loop(0, depth, step, table)


def integrate(
    fn: Callable,
    domain,
    key,
    *,
    splits_per_dim: int = 3,
    n_per: int = 2048,
    depth: int = 8,
    k_split: int = 32,
) -> TreeSearchResult:
    """Full stratified + tree-search integration of a single integrand.

    Args:
      fn: integrand mapping (..., dim) -> (...,); pure JAX.
      domain: (dim, 2) box.
      key: (k0, k1) Threefry key words.
    """
    # The initial grid is built host-side (python product over cells), so the
    # domain must be a *concrete* array even when `integrate` runs under jit.
    import numpy as np
    domain = np.asarray(domain, np.float32)
    dim = domain.shape[0]
    n0 = splits_per_dim ** dim
    if n0 < k_split:
        raise ValueError(
            f"initial grid ({n0}) must be >= k_split ({k_split}); "
            f"raise splits_per_dim or lower k_split")
    cap = stratified.suggested_capacity(dim, splits_per_dim, depth, k_split)
    table = stratified.initial_grid(domain, splits_per_dim, cap)
    mean0, var0 = stratified.eval_strata(
        fn, table.boxes[:n0], jnp.arange(n0), 0, n_per, key)
    table = table._replace(mean=table.mean.at[:n0].set(mean0),
                           var=table.var.at[:n0].set(var0))
    table = refine(fn, table, key, n0=n0, n_per=n_per, depth=depth,
                   k_split=k_split)
    integral, stderr = stratified.table_estimate(table, n_per)
    n_evals = jnp.asarray((n0 + 2 * depth * k_split) * n_per, jnp.int32)
    return TreeSearchResult(integral=integral, stderr=stderr, table=table,
                            n_evals=n_evals)
