"""``ZMCNormal`` — stratified sampling + heuristic tree search (v1–v3 API).

For single high-dimensional integrands (the paper recommends it for
dimensionality 8–12).  Wraps :mod:`repro.core.tree_search` and adds the
original package's trial semantics: ``evaluate()`` runs ``num_trials``
independent refinements and reports their mean and spread.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rng, tree_search


@dataclasses.dataclass
class NormalResult:
    integral: float
    stderr: float              # combined in-run stderr (mean over trials)
    trial_values: np.ndarray   # (num_trials,)

    @property
    def trial_std(self) -> float:
        if len(self.trial_values) < 2:
            return float(self.stderr)
        return float(self.trial_values.std(ddof=1))


class ZMCNormal:
    """Adaptive stratified MC for a single integrand.

    Args:
      fn: integrand mapping (..., dim) -> (...,); pure JAX.
      domain: (dim, 2) finite box.
      splits_per_dim: initial uniform grid resolution per dimension.
      n_per_stratum: samples used to estimate each stratum.
      depth: tree-search iterations.
      k_split: strata refined per iteration.
    """

    def __init__(
        self,
        fn: Callable,
        domain,
        seed: int = 0,
        *,
        splits_per_dim: int = 3,
        n_per_stratum: int = 2048,
        depth: int = 8,
        k_split: int = 32,
        mesh=None,
    ):
        self.fn = fn
        self.domain = np.asarray(domain, np.float32)
        if not np.all(np.isfinite(self.domain)):
            raise ValueError(
                "ZMCNormal requires a finite box; compactify the integrand "
                "first (see repro.core.domains.compactify)")
        self.seed = seed
        self.mesh = mesh   # strata shard over 'model', samples over 'data'
        self.opts = dict(splits_per_dim=splits_per_dim, n_per=n_per_stratum,
                         depth=depth, k_split=k_split)
        self._jitted = jax.jit(
            lambda k0, k1: tree_search.integrate(
                self.fn, self.domain, (k0, k1), **self.opts))

    def evaluate(self, num_trials: int = 5) -> NormalResult:
        from repro.distributed.sharding import logical_sharding
        vals, errs = [], []
        with logical_sharding(self.mesh):
            for t in range(num_trials):
                k0, k1 = rng.fold_key(self.seed, t)
                res = self._jitted(jnp.uint32(k0), jnp.uint32(k1))
                vals.append(float(res.integral))
                errs.append(float(res.stderr))
        vals = np.asarray(vals)
        return NormalResult(integral=float(vals.mean()),
                            stderr=float(np.mean(errs)),
                            trial_values=vals)
