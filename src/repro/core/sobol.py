"""Randomised Sobol quasi-Monte-Carlo sampling (beyond-paper upgrade).

ZMCintegral uses plain pseudo-random MC: error ~ N^(-1/2).  A digitally
shifted Sobol low-discrepancy sequence converges ~ N^(-1) (log N)^d on
smooth integrands — at the paper's N = 10^6 that is orders of magnitude
more accuracy for the *same* sample budget, i.e. a direct improvement of
the paper's time-to-accuracy metric (measured in EXPERIMENTS.md §Perf
iteration 9: ~30x stderr reduction on the Fig.-1 family).

Implementation notes:

* Direction numbers: Joe-Kuo D6 initialisation for dimensions 2..8
  (dimension 1 is van der Corput).  Up to 8 dims covers the paper's
  use-cases (the engine falls back to pseudo-random MC above that).
* Gray-code construction evaluated *by index*: point i is the XOR of the
  direction vectors selected by the bits of gray(i) — O(32) vector ops,
  fully counter-addressed like the Threefry path, so sharding / resume /
  elastic semantics are unchanged.
* Randomisation: per-(function, dimension) digital shift derived from the
  Threefry key — unbiased, and independent trials give a valid stderr.

The same construction runs inside the Pallas kernel path (u32 XOR/shift
ops only); the pure-jnp form here is the oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rng as rng_lib

MAX_DIM = 8
_BITS = 32

# Joe-Kuo D6: (s, a, m[1..s]) per dimension (dim 1 handled separately)
_JOE_KUO = {
    2: (1, 0, [1]),
    3: (2, 1, [1, 3]),
    4: (3, 1, [1, 3, 1]),
    5: (3, 2, [1, 1, 1]),
    6: (4, 1, [1, 1, 3, 3]),
    7: (4, 4, [1, 3, 5, 13]),
    8: (5, 2, [1, 1, 5, 5, 17]),
}


@functools.lru_cache(maxsize=None)
def direction_vectors(dim: int) -> np.ndarray:
    """(dim, 32) uint32 direction vectors V[d, j]."""
    if dim > MAX_DIM:
        raise ValueError(f"sobol supports dim <= {MAX_DIM}; got {dim}")
    v = np.zeros((dim, _BITS), np.uint64)
    # dimension 1: van der Corput
    for j in range(_BITS):
        v[0, j] = 1 << (31 - j)
    for d in range(2, dim + 1):
        s, a, m = _JOE_KUO[d]
        row = v[d - 1]
        for j in range(min(s, _BITS)):
            row[j] = np.uint64(m[j]) << (31 - j)
        for j in range(s, _BITS):
            x = row[j - s] ^ (row[j - s] >> np.uint64(s))
            for k in range(1, s):
                if (a >> (s - 1 - k)) & 1:
                    x ^= row[j - k]
            row[j] = x
    return v.astype(np.uint32)


def sobol_bits(indices, dim: int):
    """Raw Sobol integer points.

    indices: uint32 array of point indices (any shape).
    Returns uint32 array shaped indices.shape + (dim,).
    """
    v = jnp.asarray(direction_vectors(dim))           # (dim, 32)
    idx = jnp.asarray(indices, jnp.uint32)
    gray = idx ^ (idx >> np.uint32(1))

    def body(j, acc):
        bit = (gray >> jnp.uint32(j)) & np.uint32(1)
        contrib = jnp.where(bit[..., None].astype(bool), v[:, j], 0)
        return acc ^ contrib

    acc0 = jnp.zeros(gray.shape + (dim,), jnp.uint32)
    return jax.lax.fori_loop(0, _BITS, body, acc0)


def shifts_for(k0, k1, fn_ids, dim: int):
    """Per-(function, dim) digital-shift words from the Threefry key."""
    fn_ids = jnp.asarray(fn_ids, jnp.uint32)
    d = jnp.arange(dim, dtype=jnp.uint32)
    c1 = (fn_ids[:, None] * np.uint32(rng_lib.DIM_STRIDE) + d[None, :])
    # dedicated counter plane (c0 = 0xS0B01) so shifts never collide with
    # the MC sample stream
    c0 = jnp.full_like(c1, np.uint32(0x50B01))
    return rng_lib.random_bits(k0, k1, c0, c1)        # (F, dim)


def sobol_uniforms_for(k0, k1, fn_ids, sample_ids, n_dim: int):
    """Drop-in replacement for rng.uniforms_for using shifted Sobol points.

    Returns (F, S, n_dim) float32 in [0, 1).  The digital shift differs per
    function (and per key), so trials/functions are independently
    randomised while sharing one low-discrepancy stream.
    """
    pts = sobol_bits(jnp.asarray(sample_ids, jnp.uint32), n_dim)  # (S, dim)
    shift = shifts_for(k0, k1, fn_ids, n_dim)                     # (F, dim)
    mixed = pts[None, :, :] ^ shift[:, None, :]
    return rng_lib.bits_to_uniform(mixed)
