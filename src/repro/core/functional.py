"""``ZMCFunctional`` — parameter-scan integration (the v5 feature).

One integrand, evaluated over a (possibly huge) grid of parameter vectors:
``I(theta_j) = Int f(x; theta_j) dx`` for j = 1..n_param.  This is exactly a
single :class:`IntegrandFamily` whose "functions" are the parameter points,
so the class is a thin, API-compatible wrapper over the multi-function
engine — which is also how v5.1 subsumes v5 in the paper.
"""

from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.integrand import IntegrandFamily, MultiFunctionSpec
from repro.core.multifunctions import MultiFunctionResult, ZMCMultiFunctions


class ZMCFunctional:
    """Scan a parameter grid of one integrand.

    Args:
      fn: ``fn(x, theta) -> value`` with x (..., dim), theta a single
        parameter pytree.
      param_grid: pytree whose leaves have leading axis ``n_param``.
      domain: (dim, 2) shared integration box (may contain inf).
    """

    def __init__(
        self,
        fn: Callable[[jax.Array, Any], jax.Array],
        param_grid: Any,
        domain,
        n_samples: int = 10**5,
        seed: int = 0,
        *,
        mesh: Mesh | None = None,
        chunk: int = 8192,
        fn_chunk: int | None = None,
        use_kernel: bool = False,
        name: str = "functional",
    ):
        domain = np.asarray(domain, np.float32)
        if domain.ndim != 2 or domain.shape[-1] != 2:
            raise ValueError(f"domain must be (dim, 2); got {domain.shape}")
        leaves = jax.tree_util.tree_leaves(param_grid)
        if not leaves:
            raise ValueError("param_grid must have at least one leaf")
        n_param = int(np.shape(leaves[0])[0])
        domains = jnp.broadcast_to(jnp.asarray(domain), (n_param,) + domain.shape)
        family = IntegrandFamily(fn=fn, params=param_grid, domains=domains,
                                 name=name).validate()
        self._engine = ZMCMultiFunctions(
            MultiFunctionSpec.from_families([family]),
            n_samples=n_samples, seed=seed, mesh=mesh, chunk=chunk,
            fn_chunk=fn_chunk, use_kernel=use_kernel)
        self.n_param = n_param

    def evaluate(self, num_trials: int = 1) -> MultiFunctionResult:
        return self._engine.evaluate(num_trials=num_trials)

    def evaluate_resumable(self, **kw) -> MultiFunctionResult:
        return self._engine.evaluate_resumable(**kw)
