"""Integration-domain transforms.

A domain is a per-function box ``(n_fn, dim, 2)`` of ``[lo, hi]`` pairs.
Finite boxes map uniforms affinely; infinite / semi-infinite edges use the
standard tangent / rational compactifications with their Jacobians folded
into the integrand value, so every solver only ever samples the unit cube.

The Pallas fast path (``repro.kernels.mc_eval``) handles finite boxes only —
``compactify`` rewrites an infinite-domain family into an equivalent
finite-domain family first, so kernels never see infinities.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def box_volume(domains, dims=None):
    """Volume of each function's active box.

    Args:
      domains: (n_fn, dim, 2) array.
      dims: optional (n_fn,) active-dimension counts; padding dims (with
        lo == hi == 0 convention) are excluded by masking, not by volume
        (a padded dim has hi - lo == 0 which would zero the product).

    Returns: (n_fn,) float32 volumes.
    """
    widths = domains[..., 1] - domains[..., 0]
    if dims is not None:
        d = jnp.arange(domains.shape[1])
        mask = d[None, :] < jnp.asarray(dims)[:, None]
        widths = jnp.where(mask, widths, 1.0)
    return jnp.prod(widths, axis=-1)


def affine_from_unit(u, domains):
    """Map unit-cube uniforms ``u`` (..., dim) into the box. Broadcasts."""
    lo = domains[..., 0]
    hi = domains[..., 1]
    return lo + u * (hi - lo)


def is_finite_box(domains) -> bool:
    return bool(np.all(np.isfinite(np.asarray(domains))))


def compactify(fn, domains):
    """Rewrite (fn, domains) with infinite edges into a finite-box problem.

    Per-dimension rules (u is the finite coordinate sampled in the new box):

    * ``(-inf, inf)``  -> x = tan(pi*(u - 1/2)),  u in (0, 1),  J = pi*sec^2
    * ``[a,  inf)``    -> x = a + u/(1-u),        u in [0, 1),  J = 1/(1-u)^2
    * ``(-inf, b]``    -> x = b - u/(1-u),        u in [0, 1),  J = 1/(1-u)^2
    * finite           -> identity

    Returns ``(fn2, domains2)`` where ``fn2(u, params)`` evaluates the
    original integrand times the Jacobian, and ``domains2`` is finite.
    The transform is per-function static (derived from the numpy domain
    array), so it traces to pure jnp ops.
    """
    domains = np.asarray(domains, np.float64)
    if is_finite_box(domains):
        return fn, jnp.asarray(domains, jnp.float32)
    if domains.ndim != 3:
        raise ValueError("compactify expects (n_fn, dim, 2) domains")
    lo_inf = ~np.isfinite(domains[..., 0])
    hi_inf = ~np.isfinite(domains[..., 1])
    both = lo_inf & hi_inf
    upper = ~lo_inf & hi_inf
    lower = lo_inf & ~hi_inf

    new_domains = domains.copy()
    new_domains[..., 0] = np.where(both | upper | lower, 0.0, domains[..., 0])
    new_domains[..., 1] = np.where(both | upper | lower, 1.0, domains[..., 1])

    # Per-function transform metadata rides along with the user params so the
    # engine's per-function vmap slices it consistently (leading n_fn axis).
    aux = {
        "both": jnp.asarray(both),
        "upper": jnp.asarray(upper),
        "lower": jnp.asarray(lower),
        "flo": jnp.asarray(
            np.where(np.isfinite(domains[..., 0]), domains[..., 0], 0.0), jnp.float32),
        "fhi": jnp.asarray(
            np.where(np.isfinite(domains[..., 1]), domains[..., 1], 0.0), jnp.float32),
    }

    def transformed(u, wrapped):
        # u: (..., dim) sampled in the *new* (finite) box: unit interval on
        # transformed dims, the original interval elsewhere. ``wrapped`` is
        # {"inner": user params, "aux": per-function masks} with the leading
        # n_fn axis already sliced away by the engine's vmap.
        a = wrapped["aux"]
        b, up, lw = a["both"], a["upper"], a["lower"]
        eps = jnp.asarray(1e-7, u.dtype)
        uc = jnp.clip(u, eps, 1.0 - eps)
        tan_x = jnp.tan(jnp.pi * (uc - 0.5))
        tan_j = jnp.pi / jnp.square(jnp.cos(jnp.pi * (uc - 0.5)))
        rat = uc / (1.0 - uc)
        rat_j = 1.0 / jnp.square(1.0 - uc)
        x = jnp.where(b, tan_x,
            jnp.where(up, a["flo"] + rat,
            jnp.where(lw, a["fhi"] - rat, u)))
        jac = jnp.where(b, tan_j, jnp.where(up | lw, rat_j, jnp.ones_like(uc)))
        return fn(x, wrapped["inner"]) * jnp.prod(jac, axis=-1)

    return transformed, jnp.asarray(new_domains, jnp.float32), aux
