"""Integration-domain transforms.

A domain is a per-function box ``(n_fn, dim, 2)`` of ``[lo, hi]`` pairs.
Finite boxes map uniforms affinely; infinite / semi-infinite edges use the
standard tangent / rational compactifications with their Jacobians folded
into the integrand value, so every solver only ever samples the unit cube.

``compactify`` rewrites an infinite-domain family into an equivalent
finite-domain family first, so solvers never see infinities.  The
transform is **static per (function, axis)** — a kind code plus a finite
shift, derived from the numpy domain array (:func:`transform_params`) —
which is what lets the fused Pallas path evaluate compactified families
too: the codes pack into kernel parameter columns and the in-kernel
wrapper stage (``repro.kernels.template.compactified_body``) applies the
very same :func:`apply_transform` the chunked closure uses.

The importance-map stage composes *outside* this one: an adapted family
(``repro.core.adaptive``) maps uniforms through its grid's inverse CDF
first, then the transform stage maps the grid's x-space — which is the
canonical (compactified) box — onward.  Packed rows follow the same
order: ``[base params][sweep table][grid edges][transform columns]``.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Per-axis transform kind codes.  Static (host-side) metadata, but they
# also ride inside f32 kernel parameter columns — keep them exact small
# ints.
TRANSFORM_NONE = 0   # finite edge: identity
TRANSFORM_TAN = 1    # (-inf, inf): x = tan(pi*(u - 1/2))
TRANSFORM_UPPER = 2  # [a,  inf):   x = a + u/(1-u)
TRANSFORM_LOWER = 3  # (-inf, b]:   x = b - u/(1-u)

# Samples are clamped into the open unit interval before transforming so
# the tangent/rational maps stay finite at the box edges.
CLIP_EPS = 1e-7


def box_volume(domains, dims=None):
    """Volume of each function's active box.

    Args:
      domains: (n_fn, dim, 2) array.
      dims: optional (n_fn,) active-dimension counts; padding dims (with
        lo == hi == 0 convention) are excluded by masking, not by volume
        (a padded dim has hi - lo == 0 which would zero the product).

    Returns: (n_fn,) float32 volumes.
    """
    widths = domains[..., 1] - domains[..., 0]
    if dims is not None:
        d = jnp.arange(domains.shape[1])
        mask = d[None, :] < jnp.asarray(dims)[:, None]
        widths = jnp.where(mask, widths, 1.0)
    return jnp.prod(widths, axis=-1)


def affine_from_unit(u, domains):
    """Map unit-cube uniforms ``u`` (..., dim) into the box. Broadcasts."""
    lo = domains[..., 0]
    hi = domains[..., 1]
    return lo + u * (hi - lo)


def is_finite_box(domains) -> bool:
    return bool(np.all(np.isfinite(np.asarray(domains))))


def transform_params(domains):
    """Static per-(function, axis) compactification metadata.

    Args:
      domains: (n_fn, dim, 2) possibly-infinite boxes (numpy/array).

    Returns ``(kind, shift, new_domains)``:
      kind: int32 (n_fn, dim) ``TRANSFORM_*`` code per axis;
      shift: float32 (n_fn, dim) finite anchor of half-infinite axes
        (the ``a`` of ``[a, inf)``, the ``b`` of ``(-inf, b]``), 0
        elsewhere;
      new_domains: float32 finite sampling box — transformed axes become
        [0, 1], finite axes keep their original edges.

    All three are host numpy: the transform is static per function, so
    it can parameterize traced jnp code (:func:`apply_transform`) and
    pack into fused-kernel parameter columns alike.
    """
    domains = np.asarray(domains, np.float64)
    lo_inf = ~np.isfinite(domains[..., 0])
    hi_inf = ~np.isfinite(domains[..., 1])
    kind = np.where(lo_inf & hi_inf, TRANSFORM_TAN,
                    np.where(~lo_inf & hi_inf, TRANSFORM_UPPER,
                             np.where(lo_inf & ~hi_inf, TRANSFORM_LOWER,
                                      TRANSFORM_NONE)))
    shift = np.where(kind == TRANSFORM_UPPER, domains[..., 0],
                     np.where(kind == TRANSFORM_LOWER, domains[..., 1], 0.0))
    new_domains = domains.copy()
    transformed = kind != TRANSFORM_NONE
    new_domains[..., 0] = np.where(transformed, 0.0, domains[..., 0])
    new_domains[..., 1] = np.where(transformed, 1.0, domains[..., 1])
    return (kind.astype(np.int32), shift.astype(np.float32),
            new_domains.astype(np.float32))


def apply_transform(u, kind, shift):
    """Map unit-interval samples through the per-axis compactification.

    Pure jnp; ``kind``/``shift`` broadcast against ``u`` — the chunked
    closure passes per-function ``(dim,)`` rows, the fused kernel
    per-(function, axis) scalars read from packed parameter columns.
    ``kind`` may be integer or float (the codes are exact small ints in
    f32, so the comparisons hold either way).

    Returns ``(x, jac)``: original-space coordinates and the per-axis
    Jacobian factor ``dx/du`` (1 on finite axes, where ``x == u``
    untouched by the clamp).
    """
    eps = jnp.asarray(CLIP_EPS, u.dtype)
    uc = jnp.clip(u, eps, 1.0 - eps)
    tan_x = jnp.tan(jnp.pi * (uc - 0.5))
    tan_j = jnp.pi / jnp.square(jnp.cos(jnp.pi * (uc - 0.5)))
    rat = uc / (1.0 - uc)
    rat_j = 1.0 / jnp.square(1.0 - uc)
    both = kind == TRANSFORM_TAN
    upper = kind == TRANSFORM_UPPER
    lower = kind == TRANSFORM_LOWER
    x = jnp.where(both, tan_x,
                  jnp.where(upper, shift + rat,
                            jnp.where(lower, shift - rat, u)))
    jac = jnp.where(both, tan_j,
                    jnp.where(upper | lower, rat_j, jnp.ones_like(uc)))
    return x, jac


def compactify(fn, domains):
    """Rewrite (fn, domains) with infinite edges into a finite-box problem.

    Per-dimension rules (u is the finite coordinate sampled in the new box):

    * ``(-inf, inf)``  -> x = tan(pi*(u - 1/2)),  u in (0, 1),  J = pi*sec^2
    * ``[a,  inf)``    -> x = a + u/(1-u),        u in [0, 1),  J = 1/(1-u)^2
    * ``(-inf, b]``    -> x = b - u/(1-u),        u in [0, 1),  J = 1/(1-u)^2
    * finite           -> identity

    Returns ``(fn2, domains2, aux)`` where ``fn2(u, params)`` evaluates
    the original integrand times the Jacobian, ``domains2`` is finite,
    and ``aux = {"kind", "shift"}`` holds the static per-(function, axis)
    transform parameters (:func:`transform_params`) — the same arrays the
    fused Pallas path packs into kernel parameter columns.  Finite boxes
    return ``(fn, domains)`` unchanged.
    """
    domains = np.asarray(domains, np.float64)
    if is_finite_box(domains):
        return fn, jnp.asarray(domains, jnp.float32)
    if domains.ndim != 3:
        raise ValueError("compactify expects (n_fn, dim, 2) domains")
    kind, shift, new_domains = transform_params(domains)
    # Per-function transform metadata rides along with the user params so the
    # engine's per-function vmap slices it consistently (leading n_fn axis).
    aux = {"kind": jnp.asarray(kind), "shift": jnp.asarray(shift)}

    def transformed(u, wrapped):
        # u: (..., dim) sampled in the *new* (finite) box: unit interval on
        # transformed dims, the original interval elsewhere. ``wrapped`` is
        # {"inner": user params, "aux": {"kind", "shift"}} with the leading
        # n_fn axis already sliced away by the engine's vmap.
        a = wrapped["aux"]
        x, jac = apply_transform(u, a["kind"], a["shift"])
        return fn(x, wrapped["inner"]) * jnp.prod(jac, axis=-1)

    return transformed, jnp.asarray(new_domains, jnp.float32), aux
