# The paper's primary contribution: massively-parallel multi-function
# Monte-Carlo integration (ZMCintegral-v5.1), TPU-native.
#
# Three solver classes mirror the original package:
#   ZMCNormal          - stratified sampling + heuristic tree search (dim 8-12)
#   ZMCFunctional      - one integrand x large parameter grid (v5)
#   ZMCMultiFunctions  - many heterogeneous integrands (the v5.1 feature)
#
# Variance-reduction substrate (the service's adaptive planner builds on
# these; see docs/adaptive.md):
#   adaptive    - VEGAS importance grids: pilot, refine, inverse-CDF map
#   stratified  - fixed-capacity stratum tables + per-stratum statistics
#   tree_search - priority-driven stratum refinement (dim 8-12 escalation)

from repro.core import adaptive, stratified, tree_search
from repro.core.adaptive import region_scores
from repro.core.integrand import (
    IntegrandFamily,
    MultiFunctionSpec,
    abs_sum_family,
    gaussian_analytic,
    gaussian_family,
    harmonic_analytic,
    harmonic_family,
)
from repro.core.direct_mc import (
    MCResult,
    SumsState,
    family_sums,
    finalize,
    merge_sums,
    sharded_family_sums,
)
from repro.core.functional import ZMCFunctional
from repro.core.multifunctions import MultiFunctionResult, ZMCMultiFunctions
from repro.core.normal import NormalResult, ZMCNormal

__all__ = [
    "IntegrandFamily",
    "MultiFunctionSpec",
    "MCResult",
    "SumsState",
    "MultiFunctionResult",
    "NormalResult",
    "ZMCFunctional",
    "ZMCMultiFunctions",
    "ZMCNormal",
    "abs_sum_family",
    "adaptive",
    "family_sums",
    "finalize",
    "gaussian_analytic",
    "gaussian_family",
    "harmonic_analytic",
    "harmonic_family",
    "merge_sums",
    "region_scores",
    "sharded_family_sums",
    "stratified",
    "tree_search",
]
