"""Stratified sampling primitives (the ``ZMCintegral_normal`` substrate).

The domain is partitioned into axis-aligned boxes ("strata"); each stratum
is estimated independently with a fixed sample budget and the estimates are
combined.  Stratification both reduces variance and exposes *where* the
integrand fluctuates — the per-stratum variance drives the heuristic tree
search in :mod:`repro.core.tree_search`, and the same ``vol * sqrt(var)``
scores seed the service's adaptive planner
(:func:`repro.core.adaptive.region_scores` grades how non-uniform an
integrand's mass is before committing to a VEGAS grid fit).  Exported
from ``repro.core`` alongside both.

All shapes are static (TPU requirement): a fixed-capacity stratum table with
an active mask replaces the original implementation's dynamically-growing
Python lists.
"""

from __future__ import annotations

import itertools
import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rng


class StratumTable(NamedTuple):
    """Fixed-capacity pool of strata plus per-stratum statistics."""
    boxes: jax.Array    # (cap, dim, 2)
    mean: jax.Array     # (cap,) per-stratum sample mean of f
    var: jax.Array      # (cap,) per-stratum population variance of f
    active: jax.Array   # (cap,) bool

    @property
    def capacity(self) -> int:
        return self.boxes.shape[0]

    @property
    def dim(self) -> int:
        return self.boxes.shape[1]


def initial_grid(domain, splits_per_dim: int, capacity: int) -> StratumTable:
    """Uniform grid of ``splits_per_dim**dim`` strata, padded to capacity."""
    domain = np.asarray(domain, np.float32)
    dim = domain.shape[0]
    n0 = splits_per_dim ** dim
    if n0 > capacity:
        raise ValueError(f"initial grid {n0} exceeds capacity {capacity}")
    edges = [np.linspace(domain[d, 0], domain[d, 1], splits_per_dim + 1)
             for d in range(dim)]
    boxes = np.zeros((capacity, dim, 2), np.float32)
    boxes[:, :, 1] = 1.0  # benign padding boxes
    for i, combo in enumerate(itertools.product(range(splits_per_dim), repeat=dim)):
        for d, c in enumerate(combo):
            boxes[i, d, 0] = edges[d][c]
            boxes[i, d, 1] = edges[d][c + 1]
    active = np.zeros((capacity,), bool)
    active[:n0] = True
    zeros = jnp.zeros((capacity,), jnp.float32)
    return StratumTable(boxes=jnp.asarray(boxes), mean=zeros, var=zeros,
                        active=jnp.asarray(active))


def stratum_volumes(table: StratumTable) -> jax.Array:
    widths = table.boxes[..., 1] - table.boxes[..., 0]
    return jnp.prod(widths, axis=-1)


def eval_strata(fn: Callable, boxes, slot_ids, epoch, n_per: int, key,
                use_kernel: bool = False):
    """Sample ``n_per`` points in each box and return (mean, var) per box.

    RNG counters: function-id slot carries ``slot + (epoch+1) * STRIDE`` so
    re-evaluating the same slot in a later refinement epoch draws fresh,
    reproducible numbers.  ``fn`` maps (..., dim) -> (...,).

    ``use_kernel`` routes the per-stratum moment reduction through the
    Pallas ``stratum_moments`` kernel (single HBM pass; requires n_per to
    be a 512-multiple).
    """
    from repro.distributed.sharding import constrain
    k0, k1 = key
    cap_stride = jnp.uint32(1 << 16)
    ids = jnp.asarray(slot_ids, jnp.uint32) + (jnp.uint32(epoch) + 1) * cap_stride
    sample_ids = jnp.arange(n_per, dtype=jnp.uint32)
    u = rng.uniforms_for(k0, k1, ids, sample_ids, boxes.shape[-2])
    # On a mesh, samples shard over the data/pod axes.  The stratum axis is
    # deliberately NOT sharded: it is tiny (k_split-scale) so there is no
    # parallelism to win, and constraining it over 'model' inside the
    # refinement fori_loop trips an XLA SPMD miscompile on the 0.4.x line
    # (model-sharded updates scattered into the stratum table produce wrong
    # sums on the host-platform multi-device backend; diagnosed via
    # tests/distributed/progs/prog_sharded_mc.py's ZMCNormal section).
    u = constrain(u, (None, "sample", None))
    lo = boxes[:, None, :, 0]
    hi = boxes[:, None, :, 1]
    x = lo + u * (hi - lo)
    vals = fn(x)
    vals = constrain(vals, (None, "sample"))
    if use_kernel:
        from repro.kernels.moments.ops import stratum_moments
        m = stratum_moments(vals)
        return m.mean, m.m2 / jnp.maximum(m.count, 1.0)
    mean = jnp.mean(vals, axis=-1)
    var = jnp.maximum(jnp.mean(jnp.square(vals), axis=-1) - jnp.square(mean), 0.0)
    return mean, var


def table_estimate(table: StratumTable, n_per: int):
    """(integral, stderr) from the current per-stratum statistics."""
    vol = stratum_volumes(table)
    act = table.active.astype(jnp.float32)
    total = jnp.sum(act * vol * table.mean)
    var = jnp.sum(act * jnp.square(vol) * table.var / float(n_per))
    return total, jnp.sqrt(var)


def suggested_capacity(dim: int, splits_per_dim: int, depth: int, k_split: int) -> int:
    return splits_per_dim ** dim + depth * k_split
