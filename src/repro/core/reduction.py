"""Numerically-careful accumulation helpers.

TPU has no fast float64, so long Monte-Carlo reductions accumulate in f32.
Raw serial summation of 10^9 samples would lose ~half the mantissa; we use

* chunked **pairwise** partial sums (XLA's reduce is already tree-shaped
  inside a chunk; chunks are combined pairwise by construction),
* optional **Kahan** compensated accumulation across chunks,
* **Welford/Chan** moment combination so that (count, mean, M2) triples from
  different devices / restarts merge exactly, which is what the checkpoint
  format stores.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Moments(NamedTuple):
    """Streaming first/second moments of a batch of estimators.

    Shapes: all (n_fn,) (or any common broadcast shape).
    ``m2`` is the sum of squared deviations (Welford's M2), *not* variance.
    """
    count: jax.Array
    mean: jax.Array
    m2: jax.Array

    @property
    def variance(self):
        return self.m2 / jnp.maximum(self.count - 1.0, 1.0)

    @property
    def stderr_of_mean(self):
        return jnp.sqrt(self.variance / jnp.maximum(self.count, 1.0))


def moments_zero(shape, dtype=jnp.float32) -> Moments:
    z = jnp.zeros(shape, dtype)
    return Moments(count=z, mean=z, m2=z)


def moments_from_sums(n, s1, s2) -> Moments:
    """Build Moments from raw (count, sum, sum-of-squares)."""
    n = jnp.asarray(n, s1.dtype)
    mean = s1 / jnp.maximum(n, 1.0)
    m2 = jnp.maximum(s2 - n * jnp.square(mean), 0.0)
    return Moments(count=n, mean=mean, m2=m2)


def moments_combine(a: Moments, b: Moments) -> Moments:
    """Chan et al. parallel combination — exact under permutation."""
    n = a.count + b.count
    safe_n = jnp.maximum(n, 1.0)
    delta = b.mean - a.mean
    mean = a.mean + delta * (b.count / safe_n)
    m2 = a.m2 + b.m2 + jnp.square(delta) * (a.count * b.count / safe_n)
    return Moments(count=n, mean=mean, m2=m2)


class KahanAcc(NamedTuple):
    total: jax.Array
    comp: jax.Array


def kahan_zero(shape, dtype=jnp.float32) -> KahanAcc:
    z = jnp.zeros(shape, dtype)
    return KahanAcc(total=z, comp=z)


def kahan_add(acc: KahanAcc, value) -> KahanAcc:
    """One compensated accumulation step (Kahan–Babuska)."""
    y = value - acc.comp
    t = acc.total + y
    comp = (t - acc.total) - y
    return KahanAcc(total=t, comp=comp)


def pairwise_sum(x, axis: int = -1):
    """Pairwise (tree) reduction along ``axis``.

    jnp.sum already lowers to a tree reduce on TPU; this exists for the
    oracle paths where we want a *defined* association order to compare the
    Pallas kernels against bit-for-bit at f32.
    """
    x = jnp.moveaxis(x, axis, -1)
    n = x.shape[-1]
    while n > 1:
        half = n // 2
        pairs = x[..., : 2 * half]
        s = pairs[..., 0::2] + pairs[..., 1::2]
        if n % 2:
            s = jnp.concatenate([s, x[..., -1:]], axis=-1)
        x = s
        n = x.shape[-1]
    return x[..., 0]
