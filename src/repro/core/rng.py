"""Counter-based random numbers for Monte-Carlo sampling.

ZMCintegral (the paper) uses Numba's per-thread stateful ``xoroshiro128+``
streams.  Stateful per-thread RNG does not survive the move to TPU SPMD:

* there is no per-thread scalar state inside a Pallas kernel,
* elastic restart / re-sharding would change which "thread" draws which
  sample, silently changing the estimate.

We therefore use a **counter-based** generator (Threefry-2x32, Salmon et al.
2011, the same family JAX's PRNG is built on): every scalar uniform is a pure
function ``u = T(key, counter)`` of a 64-bit key and a 64-bit counter.  The
counter encodes *which* sample this is — ``(function_id, dim, sample_index)``
— so the full sample stream is

* reproducible across restarts,
* independent of the mesh shape (elastic resharding draws identical numbers),
* computable *inside* a Pallas kernel with plain uint32 vector ops (no HBM
  traffic for random bits).

The identical algorithm is implemented three times and cross-checked by the
test-suite: here (pure jnp, the reference), in ``repro.kernels.mc_eval``
(Pallas), and implicitly via the oracle in ``repro.kernels.mc_eval.ref``.

Counter layout
--------------
``c0 = sample_index`` (uint32; up to 2**32 samples per function per key)
``c1 = function_id * DIM_STRIDE + dim_index`` (uint32)

``DIM_STRIDE = 256`` supports integrands of up to 256 dimensions and
``2**24 ≈ 1.6e7`` distinct functions per key — three orders of magnitude
beyond the paper's 10^4-integrand target.  Independent *trials* (the paper's
"10 independent evaluations") use distinct keys, derived by folding the trial
index into the key.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

# Up to 256 dims per integrand; function_id occupies the high 24 bits of c1.
DIM_STRIDE = 256

_KS_PARITY = np.uint32(0x1BD11BDA)
# Threefry-2x32 rotation schedule (two alternating groups of four rounds).
_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))

_U32 = jnp.uint32
_INV_2_24 = np.float32(1.0 / (1 << 24))


def _rotl32(x, r: int):
    r = np.uint32(r)
    return (x << r) | (x >> np.uint32(32 - r))


def threefry2x32(k0, k1, c0, c1):
    """Full 20-round Threefry-2x32 block cipher.

    All inputs are (broadcastable) uint32 arrays; returns the two uint32
    output words.  This is the standard Threefry-2x32 from Random123 —
    bit-exact with the version in ``repro.kernels.mc_eval.kernel`` (asserted
    by ``tests/kernels/test_rng_parity.py``).
    """
    k0 = jnp.asarray(k0, _U32)
    k1 = jnp.asarray(k1, _U32)
    x0 = jnp.asarray(c0, _U32) + k0
    x1 = jnp.asarray(c1, _U32) + k1
    ks = (k0, k1, k0 ^ k1 ^ _KS_PARITY)
    for group in range(5):
        rs = _ROTATIONS[group % 2]
        for r in rs:
            x0 = x0 + x1
            x1 = _rotl32(x1, r)
            x1 = x1 ^ x0
        x0 = x0 + ks[(group + 1) % 3]
        x1 = x1 + ks[(group + 2) % 3] + np.uint32(group + 1)
    return x0, x1


def random_bits(k0, k1, c0, c1):
    """First output word of the Threefry block — one uint32 per counter."""
    return threefry2x32(k0, k1, c0, c1)[0]


def bits_to_uniform(bits):
    """Map uint32 bits to float32 uniforms in [0, 1).

    Uses the top 24 bits so the result is exactly representable in f32 and
    the mapping matches what the Pallas kernel computes with the same ops.
    """
    return (bits >> np.uint32(8)).astype(jnp.float32) * _INV_2_24


def fold_key(seed: int, stream: int = 0) -> tuple[np.uint32, np.uint32]:
    """Derive a (k0, k1) key pair from a python seed and a stream index.

    Distinct streams (e.g. independent trials) get statistically independent
    sample sets because the key enters every Threefry block.
    """
    seed = int(seed)
    k0 = np.uint32(seed & 0xFFFFFFFF)
    k1 = np.uint32(((seed >> 32) & 0xFFFFFFFF) ^ (int(stream) & 0xFFFFFFFF))
    # One mixing round so that (seed=0, stream=0) and (seed=0, stream=1)
    # do not share a trivially-related key.
    m0, m1 = threefry2x32(k0, k1, np.uint32(0x9E3779B9), np.uint32(0x7F4A7C15))
    return np.uint32(m0), np.uint32(m1)


def counter_c1(fn_ids, dims):
    """c1 word for (function_id, dim) pairs. Shapes broadcast."""
    fn_ids = jnp.asarray(fn_ids, _U32)
    dims = jnp.asarray(dims, _U32)
    return fn_ids * np.uint32(DIM_STRIDE) + dims


def uniforms_for(k0, k1, fn_ids, sample_ids, n_dim: int):
    """Uniform samples for a (function, sample, dim) grid.

    Args:
      k0, k1: uint32 key words.
      fn_ids: (F,) int array of global function ids.
      sample_ids: (S,) uint32 array of global sample indices.
      n_dim: number of dimensions to draw.

    Returns:
      (F, S, n_dim) float32 array of uniforms in [0, 1).
    """
    fn_ids = jnp.asarray(fn_ids)
    sample_ids = jnp.asarray(sample_ids, _U32)
    d = jnp.arange(n_dim, dtype=_U32)
    shape = (fn_ids.shape[0], sample_ids.shape[0], n_dim)
    c1 = jnp.broadcast_to(counter_c1(fn_ids[:, None, None], d[None, None, :]), shape)
    c0 = jnp.broadcast_to(sample_ids[None, :, None], shape)
    bits = random_bits(k0, k1, c0, c1)
    return bits_to_uniform(bits)
