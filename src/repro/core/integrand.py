"""Integrand specification: function *families*.

ZMCintegral-v5.1 accepts ~10^4 arbitrary Python callables and JIT-compiles
each with Numba.  XLA cannot compile 10^4 separate kernels cheaply, and it
does not need to: the paper's own use-cases (harmonic bases, collision
integrals per energy beam / Feynman graph) are *parameterised families* —
one code shape, many parameter vectors.  We make that structure explicit:

* an :class:`IntegrandFamily` is one traced JAX function plus a stacked
  parameter pytree (leading axis = function index), a per-function domain
  box and an optional per-function active-dimension count;
* a :class:`MultiFunctionSpec` is an ordered list of families — this is the
  unit the multi-function solver consumes.  Families may have different
  dimensionality, different code and different domains, exactly matching the
  paper's Eq. (2) example (|x1+x2| for n<50, |x1+x2-x3| for n>=50).

Truly heterogeneous one-off callables are still expressible: a family of
size 1 per callable (the engine batches *across* families only at the
scheduling level, so this degrades gracefully rather than failing).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import domains as domains_lib


Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class IntegrandFamily:
    """A batch of integrands sharing one functional form.

    Attributes:
      fn: ``fn(x, params) -> value``; ``x`` has shape (..., dim) and params
        is a *single* function's parameter pytree (the engine vmaps over the
        leading function axis of :attr:`params`).  Must be pure JAX.
      params: pytree whose leaves all have leading axis ``n_fn``.
      domains: (n_fn, dim, 2) float array of [lo, hi] boxes.  May contain
        +-inf; the engine compactifies before sampling.
      name: label used in reports and benchmarks.
      kernel: optional registered Pallas fast-path name (see
        ``repro.kernels.registry``).  ``None`` -> pure-JAX evaluation.
      compact: set by :meth:`compactified` — ``params`` is the
        ``{"inner": user params, "aux": {"kind", "shift"}}`` wrapper
        around an infinite-domain integrand, and kernel dispatch must
        apply the transform stage (``repro.kernels.template``).
      swept: set by :meth:`swept_over` — the sorted parameter names a
        sweep table overrides.  ``params`` (or ``params["inner"]`` once
        compactified) is the ``{"base": template params, "table": {name:
        per-point values}}`` wrapper; each function row is one grid
        point, and kernel dispatch substitutes the table columns into
        the packed template row in-kernel
        (``repro.kernels.template.swept_body``).
      adapt_bins: set by :meth:`adapted` — bins per axis of the VEGAS
        importance grid (0 = unadapted).  ``params`` is the ``{"inner":
        wrapped params, "grid": (n_fn, dim, n_bins + 1) edges}`` wrapper,
        the domain box is the unit cube, and kernel dispatch applies the
        inverse-CDF map stage (``repro.kernels.template.adapted_body``).
    """

    fn: Callable[[Array, Any], Array]
    params: Any
    domains: Array
    name: str = "family"
    kernel: str | None = None
    compact: bool = False
    swept: tuple[str, ...] = ()
    adapt_bins: int = 0

    # -- pytree plumbing (fn/name/kernel/compact/swept/adapt_bins are static)
    def tree_flatten(self):
        return ((self.params, self.domains),
                (self.fn, self.name, self.kernel, self.compact, self.swept,
                 self.adapt_bins))

    @classmethod
    def tree_unflatten(cls, aux, children):
        fn, name, kernel, compact, swept, adapt_bins = aux
        params, domains = children
        return cls(fn=fn, params=params, domains=domains, name=name,
                   kernel=kernel, compact=compact, swept=swept,
                   adapt_bins=adapt_bins)

    # -- derived sizes --------------------------------------------------------
    @property
    def n_fn(self) -> int:
        return int(self.domains.shape[0])

    @property
    def dim(self) -> int:
        return int(self.domains.shape[1])

    def validate(self) -> "IntegrandFamily":
        d = np.asarray(self.domains)
        if d.ndim != 3 or d.shape[-1] != 2:
            raise ValueError(f"domains must be (n_fn, dim, 2); got {d.shape}")
        leaves = jax.tree_util.tree_leaves(self.params)
        for leaf in leaves:
            if np.shape(leaf)[:1] != (d.shape[0],):
                raise ValueError(
                    f"every params leaf needs leading axis n_fn={d.shape[0]}; "
                    f"got leaf of shape {np.shape(leaf)}")
        finite = np.isfinite(d)
        lo_le_hi = np.where(finite.all(-1), d[..., 0] <= d[..., 1], True)
        if not np.all(lo_le_hi):
            raise ValueError("domain boxes must satisfy lo <= hi")
        return self

    def compactified(self) -> "IntegrandFamily":
        """Return an equivalent family whose domain box is finite.

        The result keeps :attr:`kernel`: registered forms evaluate
        compactified families on the fused Pallas path (the static
        transform params pack into kernel parameter columns and an
        in-kernel wrapper stage applies them — see
        ``repro.kernels.template.compactified_body``).  Forms that opt
        out via ``supports_compactified=False`` fall back to the chunked
        path at dispatch time, exactly like any other capability miss.
        """
        if domains_lib.is_finite_box(self.domains):
            return self
        fn2, new_domains, aux = domains_lib.compactify(self.fn, self.domains)
        return IntegrandFamily(
            fn=fn2,
            params={"inner": self.params, "aux": aux},
            domains=new_domains,
            name=self.name + ":compactified",
            kernel=self.kernel,
            compact=True,
            swept=self.swept,
        )

    def inner(self) -> "IntegrandFamily":
        """The pre-transform parameter view of a compactified family.

        Kernel param packers (``KernelForm.pack_params``) consume this:
        same shapes and finite box, but ``params`` is the original user
        pytree rather than the ``{"inner", "aux"}`` wrapper.  Identity
        for non-compact families; unwraps the importance-grid stage
        first on adapted ones.
        """
        if self.adapt_bins:
            return self.adapt_inner().inner()
        if not self.compact:
            return self
        return IntegrandFamily(fn=self.fn, params=self.params["inner"],
                               domains=self.domains, name=self.name,
                               kernel=self.kernel, swept=self.swept)

    def adapted(self, edges, *, epoch: int = 1) -> "IntegrandFamily":
        """Wrap this finite-box family with a VEGAS importance grid.

        Args:
          edges: (n_fn, dim, n_bins + 1) per-axis bin edges, strictly
            increasing and spanning this family's box
            (:func:`repro.core.adaptive.refine_edges` output).
          epoch: grid-epoch label (cosmetic: it suffixes :attr:`name`;
            the service keys epoch streams by content hash, which the
            edge values already make distinct).

        Returns a family whose domain is the unit cube: uniforms map
        through the grid's inverse CDF with the bin-width Jacobian
        folded into the value (``repro.core.adaptive.apply_map``), so
        its plain MC estimate is an unbiased importance-sampled estimate
        of the same integral, at the variance the grid earns.  Keeps
        :attr:`kernel`: registered forms evaluate adapted families on
        the fused Pallas path through the ``adapted_body`` wrapper
        stage.  Refits never nest: refine from :meth:`adapt_inner`.
        """
        if self.adapt_bins:
            raise ValueError("family is already adapted — refit from "
                             "adapt_inner(), grids never nest")
        if not domains_lib.is_finite_box(self.domains):
            raise ValueError("importance grids need a finite box — "
                             "compactify before adapting")
        edges = jnp.asarray(edges, jnp.float32)
        if edges.ndim != 3 or edges.shape[:2] != (self.n_fn, self.dim):
            raise ValueError(
                f"edges must be (n_fn={self.n_fn}, dim={self.dim}, "
                f"n_bins + 1); got {edges.shape}")
        n_bins = int(edges.shape[-1]) - 1
        if n_bins < 1:
            raise ValueError("importance grids need at least one bin")
        from repro.core import adaptive as adaptive_lib
        inner_fn = self.fn

        def fn(u, p):
            x, jac = adaptive_lib.apply_map(u, p["grid"])
            return inner_fn(x, p["inner"]) * jac

        unit = jnp.broadcast_to(
            jnp.asarray([0.0, 1.0], jnp.float32),
            (self.n_fn, self.dim, 2))
        return IntegrandFamily(
            fn=fn,
            params={"inner": self.params, "grid": edges},
            domains=unit,
            name=f"{self.name}:adapted[e{int(epoch)}]",
            kernel=self.kernel,
            compact=self.compact,
            swept=self.swept,
            adapt_bins=n_bins,
        )

    def adapt_inner(self) -> "IntegrandFamily":
        """The pre-grid view of an adapted family.

        Same shapes, ``params`` without the ``{"inner", "grid"}``
        wrapper, and the original finite box recovered from the grid's
        outermost edges (the grid spans it by construction).  Kernel
        param packers and refits consume this.  Identity for unadapted
        families.  ``fn`` is kept as-is (the packers only read params;
        to *evaluate* the pre-grid integrand use the base family the
        grid was fit from).
        """
        if not self.adapt_bins:
            return self
        edges = self.params["grid"]
        box = jnp.stack([edges[..., 0], edges[..., -1]], axis=-1)
        return IntegrandFamily(fn=self.fn, params=self.params["inner"],
                               domains=box, name=self.name,
                               kernel=self.kernel, compact=self.compact,
                               swept=self.swept)

    def swept_over(self, table: dict) -> "IntegrandFamily":
        """Sweep this single-function template over a parameter table.

        Args:
          table: mapping from parameter name (a top-level key of
            :attr:`params`) to its per-point values — shape
            ``(n_points,) + base_leaf.shape[1:]`` (the leading axis
            replaces the template's function axis).
        Returns:
          A family with ``n_fn == n_points``: function row ``j`` is the
          template with the named parameters overridden by
          ``table[name][j]``.  The swept family evaluates on the chunked
          path by merging the table into the base params, and on the
          fused Pallas path by substituting table columns into the
          packed template row in-kernel — bit-identically, since the
          sample counters depend only on (global fn id, sample id).

        Sweep before :meth:`compactified`: the canonicalizer composes
        the two stages as ``compactify(sweep(template))``.
        """
        if self.compact or self.adapt_bins:
            raise ValueError("sweep the template before compactifying or "
                             "adapting (canonicalization composes the "
                             "stages)")
        if self.n_fn != 1:
            raise ValueError(
                f"sweep template must be a single function (n_fn == 1); "
                f"got n_fn={self.n_fn}")
        if not isinstance(self.params, dict):
            raise ValueError("sweep templates need dict params (the table "
                             "overrides parameters by name)")
        if not table:
            raise ValueError("sweep table must name at least one parameter")
        names = tuple(sorted(table))
        missing = [n for n in names if n not in self.params]
        if missing:
            raise ValueError(
                f"sweep table names {missing} not in template params "
                f"{sorted(self.params)}")
        cols = {n: jnp.asarray(np.asarray(table[n], np.float32))
                for n in names}
        n_points = {int(v.shape[0]) for v in cols.values()}
        if len(n_points) != 1:
            raise ValueError(
                f"sweep table axes disagree on n_points: { {n: int(v.shape[0]) for n, v in cols.items()} }")
        (n_pts,) = n_points
        for n in names:
            base_leaf = np.asarray(self.params[n])
            if cols[n].shape[1:] != base_leaf.shape[1:]:
                raise ValueError(
                    f"sweep axis {n!r} has per-point shape "
                    f"{cols[n].shape[1:]}, template expects "
                    f"{base_leaf.shape[1:]}")
        base = jax.tree_util.tree_map(
            lambda leaf: jnp.broadcast_to(
                jnp.asarray(leaf), (n_pts,) + np.shape(leaf)[1:]),
            self.params)
        domains = jnp.broadcast_to(jnp.asarray(self.domains),
                                   (n_pts,) + self.domains.shape[1:])

        base_fn = self.fn

        def fn(x, p):
            return base_fn(x, {**p["base"], **p["table"]})

        return IntegrandFamily(
            fn=fn,
            params={"base": base, "table": cols},
            domains=domains,
            name=f"{self.name}:sweep[{n_pts}]",
            kernel=self.kernel,
            swept=names,
        ).validate()

    def sweep_base(self) -> "IntegrandFamily":
        """The template-parameter view of a swept family.

        Kernel param packers consume this: ``params`` is the broadcast
        base pytree (every row the template point), without the
        ``{"base", "table"}`` wrapper.  Call on the :meth:`inner` view
        of a compactified swept family.  Identity for non-swept ones.
        """
        if not self.swept:
            return self
        if self.compact:
            raise ValueError("call sweep_base() on the inner() view of a "
                             "compactified swept family")
        return IntegrandFamily(fn=self.fn, params=self.params["base"],
                               domains=self.domains, name=self.name,
                               kernel=self.kernel)

    def eval_batch(self, x: Array) -> Array:
        """Evaluate all functions on their own sample blocks.

        Args:
          x: (n_fn, B, dim) sample points (already inside each box).
        Returns:
          (n_fn, B) float values.
        """
        return jax.vmap(lambda p, xi: self.fn(xi, p))(self.params, x)


@dataclasses.dataclass(frozen=True)
class MultiFunctionSpec:
    """An ordered collection of integrand families (the v5.1 workload)."""

    families: tuple[IntegrandFamily, ...]

    @classmethod
    def from_families(cls, families: Sequence[IntegrandFamily]) -> "MultiFunctionSpec":
        fams = tuple(f.validate() for f in families)
        if not fams:
            raise ValueError("need at least one family")
        return cls(families=fams)

    @property
    def n_fn_total(self) -> int:
        return sum(f.n_fn for f in self.families)

    def offsets(self) -> list[int]:
        """Global function-id offset of each family (for RNG counters)."""
        out, acc = [], 0
        for f in self.families:
            out.append(acc)
            acc += f.n_fn
        return out


# ---------------------------------------------------------------------------
# Stock families used across tests, examples and benchmarks.
# ---------------------------------------------------------------------------

def harmonic_family(n: int, dim: int = 4, *, a=None, b=None, k=None,
                    lo: float = 0.0, hi: float = 1.0) -> IntegrandFamily:
    """The paper's Fig.-1 family: f_n(x) = a_n cos(k_n.x) + b_n sin(k_n.x).

    Defaults reproduce the paper exactly: a_n = b_n = 1,
    k_n = ((n+50)/(2*pi)) * (1,...,1), domain [0,1]^dim, n = 1..n.
    """
    idx = np.arange(1, n + 1, dtype=np.float32)
    if a is None:
        a = np.ones(n, np.float32)
    if b is None:
        b = np.ones(n, np.float32)
    if k is None:
        k = np.repeat(((idx + 50.0) / (2.0 * np.pi))[:, None], dim, axis=1)
    dom = np.broadcast_to(
        np.asarray([lo, hi], np.float32), (n, dim, 2)).copy()

    def fn(x, p):
        phase = jnp.sum(x * p["k"], axis=-1)
        return p["a"] * jnp.cos(phase) + p["b"] * jnp.sin(phase)

    return IntegrandFamily(
        fn=fn,
        params={"a": jnp.asarray(a), "b": jnp.asarray(b), "k": jnp.asarray(k)},
        domains=jnp.asarray(dom),
        name=f"harmonic[{n}x{dim}d]",
        kernel="mc_eval_harmonic",
    ).validate()


def harmonic_analytic(n: int, dim: int = 4) -> np.ndarray:
    """Closed form of the paper's Fig.-1 integrals over [0,1]^dim.

    With c = (n+50)/(2*pi) and k = c*(1,..,1):
      Int cos(k.x) dx = Re[e^{i c d/2}] * sinc-term,  etc.
    Specifically Int_{[0,1]^d} e^{i c sum(x)} dx = (e^{ic}-1)^d/(ic)^d
    = e^{i c d/2} (sin(c/2)/(c/2))^d, so
      F_n = [cos(c d/2) + sin(c d/2)] * (sin(c/2)/(c/2))^d.
    """
    idx = np.arange(1, n + 1, dtype=np.float64)
    c = (idx + 50.0) / (2.0 * np.pi)
    s = (np.sin(c / 2.0) / (c / 2.0)) ** dim
    return (np.cos(c * dim / 2.0) + np.sin(c * dim / 2.0)) * s


def abs_sum_family(n: int, dim: int, coeff, *, sign_last: float = 1.0,
                   lo: float = 0.0, hi: float = 1.0) -> IntegrandFamily:
    """The paper's Eq.-(2) family: g_n(x) = c_n * |x_1 + x_2 (+/-) x_3 ...|."""
    coeff = np.asarray(coeff, np.float32).reshape(n)
    dom = np.broadcast_to(np.asarray([lo, hi], np.float32), (n, dim, 2)).copy()
    signs = np.ones(dim, np.float32)
    signs[-1] = sign_last
    # signs ride along as per-function params so the registered kernel form
    # can pack them (the eval body sees params only, never the closure)
    signs_p = np.broadcast_to(signs, (n, dim)).copy()

    def fn(x, p):
        return p["c"] * jnp.abs(jnp.sum(x * p["s"], axis=-1))

    return IntegrandFamily(
        fn=fn,
        params={"c": jnp.asarray(coeff), "s": jnp.asarray(signs_p)},
        domains=jnp.asarray(dom),
        name=f"abs_sum[{n}x{dim}d]",
        kernel="mc_eval_abs_sum",
    ).validate()


def gaussian_analytic(n: int, dim: int, *, sigma=None,
                      half: bool = False) -> np.ndarray:
    """Closed form of :func:`gaussian_family` over R^dim:
    ``(sigma sqrt(2 pi))^dim`` — or over the positive orthant
    ``[0, inf)^dim`` with ``half=True`` (one factor of 2 per axis).
    Defaults mirror :func:`gaussian_family`'s sigma grid."""
    if sigma is None:
        sigma = np.linspace(0.5, 2.0, n)
    full = (np.asarray(sigma, np.float64) * np.sqrt(2.0 * np.pi)) ** dim
    return full / (2.0 ** dim) if half else full


def gaussian_family(n: int, dim: int, *, sigma=None, lo=-4.0, hi=4.0) -> IntegrandFamily:
    """Product Gaussians; analytic value erf-expressible. Used in tests."""
    if sigma is None:
        sigma = np.linspace(0.5, 2.0, n).astype(np.float32)
    sigma = np.asarray(sigma, np.float32).reshape(n)
    dom = np.broadcast_to(np.asarray([lo, hi], np.float32), (n, dim, 2)).copy()

    def fn(x, p):
        return jnp.exp(-0.5 * jnp.sum(jnp.square(x), axis=-1) / jnp.square(p["sigma"]))

    return IntegrandFamily(
        fn=fn,
        params={"sigma": jnp.asarray(sigma)},
        domains=jnp.asarray(dom),
        name=f"gaussian[{n}x{dim}d]",
        kernel="mc_eval_gaussian",
    ).validate()
