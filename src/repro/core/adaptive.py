"""VEGAS importance grids: the adaptive variance-reduction substrate.

The service's wave planner (``repro.service.engine``) drives fixed-round
waves; on peaked integrands the frontier is *samples needed*, not
launches.  This module supplies the classic remedy (Lepage's VEGAS,
adapted for batch evaluation a la Kanzaki arXiv:1010.2107): a separable
per-axis importance grid whose inverse-CDF map concentrates samples
where the pilot found variance, with the Jacobian folded into the
integrand value.

Everything here is deterministic and counter-addressed so adapted
streams keep the service's bit-identical-resume contract:

* :func:`initial_edges` — the uniform (identity-map) grid over a finite
  box;
* :func:`pilot_weights` — per-(function, axis, bin) importance from a
  pure counter-based pilot wave (``repro.core.rng``): same key, same
  weights, on any backend, after any restart;
* :func:`refine_edges` — the classic smoothed/damped equal-importance
  redistribution, pure numpy, no RNG;
* :func:`apply_map` — the piecewise-linear inverse-CDF map ``u -> (x,
  jacobian)`` the chunked path evaluates; the fused Pallas path applies
  the *same* arithmetic in-kernel via
  ``repro.kernels.template.adapted_body`` reading the packed edge
  columns.

The per-*region* seed heuristics live next door: a coarse
:mod:`repro.core.stratified` scan (:func:`region_scores`) grades how
non-uniform an integrand's mass is before the planner commits to a grid
fit, and :mod:`repro.core.tree_search` escalates to full region
refinement for the hardest (dim 8-12) cases.  Both are exported from
``repro.core`` alongside this module.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rng
from repro.core import stratified

# Default bins per axis.  16 keeps the packed edge columns small
# (dim * 17 extra f32 columns per function row) while giving the
# canonical peaked workloads (Genz corner-peak, narrow Gaussians) an
# order of magnitude of variance reduction.
N_BINS = 16

# Damping exponent of the refinement step (Lepage's alpha): 0 freezes
# the grid, large values chase the pilot histogram aggressively.
ALPHA = 1.5

# Every old bin retains at least this fraction of the mean per-bin
# importance during redistribution, so pilot-empty bins can never
# collapse a new bin to zero width (the map must stay bijective and the
# in-kernel Jacobian nonzero).
_MIN_IMPORTANCE = 1e-3


def initial_edges(domains, n_bins: int = N_BINS) -> np.ndarray:
    """Uniform per-axis bin edges over a finite box.

    Args:
      domains: (n_fn, dim, 2) finite [lo, hi] boxes.
    Returns:
      float32 (n_fn, dim, n_bins + 1) edges; the induced map is affine,
      so an un-refined grid reproduces plain uniform sampling.
    """
    domains = np.asarray(domains, np.float64)
    if not np.all(np.isfinite(domains)):
        raise ValueError("importance grids need a finite box — "
                         "compactify the family first")
    lo = domains[..., :1]
    hi = domains[..., 1:]
    t = np.linspace(0.0, 1.0, int(n_bins) + 1)
    return (lo + t * (hi - lo)).astype(np.float32)


def apply_map(u, edges):
    """Piecewise-linear inverse-CDF map through an importance grid.

    Args:
      u: (..., dim) uniforms in [0, 1).
      edges: (dim, n_bins + 1) per-axis bin edges (strictly increasing).
        Leading batch axes broadcast against ``u``.
    Returns:
      ``(x, jac)``: mapped points of ``u``'s shape and the per-point
      Jacobian ``prod_d n_bins * width(selected bin)`` (the density the
      integrand value must be multiplied by so the estimate is unbiased).

    The same arithmetic — bin select, linear interpolation, bin-width
    product — runs in-kernel as ``template.adapted_body``; the two paths
    agree bit for bit, which the resume/digest tests rely on.
    """
    edges = jnp.asarray(edges, jnp.float32)
    n_bins = edges.shape[-1] - 1
    s = u * float(n_bins)
    idx = jnp.minimum(s.astype(jnp.int32), n_bins - 1)
    frac = s - idx.astype(jnp.float32)
    e = jnp.broadcast_to(edges, u.shape + (n_bins + 1,))
    e0 = jnp.take_along_axis(e, idx[..., None], axis=-1)[..., 0]
    e1 = jnp.take_along_axis(e, idx[..., None] + 1, axis=-1)[..., 0]
    x = e0 + frac * (e1 - e0)
    jac = jnp.prod((e1 - e0) * float(n_bins), axis=-1)
    return x, jac


def pilot_weights(family, edges, key, n_samples: int) -> np.ndarray:
    """Per-(function, axis, bin) importance from one deterministic pilot.

    Draws ``n_samples`` counter-addressed uniforms per function
    (:func:`repro.core.rng.uniforms_for` under ``key = (k0, k1)``), maps
    them through the *current* grid, and bins the squared weighted
    integrand ``(f(x) * jac)^2`` by grid cell — the classic VEGAS
    importance accumulator.  Pure: same (family, edges, key) -> same
    weights, so a crashed-and-resumed planner refits the identical grid.

    Args:
      family: a finite-box :class:`~repro.core.integrand.IntegrandFamily`
        (the *base* stream — never an already-adapted view).
      edges: float32 (n_fn, dim, n_bins + 1) current grid.
    Returns:
      float64 (n_fn, dim, n_bins) nonnegative weights.
    """
    k0, k1 = key
    edges = jnp.asarray(edges, jnp.float32)
    n_bins = int(edges.shape[-1]) - 1
    fn_ids = jnp.arange(family.n_fn)
    sample_ids = jnp.arange(int(n_samples), dtype=jnp.uint32)
    u = rng.uniforms_for(k0, k1, fn_ids, sample_ids, family.dim)
    x, jac = jax.vmap(apply_map)(u, edges)          # per-function grids
    f = family.eval_batch(x)
    d2 = jnp.square(f * jac)                        # (n_fn, S)
    idx = jnp.minimum((u * float(n_bins)).astype(jnp.int32), n_bins - 1)
    onehot = jax.nn.one_hot(idx, n_bins, dtype=jnp.float32)
    w = jnp.einsum("fs,fsdb->fdb", d2, onehot)
    return np.asarray(w, np.float64)


def refine_edges(edges, weights, *, alpha: float = ALPHA) -> np.ndarray:
    """One VEGAS refinement: redistribute edges toward equal importance.

    Per (function, axis): smooth the binned weights with the standard
    (1, 6, 1)/8 stencil, damp with Lepage's ``((w - 1) / ln w)^alpha``
    compression, then walk the old bins placing new edges at equal
    cumulative importance.  Pure numpy, deterministic, and total: axes
    whose pilot weights are degenerate (all-zero or non-finite) keep
    their current edges.

    Returns float32 edges of the input shape, strictly increasing per
    axis (``_MIN_IMPORTANCE`` floors empty bins so no width collapses).
    """
    edges = np.asarray(edges, np.float64)
    weights = np.asarray(weights, np.float64)
    if weights.shape[:-1] != edges.shape[:-1] or \
            weights.shape[-1] != edges.shape[-1] - 1:
        raise ValueError(f"weights {weights.shape} do not match edges "
                         f"{edges.shape}")
    out = np.array(edges, copy=True)
    n_fn, dim = edges.shape[0], edges.shape[1]
    for f in range(n_fn):
        for d in range(dim):
            out[f, d] = _refine_axis(edges[f, d], weights[f, d], alpha)
    return out.astype(np.float32)


def _refine_axis(e, w, alpha: float) -> np.ndarray:
    n_bins = w.shape[0]
    if not np.all(np.isfinite(w)) or w.sum() <= 0.0 or n_bins < 2:
        return e
    s = np.empty_like(w)
    s[0] = (7.0 * w[0] + w[1]) / 8.0
    s[-1] = (w[-2] + 7.0 * w[-1]) / 8.0
    if n_bins > 2:
        s[1:-1] = (w[:-2] + 6.0 * w[1:-1] + w[2:]) / 8.0
    s = s / s.sum()
    # Lepage compression: r -> ((s - 1)/ln s)^alpha in (0, 1), monotone
    # in s; the limit at s -> 1 is 1.
    with np.errstate(divide="ignore", invalid="ignore"):
        r = np.where(s > 0.0, ((s - 1.0) / np.log(s)) ** alpha, 0.0)
    r = np.where(np.abs(s - 1.0) < 1e-12, 1.0, r)
    r = np.maximum(r, r.sum() * _MIN_IMPORTANCE / n_bins)
    per = r.sum() / n_bins
    new = np.array(e, copy=True)
    j = 0
    acc = 0.0
    for i in range(1, n_bins):
        target = per * i
        while j < n_bins - 1 and acc + r[j] < target:
            acc += r[j]
            j += 1
        frac = (target - acc) / r[j]
        new[i] = e[j] + frac * (e[j + 1] - e[j])
    return new


def region_scores(fn, domain, key, *, splits_per_dim: int = 2,
                  n_per: int = 256):
    """Coarse per-region variance scan (the stratified seed heuristic).

    Grades how non-separably peaked one integrand is before the planner
    commits to an axis-factorized grid: a uniform stratified scan
    (:func:`repro.core.stratified.initial_grid` /
    :func:`~repro.core.stratified.eval_strata`) whose per-stratum
    ``volume * sqrt(variance)`` scores are the same priorities
    :func:`repro.core.tree_search.refine` splits on — the escalation
    path when a separable grid cannot help.

    Args:
      fn: one integrand, (..., dim) -> (...).
      domain: (dim, 2) finite box.
      key: (k0, k1) counter key pair.
    Returns:
      ``(boxes, scores)``: the (n_strata, dim, 2) stratum boxes and
      their float32 priority scores.
    """
    domain = np.asarray(domain, np.float32)
    n_strata = int(splits_per_dim) ** domain.shape[0]
    table = stratified.initial_grid(domain, int(splits_per_dim), n_strata)
    slots = jnp.arange(n_strata, dtype=jnp.uint32)
    _, var = stratified.eval_strata(fn, table.boxes, slots, 0, int(n_per),
                                    key)
    vol = stratified.stratum_volumes(table)
    return np.asarray(table.boxes), np.asarray(vol * jnp.sqrt(var))
