"""Direct Monte-Carlo engine (the v5/v5.1 sampling core).

The paper's ``ZMCintegral_functional`` / ``ZMCintegral_multifunctions``
classes both reduce to the same computation: for every integrand ``i`` draw
``N`` uniforms in its box and form

    mean_i   = vol_i / N * sum_s f_i(x_s)
    stderr_i = vol_i * sqrt( (E[f^2] - E[f]^2) / N )

This module provides that computation three ways:

* :func:`family_sums` — single-device, chunked over samples (and optionally
  over functions) so arbitrarily large (n_fn, N) fit in memory;
* :func:`family_sums` with ``kernel=...`` — the Pallas fused fast path for
  registered families (sampling + eval + block reduction in VMEM);
* :func:`sharded_family_sums` — the multi-chip path: functions shard over
  the ``model`` mesh axis, samples over ``data`` (and ``pod``); a single
  ``psum`` of the (s1, s2) partials over the sample axes finalises the
  estimate.  Communication is O(n_fn), independent of N — this is the
  compile-time form of the paper's "linear scaling with GPUs" claim.

Counters are global: sample ``s`` of function ``i`` uses the same Threefry
counter no matter how the work is split, so every path (single device,
sharded, kernel, restarted-from-checkpoint) computes *identical* sums up to
f32 association order.
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import rng
from repro.core.domains import affine_from_unit, box_volume
from repro.core.integrand import IntegrandFamily


class SumsState(NamedTuple):
    """Raw accumulators; mergeable across chunks/devices/restarts."""
    s1: jax.Array      # (n_fn,) sum of f
    s2: jax.Array      # (n_fn,) sum of f^2
    n: jax.Array       # scalar or (n_fn,): samples accumulated


class MCResult(NamedTuple):
    mean: jax.Array    # (n_fn,) integral estimates
    stderr: jax.Array  # (n_fn,) standard error of the estimate
    n: jax.Array       # samples per function


def _eval_chunk(family: IntegrandFamily, k0, k1, fn_ids, sample_ids, valid,
                sampler: str = "mc"):
    """Evaluate one (n_fn, chunk) block of samples. Returns (s1, s2) sums."""
    if sampler == "sobol":
        from repro.core import sobol
        u = sobol.sobol_uniforms_for(k0, k1, fn_ids, sample_ids, family.dim)
    else:
        u = rng.uniforms_for(k0, k1, fn_ids, sample_ids, family.dim)
    x = affine_from_unit(u, family.domains[:, None, :, :])
    vals = family.eval_batch(x)
    vals = jnp.where(valid[None, :], vals, 0.0)
    return jnp.sum(vals, axis=-1), jnp.sum(jnp.square(vals), axis=-1)


def family_sums(
    family: IntegrandFamily,
    n_samples: int,
    key: tuple,
    *,
    fn_offset: int = 0,
    sample_offset: int = 0,
    chunk: int = 8192,
    fn_chunk: int | None = None,
    use_kernel: bool = False,
    sampler: str = "mc",
) -> SumsState:
    """Chunked (s1, s2) sums for every function in the family.

    Args:
      n_samples: samples per function contributed by *this* call.
      key: (k0, k1) uint32 Threefry key words.
      fn_offset: global id of this family's function 0 (multi-family specs).
      sample_offset: global index of the first sample (sharding / resume).
      chunk: samples per inner step; bounds peak memory at
        n_fn * chunk * dim floats.
      fn_chunk: optional function-axis blocking for >=10^4-integrand specs.
      use_kernel: dispatch to the registered Pallas fast path if the family
        declares one (``family.kernel``) *and* the registered form supports
        (dim, sampler); anything else falls back to the chunked path here.
        Whole-spec fusion (one launch per dim bucket) lives one level up,
        in ``ZMCMultiFunctions`` via ``repro.kernels.mc_eval.multi``.
    """
    n_fn = family.n_fn
    if fn_chunk is not None and fn_chunk < n_fn:
        return _fn_blocked_sums(family, n_samples, key, fn_offset=fn_offset,
                                sample_offset=sample_offset, chunk=chunk,
                                fn_chunk=fn_chunk)

    fn_ids = jnp.uint32(fn_offset) + jnp.arange(n_fn, dtype=jnp.uint32)
    return _sums_with_ids(family, n_samples, key, fn_ids,
                          jnp.uint32(sample_offset), chunk, use_kernel,
                          sampler=sampler)


def _fn_blocked_sums(family, n_samples, key, *, fn_offset, sample_offset,
                     chunk, fn_chunk) -> SumsState:
    """lax.map over function blocks to bound memory for huge n_fn."""
    n_fn = family.n_fn
    n_blocks = math.ceil(n_fn / fn_chunk)
    pad = n_blocks * fn_chunk - n_fn

    def pad_leaf(leaf):
        cfg = [(0, pad)] + [(0, 0)] * (leaf.ndim - 1)
        return jnp.pad(leaf, cfg)

    params = jax.tree.map(pad_leaf, family.params)
    domains = pad_leaf(family.domains)
    # padded rows get [0,1] boxes so volumes stay finite; results are sliced off
    if pad:
        domains = domains.at[n_fn:, :, 0].set(0.0).at[n_fn:, :, 1].set(1.0)

    def block(idx):
        sl = lambda leaf: jax.lax.dynamic_slice_in_dim(leaf, idx * fn_chunk, fn_chunk)
        fam = IntegrandFamily(fn=family.fn, params=jax.tree.map(sl, params),
                              domains=sl(domains), name=family.name,
                              compact=family.compact)
        out = family_sums(fam, n_samples, key,
                          fn_offset=fn_offset + idx * fn_chunk,
                          sample_offset=sample_offset, chunk=chunk)
        return out.s1, out.s2

    s1b, s2b = jax.lax.map(block, jnp.arange(n_blocks))
    s1 = s1b.reshape(-1)[:n_fn]
    s2 = s2b.reshape(-1)[:n_fn]
    return SumsState(s1=s1, s2=s2, n=jnp.float32(n_samples))


def finalize(family: IntegrandFamily, sums: SumsState) -> MCResult:
    """Turn raw sums into (mean, stderr) integral estimates."""
    vol = box_volume(family.domains)
    n = jnp.maximum(sums.n, 1.0)
    mean_f = sums.s1 / n
    var_f = jnp.maximum(sums.s2 / n - jnp.square(mean_f), 0.0)
    return MCResult(mean=vol * mean_f,
                    stderr=vol * jnp.sqrt(var_f / n),
                    n=sums.n)


def merge_sums(a: SumsState, b: SumsState) -> SumsState:
    return SumsState(s1=a.s1 + b.s1, s2=a.s2 + b.s2, n=a.n + b.n)


# ---------------------------------------------------------------------------
# Sharded path
# ---------------------------------------------------------------------------

def _pad_family_to(family: IntegrandFamily, n_fn_padded: int) -> IntegrandFamily:
    pad = n_fn_padded - family.n_fn
    if pad == 0:
        return family

    def pad_leaf(leaf):
        cfg = [(0, pad)] + [(0, 0)] * (leaf.ndim - 1)
        return jnp.pad(leaf, cfg)

    domains = pad_leaf(family.domains)
    domains = domains.at[family.n_fn:, :, 0].set(0.0).at[family.n_fn:, :, 1].set(1.0)
    # padded compact rows get kind 0 (identity) from the zero-pad, so the
    # transform stage leaves them untouched
    return IntegrandFamily(fn=family.fn,
                           params=jax.tree.map(pad_leaf, family.params),
                           domains=domains, name=family.name,
                           kernel=family.kernel, compact=family.compact)


def sharded_family_sums(
    family: IntegrandFamily,
    n_samples: int,
    key: tuple,
    mesh: Mesh,
    *,
    fn_axis: str = "model",
    sample_axes: Sequence[str] = ("data",),
    fn_offset: int = 0,
    sample_offset: int = 0,
    chunk: int = 8192,
    use_kernel: bool = False,
    sampler: str = "mc",
):
    """Multi-chip (s1, s2) sums.

    Functions shard over ``fn_axis``; each sample-axis shard draws a disjoint
    counter range of samples; one psum over ``sample_axes`` merges partials.

    Returns ``(sums, padded_family)`` where arrays in ``sums`` have the
    padded n_fn length and carry a NamedSharding over ``fn_axis``.
    """
    sample_axes = tuple(sample_axes)
    fn_par = mesh.shape[fn_axis]
    sample_par = int(np.prod([mesh.shape[a] for a in sample_axes]))
    n_fn_padded = math.ceil(family.n_fn / fn_par) * fn_par
    fam = _pad_family_to(family, n_fn_padded)
    per_shard_samples = math.ceil(n_samples / sample_par)

    fn_ids = fn_offset + jnp.arange(n_fn_padded, dtype=jnp.uint32)
    k0, k1 = key

    fn_spec = P(fn_axis)
    rep = P()

    def local(params, domains, fn_ids_local):
        # which sample shard am I? -> disjoint global sample range
        idx = jnp.uint32(0)
        mult = 1
        for a in reversed(sample_axes):
            idx = idx + jnp.uint32(jax.lax.axis_index(a)) * jnp.uint32(mult)
            mult *= mesh.shape[a]
        shard_offset = (jnp.uint32(sample_offset)
                        + idx * jnp.uint32(per_shard_samples))
        fam_local = IntegrandFamily(fn=fam.fn, params=params, domains=domains,
                                    name=fam.name, kernel=fam.kernel,
                                    compact=fam.compact)
        # fn_offset already folded into fn_ids_local; pass offset via ids
        sums = _sums_with_ids(fam_local, per_shard_samples, (k0, k1),
                              fn_ids_local, shard_offset, chunk, use_kernel,
                              sampler=sampler)
        s1 = jax.lax.psum(sums.s1, sample_axes)
        s2 = jax.lax.psum(sums.s2, sample_axes)
        n = jnp.float32(per_shard_samples * sample_par)
        return s1, s2, n

    spec_params = jax.tree.map(lambda _: fn_spec, fam.params)
    out = shard_map(
        local, mesh=mesh,
        in_specs=(spec_params, fn_spec, fn_spec),
        out_specs=(fn_spec, fn_spec, rep),
    )(fam.params, fam.domains, fn_ids)
    s1, s2, n = out
    return SumsState(s1=s1, s2=s2, n=n), fam


def _sums_with_ids(family, n_samples, key, fn_ids, sample_offset, chunk,
                   use_kernel, sampler: str = "mc") -> SumsState:
    """Like family_sums but with explicit (traced) fn ids / sample offset.

    ``use_kernel`` dispatch is capability-checked: the registered Pallas
    fast path runs only if the family's form supports (dim, sampler) —
    compactified infinite-domain families included, gated by the form's
    ``supports_compactified`` flag; otherwise — unregistered form,
    unsupported dimension (e.g. Sobol beyond dim 8) — the chunked
    pure-JAX path below takes over silently.
    """
    if sampler == "sobol":
        from repro.core.sobol import MAX_DIM
        if family.dim > MAX_DIM:
            # documented sobol contract: beyond the Joe-Kuo table the
            # engine degrades to pseudo-random MC (still unbiased)
            sampler = "mc"
    if use_kernel and family.kernel is not None:
        from repro.kernels import registry
        impl = registry.lookup(family.kernel, dim=family.dim,
                               sampler=sampler,
                               compactified=family.compact,
                               sweep=family.swept,
                               adapted=bool(family.adapt_bins))
        if impl is not None:
            return impl(family, n_samples, key, fn_ids=fn_ids,
                        sample_offset=sample_offset)
    k0, k1 = key
    n_fn = family.n_fn
    n_chunks = max(1, math.ceil(n_samples / chunk))

    def body(i, acc):
        s1, s2 = acc
        start = jnp.uint32(sample_offset) + jnp.uint32(i) * jnp.uint32(chunk)
        sample_ids = start + jnp.arange(chunk, dtype=jnp.uint32)
        valid = (jnp.uint32(i) * jnp.uint32(chunk)
                 + jnp.arange(chunk, dtype=jnp.uint32)) < jnp.uint32(n_samples)
        c1, c2 = _eval_chunk(family, k0, k1, fn_ids, sample_ids, valid,
                             sampler=sampler)
        return (s1 + c1, s2 + c2)

    # derive the carry zeros from fn_ids AND sample_offset so that, under
    # shard_map, they carry the same varying-manual-axes type as the loop
    # body's outputs (fn_ids varies over the fn axis, sample_offset over the
    # sample axes)
    zeros = (0.0 * fn_ids.astype(jnp.float32)
             + 0.0 * jnp.asarray(sample_offset).astype(jnp.float32))
    s1, s2 = jax.lax.fori_loop(0, n_chunks, body, (zeros, zeros))
    return SumsState(s1=s1, s2=s2, n=jnp.float32(n_samples))
