"""``ZMCMultiFunctions`` — the v5.1 headline feature.

Evaluates an arbitrary collection of integrand families (different forms,
dimensions and domains) in one shot, on one device or across a TPU mesh.

API sketch (mirrors the paper's ``ZMCintegral_multifunctions``)::

    spec = MultiFunctionSpec.from_families([
        harmonic_family(100, 4),                       # Eq. (1)
        abs_sum_family(49, 2, coeff_a),                # Eq. (2), n < 50
        abs_sum_family(51, 3, coeff_b, sign_last=-1),  # Eq. (2), n >= 50
    ])
    zmc = ZMCMultiFunctions(spec, n_samples=10**6, seed=0)
    result = zmc.evaluate(num_trials=10)
    result.trial_mean, result.trial_std   # paper Fig. 1 red band

Fault tolerance: :meth:`evaluate_resumable` splits the sample budget into
rounds and checkpoints the raw ``(s1, s2, n)`` accumulators after each round.
Because the RNG is counter-based, a restart — even onto a *different mesh* —
continues the exact same sample stream (verified by
``tests/core/test_resume.py``).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import direct_mc, rng
from repro.core.integrand import IntegrandFamily, MultiFunctionSpec


@dataclasses.dataclass
class MultiFunctionResult:
    """Per-function estimates, stacked across independent trials."""
    means: np.ndarray     # (num_trials, n_fn_total)
    stderrs: np.ndarray   # (num_trials, n_fn_total) in-trial MC stderr
    n_samples: int
    names: tuple[str, ...]

    @property
    def trial_mean(self) -> np.ndarray:
        """Average over independent trials (paper's bar F_n)."""
        return self.means.mean(axis=0)

    @property
    def trial_std(self) -> np.ndarray:
        """Std over independent trials (paper's triangle F_n)."""
        if self.means.shape[0] < 2:
            return self.stderrs[0]
        return self.means.std(axis=0, ddof=1)


class ZMCMultiFunctions:
    """Multi-function direct-MC integrator (single device or mesh)."""

    def __init__(
        self,
        spec: MultiFunctionSpec | Sequence[IntegrandFamily],
        n_samples: int = 10**6,
        seed: int = 0,
        *,
        mesh: Mesh | None = None,
        fn_axis: str = "model",
        sample_axes: Sequence[str] | None = None,
        chunk: int = 8192,
        fn_chunk: int | None = None,
        use_kernel: bool = False,
        sampler: str = "mc",          # "mc" | "sobol" (dim <= 8, RQMC)
    ):
        if not isinstance(spec, MultiFunctionSpec):
            spec = MultiFunctionSpec.from_families(spec)
        # infinite domains are rewritten into finite boxes up-front
        self.spec = MultiFunctionSpec(
            families=tuple(f.compactified() for f in spec.families))
        self.n_samples = int(n_samples)
        self.seed = int(seed)
        self.mesh = mesh
        self.fn_axis = fn_axis
        if sample_axes is None and mesh is not None:
            sample_axes = tuple(a for a in mesh.axis_names if a != fn_axis)
        self.sample_axes = tuple(sample_axes) if sample_axes else ("data",)
        self.chunk = int(chunk)
        self.fn_chunk = fn_chunk
        self.use_kernel = bool(use_kernel)
        self.sampler = sampler
        self._jitted = {}
        self._fusion_plan = None

    # -- single-trial sums ----------------------------------------------------
    def _get_fusion_plan(self):
        """Bucketed fused-kernel plan for the whole spec (built once)."""
        if self._fusion_plan is None:
            from repro.kernels.mc_eval import multi
            self._fusion_plan = multi.plan_spec(self.spec,
                                                sampler=self.sampler)
        return self._fusion_plan

    def _trial_sums(self, trial: int, n_samples: int, sample_offset: int):
        """Raw per-function sums for one independent trial.

        With ``use_kernel=True``, every family whose form is registered
        runs through the fused multi-family path — one pallas_call per
        (dim, sampler) bucket for the whole spec, the paper's
        10^3-integrand workload included — and only unregistered forms
        fall back to the per-family chunked JAX path below.  On a mesh
        the same buckets are built host-side and launched inside
        ``shard_map`` (functions over ``fn_axis``, samples over the
        remaining axes), so multi-chip runs get the same launch
        reduction as the single-device path.
        """
        key = rng.fold_key(self.seed, trial)
        fused = {}
        if self.use_kernel:
            from repro.kernels.mc_eval import multi
            if self.mesh is None:
                fused = multi.eval_plan(self._get_fusion_plan(), n_samples,
                                        key, sample_offset=sample_offset)
            else:
                fused = multi.sharded_eval_plan(
                    self._get_fusion_plan(), n_samples, key, self.mesh,
                    fn_axis=self.fn_axis, sample_axes=self.sample_axes,
                    sample_offset=sample_offset)
        out = []
        offsets = self.spec.offsets()
        for idx, (fam, off) in enumerate(zip(self.spec.families, offsets)):
            if idx in fused:
                out.append(fused[idx])
                continue
            if self.mesh is not None:
                sums, padded = direct_mc.sharded_family_sums(
                    fam, n_samples, key, self.mesh,
                    fn_axis=self.fn_axis, sample_axes=self.sample_axes,
                    fn_offset=off, sample_offset=sample_offset,
                    chunk=self.chunk, use_kernel=self.use_kernel,
                    sampler=self.sampler)
                sums = direct_mc.SumsState(
                    s1=sums.s1[: fam.n_fn], s2=sums.s2[: fam.n_fn], n=sums.n)
            else:
                fn = self._get_jitted(fam, off)
                sums = fn(fam, jnp.uint32(n_samples), jnp.uint32(sample_offset),
                          jnp.uint32(key[0]), jnp.uint32(key[1]))
            out.append(sums)
        return out

    def _get_jitted(self, fam: IntegrandFamily, off: int):
        cache_key = (id(fam.fn), fam.n_fn, fam.dim, off, self.use_kernel,
                     self.sampler)
        if cache_key not in self._jitted:
            chunk, fn_chunk, use_kernel = self.chunk, self.fn_chunk, self.use_kernel
            sampler = self.sampler

            # n_samples is static (fori bounds): jit-cache per sample count
            def runner(family, n_samples, sample_offset, k0, k1,
                       _cache={}):
                n = int(n_samples)
                if n not in _cache:
                    _cache[n] = jax.jit(
                        lambda family, sample_offset, k0, k1: direct_mc.family_sums(
                            family, n, (k0, k1), fn_offset=off,
                            sample_offset=sample_offset, chunk=chunk,
                            fn_chunk=fn_chunk, use_kernel=use_kernel,
                            sampler=sampler))
                return _cache[n](family, sample_offset, k0, k1)

            self._jitted[cache_key] = runner
        return self._jitted[cache_key]

    # -- public API ------------------------------------------------------------
    def evaluate(self, num_trials: int = 1) -> MultiFunctionResult:
        """Run ``num_trials`` independent evaluations of every integrand."""
        means, stderrs = [], []
        for t in range(num_trials):
            sums_per_family = self._trial_sums(t, self.n_samples, 0)
            m, s = self._finalize(sums_per_family)
            means.append(m)
            stderrs.append(s)
        names = tuple(f.name for f in self.spec.families)
        return MultiFunctionResult(
            means=np.stack(means), stderrs=np.stack(stderrs),
            n_samples=self.n_samples, names=names)

    def _finalize(self, sums_per_family):
        m, s = [], []
        for fam, sums in zip(self.spec.families, sums_per_family):
            res = direct_mc.finalize(fam, sums)
            m.append(np.asarray(jax.device_get(res.mean)))
            s.append(np.asarray(jax.device_get(res.stderr)))
        return np.concatenate(m), np.concatenate(s)

    # -- fault-tolerant evaluation ----------------------------------------------
    def _ckpt_tag(self) -> str:
        blob = json.dumps({
            "n_samples": self.n_samples, "seed": self.seed,
            "families": [(f.name, f.n_fn, f.dim) for f in self.spec.families],
        }, sort_keys=True).encode()
        return hashlib.sha1(blob).hexdigest()[:12]

    def evaluate_resumable(
        self,
        rounds: int = 8,
        checkpoint_dir: str | None = None,
        trial: int = 0,
        fail_after_round: int | None = None,
    ) -> MultiFunctionResult:
        """Evaluate one trial in ``rounds`` checkpointed increments.

        ``fail_after_round`` injects a crash (for the fault-tolerance tests);
        re-calling with the same ``checkpoint_dir`` resumes and produces sums
        identical to an uninterrupted run.
        """
        per_round = -(-self.n_samples // rounds)  # ceil
        state = None   # list[SumsState] per family
        start_round = 0
        tag = self._ckpt_tag()
        path = None
        if checkpoint_dir is not None:
            os.makedirs(checkpoint_dir, exist_ok=True)
            path = os.path.join(checkpoint_dir, f"zmc_{tag}_t{trial}.npz")
            if os.path.exists(path):
                data = np.load(path)
                start_round = int(data["round"])
                state = []
                for i in range(len(self.spec.families)):
                    state.append(direct_mc.SumsState(
                        s1=jnp.asarray(data[f"s1_{i}"]),
                        s2=jnp.asarray(data[f"s2_{i}"]),
                        n=jnp.asarray(data[f"n_{i}"])))

        for r in range(start_round, rounds):
            n_this = min(per_round, self.n_samples - r * per_round)
            if n_this <= 0:
                break
            sums = self._trial_sums(trial, n_this, r * per_round)
            if state is None:
                state = list(sums)
            else:
                state = [direct_mc.merge_sums(a, b) for a, b in zip(state, sums)]
            if path is not None:
                payload = {"round": r + 1}
                for i, st in enumerate(state):
                    payload[f"s1_{i}"] = np.asarray(st.s1)
                    payload[f"s2_{i}"] = np.asarray(st.s2)
                    payload[f"n_{i}"] = np.asarray(st.n)
                tmp = path + ".tmp.npz"
                np.savez(tmp, **payload)
                os.replace(tmp, path)
            if fail_after_round is not None and r == fail_after_round:
                raise RuntimeError(f"injected failure after round {r}")

        m, s = self._finalize(state)
        names = tuple(f.name for f in self.spec.families)
        return MultiFunctionResult(
            means=m[None], stderrs=s[None],
            n_samples=self.n_samples, names=names)
