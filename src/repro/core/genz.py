"""Genz test-function families — the standard integration benchmark suite.

Genz (1984) defined six families that probe distinct failure modes of
cubature methods; every family has a closed-form integral over [0,1]^d, so
they extend the paper's single harmonic validation into a full accuracy
benchmark (``benchmarks/genz_accuracy.py``) and drive the MC-vs-RQMC
comparison in EXPERIMENTS.md.

Each constructor returns an :class:`IntegrandFamily` of ``n`` random
instances (affective parameters a, u drawn from the framework's own
counter-based RNG for reproducibility) plus the vector of exact values.

Families (x in [0,1]^d; a, u parameter vectors):
  oscillatory   cos(2 pi u_1 + sum a_i x_i)
  product_peak  prod 1 / (a_i^-2 + (x_i - u_i)^2)
  corner_peak   (1 + sum a_i x_i)^-(d+1)
  gaussian      exp(-sum a_i^2 (x_i - u_i)^2)
  continuous    exp(-sum a_i |x_i - u_i|)
  discontinuous exp(sum a_i x_i) * [x_1 < u_1][x_2 < u_2]
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from repro.core import rng as rng_lib
from repro.core.integrand import IntegrandFamily


def _params(n: int, dim: int, seed: int, difficulty: float):
    """Reproducible (a, u) with sum(a) normalised to `difficulty`."""
    k0, k1 = rng_lib.fold_key(seed, stream=0x6E42)
    u = np.asarray(rng_lib.uniforms_for(
        k0, k1, np.arange(n), np.arange(dim, dtype=np.uint32), 1))[:, :, 0]
    a_raw = np.asarray(rng_lib.uniforms_for(
        k0, k1, np.arange(n) + (1 << 20), np.arange(dim, dtype=np.uint32),
        1))[:, :, 0] + 0.1
    a = a_raw * (difficulty / a_raw.sum(axis=1, keepdims=True))
    return a.astype(np.float32), u.astype(np.float32)


def _family(fn, a, u, name, kernel=None):
    n, dim = a.shape
    dom = np.broadcast_to(np.asarray([0.0, 1.0], np.float32),
                          (n, dim, 2)).copy()
    return IntegrandFamily(
        fn=fn, params={"a": jnp.asarray(a), "u": jnp.asarray(u)},
        domains=jnp.asarray(dom), name=name, kernel=kernel).validate()


# -- oscillatory -------------------------------------------------------------

def oscillatory(n: int, dim: int, seed: int = 0, difficulty: float = 9.0):
    a, u = _params(n, dim, seed, difficulty)

    def fn(x, p):
        return jnp.cos(2 * jnp.pi * p["u"][..., 0]
                       + jnp.sum(p["a"] * x, axis=-1))

    # exact: Re[e^{i 2pi u1} prod (e^{i a_j} - 1)/(i a_j)]
    phase = 2 * np.pi * u[:, 0] + a.sum(1) / 2
    mag = np.prod(2 * np.sin(a / 2) / a, axis=1)
    exact = mag * np.cos(phase)
    return _family(fn, a, u, f"genz_osc[{n}x{dim}]",
                   kernel="mc_eval_genz_osc"), exact


# -- product peak -------------------------------------------------------------

def product_peak(n: int, dim: int, seed: int = 1, difficulty: float = 7.25):
    a, u = _params(n, dim, seed, difficulty)

    def fn(x, p):
        return jnp.prod(1.0 / (p["a"] ** -2 + jnp.square(x - p["u"])),
                        axis=-1)

    exact = np.prod(a * (np.arctan(a * (1 - u)) + np.arctan(a * u)), axis=1)
    return _family(fn, a, u, f"genz_peak[{n}x{dim}]"), exact


# -- corner peak --------------------------------------------------------------

def corner_peak(n: int, dim: int, seed: int = 2, difficulty: float = 1.85):
    a, u = _params(n, dim, seed, difficulty)

    def fn(x, p):
        return (1.0 + jnp.sum(p["a"] * x, axis=-1)) ** (-(dim + 1.0))

    # exact via inclusion-exclusion:
    #   (d! prod a_i)^-1 sum_{S subset [d]} (-1)^|S| (1 + sum_{i in S} a_i)^-1
    # (check d=1: (1/a)(1 - 1/(1+a)) = 1/(1+a) = int (1+ax)^-2)
    exact = np.zeros(n)
    for i in range(n):
        total = 0.0
        for mask in range(1 << dim):
            s = bin(mask).count("1")
            sub = sum(a[i, j] for j in range(dim) if (mask >> j) & 1)
            total += (-1.0) ** s / (1.0 + sub)
        exact[i] = total / (math.factorial(dim) * np.prod(a[i]))
    return _family(fn, a, u, f"genz_corner[{n}x{dim}]",
                   kernel="mc_eval_genz_corner"), exact


# -- gaussian ------------------------------------------------------------------

def _erf(x):
    # Abramowitz-Stegun 7.1.26, |err| < 1.5e-7 — keeps numpy-only
    sign = np.sign(x)
    x = np.abs(x)
    t = 1.0 / (1.0 + 0.3275911 * x)
    y = 1.0 - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t
                - 0.284496736) * t + 0.254829592) * t * np.exp(-x * x)
    return sign * y


def gaussian_peak(n: int, dim: int, seed: int = 3, difficulty: float = 7.03):
    a, u = _params(n, dim, seed, difficulty)

    def fn(x, p):
        return jnp.exp(-jnp.sum(jnp.square(p["a"] * (x - p["u"])), axis=-1))

    exact = np.prod(np.sqrt(np.pi) / (2 * a)
                    * (_erf(a * (1 - u)) + _erf(a * u)), axis=1)
    return _family(fn, a, u, f"genz_gauss[{n}x{dim}]"), exact


# -- continuous (C0) -----------------------------------------------------------

def continuous(n: int, dim: int, seed: int = 4, difficulty: float = 2.04):
    a, u = _params(n, dim, seed, difficulty)

    def fn(x, p):
        return jnp.exp(-jnp.sum(p["a"] * jnp.abs(x - p["u"]), axis=-1))

    exact = np.prod((2.0 - np.exp(-a * u) - np.exp(-a * (1 - u))) / a, axis=1)
    return _family(fn, a, u, f"genz_cont[{n}x{dim}]"), exact


# -- discontinuous --------------------------------------------------------------

def discontinuous(n: int, dim: int, seed: int = 5, difficulty: float = 4.3):
    a, u = _params(n, dim, seed, difficulty)

    def fn(x, p):
        inside = (x[..., 0] < p["u"][..., 0])
        if x.shape[-1] > 1:
            inside = inside & (x[..., 1] < p["u"][..., 1])
        return jnp.where(inside, jnp.exp(jnp.sum(p["a"] * x, axis=-1)), 0.0)

    exact = np.ones(n)
    for j in range(dim):
        hi = u[:, j] if j < 2 else 1.0
        exact *= (np.exp(a[:, j] * hi) - 1.0) / a[:, j]
    return _family(fn, a, u, f"genz_disc[{n}x{dim}]"), exact


ALL = {
    "oscillatory": oscillatory,
    "product_peak": product_peak,
    "corner_peak": corner_peak,
    "gaussian": gaussian_peak,
    "continuous": continuous,
    "discontinuous": discontinuous,
}
