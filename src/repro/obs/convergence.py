"""Per-stream convergence accounting: stderr-vs-rounds trajectories.

The cache knows each stream's *current* stderr; nothing in the service
remembered how it got there.  This module records one
:class:`TrajectoryPoint` per folded round at deposit time — the
measured-variance data layer the adaptive-VEGAS / m-Cubes planner
(ROADMAP "Adaptive variance reduction") will consume to allocate
samples by *observed* convergence rather than the 1/sqrt(n) prior, and
the raw material for the paper's convergence plots.

Recording happens inside :meth:`ResultCache.deposit_wave` right after
each round folds, so a trajectory is exactly the sequence of states the
engine's precision checks saw: ``(rounds_done, n, stderr_max,
stderr_mean)`` after every fold.  Deposits are wave-batched host work
(off the device critical path) and each point is O(n_fn) numpy — the
same cost as one ``meets()`` check the engine already pays per wave.

Memory is bounded per stream: past ``max_points`` the log *decimates* —
it keeps every other retained point and doubles its sampling stride, so
a million-round stream keeps a uniformly-thinned skeleton of its whole
history instead of an arbitrary prefix or suffix.  The stream's latest
point is always reported (tracked separately as the frontier), so a
trajectory ends at the true fold frontier regardless of stride.
"""

from __future__ import annotations

import dataclasses
import threading


@dataclasses.dataclass(frozen=True)
class TrajectoryPoint:
    """Stream state right after one round folded."""

    rounds_done: int     # fold frontier after this round
    n: int               # accumulated samples
    stderr_max: float    # worst per-function standard error
    stderr_mean: float   # mean per-function standard error


@dataclasses.dataclass
class _Traj:
    points: list            # retained points, one per `stride` records
    stride: int = 1
    pending: int = 0        # records since the last retained point
    frontier: TrajectoryPoint | None = None   # latest, if not retained


class ConvergenceLog:
    """Bounded per-stream trajectories, keyed by stream content hash."""

    def __init__(self, max_points: int = 512):
        if max_points < 4:
            raise ValueError("max_points must be at least 4")
        self.max_points = int(max_points)
        self._lock = threading.Lock()
        self._streams: dict[str, _Traj] = {}

    def record(self, chash: str, *, rounds_done: int, n: int,
               stderr_max: float, stderr_mean: float) -> None:
        point = TrajectoryPoint(rounds_done=int(rounds_done), n=int(n),
                                stderr_max=float(stderr_max),
                                stderr_mean=float(stderr_mean))
        with self._lock:
            traj = self._streams.get(chash)
            if traj is None:
                traj = self._streams[chash] = _Traj(points=[])
            traj.pending += 1
            if traj.pending >= traj.stride:
                traj.points.append(point)
                traj.pending = 0
                traj.frontier = None
                if len(traj.points) > self.max_points:
                    traj.points = traj.points[::2]
                    traj.stride *= 2
            else:
                traj.frontier = point

    def trajectory(self, chash: str) -> list[TrajectoryPoint]:
        """Thinned history plus the exact current frontier point."""
        with self._lock:
            traj = self._streams.get(chash)
            if traj is None:
                return []
            points = list(traj.points)
            if traj.frontier is not None:
                points.append(traj.frontier)
            return points

    def stride(self, chash: str) -> int:
        with self._lock:
            traj = self._streams.get(chash)
            return traj.stride if traj is not None else 1

    def streams(self) -> list[str]:
        with self._lock:
            return list(self._streams)

    def snapshot(self) -> dict:
        """JSON-able ``{chash: {"stride", "points": [[rounds, n,
        stderr_max, stderr_mean], ...]}}`` for bench/CLI artifacts."""
        out = {}
        for chash in self.streams():
            points = self.trajectory(chash)
            out[chash] = {
                "stride": self.stride(chash),
                "points": [[p.rounds_done, p.n, p.stderr_max, p.stderr_mean]
                           for p in points],
            }
        return out
