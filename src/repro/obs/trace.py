"""Span tracing for the wave pipeline, Chrome-trace/Perfetto format.

A :class:`Tracer` turns ``with tracer.span("launch", wave=3):`` into a
complete-duration event (``ph: "X"``) and ``tracer.instant(...)`` into
an instant event (``ph: "i"``), both in the Trace Event Format that
``chrome://tracing`` and https://ui.perfetto.dev load directly.  Events
flow to pluggable sinks:

* :class:`JsonlWriter` — the on-disk artifact: one event object per
  line.  The file opens with ``[`` and each event line ends with a
  comma; the Trace Event spec makes the closing ``]`` optional, so a
  crash mid-run still leaves a loadable trace (and CI can upload it
  verbatim).  :func:`load_trace` parses one back for assertions.
* any callable ``sink(event_dict)`` — tests collect into a list.

The six pipeline stages the engine instruments are named in
:data:`STAGES`; the acceptance gate asserts a served workload's trace
covers all six.  With ``jax_annotations=True`` every span additionally
enters a ``jax.profiler.TraceAnnotation`` so the same stage names line
up inside a device profile (XProf/TensorBoard) — lazily imported and
silently skipped where unavailable.

When tracing is off the engine holds the module-level :data:`NULL`
tracer: ``span()`` returns one shared no-op context manager, so the
disabled hot path costs two attribute lookups per stage per wave.

Timestamps come from :mod:`repro.obs.clock` (monotonic ns -> trace µs)
— never from ``time`` directly (rule OBS001).
"""

from __future__ import annotations

import json
import os
import threading

from repro.obs import clock

# The wave-pipeline stages engine/batcher/store instrument, in causal
# order.  plan: the fair round-robin budget split.  launch: fused
# pallas_call dispatch (async — returns device futures).  device_execute:
# blocking until the device finishes the wave.  transfer: materializing
# sums on host.  deposit: cache fold + request completion.  wal_commit:
# the group-committed journal write+fsync.
STAGES = ("plan", "launch", "device_execute", "transfer", "deposit",
          "wal_commit")


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The disabled tracer: every operation is a shared no-op."""

    enabled = False

    def span(self, name: str, **args):
        return _NULL_SPAN

    def instant(self, name: str, **args) -> None:
        return None

    def flush(self) -> None:
        return None

    def close(self) -> None:
        return None


NULL = NullTracer()


class _Span:
    __slots__ = ("tracer", "name", "args", "t0", "annotation")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self.tracer = tracer
        self.name = name
        self.args = args
        self.annotation = None

    def __enter__(self):
        self.t0 = clock.monotonic_ns()
        ann = self.tracer._annotation
        if ann is not None:
            self.annotation = ann(self.name)
            self.annotation.__enter__()
        return self

    def __exit__(self, *exc):
        if self.annotation is not None:
            self.annotation.__exit__(*exc)
        t1 = clock.monotonic_ns()
        self.tracer._emit({
            "ph": "X", "name": self.name, "cat": "wave",
            "ts": self.t0 // 1000, "dur": max((t1 - self.t0) // 1000, 1),
            "pid": self.tracer.pid, "tid": threading.get_ident() & 0xFFFF,
            "args": self.args,
        })
        return False


class Tracer:
    """Emits trace events to sinks; enabled iff it has at least one."""

    enabled = True

    def __init__(self, *sinks, jax_annotations: bool = False):
        self.pid = os.getpid()
        self._sinks = list(sinks)
        self._annotation = None
        if jax_annotations:
            try:
                from jax.profiler import TraceAnnotation
                self._annotation = TraceAnnotation
            except Exception:       # profiler moved / absent: trace anyway
                self._annotation = None

    def add_sink(self, sink) -> None:
        self._sinks.append(sink)

    def span(self, name: str, **args) -> _Span:
        """Context manager timing one pipeline stage."""
        return _Span(self, name, args)

    def instant(self, name: str, **args) -> None:
        """A point event (failure paths: restarts, stragglers, torn
        commits) carrying stream/wave identity in ``args``."""
        self._emit({
            "ph": "i", "name": name, "cat": "event", "s": "t",
            "ts": clock.monotonic_ns() // 1000,
            "pid": self.pid, "tid": threading.get_ident() & 0xFFFF,
            "args": args,
        })

    def _emit(self, event: dict) -> None:
        for sink in self._sinks:
            sink(event)

    def flush(self) -> None:
        for sink in self._sinks:
            if hasattr(sink, "flush"):
                sink.flush()

    def close(self) -> None:
        for sink in self._sinks:
            if hasattr(sink, "close"):
                sink.close()


class JsonlWriter:
    """Trace sink writing the crash-tolerant headless-array JSONL file."""

    def __init__(self, path: str):
        self.path = str(path)
        self._lock = threading.Lock()
        self._f = open(self.path, "w", encoding="utf-8")
        self._f.write("[\n")
        self.n_events = 0

    def __call__(self, event: dict) -> None:
        line = json.dumps(event, sort_keys=True,
                          separators=(",", ":")) + ",\n"
        with self._lock:
            self._f.write(line)
            self.n_events += 1

    def flush(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.flush()

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.flush()
                self._f.close()


def load_trace(path: str) -> list[dict]:
    """Parse a :class:`JsonlWriter` artifact (or any Trace Event JSON
    array, trailing-comma/unclosed included) back into event dicts."""
    with open(path, encoding="utf-8") as f:
        text = f.read().strip()
    if text.startswith("["):
        text = text[1:]
    text = text.rstrip("]").rstrip().rstrip(",")
    if not text:
        return []
    return json.loads(f"[{text}]")


def span_totals(events: list[dict]) -> dict[str, float]:
    """Total seconds per span name over a parsed trace (``ph == "X"``).

    The host-per-wave bench phase aggregates with this; dur is µs."""
    totals: dict[str, float] = {}
    for ev in events:
        if ev.get("ph") == "X":
            totals[ev["name"]] = (totals.get(ev["name"], 0.0)
                                  + ev.get("dur", 0) / 1e6)
    return totals
