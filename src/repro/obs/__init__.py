"""Telemetry for the integration service: tracing, metrics, convergence.

One :class:`Observability` object threads through the whole service
stack (engine -> batcher -> cache -> store) and bundles the four
telemetry channels:

* ``tracer``       — wave-pipeline span/instant events
  (:mod:`repro.obs.trace`, Chrome-trace/Perfetto JSONL);
* ``metrics``      — the counter/gauge/histogram registry with
  Prometheus text + JSON expositions (:mod:`repro.obs.metrics`);
* ``convergence``  — per-stream stderr-vs-rounds trajectories
  (:mod:`repro.obs.convergence`);
* ``clock``        — the single wall-clock shim every service-layer
  timestamp goes through (:mod:`repro.obs.clock`, rule OBS001).

``Observability.disabled()`` (the engine default) carries the null
tracer and skips convergence recording; metric objects still exist so
call sites never branch, and the whole disabled path costs a few dict
lookups and locked adds per *wave* — measured ≤5% of wave wall time by
the ``service_bench`` host-cost phase, CI-gated.

Construction is cheap and side-effect free; sinks (trace file, metrics
port) attach at the edges (``serve_integrals`` flags, bench phases).
"""

from __future__ import annotations

from repro.obs import clock
from repro.obs.convergence import ConvergenceLog, TrajectoryPoint
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               service_metrics)
from repro.obs.trace import (STAGES, JsonlWriter, NullTracer, Tracer,
                             load_trace, span_totals)

__all__ = [
    "Observability", "ConvergenceLog", "TrajectoryPoint",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "service_metrics",
    "STAGES", "JsonlWriter", "NullTracer", "Tracer", "load_trace",
    "span_totals", "clock",
]


class Observability:
    """The telemetry bundle the engine threads through the stack."""

    def __init__(self, *, tracer=None, metrics: MetricsRegistry | None = None,
                 convergence: ConvergenceLog | None = None,
                 record_convergence: bool = True):
        from repro.obs.trace import NULL
        self.tracer = tracer if tracer is not None else NULL
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.convergence = (convergence if convergence is not None
                            else ConvergenceLog())
        self.record_convergence = bool(record_convergence)
        # the canonical service metric handles, pre-resolved so hot
        # paths never pay the registry lookup
        self.m = service_metrics(self.metrics)
        if self.tracer.enabled:
            # spans already time the stages; mirror their durations into
            # the per-stage latency histogram so the Prometheus
            # exposition and the trace artifact can never disagree
            stage_hist = self.m["stage_seconds"]

            def _stage_sink(ev: dict) -> None:
                if ev.get("ph") == "X" and ev["name"] in STAGES:
                    stage_hist.observe(ev["dur"] / 1e6, stage=ev["name"])

            self.tracer.add_sink(_stage_sink)

    @classmethod
    def disabled(cls) -> "Observability":
        """The default: null tracer, no convergence recording, metrics
        still counted (they are the service's own observables)."""
        return cls(record_convergence=False)

    @classmethod
    def enabled(cls, *, trace_path: str | None = None,
                jax_annotations: bool = False,
                sinks=(), max_trajectory_points: int = 512
                ) -> "Observability":
        """Full telemetry: tracing (to ``trace_path`` and/or extra
        ``sinks``), metrics, convergence accounting."""
        all_sinks = list(sinks)
        if trace_path is not None:
            all_sinks.append(JsonlWriter(trace_path))
        tracer = Tracer(*all_sinks, jax_annotations=jax_annotations)
        return cls(tracer=tracer,
                   convergence=ConvergenceLog(max_trajectory_points),
                   record_convergence=True)

    @property
    def tracing(self) -> bool:
        return self.tracer.enabled

    def span(self, name: str, **args):
        return self.tracer.span(name, **args)

    def event(self, name: str, **args) -> None:
        self.tracer.instant(name, **args)

    def close(self) -> None:
        self.tracer.close()
