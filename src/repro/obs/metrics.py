"""Process-local metrics: counters, gauges, histograms, two expositions.

A :class:`MetricsRegistry` is a flat namespace of named metrics, each
optionally split by a fixed tuple of label names (Prometheus-style:
``zmc_bucket_rounds_total{dim="3",sampler="mc"}``).  The registry is
what the engine threads through the service stack and what
``serve_integrals --metrics-port / --metrics-json`` exposes:

* :meth:`MetricsRegistry.render_prometheus` — the text exposition
  format v0.0.4 (``# TYPE`` headers, one sample per line), scrapeable
  by a real Prometheus and asserted verbatim in tests;
* :meth:`MetricsRegistry.snapshot` — a plain JSON-able dict for bench
  artifacts (``BENCH_7.json`` embeds one).

Hot-path cost: an increment is one dict lookup (amortized: call sites
hold the child handle) plus one locked float add.  Each metric carries
its own small lock so concurrent wave drivers never lose increments —
the CI gate compares these counters *exactly* against the engine's own
observables (``template.launch_count``, ``RoundBatcher.fallback_rounds``),
so approximate lock-free adds are not good enough.

The canonical metric names the service exports (and the ROADMAP's
autotune / adaptive-planner items consume) are declared in
:func:`service_metrics` — one place, so the bench, the docs and the
exposition can never drift apart.
"""

from __future__ import annotations

import threading
from typing import Iterable, Mapping

# Default histogram buckets: exponential from 1 ms to ~2 min, tuned for
# wave/stage durations (interpret-mode CPU waves sit in the 0.1-10 s
# decade; real-accelerator waves in the 1-100 ms decade).
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 120.0)


def _label_key(labels: Mapping[str, object] | None,
               names: tuple[str, ...]) -> tuple[str, ...]:
    labels = labels or {}
    if set(labels) != set(names):
        raise ValueError(f"metric wants labels {names}, got {tuple(labels)}")
    return tuple(str(labels[n]) for n in names)


class Counter:
    """Monotone float/int accumulator, optionally labelled."""

    kind = "counter"

    def __init__(self, name: str, help_: str,
                 labelnames: tuple[str, ...] = ()):
        self.name = name
        self.help = help_
        self.labelnames = tuple(labelnames)
        self._values: dict[tuple[str, ...], float] = {}
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        key = _label_key(labels, self.labelnames)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        key = _label_key(labels, self.labelnames)
        with self._lock:
            return self._values.get(key, 0.0)

    def _samples(self):
        with self._lock:
            items = sorted(self._values.items())
        for key, val in items:
            yield self.name, dict(zip(self.labelnames, key)), val

    def _snapshot(self):
        with self._lock:
            if not self.labelnames:
                return self._values.get((), 0.0)
            return {",".join(k): v for k, v in sorted(self._values.items())}


class Gauge(Counter):
    """A value that goes up and down (in-flight depth, pending size)."""

    kind = "gauge"

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = _label_key(labels, self.labelnames)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def dec(self, amount: float = 1.0, **labels) -> None:
        self.inc(-amount, **labels)

    def set(self, value: float, **labels) -> None:
        key = _label_key(labels, self.labelnames)
        with self._lock:
            self._values[key] = float(value)


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics), labelled."""

    kind = "histogram"

    def __init__(self, name: str, help_: str,
                 labelnames: tuple[str, ...] = (),
                 buckets: Iterable[float] = DEFAULT_BUCKETS):
        self.name = name
        self.help = help_
        self.labelnames = tuple(labelnames)
        self.buckets = tuple(sorted(buckets))
        self._series: dict[tuple[str, ...], list] = {}
        self._lock = threading.Lock()

    def observe(self, value: float, **labels) -> None:
        key = _label_key(labels, self.labelnames)
        with self._lock:
            series = self._series.get(key)
            if series is None:
                # [bucket counts..., +Inf count, sum, count]
                series = [0] * (len(self.buckets) + 1) + [0.0, 0]
                self._series[key] = series
            for i, edge in enumerate(self.buckets):
                if value <= edge:
                    series[i] += 1
                    break
            else:
                series[len(self.buckets)] += 1
            series[-2] += float(value)
            series[-1] += 1

    def count(self, **labels) -> int:
        key = _label_key(labels, self.labelnames)
        with self._lock:
            series = self._series.get(key)
            return int(series[-1]) if series else 0

    def sum(self, **labels) -> float:
        key = _label_key(labels, self.labelnames)
        with self._lock:
            series = self._series.get(key)
            return float(series[-2]) if series else 0.0

    def _samples(self):
        with self._lock:
            items = sorted((k, list(v)) for k, v in self._series.items())
        for key, series in items:
            labels = dict(zip(self.labelnames, key))
            cum = 0
            for i, edge in enumerate(self.buckets):
                cum += series[i]
                yield (f"{self.name}_bucket",
                       {**labels, "le": _fmt(edge)}, cum)
            cum += series[len(self.buckets)]
            yield f"{self.name}_bucket", {**labels, "le": "+Inf"}, cum
            yield f"{self.name}_sum", labels, series[-2]
            yield f"{self.name}_count", labels, series[-1]

    def _snapshot(self):
        with self._lock:
            items = sorted((k, list(v)) for k, v in self._series.items())
        out = {}
        for key, series in items:
            out[",".join(key)] = {
                "count": int(series[-1]), "sum": series[-2],
                "buckets": {_fmt(e): int(series[i])
                            for i, e in enumerate(self.buckets)},
                "overflow": int(series[len(self.buckets)]),
            }
        return out if self.labelnames else out.get("", {
            "count": 0, "sum": 0.0, "buckets": {}, "overflow": 0})


def _fmt(x: float) -> str:
    return f"{x:g}"


class MetricsRegistry:
    """Named metrics + the two expositions (Prometheus text, JSON)."""

    def __init__(self):
        self._metrics: dict[str, object] = {}
        self._lock = threading.Lock()

    def counter(self, name: str, help_: str = "",
                labelnames: tuple[str, ...] = ()) -> Counter:
        return self._get_or_make(Counter, name, help_, labelnames)

    def gauge(self, name: str, help_: str = "",
              labelnames: tuple[str, ...] = ()) -> Gauge:
        return self._get_or_make(Gauge, name, help_, labelnames)

    def histogram(self, name: str, help_: str = "",
                  labelnames: tuple[str, ...] = (),
                  buckets: Iterable[float] = DEFAULT_BUCKETS) -> Histogram:
        with self._lock:
            metric = self._metrics.get(name)
            if metric is None:
                metric = Histogram(name, help_, labelnames, buckets)
                self._metrics[name] = metric
        if not isinstance(metric, Histogram):
            raise TypeError(f"{name} already registered as {metric.kind}")
        return metric

    def _get_or_make(self, cls, name, help_, labelnames):
        with self._lock:
            metric = self._metrics.get(name)
            if metric is None:
                metric = cls(name, help_, labelnames)
                self._metrics[name] = metric
        if type(metric) is not cls:
            raise TypeError(f"{name} already registered as {metric.kind}")
        if metric.labelnames != tuple(labelnames):
            raise ValueError(
                f"{name} registered with labels {metric.labelnames}, "
                f"asked for {tuple(labelnames)}")
        return metric

    def get(self, name: str):
        return self._metrics.get(name)

    def render_prometheus(self) -> str:
        """Text exposition format v0.0.4."""
        lines = []
        with self._lock:
            metrics = sorted(self._metrics.items())
        for name, metric in metrics:
            if metric.help:
                lines.append(f"# HELP {name} {metric.help}")
            lines.append(f"# TYPE {name} {metric.kind}")
            for sample, labels, value in metric._samples():
                if labels:
                    inner = ",".join(f'{k}="{v}"'
                                     for k, v in labels.items())
                    lines.append(f"{sample}{{{inner}}} {_fmt_val(value)}")
                else:
                    lines.append(f"{sample} {_fmt_val(value)}")
        return "\n".join(lines) + "\n"

    def snapshot(self) -> dict:
        """JSON-able view: ``{name: {"type", "value"}}``."""
        with self._lock:
            metrics = sorted(self._metrics.items())
        return {name: {"type": m.kind, "value": m._snapshot()}
                for name, m in metrics}


def _fmt_val(value: float) -> str:
    as_int = int(value)
    return str(as_int) if value == as_int else repr(float(value))


def service_metrics(registry: MetricsRegistry) -> dict:
    """Declare (idempotently) every metric the service stack exports.

    One place for the canonical names so the engine, the bench gates and
    the ROADMAP's consumer list (autotuner, adaptive planner) agree:

    ==============================  =============================================
    zmc_kernel_launches_total        pallas_call dispatches (= template counter)
    zmc_fallback_rounds_total        rounds on the chunked path (= batcher obs)
    zmc_cache_requests_total         {outcome=hit|miss} request-level cache fate
    zmc_warm_zero_launch_total       requests served entirely from cache
    zmc_requests_submitted_total     submit() calls accepted
    zmc_requests_served_total        results finalized
    zmc_waves_total                  engine waves deposited
    zmc_wave_restarts_total          run_with_restarts retries
    zmc_straggler_events_total       StepWatchdog threshold trips
    zmc_deposit_rounds_total         rounds folded into the cache
    zmc_inflight_rounds              gauge: rounds dispatched, not yet deposited
    zmc_pending_requests             gauge: requests parked in the pending table
    zmc_wave_seconds                 histogram: end-to-end wave wall time
    zmc_stage_seconds                histogram {stage}: per-pipeline-stage time
    zmc_wave_rounds                  histogram {sampler}: rounds per fused launch
    zmc_bucket_rounds_total          {dim,sampler}: rounds per bucket signature
    zmc_wal_bytes_total              journal bytes written
    zmc_wal_fsync_seconds            histogram: fsync+write latency per commit
    zmc_wal_commits_total            journal write batches
    zmc_sweep_requests_total         sweep requests accepted
    zmc_sweep_points_total           grid points across accepted sweeps
    zmc_sweep_slices_total           {outcome=new|shared}: canonical sweep
                                     slices allocated vs deduped onto an
                                     existing cache stream
    zmc_faults_injected_total        {stage}: chaos-harness faults fired
                                     (agrees with FaultPlan.fired)
    zmc_retries_total                {stage}: retry attempts the unified
                                     policy actually ran (agrees with
                                     EngineStats.restarts)
    zmc_quarantined_streams_total    streams quarantined by the poison
                                     ladder (agrees with
                                     ResultCache.quarantined_streams)
    zmc_deadline_expirations_total   tickets failed on an expired deadline
    zmc_adapted_streams_total        importance-grid epoch streams opened
                                     (one per VEGAS grid fit, incl. epoch 1)
    zmc_grid_refits_total            grid refits (epoch openings beyond the
                                     first; agrees with ``grid_refit`` trace
                                     events)
    ==============================  =============================================
    """
    return {
        "launches": registry.counter(
            "zmc_kernel_launches_total",
            "fused pallas_call dispatches (agrees with "
            "repro.kernels.template.launch_count)"),
        "fallback_rounds": registry.counter(
            "zmc_fallback_rounds_total",
            "rounds served by the chunked per-round path (agrees with "
            "RoundBatcher.fallback_rounds)"),
        "cache_requests": registry.counter(
            "zmc_cache_requests_total",
            "request-level cache outcomes", ("outcome",)),
        "warm_zero_launch": registry.counter(
            "zmc_warm_zero_launch_total",
            "requests served entirely from cache (zero launches)"),
        "submitted": registry.counter(
            "zmc_requests_submitted_total", "accepted submit() calls"),
        "served": registry.counter(
            "zmc_requests_served_total", "finalized results"),
        "waves": registry.counter(
            "zmc_waves_total", "engine waves deposited"),
        "restarts": registry.counter(
            "zmc_wave_restarts_total",
            "wave attempts retried by run_with_restarts"),
        "stragglers": registry.counter(
            "zmc_straggler_events_total",
            "StepWatchdog threshold trips"),
        "deposit_rounds": registry.counter(
            "zmc_deposit_rounds_total", "rounds folded into the cache"),
        "inflight": registry.gauge(
            "zmc_inflight_rounds",
            "rounds dispatched but not yet deposited (wave depth)"),
        "pending": registry.gauge(
            "zmc_pending_requests", "requests parked in the pending table"),
        "wave_seconds": registry.histogram(
            "zmc_wave_seconds", "end-to-end wave wall time"),
        "stage_seconds": registry.histogram(
            "zmc_stage_seconds",
            "wall time per wave-pipeline stage", ("stage",)),
        "wave_rounds": registry.histogram(
            "zmc_wave_rounds", "rounds per fused launch group", ("sampler",),
            buckets=(1, 2, 4, 8, 16, 32, 64)),
        "bucket_rounds": registry.counter(
            "zmc_bucket_rounds_total",
            "rounds evaluated per (dim, sampler) bucket signature",
            ("dim", "sampler")),
        "wal_bytes": registry.counter(
            "zmc_wal_bytes_total", "journal bytes written"),
        "wal_fsync_seconds": registry.histogram(
            "zmc_wal_fsync_seconds",
            "write+fsync latency per journal commit"),
        "wal_commits": registry.counter(
            "zmc_wal_commits_total", "journal write batches"),
        "sweep_submitted": registry.counter(
            "zmc_sweep_requests_total", "accepted sweep requests"),
        "sweep_points": registry.counter(
            "zmc_sweep_points_total",
            "grid points across accepted sweep requests"),
        "sweep_slices": registry.counter(
            "zmc_sweep_slices_total",
            "canonical sweep slices by cache fate (shared = deduped onto "
            "an existing stream, incl. sub-grid overlap with another "
            "client's sweep)", ("outcome",)),
        "faults_injected": registry.counter(
            "zmc_faults_injected_total",
            "deterministic chaos faults fired (agrees with "
            "FaultPlan.fired)", ("stage",)),
        "retries": registry.counter(
            "zmc_retries_total",
            "retry attempts run by the unified policy (agrees with "
            "EngineStats.restarts across stages)", ("stage",)),
        "quarantined_streams": registry.counter(
            "zmc_quarantined_streams_total",
            "streams quarantined by the poison ladder (agrees with "
            "ResultCache.quarantined_streams)"),
        "deadline_expirations": registry.counter(
            "zmc_deadline_expirations_total",
            "tickets completed as RequestFailed on an expired deadline"),
        "adapted_streams": registry.counter(
            "zmc_adapted_streams_total",
            "importance-grid epoch streams opened (one per VEGAS grid "
            "fit, including the first epoch)"),
        "grid_refits": registry.counter(
            "zmc_grid_refits_total",
            "importance-grid refits (epoch openings beyond the first)"),
    }
