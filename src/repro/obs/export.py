"""Metrics exposition endpoints: Prometheus scrape + JSON artifacts.

:class:`MetricsServer` is the live side of ``serve_integrals
--metrics-port``: a daemon-threaded stdlib HTTP server answering

* ``GET /metrics``      — Prometheus text exposition (scrapeable),
* ``GET /metrics.json`` — the JSON snapshot,
* ``GET /convergence``  — per-stream stderr-vs-rounds trajectories.

It binds on construction (so a busy port fails loudly at startup, not
at first scrape) and serves whatever the registry holds at request
time — no caching, no background aggregation.

:func:`write_snapshot` is the batch side (``--metrics-json``): one JSON
file carrying the metrics snapshot, the convergence trajectories and a
wall-clock stamp, the shape ``BENCH_7.json`` embeds and CI uploads.
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.obs import clock


class MetricsServer:
    """Serve a registry (and optional convergence log) over HTTP."""

    def __init__(self, registry, *, port: int = 0, host: str = "127.0.0.1",
                 convergence=None):
        self.registry = registry
        self.convergence = convergence
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):                      # noqa: N802 (stdlib API)
                if self.path.rstrip("/") in ("", "/metrics".rstrip("/"),
                                             "/metrics"):
                    body = outer.registry.render_prometheus().encode()
                    ctype = "text/plain; version=0.0.4; charset=utf-8"
                elif self.path == "/metrics.json":
                    body = json.dumps(outer.registry.snapshot(),
                                      sort_keys=True).encode()
                    ctype = "application/json"
                elif self.path == "/convergence":
                    log = outer.convergence
                    body = json.dumps(log.snapshot() if log else {},
                                      sort_keys=True).encode()
                    ctype = "application/json"
                else:
                    self.send_error(404)
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):          # silence per-request spam
                return None

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="metrics-server",
            daemon=True)
        self._thread.start()

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


def write_snapshot(path: str, registry, *, convergence=None,
                   extra: dict | None = None) -> dict:
    """Write the one-file JSON artifact (metrics + trajectories)."""
    payload = {
        "wall_time": clock.wall(),
        "metrics": registry.snapshot(),
        "convergence": convergence.snapshot() if convergence else {},
    }
    if extra:
        payload.update(extra)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return payload
