"""The service layer's ONLY wall-clock access point.

Every timestamp the telemetry subsystem (and anything under
``repro/service/``) reads comes through this module, never from ``time``
directly.  That keeps the no-wall-clock purity invariant machine
checkable: PUR001 bans ``time`` outright in kernels/core, and OBS001
(:mod:`repro.analysis.boundary`) bans it in ``repro/service/`` and
``repro/obs/`` *except here* — so "the service only tells time through
the clock shim" is a lint rule, not a convention.

Two clocks:

* :func:`monotonic` / :func:`monotonic_ns` — interval measurement
  (span durations, fsync latency, overhead gates).  Never jumps.
* :func:`wall` — epoch seconds for human-facing timestamps in exported
  artifacts (metrics snapshots, trace metadata).  Never used to derive
  any computation.

Tests that need deterministic time install a fake via :func:`set_clock`
(restore with ``set_clock(None)``); the fake drives *both* monotonic and
wall readings so recorded spans stay internally consistent.
"""

from __future__ import annotations

import time as _time  # analysis: ignore[OBS001] - this IS the shim

from typing import Callable


class _FakeState:
    clock: Callable[[], float] | None = None


def set_clock(clock: Callable[[], float] | None) -> None:
    """Install a fake time source (seconds, float) for tests, or
    ``None`` to restore the real clocks."""
    _FakeState.clock = clock


def monotonic() -> float:
    """Seconds on a monotonically non-decreasing clock (intervals)."""
    if _FakeState.clock is not None:
        return _FakeState.clock()
    return _time.monotonic()


def monotonic_ns() -> int:
    """Nanoseconds on the monotonic clock (trace event timestamps)."""
    if _FakeState.clock is not None:
        return int(_FakeState.clock() * 1e9)
    return _time.monotonic_ns()


def wall() -> float:
    """Epoch seconds — labelling exported artifacts only."""
    if _FakeState.clock is not None:
        return _FakeState.clock()
    return _time.time()


def sleep(seconds: float) -> None:
    """Block for ``seconds`` — the service's only sleep primitive
    (retry backoff via :mod:`repro.service.resilience`, rule RES001).
    Under a fake clock this returns immediately: fake time only moves
    when the test advances it, so a real block would deadlock."""
    if _FakeState.clock is not None:
        return
    _time.sleep(seconds)
