"""Deterministic synthetic token pipeline — sharded and checkpointable.

Tokens are a pure function of (seed, step, global position) via the same
counter-based Threefry used everywhere else, so

* every data-parallel shard materialises exactly its slice (no host has to
  hold the global batch),
* restarting from step k reproduces the identical stream (checkpoint stores
  only the step counter),
* an elastic restart onto a different mesh still consumes the same global
  token sequence.

`[audio]`/`[vlm]` frontends are stubs per the assignment: frames / patch
embeddings are generated as deterministic pseudo-random floats with the
same counter discipline.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rng as rng_lib
from repro.models.config import ModelConfig


@dataclasses.dataclass
class StreamState:
    step: int = 0


class TokenStream:
    """Deterministic global batch stream for one (cfg, batch, seq)."""

    def __init__(self, cfg: ModelConfig, global_batch: int, seq_len: int,
                 seed: int = 0):
        self.cfg = cfg
        self.batch = global_batch
        self.seq = seq_len
        self.k0, self.k1 = rng_lib.fold_key(seed, stream=0xDA7A)
        self.state = StreamState()

    # -- deterministic content -------------------------------------------------
    def _tokens(self, step: int, rows: np.ndarray) -> np.ndarray:
        """(len(rows), seq) int32 tokens for global batch rows at `step`."""
        s = np.uint32(step)
        c0 = (s * np.uint32(self.batch) + rows[:, None].astype(np.uint32))
        c0 = np.broadcast_to(c0, (len(rows), self.seq)).astype(np.uint32)
        c1 = np.broadcast_to(np.arange(self.seq, dtype=np.uint32)[None, :],
                             c0.shape)
        bits = np.asarray(rng_lib.random_bits(self.k0, self.k1,
                                              jnp.asarray(c0), jnp.asarray(c1)))
        return (bits % np.uint32(self.cfg.vocab_size)).astype(np.int32)

    def _floats(self, step: int, rows: np.ndarray, width: int,
                tag: int) -> np.ndarray:
        s = np.uint32(step)
        c0 = (s * np.uint32(self.batch) + rows[:, None, None].astype(np.uint32))
        c0 = np.broadcast_to(c0, (len(rows), self.seq, width)).astype(np.uint32)
        pos = np.arange(self.seq, dtype=np.uint32)[None, :, None]
        feat = np.arange(width, dtype=np.uint32)[None, None, :]
        c1 = (pos * np.uint32(width) + feat
              + np.uint32(tag) * np.uint32(1 << 24))
        c1 = np.broadcast_to(c1, c0.shape)
        u = np.asarray(rng_lib.bits_to_uniform(rng_lib.random_bits(
            self.k0, self.k1, jnp.asarray(c0), jnp.asarray(c1))))
        return (u * 2.0 - 1.0).astype(np.float32)

    # -- public API --------------------------------------------------------------
    def next_batch(self, rows: np.ndarray | None = None) -> dict:
        """Next global batch (or just `rows` of it, for sharded hosts)."""
        step = self.state.step
        self.state.step += 1
        if rows is None:
            rows = np.arange(self.batch)
        cfg = self.cfg
        if cfg.family == "encoder":
            frames = self._floats(step, rows, cfg.frontend_dim, tag=1)
            labels = self._tokens(step, rows)
            return {"frames": jnp.asarray(frames),
                    "labels": jnp.asarray(labels)}
        toks = self._tokens(step, rows)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
        if cfg.family == "vlm":
            nv = max(1, self.seq // 8)
            vis = self._floats(step, rows, cfg.frontend_dim, tag=2)[:, :nv]
            pos = np.broadcast_to(np.arange(self.seq, dtype=np.int32),
                                  (3, len(rows), self.seq)).copy()
            batch["vision_embeds"] = jnp.asarray(vis)
            batch["positions"] = jnp.asarray(pos)
        return batch

    # -- checkpointing -------------------------------------------------------------
    def snapshot(self) -> dict:
        return {"step": self.state.step}

    def restore(self, snap: dict):
        self.state.step = int(snap["step"])
