"""Gradient compression: int8 quantisation with error feedback.

A distributed-optimisation trick for bandwidth-bound data-parallel
reduction: gradients are quantised to int8 with a per-tensor scale before
the cross-replica sum and the quantisation residual is fed back into the
next step (error feedback keeps the *accumulated* update unbiased —
Karimireddy et al. 2019).  4x fewer bytes on the DP all-reduce.

Two integration points:

* :func:`compress_tree` / EF state in the train step — quantise-dequantise
  with feedback applied to the grads the optimizer consumes (models the
  numerics; XLA's auto-parallel all-reduce then carries bf16);
* :func:`compressed_psum` — the explicit manual-collective form for
  shard_map regions (pipeline stages, the MC engine): psum of int32-packed
  int8 payloads, i.e. the actual wire format.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x, *, bits: int = 8):
    """Per-tensor symmetric quantisation. Returns (q int8, scale f32)."""
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    qmax = float(2 ** (bits - 1) - 1)
    scale = jnp.maximum(amax / qmax, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -qmax, qmax)
    return q.astype(jnp.int8), scale


def dequantize(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def ef_compress(g, err):
    """Error-feedback step: (g + err) -> quantised ghat, new residual."""
    target = g.astype(jnp.float32) + err
    q, scale = quantize(target)
    ghat = dequantize(q, scale)
    return ghat.astype(g.dtype), (target - ghat)


def init_error_tree(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_tree(grads, err_tree):
    """Apply EF-int8 to every leaf. Returns (ghat_tree, new_err_tree)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_tree)
    out = [ef_compress(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def compressed_psum(x, axis_name: str):
    """int8-quantised psum for shard_map regions.

    Each shard quantises locally; the int8 payloads (as int32 partials to
    survive summation) and scales are psum'ed, then dequantised.  Bytes on
    the wire: N int8 + 1 f32 per shard vs N f32 — ~4x reduction.
    """
    q, scale = quantize(x)
    # sum of per-shard dequantised values = psum(q_i * scale_i); since scales
    # differ, send q*scale folded at int8 resolution: psum int32 of q and a
    # max-scale normalisation would bias - instead psum(q * scale) directly
    # in f32 per-element would defeat compression, so we use a SHARED scale:
    smax = jax.lax.pmax(scale, axis_name)
    q2 = jnp.clip(jnp.round(x.astype(jnp.float32) / smax), -127, 127)
    total = jax.lax.psum(q2.astype(jnp.int32), axis_name)
    return (total.astype(jnp.float32) * smax).astype(x.dtype)
