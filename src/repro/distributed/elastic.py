"""Elastic scaling: resume the same logical state on a different mesh.

Nothing in the framework's state is mesh-shaped: checkpoints store full
arrays, the data pipeline and MC counters are step-addressed, and sharding
is (re)derived from logical axes.  So elastic resize = restore + re-derive
shardings on the new mesh.  ``tests/distributed/test_elastic.py`` saves on
a (4,2) mesh and bit-exactly resumes on (2,4) and (8,1).
"""

from __future__ import annotations

from jax.sharding import Mesh

from repro.distributed import checkpoint as ckpt
from repro.distributed.sharding import tree_shardings


def elastic_restore(directory: str, step: int, abstract_tree, spec_tree,
                    mesh: Mesh):
    """Restore a checkpoint onto `mesh` (any shape/axis layout)."""
    shardings = tree_shardings(abstract_tree, spec_tree, mesh)
    tree, manifest = ckpt.restore(directory, step, abstract_tree,
                                  shardings=shardings)
    return tree, manifest


def reshard(tree, abstract_tree, spec_tree, mesh: Mesh):
    """Move live state onto a new mesh (shrink/grow without a checkpoint)."""
    import jax
    shardings = tree_shardings(abstract_tree, spec_tree, mesh)
    flat, treedef = jax.tree_util.tree_flatten(tree)
    flat_s = treedef.flatten_up_to(shardings)
    return treedef.unflatten(
        [jax.device_put(x, s) for x, s in zip(flat, flat_s)])
