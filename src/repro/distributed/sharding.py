"""Logical-axis sharding rules (MaxText-style) shared by every workload.

Model code annotates parameters and activations with *logical* axis names
('batch', 'heads', 'mlp', 'experts', ...).  A rule table maps logical names
to physical mesh axes; :func:`logical_to_spec` applies the table with a
divisibility fallback (an axis that does not divide evenly is left
unsharded — e.g. chatglm3's 2 KV heads on a 16-way model axis), which is
what makes one rule table serve all ten architectures.

The MC integration engine uses the same table: its 'fn' axis aliases
'experts' (function index -> model axis) and 'sample' aliases 'batch'.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> physical mesh axis (or tuple of axes, or None)
#
# 'embed' -> 'data' is the FSDP axis: parameters (and their optimizer
# moments) shard 2D over (model x data), so no chip ever holds a
# model-parallel-only replica.  Activations are unaffected: their batch dim
# claims 'data' first and the used-set rule skips a second use.  XLA inserts
# the per-layer weight all-gathers (and overlaps them with compute on TPU).
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "cache_seq": "model",      # decode KV cache: sequence sharded for flash-decode
    "cache_kv": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "embed": "data",
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_mlp": None,
    "shared_mlp": "model",
    "q_lora": None,
    "kv_lora": None,
    "ssm_heads": "model",
    "ssm_state": None,
    "conv": None,
    "layers": None,
    "frontend": None,
    "stats": None,
    # attention score sharding: q-sequence over model (context parallel)
    # when heads cannot shard (see layers.sdpa)
    "attn_q_seq": "model",
    "qgroup": None,
    # MC integration engine aliases
    "fn": "model",
    "sample": ("pod", "data"),
}

# §Perf iteration 8: sub-1B models on a fixed 16x16 mesh should not pay
# Megatron-TP activation all-reduces — replicate the (tiny) weights and
# spread the batch over BOTH axes instead.  On the multi-pod mesh the batch
# (256) cannot cover 512 chips; ('data','model') still covers the pod and
# the pod axis stays pure-DP.
SMALL_DP_RULES: dict[str, Any] = dict(
    DEFAULT_RULES,
    batch=[("data", "model"), ("data",), ("model",)],
    sample=[("data", "model"), ("data",), ("model",)],
    embed=None, mlp=None, vocab=None, heads=None, kv_heads=None,
    shared_mlp=None, ssm_heads=None, attn_q_seq=None, experts=None,
)

PROFILES = {"default": DEFAULT_RULES, "small_dp": SMALL_DP_RULES}


def rules_for(cfg) -> dict[str, Any]:
    """Rule table for a model config (reads cfg.sharding_profile)."""
    return dict(PROFILES[getattr(cfg, "sharding_profile", "default")])


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Mesh | None = None
        self.rules: dict[str, Any] = dict(DEFAULT_RULES)
        self.enabled: bool = True


_CTX = _Ctx()


@contextlib.contextmanager
def logical_sharding(mesh: Mesh | None, rules: dict[str, Any] | None = None):
    """Enable logical-axis constraints for model code traced inside."""
    prev = (_CTX.mesh, _CTX.rules, _CTX.enabled)
    _CTX.mesh = mesh
    _CTX.rules = dict(DEFAULT_RULES if rules is None else rules)
    _CTX.enabled = mesh is not None
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules, _CTX.enabled = prev


@contextlib.contextmanager
def no_constraints():
    """Disable constraints (inside shard_map bodies)."""
    prev = _CTX.enabled
    _CTX.enabled = False
    try:
        yield
    finally:
        _CTX.enabled = prev


def current_mesh() -> Mesh | None:
    return _CTX.mesh


def _candidates(logical: str | None, mesh: Mesh, rules) -> list[tuple[str, ...]]:
    """Candidate physical mappings for a logical axis, in preference order.

    A rule value may be a str, a tuple (one multi-axis mapping), or a LIST
    of str/tuple alternatives tried until one divides the dimension (e.g.
    small_dp batch: [('data','model'), 'data'] — the 256-batch train shape
    covers both axes, the 32-batch prefill falls back to data only).
    """
    if logical is None:
        return []
    phys = rules.get(logical, None)
    if phys is None:
        return []
    alts = phys if isinstance(phys, list) else [phys]
    out = []
    for alt in alts:
        if isinstance(alt, str):
            alt = (alt,)
        filtered = tuple(a for a in alt if a in mesh.axis_names)
        if filtered:
            out.append(filtered)
    return out


def _physical_axes(logical: str | None, mesh: Mesh, rules) -> tuple[str, ...]:
    cands = _candidates(logical, mesh, rules)
    return cands[0] if cands else ()


# When the primary rule for a parameter cannot shard the model axis (e.g.
# qwen2.5's 40 heads on a 16-way axis), retry these logical dims in order —
# 'head_dim' first reproduces Megatron's row/column-parallel attention
# (o-proj contracts over the sharded dim -> one psum), 'embed' last.
_MODEL_RETRY_PRIORITY = ("head_dim", "kv_lora", "q_lora", "mlp",
                         "frontend", "embed")
# axes that mark an array as an activation/cache (no retry pass)
_ACTIVATION_AXES = {"batch", "seq", "cache_seq", "sample"}


def logical_to_spec(shape: Sequence[int], axes: Sequence[str | None],
                    mesh: Mesh, rules=None, *, param_retry: bool = False) -> P:
    """PartitionSpec for one array, with divisibility fallback.

    ``param_retry``: for parameter-like arrays, if the 'model' axis ended up
    unused (primary rule non-divisible), retry alternate dims so no large
    parameter is ever fully replicated.
    """
    rules = rules if rules is not None else _CTX.rules
    used: set[str] = set()
    entries: list = []
    for dim, name in zip(shape, axes):
        placed = False
        for cand in _candidates(name, mesh, rules):
            phys = tuple(a for a in cand if a not in used)
            if not phys or len(phys) != len(cand):
                continue  # partially-consumed mapping: try next alternative
            size = int(np.prod([mesh.shape[a] for a in phys]))
            if dim % size == 0:
                entries.append(phys if len(phys) > 1 else phys[0])
                used.update(phys)
                placed = True
                break
        if not placed:
            entries.append(None)

    if (param_retry and "model" in mesh.axis_names and "model" not in used
            and not (_ACTIVATION_AXES & set(a for a in axes if a))):
        msize = mesh.shape["model"]
        for want in _MODEL_RETRY_PRIORITY:
            placed = False
            for i, (dim, name) in enumerate(zip(shape, axes)):
                if name == want and entries[i] is None and dim % msize == 0:
                    entries[i] = "model"
                    placed = True
                    break
            if placed:
                break

    # strip trailing Nones for tidiness
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def named_sharding(shape, axes, mesh: Mesh | None = None, rules=None) -> NamedSharding:
    mesh = mesh if mesh is not None else _CTX.mesh
    return NamedSharding(mesh, logical_to_spec(shape, axes, mesh, rules,
                                               param_retry=True))


def constrain(x, axes: Sequence[str | None]):
    """with_sharding_constraint(x, logical axes); no-op without a mesh."""
    if not _CTX.enabled or _CTX.mesh is None:
        return x
    spec = logical_to_spec(x.shape, axes, _CTX.mesh, _CTX.rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, spec))


def is_axes_leaf(x) -> bool:
    """A logical-axes annotation: tuple of str/None (possibly empty)."""
    return isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x)


def tree_shardings(abstract_tree, spec_tree, mesh: Mesh | None = None,
                   rules=None):
    """NamedSharding tree for a (ShapeDtypeStruct tree, logical-axes tree)."""
    mesh = mesh if mesh is not None else _CTX.mesh
    leaves, treedef = jax.tree.flatten(abstract_tree)
    axes_leaves, axes_treedef = jax.tree.flatten(spec_tree, is_leaf=is_axes_leaf)
    if treedef.num_leaves != axes_treedef.num_leaves:
        raise ValueError(
            f"params/axes tree mismatch: {treedef.num_leaves} vs "
            f"{axes_treedef.num_leaves} leaves")
    shardings = [named_sharding(l.shape, a, mesh, rules)
                 for l, a in zip(leaves, axes_leaves)]
    return jax.tree.unflatten(treedef, shardings)
