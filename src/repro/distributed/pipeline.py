"""GPipe-style pipeline parallelism over a mesh axis (multi-pod option).

Maps a stack of identical stages onto the 'pod' (or any) mesh axis and
streams microbatches through with ``collective_permute``.  This is the
alternative multi-pod strategy to pure data parallelism: activations cross
the (slow) pod interconnect once per stage boundary instead of gradients
crossing it once per step — the right trade when
``activation_bytes * microbatches < grad_bytes``.

Single-program schedule (classic JAX SPMD pipelining): every device runs
the same loop of ``M + P - 1`` ticks; at tick t, device p processes
microbatch ``t - p`` (when valid) and then shifts its output to device
p+1.  Bubble fraction = (P-1)/(M+P-1).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def pipeline_apply(
    stage_fn: Callable,
    stage_params,          # pytree; leaves have leading axis = n_stages
    x,                     # (M, mb, ...) microbatched input
    mesh: Mesh,
    axis: str = "pod",
):
    """Run x through n_stages of `stage_fn` pipelined over `axis`.

    stage_fn(params_slice, x_mb) -> y_mb with identical shape/dtype
    (inter-stage activations must be shape-stable, as in GPipe).
    Returns (M, mb, ...) outputs.
    """
    n_stages = mesh.shape[axis]
    m = x.shape[0]

    def body(params_local, xs):
        # params_local: this device's stage params; shard_map keeps the
        # sharded leading axis as size 1 -> squeeze it away.
        params_local = jax.tree.map(lambda a: a[0], params_local)
        # xs: full microbatch stack (replicated over `axis`).
        p_idx = jax.lax.axis_index(axis)
        n_ticks = m + n_stages - 1

        def tick(carry, t):
            state, outputs = carry
            # stage 0 ingests microbatch t; others take the permuted state
            mb_idx = jnp.clip(t, 0, m - 1)
            fresh = jnp.take(xs, mb_idx, axis=0)
            inp = jnp.where(p_idx == 0, fresh, state)
            out = stage_fn(params_local, inp)
            # my microbatch id at tick t is t - p_idx
            my_mb = t - p_idx
            is_last = p_idx == (n_stages - 1)
            valid = (my_mb >= 0) & (my_mb < m) & is_last
            upd = jax.lax.dynamic_update_slice_in_dim(
                outputs, out[None], jnp.clip(my_mb, 0, m - 1), axis=0)
            outputs = jnp.where(valid, upd, outputs)
            # shift activations to the next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            state = jax.lax.ppermute(out, axis, perm)
            return (state, outputs), None

        # fold in p_idx so the carries enter the scan already varying over
        # `axis` (the loop body's ppermute makes the outputs varying)
        vary0 = (p_idx * 0).astype(xs.dtype)
        state0 = jnp.zeros_like(jnp.take(xs, 0, axis=0)) + vary0
        outputs0 = jnp.zeros_like(xs) + vary0
        (state, outputs), _ = jax.lax.scan(
            tick, (state0, outputs0), jnp.arange(n_ticks))
        # only the last stage holds real outputs; broadcast them back
        outputs = jax.lax.psum(
            jnp.where(p_idx == n_stages - 1, outputs, jnp.zeros_like(outputs)),
            axis)
        return outputs

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    other_axes = [a for a in mesh.axis_names if a != axis]
    return shard_map(
        body, mesh=mesh,
        in_specs=(spec_params, P()),
        out_specs=P(),
    )(stage_params, x)
