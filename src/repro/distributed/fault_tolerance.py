"""Fault tolerance: restart driver, step watchdog, straggler mitigation.

TPU SPMD cannot tolerate per-device divergence, so fault handling lives at
the *driver* level (the pattern used by production TPU frameworks):

* **checkpoint/restart** — :func:`run_with_restarts` wraps the train loop;
  on any exception it restores the latest checkpoint and continues, up to a
  restart budget.  Because data pipeline + RNG + MC counters are all pure
  functions of the step, a restart replays the identical computation.
* **straggler detection** — :class:`StepWatchdog` tracks a robust moving
  estimate of step time; steps exceeding ``threshold x median`` raise a
  :class:`StragglerEvent` record.  On a real pod this feeds the re-shard /
  replace-host decision (here: logged + queryable, and the MC driver uses
  it to re-issue work units).
* **work re-issue** — for the embarrassingly-parallel MC workload, chunks
  are recomputable from counters alone; :class:`WorkQueue` re-issues chunks
  whose shard died (used by the integration driver).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import numpy as np


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    median: float


class StepWatchdog:
    """Flags steps slower than ``threshold`` x running median."""

    def __init__(self, threshold: float = 3.0, window: int = 32,
                 warmup: int = 3):
        self.threshold = threshold
        self.window = window
        self.warmup = warmup
        self.durations: list[float] = []
        self.events: list[StragglerEvent] = []
        self._t0: float | None = None
        self._step = 0

    def __enter__(self):
        self._t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        dt = time.monotonic() - self._t0
        hist = self.durations[-self.window:]
        if len(hist) >= self.warmup:
            med = float(np.median(hist))
            if dt > self.threshold * med:
                self.events.append(StragglerEvent(self._step, dt, med))
        self.durations.append(dt)
        self._step += 1
        return False

    @property
    def straggler_count(self) -> int:
        return len(self.events)


def run_with_restarts(body: Callable[[int], Any], *, max_restarts: int = 3,
                      on_restart: Callable[[int, Exception], None] | None = None):
    """Run ``body(attempt)`` with restart-on-exception semantics.

    ``body`` is responsible for restoring from its checkpoint directory at
    entry (the standard resume-from-latest pattern).  Returns body's result.
    """
    last: Exception | None = None
    for attempt in range(max_restarts + 1):
        try:
            return body(attempt)
        except Exception as e:  # noqa: BLE001 - driver-level catch is the point
            last = e
            if on_restart is not None:
                on_restart(attempt, e)
            if attempt == max_restarts:
                raise
    raise last  # unreachable


class WorkQueue:
    """Re-issuable chunk queue for the MC engine (counter-addressed work).

    Chunks are (sample_offset, n_samples) ranges; because the RNG is
    counter-based, *any* worker can (re)compute any chunk at any time and
    the merged result is independent of who computed what.
    """

    def __init__(self, total_samples: int, chunk: int):
        self.chunk = chunk
        self.pending: list[tuple[int, int]] = []
        off = 0
        while off < total_samples:
            n = min(chunk, total_samples - off)
            self.pending.append((off, n))
            off += n
        self.in_flight: dict[int, tuple[int, int]] = {}
        self.done: list[tuple[int, int]] = []
        self._next_ticket = 0

    def take(self) -> tuple[int, tuple[int, int]] | None:
        if not self.pending:
            return None
        item = self.pending.pop(0)
        ticket = self._next_ticket
        self._next_ticket += 1
        self.in_flight[ticket] = item
        return ticket, item

    def complete(self, ticket: int):
        self.done.append(self.in_flight.pop(ticket))

    def fail(self, ticket: int):
        """Worker died: chunk goes back to pending (re-issue)."""
        self.pending.insert(0, self.in_flight.pop(ticket))

    @property
    def finished(self) -> bool:
        return not self.pending and not self.in_flight
