"""Sharding-agnostic checkpointing with atomic commits and an async writer.

Layout:  <dir>/step_<k>/          one .npy per leaf + manifest.json
         <dir>/step_<k>.tmp/      staging (os.replace'd on commit)

The manifest stores leaf paths, shapes, dtypes and a config fingerprint.
Restore is **mesh-independent**: arrays are read whole and device_put with
whatever shardings the *new* mesh prescribes — this is the elastic-restart
path (save on mesh A, resume on mesh B), tested in
``tests/distributed/test_elastic.py``.

Multi-host note: in a real multi-host deployment each host would write only
its addressable shards (ocdbt-style); this single-process implementation
gathers full arrays, which is the correct semantics at this scale and keeps
the format trivially portable.
"""

from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
from typing import Any

import jax
import ml_dtypes  # noqa: F401  (registers bfloat16/fp8 with numpy)
import numpy as np


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(_key_str(k) for k in path)
        out.append((name, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def _sanitize(name: str) -> str:
    return re.sub(r"[^A-Za-z0-9_./-]", "_", name).replace("/", "__")


def save(directory: str, step: int, tree, *, extra: dict | None = None):
    """Atomically write `tree` under <directory>/step_<step>."""
    final = os.path.join(directory, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for name, leaf in _leaf_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        fname = _sanitize(name) + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"name": name, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(directory, d, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(directory: str, step: int, like_tree, *, shardings=None):
    """Read <directory>/step_<step> into the structure of `like_tree`.

    `shardings`: optional matching tree of NamedShardings (elastic resume
    onto a different mesh); leaves are device_put accordingly.
    """
    final = os.path.join(directory, f"step_{step}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    by_name = {l["name"]: l for l in manifest["leaves"]}

    names = [n for n, _ in _leaf_paths(like_tree)]
    leaves_like, treedef = jax.tree_util.tree_flatten(like_tree)
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(names))
    out = []
    for name, like, shard in zip(names, leaves_like, shard_leaves):
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = np.load(os.path.join(final, by_name[name]["file"]))
        if arr.dtype.kind == "V":
            # ml_dtypes (bfloat16, fp8) round-trip through .npy as raw void
            # records; reinterpret via the dtype recorded in the manifest
            arr = arr.view(np.dtype(by_name[name]["dtype"]))
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"{name}: shape {arr.shape} != {like.shape}")
        arr = arr.astype(like.dtype)
        out.append(jax.device_put(arr, shard) if shard is not None
                   else jax.device_put(arr))
    return treedef.unflatten(out), manifest


class AsyncCheckpointer:
    """Background-thread writer: train loop never blocks on I/O.

    save() snapshots device arrays to host (cheap) and enqueues the write;
    wait() drains the queue (call before exit / before restore).
    """

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: queue.Queue = queue.Queue()
        self._err: list[Exception] = []
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree, extra = item
            try:
                save(self.directory, step, host_tree, extra=extra)
                self._gc()
            except Exception as e:  # surfaced on wait()
                self._err.append(e)
            finally:
                self._q.task_done()

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for d in os.listdir(self.directory)
            if (m := re.fullmatch(r"step_(\d+)", d)))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    def save(self, step: int, tree, extra: dict | None = None):
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._q.put((step, host_tree, extra))

    def wait(self):
        self._q.join()
        if self._err:
            raise self._err[0]

    def close(self):
        self.wait()
        self._q.put(None)
        self._thread.join(timeout=10)
