"""The continuously-batching integration engine (submit/poll worker).

Life of a request:

1. **submit** — each family is canonicalized and content-hashed
   (:mod:`repro.service.canonical`); the hash (plus sampler) addresses a
   :class:`~repro.service.cache.CacheEntry`, allocated on first sight
   with its own counter-space range.  If every entry already meets the
   requested precision the result is finalized immediately — a pure
   cache hit, zero launches.  Otherwise the request parks in the pending
   table (bounded: submits beyond ``max_pending`` block, or raise
   :class:`~repro.service.api.Backpressure` when non-blocking).

2. **wave** (``step``) — the engine sweeps the pending table, asks the
   cache how many more rounds each entry needs, and emits deduplicated
   ``(entry, round)`` work items — two clients scanning overlapping
   parameter grids share evaluations here.  The
   :class:`~repro.service.batcher.RoundBatcher` coalesces the wave into
   fused dimension-bucket launches.  Each wave runs under the
   :class:`~repro.distributed.fault_tolerance.StepWatchdog` and inside
   :func:`~repro.distributed.fault_tolerance.run_with_restarts`: because
   work is counter-addressed and deposits happen only at wave end, a
   crashed wave replays identically.

3. **complete** — requests whose entries all meet their precision are
   finalized from the cache accumulators and their tickets released.

``start()`` spawns the worker thread for async submit/poll service;
``step()`` drives the same loop synchronously (tests, batch jobs).

With a ``state_dir``, the cache journals every deposit through a
:class:`~repro.service.store.DurableStore` (replayed on boot, corrupt
tails truncated) and ``stop()``/``close()`` snapshot-compact on
shutdown — so a SIGKILLed engine restarts warm: already-satisfied
requests cost zero launches and partially-met ones top up from their
persisted ``sample_offset`` bit-identically to an uninterrupted run.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Sequence

import numpy as np

from repro.core import rng as rng_lib
from repro.distributed.fault_tolerance import StepWatchdog, run_with_restarts
from repro.service.api import (Backpressure, IntegrationRequest,
                               IntegrationResult)
from repro.service.batcher import RoundBatcher, WorkItem
from repro.service.cache import CacheEntry, ResultCache
from repro.service.canonical import canonical_family, family_hash
from repro.service.store import DurableStore


@dataclasses.dataclass
class EngineStats:
    submitted: int = 0
    served: int = 0
    cache_hits: int = 0        # requests served with zero new rounds
    waves: int = 0
    items_executed: int = 0
    items_requested: int = 0   # before cross-request dedup
    restarts: int = 0

    @property
    def items_deduped(self) -> int:
        return self.items_requested - self.items_executed


@dataclasses.dataclass
class _Pending:
    ticket: int
    request: IntegrationRequest
    entries: list[CacheEntry]
    event: threading.Event
    result: IntegrationResult | None = None
    new_rounds_scheduled: bool = False


class IntegrationEngine:
    """Batching, caching, fault-tolerant integral server."""

    def __init__(self, *, seed: int = 0, round_samples: int = 65536,
                 use_kernel: bool = True, mesh=None, fn_axis: str = "model",
                 sample_axes: Sequence[str] | None = None,
                 chunk: int = 8192, max_pending: int = 256,
                 max_rounds_per_wave: int = 8, max_restarts: int = 2,
                 max_retained_results: int = 4096,
                 watchdog: StepWatchdog | None = None,
                 state_dir: str | None = None,
                 compact_on_start: bool = False,
                 store_fsync: bool = True):
        self.seed = int(seed)
        self.key = rng_lib.fold_key(self.seed, 0)
        self.store = None
        if state_dir is not None:
            self.store = DurableStore(state_dir, fsync=store_fsync)
        self.cache = ResultCache(round_samples=round_samples,
                                 store=self.store)
        if sample_axes is None and mesh is not None:
            sample_axes = tuple(a for a in mesh.axis_names if a != fn_axis)
        if mesh is not None:
            sample_par = int(np.prod([mesh.shape[a] for a in sample_axes]))
            # the unfused fallback (sharded_family_sums) rounds the budget
            # up to per-shard multiples; an inexact split would draw
            # overlapping counters across consecutive cache rounds
            if round_samples % sample_par:
                raise ValueError(
                    f"round_samples={round_samples} must divide evenly over "
                    f"the {sample_par} sample-axis shards of the mesh")
        self.batcher = RoundBatcher(
            self.cache, self.key, use_kernel=use_kernel, mesh=mesh,
            fn_axis=fn_axis, sample_axes=sample_axes or ("data",),
            chunk=chunk)
        if self.store is not None:
            # only after every constructor check passed: a rejected
            # configuration must not pin meta into a fresh state dir.
            # A state dir replays one counter stream — same seed, same
            # round quantization, or the resumed samples would differ.
            self.store.ensure_meta({"seed": self.seed,
                                    "round_samples": int(round_samples)})
            if compact_on_start:
                self.cache.snapshot_to_store()
        self.max_pending = int(max_pending)
        self.max_rounds_per_wave = int(max_rounds_per_wave)
        self.max_restarts = int(max_restarts)
        self.max_retained_results = int(max_retained_results)
        self.watchdog = watchdog if watchdog is not None else StepWatchdog()
        self.stats = EngineStats()

        self._pending: dict[int, _Pending] = {}
        # FIFO-bounded: a continuously-serving engine must not retain
        # every result ever served; clients that care call release()
        self._results: collections.OrderedDict[int, IntegrationResult] = \
            collections.OrderedDict()
        self._next_ticket = 0
        self._lock = threading.RLock()
        self._work_cv = threading.Condition(self._lock)
        self._space_cv = threading.Condition(self._lock)
        self._worker: threading.Thread | None = None
        self._stop = False

    # -- submit / poll --------------------------------------------------------
    @property
    def running(self) -> bool:
        return self._worker is not None and self._worker.is_alive()

    def submit(self, request: IntegrationRequest, *, block: bool = True,
               timeout: float | None = None) -> int:
        """Register a request; returns a ticket for :meth:`poll`/:meth:`result`.

        Pure cache hits complete inline (no waiting, no launches, and no
        pending-table space needed).  Otherwise, when the pending table
        is full, blocks until space frees up — or raises
        :class:`Backpressure` with ``block=False``.  A rejected submit
        allocates nothing: counter-space ranges are only consumed once
        the request is accepted.
        """
        canon_fams = []
        for fam in request.families:
            canon = canonical_family(fam)
            chash = f"{family_hash(canon, canonicalize=False)}:{request.sampler}"
            canon_fams.append((chash, canon))

        # hit path needs no allocation: all entries must already exist
        # (a persisted stream from a previous process counts — passing
        # the family lets the cache rehydrate it, so a warm *restart*
        # serves satisfied requests with zero launches too)
        peek = [self.cache.get(chash, canon) for chash, canon in canon_fams]
        if all(e is not None for e in peek):
            req = request
            if all(self.cache.meets(e, target_stderr=req.target_stderr,
                                    n_samples=req.n_samples) for e in peek):
                with self._lock:
                    ticket = self._new_ticket()
                    pend = _Pending(ticket=ticket, request=request,
                                    entries=list(peek),
                                    event=threading.Event())
                    self.stats.cache_hits += 1
                    self._finish(pend, served_from_cache=True)
                return ticket

        with self._lock:
            while len(self._pending) >= self.max_pending:
                if not block:
                    raise Backpressure(
                        f"{len(self._pending)} requests pending "
                        f"(max_pending={self.max_pending})")
                if not self._space_cv.wait(timeout=timeout):
                    raise Backpressure("timed out waiting for pending space")
            entries = [self.cache.get_or_allocate(chash, canon)
                       for chash, canon in canon_fams]
            ticket = self._new_ticket()
            pend = _Pending(ticket=ticket, request=request, entries=entries,
                            event=threading.Event())
            if self._meets(pend):     # became satisfiable while we waited
                self.stats.cache_hits += 1
                self._finish(pend, served_from_cache=True)
                return ticket
            self._pending[ticket] = pend
            self._work_cv.notify_all()
        return ticket

    def _new_ticket(self) -> int:
        ticket = self._next_ticket
        self._next_ticket += 1
        self.stats.submitted += 1
        return ticket

    def poll(self, ticket: int) -> IntegrationResult | None:
        """Finished result for ``ticket``, or None while in flight.

        Results are retained FIFO up to ``max_retained_results``;
        long-lived clients should :meth:`release` tickets they are done
        with rather than rely on retention.
        """
        with self._lock:
            return self._results.get(ticket)

    def release(self, ticket: int) -> None:
        """Drop a finished result the client no longer needs."""
        with self._lock:
            self._results.pop(ticket, None)

    def result(self, ticket: int, timeout: float | None = None) -> IntegrationResult:
        """Block until ``ticket`` finishes (worker thread must be running
        or another thread driving :meth:`step`)."""
        with self._lock:
            res = self._results.get(ticket)
            if res is not None:
                return res
            pend = self._pending.get(ticket)
        if pend is None:
            raise KeyError(f"unknown ticket {ticket}")
        if not pend.event.wait(timeout=timeout):
            raise TimeoutError(f"ticket {ticket} still pending")
        return pend.result

    # -- the wave loop --------------------------------------------------------
    def step(self) -> bool:
        """Run one batching wave synchronously.

        Returns True when work was executed, False when the pending
        table made no progress (empty or already satisfiable).
        """
        with self._lock:
            items = self._plan_wave()
        if not items:
            with self._lock:
                self._complete_ready()
            return False

        def wave(attempt: int) -> int:
            if attempt:
                with self._lock:
                    self.stats.restarts += 1
            with self.watchdog:
                return self.batcher.execute(items)

        executed = run_with_restarts(wave, max_restarts=self.max_restarts)
        with self._lock:
            self.stats.waves += 1
            self.stats.items_executed += executed
            self._complete_ready()
        return True

    def _plan_wave(self) -> list[WorkItem]:
        items: list[WorkItem] = []
        seen: set[WorkItem] = set()
        for pend in self._pending.values():
            req = pend.request
            for entry in pend.entries:
                need = self.cache.rounds_needed(
                    entry, target_stderr=req.target_stderr,
                    n_samples=req.n_samples,
                    max_rounds=self.max_rounds_per_wave)
                if need:
                    pend.new_rounds_scheduled = True
                for r in range(entry.rounds_done, entry.rounds_done + need):
                    it = WorkItem(chash=entry.chash, round_index=r,
                                  sampler=req.sampler)
                    self.stats.items_requested += 1
                    if it not in seen:
                        seen.add(it)
                        items.append(it)
        return items

    def _meets(self, pend: _Pending) -> bool:
        req = pend.request
        return all(
            self.cache.meets(e, target_stderr=req.target_stderr,
                             n_samples=req.n_samples)
            for e in pend.entries)

    def _complete_ready(self) -> None:
        done = [p for p in self._pending.values() if self._meets(p)]
        for pend in done:
            del self._pending[pend.ticket]
            self._finish(pend,
                         served_from_cache=not pend.new_rounds_scheduled)
        if done:
            self._space_cv.notify_all()

    def _finish(self, pend: _Pending, *, served_from_cache: bool) -> None:
        means, errs = [], []
        for entry in pend.entries:
            res = entry.finalize()
            means.append(np.asarray(res.mean))
            errs.append(np.asarray(res.stderr))
        pend.result = IntegrationResult(
            means=np.concatenate(means), stderrs=np.concatenate(errs),
            n_per_family=tuple(e.n for e in pend.entries),
            names=tuple(f.name for f in pend.request.families),
            served_from_cache=served_from_cache, ticket=pend.ticket)
        self._results[pend.ticket] = pend.result
        while len(self._results) > self.max_retained_results:
            self._results.popitem(last=False)
        self.stats.served += 1
        pend.event.set()

    # -- background worker ----------------------------------------------------
    def start(self) -> None:
        """Spawn the worker thread (idempotent)."""
        with self._lock:
            if self.running:
                return
            self._stop = False
            self._worker = threading.Thread(
                target=self._run, name="integration-engine", daemon=True)
            self._worker.start()

    def stop(self, timeout: float | None = 30.0) -> None:
        with self._lock:
            self._stop = True
            self._work_cv.notify_all()
            worker = self._worker
        if worker is not None:
            worker.join(timeout=timeout)
            if worker.is_alive():
                # mid-wave; keep the handle so running stays True and a
                # start() cannot spawn a second concurrent worker
                raise TimeoutError(
                    "worker still executing a wave; it will exit at the "
                    "wave boundary (retry stop())")
            self._worker = None
        # snapshot-on-shutdown: compact the journal once no worker can
        # deposit anymore (a kill before this point only costs replay)
        self.checkpoint()

    def checkpoint(self) -> None:
        """Compact accumulated state into an atomic snapshot (no-op
        without a ``state_dir``).  Safe at any wave boundary."""
        if self.store is not None:
            self.cache.snapshot_to_store()

    def close(self, timeout: float | None = 30.0) -> None:
        """Clean shutdown: stop the worker, snapshot, release the store.

        If the worker outlives ``timeout`` the TimeoutError from
        :meth:`stop` still propagates, but the store handle is released
        regardless — the journal already holds every folded round, so
        skipping the shutdown snapshot costs replay time, never data.
        """
        try:
            self.stop(timeout=timeout)
        finally:
            if self.store is not None:
                self.store.close()

    def __enter__(self) -> "IntegrationEngine":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    def drain(self, timeout: float | None = None) -> None:
        """Block until the pending table is empty (worker running)."""
        events = []
        with self._lock:
            events = [p.event for p in self._pending.values()]
        for ev in events:
            if not ev.wait(timeout=timeout):
                raise TimeoutError("pending requests did not drain")

    def _run(self) -> None:
        while True:
            with self._lock:
                while not self._pending and not self._stop:
                    self._work_cv.wait(timeout=0.5)
                if self._stop:
                    return
            self.step()
