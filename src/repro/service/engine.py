"""The continuously-batching integration engine (submit/poll worker).

Life of a request:

1. **submit** — each family is canonicalized and content-hashed
   (:mod:`repro.service.canonical`); the hash (plus sampler) addresses a
   :class:`~repro.service.cache.CacheEntry`, allocated on first sight
   with its own counter-space range.  If every entry already meets the
   requested precision the result is finalized immediately — a pure
   cache hit, zero launches.  Otherwise the request parks in the pending
   table (bounded: submits beyond ``max_pending`` block, or raise
   :class:`~repro.service.api.Backpressure` when non-blocking).

2. **wave** (``step``) — the engine sweeps the pending table, asks the
   cache how many more rounds each entry needs beyond its fold frontier
   *plus whatever is already in flight*, and assigns the wave's round
   budget **fairly**: requests are visited round-robin (one round per
   stream per pass, rotating the starting request every wave), so when
   ``max_items_per_wave`` bounds the wave, a heavy precision ask can
   never starve a small latency-sensitive one.  The
   :class:`~repro.service.batcher.RoundBatcher` coalesces the wave into
   fused multi-round dimension-bucket launches (an R-round wave over B
   buckets costs B ``pallas_call``\\ s).  Each wave runs under the
   :class:`~repro.distributed.fault_tolerance.StepWatchdog` and inside
   :func:`~repro.distributed.fault_tolerance.run_with_restarts`: because
   work is counter-addressed and deposits happen only at wave end, a
   crashed wave replays identically.

   The background worker **pipelines** waves (double buffering): wave
   k+1's device work is dispatched while wave k's results transfer and
   group-commit on the host, keeping deposits and WAL journaling off the
   device critical path (``pipeline_waves=False`` restores strictly
   serial waves).  In-flight rounds are tracked per stream so the
   planner schedules beyond them instead of re-planning them.

2b. **adapt** (opt-in) — a request with ``adaptive=True`` and a stderr
   target samples through a VEGAS importance grid
   (:mod:`repro.core.adaptive`, ``docs/adaptive.md``): epoch 1 is fit
   at submit from a deterministic counter-keyed pilot, and the planner
   refits between waves while the target is unmet.  Every epoch is a
   NEW cache stream keyed by its grid's edges (the grid record is
   journaled *before* the child's alloc — the Layer-3 STR007 chain),
   so adapted streams keep the bit-identical resume contract: a
   restarted engine adopts the journaled chain tip instead of
   refitting.

3. **complete** — requests whose entries all meet their precision are
   finalized from the cache accumulators and their tickets released.

``start()`` spawns the worker thread for async submit/poll service;
``step()`` drives the same loop synchronously (tests, batch jobs).

With a ``state_dir``, the cache journals every deposit through a
:class:`~repro.service.store.DurableStore` (replayed on boot, corrupt
tails truncated) and ``stop()``/``close()`` snapshot-compact on
shutdown — so a SIGKILLed engine restarts warm: already-satisfied
requests cost zero launches and partially-met ones top up from their
persisted ``sample_offset`` bit-identically to an uninterrupted run.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import zlib
from typing import Sequence

import numpy as np

from repro.analysis import streams as _analysis
from repro.core import adaptive
from repro.core import rng as rng_lib
from repro.obs import Observability
from repro.obs import clock as _clock
from repro.service.api import (Backpressure, IntegrationRequest,
                               IntegrationResult, RequestFailed,
                               SweepRequest, SweepResult)
from repro.service.batcher import InFlightWave, RoundBatcher, WorkItem
from repro.service.cache import CacheEntry, ResultCache
from repro.service.canonical import (DEFAULT_SWEEP_SLICE, canonical_family,
                                     family_hash, sweep_slices)
from repro.service.faults import NULL_FAULTS, InjectedCrash
from repro.service.resilience import (Deadline, DeadlineExceeded,
                                      RetryExhausted, RetryPolicy,
                                      StepWatchdog, run_with_policy)
from repro.service.store import DurableStore


def _wave_streams(items: Sequence[WorkItem]) -> list[str]:
    """Stable, deduplicated stream-id prefixes for event payloads."""
    seen: list[str] = []
    for it in items:
        sid = it.chash[:16]
        if sid not in seen:
            seen.append(sid)
    return seen


@dataclasses.dataclass
class EngineStats:
    submitted: int = 0
    served: int = 0
    cache_hits: int = 0        # requests served with zero new rounds
    waves: int = 0
    items_executed: int = 0
    items_requested: int = 0   # before cross-request dedup
    restarts: int = 0
    failed: int = 0            # tickets completed as RequestFailed
    deadline_expirations: int = 0

    @property
    def items_deduped(self) -> int:
        return self.items_requested - self.items_executed


@dataclasses.dataclass(frozen=True)
class _SweepInfo:
    """Grid geometry a sweep ticket needs to assemble its result."""
    grid_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    n_points: int
    slice_sizes: tuple[int, ...]   # points per canonical slice, in order
    slice_names: tuple[str, ...]


@dataclasses.dataclass
class _AdaptiveState:
    """Planner-side record of one base stream's importance-grid chain.

    ``chash``/``edges``/``epoch`` track the *current* (deepest) epoch
    stream; ``base_family`` is the canonical pre-grid family every
    pilot evaluates (pilots never sample through the grid being refit —
    :func:`repro.core.adaptive.pilot_weights` maps its own uniforms).
    ``frozen`` marks a converged chain (a refit reproduced the current
    edges); it is in-memory only, but a resumed engine re-derives it
    from the same deterministic pilot.
    """

    base_chash: str
    base_family: object     # the canonical pre-grid IntegrandFamily
    sampler: str
    epoch: int
    edges: np.ndarray
    chash: str
    family: object          # the current epoch's adapted IntegrandFamily
    frozen: bool = False


@dataclasses.dataclass
class _Pending:
    ticket: int
    request: IntegrationRequest | SweepRequest
    entries: list[CacheEntry]
    event: threading.Event
    result: IntegrationResult | RequestFailed | None = None
    new_rounds_scheduled: bool = False
    sweep: _SweepInfo | None = None
    deadline: Deadline | None = None


class IntegrationEngine:
    """Batching, caching, fault-tolerant integral server."""

    def __init__(self, *, seed: int = 0, round_samples: int = 65536,
                 use_kernel: bool = True, mesh=None, fn_axis: str = "model",
                 sample_axes: Sequence[str] | None = None,
                 chunk: int = 8192, max_pending: int = 256,
                 max_rounds_per_wave: int = 8,
                 max_items_per_wave: int | None = None,
                 pipeline_waves: bool = True, max_restarts: int = 2,
                 max_retained_results: int = 4096,
                 watchdog: StepWatchdog | None = None,
                 state_dir: str | None = None,
                 compact_on_start: bool = False,
                 store_fsync: bool = True,
                 sweep_slice_points: int = DEFAULT_SWEEP_SLICE,
                 obs: Observability | None = None,
                 retry_policy: RetryPolicy | None = None,
                 faults=None, lease_ttl: float | None = 30.0,
                 adapt_bins: int = adaptive.N_BINS,
                 adapt_pilot_samples: int = 4096,
                 adapt_max_epochs: int = 3,
                 adapt_rounds_per_epoch: int = 2):
        # telemetry first: every layer below receives the same bundle
        self.obs = obs if obs is not None else Observability.disabled()
        self.seed = int(seed)
        self.key = rng_lib.fold_key(self.seed, 0)
        # the ONE retry policy (rule RES001): `max_restarts` is kept as
        # shorthand for its attempt budget; an explicit policy wins
        if retry_policy is None:
            retry_policy = RetryPolicy(max_attempts=int(max_restarts) + 1,
                                       seed=self.seed)
        self.retry = retry_policy
        self.faults = (NULL_FAULTS if faults is None
                       else faults).bind(self.obs)
        self.store = None
        if state_dir is not None:
            self.store = DurableStore(state_dir, fsync=store_fsync,
                                      obs=self.obs, faults=self.faults,
                                      lease_ttl=lease_ttl)
        self.cache = ResultCache(round_samples=round_samples,
                                 store=self.store, obs=self.obs)
        if sample_axes is None and mesh is not None:
            sample_axes = tuple(a for a in mesh.axis_names if a != fn_axis)
        if mesh is not None:
            sample_par = int(np.prod([mesh.shape[a] for a in sample_axes]))
            # the unfused fallback (sharded_family_sums) rounds the budget
            # up to per-shard multiples; an inexact split would draw
            # overlapping counters across consecutive cache rounds
            if round_samples % sample_par:
                raise ValueError(
                    f"round_samples={round_samples} must divide evenly over "
                    f"the {sample_par} sample-axis shards of the mesh")
        self.batcher = RoundBatcher(
            self.cache, self.key, use_kernel=use_kernel, mesh=mesh,
            fn_axis=fn_axis, sample_axes=sample_axes or ("data",),
            chunk=chunk, obs=self.obs, faults=self.faults)
        if self.store is not None:
            # only after every constructor check passed: a rejected
            # configuration must not pin meta into a fresh state dir.
            # A state dir replays one counter stream — same seed, same
            # round quantization, or the resumed samples would differ.
            self.store.ensure_meta({"seed": self.seed,
                                    "round_samples": int(round_samples)})
            if compact_on_start:
                self.cache.snapshot_to_store()
        if int(sweep_slice_points) < 1:
            raise ValueError("sweep_slice_points must be >= 1")
        # part of the dedupe contract: engines chunking at different
        # quanta never share sweep streams (see canonical.sweep_slices)
        self.sweep_slice_points = int(sweep_slice_points)
        self.max_pending = int(max_pending)
        self.max_rounds_per_wave = int(max_rounds_per_wave)
        if max_items_per_wave is not None and int(max_items_per_wave) <= 0:
            # 0 would silently mean "unbounded" in the planner's
            # truthiness check — reject it loudly instead
            raise ValueError("max_items_per_wave must be positive "
                             "(or None for unbounded)")
        self.max_items_per_wave = (None if max_items_per_wave is None
                                   else int(max_items_per_wave))
        self.pipeline_waves = bool(pipeline_waves)
        self.max_restarts = self.retry.max_attempts - 1
        self.max_retained_results = int(max_retained_results)
        self.watchdog = watchdog if watchdog is not None else StepWatchdog()
        # importance-grid adaptation knobs (docs/adaptive.md): pilots
        # and refit cadence are deterministic in (seed, base stream,
        # epoch) + durable rounds_done, so two engines with the same
        # knobs replay the same epoch chain
        if int(adapt_bins) < 2:
            raise ValueError("adapt_bins must be >= 2")
        if int(adapt_max_epochs) < 1 or int(adapt_rounds_per_epoch) < 1:
            raise ValueError("adapt_max_epochs and adapt_rounds_per_epoch "
                             "must be >= 1")
        self.adapt_bins = int(adapt_bins)
        self.adapt_pilot_samples = int(adapt_pilot_samples)
        self.adapt_max_epochs = int(adapt_max_epochs)
        self.adapt_rounds_per_epoch = int(adapt_rounds_per_epoch)
        self._adaptive: dict[str, _AdaptiveState] = {}
        self.stats = EngineStats()

        self._pending: dict[int, _Pending] = {}
        # FIFO-bounded: a continuously-serving engine must not retain
        # every result ever served; clients that care call release()
        self._results: collections.OrderedDict[int, IntegrationResult] = \
            collections.OrderedDict()
        self._next_ticket = 0
        # rounds dispatched but not yet deposited, per stream: the
        # planner schedules *beyond* these (pipelined waves, racing
        # step() drivers) instead of re-planning them
        self._inflight: dict[str, int] = {}
        self._rr_cursor = 0
        self._wave_seq = 0
        self._lock = threading.RLock()
        self._work_cv = threading.Condition(self._lock)
        self._space_cv = threading.Condition(self._lock)
        self._deposit_cv = threading.Condition(self._lock)
        self._worker: threading.Thread | None = None
        self._stop = False
        # armed by the first completed stop(): makes stop()/close()
        # re-entrant (second call is a no-op, no double snapshot)
        self._shutdown = False

    # -- submit / poll --------------------------------------------------------
    @property
    def running(self) -> bool:
        return self._worker is not None and self._worker.is_alive()

    def submit(self, request: IntegrationRequest | SweepRequest, *,
               block: bool = True, timeout: float | None = None) -> int:
        """Register a request; returns a ticket for :meth:`poll`/:meth:`result`.

        Accepts both request shapes — a :class:`SweepRequest` dispatches
        to :meth:`submit_sweep`.  Pure cache hits complete inline (no
        waiting, no launches, and no pending-table space needed).
        Otherwise, when the pending table is full, blocks until space
        frees up — or raises :class:`Backpressure` with ``block=False``.
        A rejected submit allocates nothing: counter-space ranges are
        only consumed once the request is accepted.
        """
        if isinstance(request, SweepRequest):
            return self.submit_sweep(request, block=block, timeout=timeout)
        # adaptation needs a precision target to chase (a pure sample
        # budget has nothing to adapt toward — the flag is ignored) and
        # never applies to swept slices (the sweep table and the grid
        # map would compete for the packed row; see docs/adaptive.md)
        adapt = bool(getattr(request, "adaptive", False)
                     and request.target_stderr is not None)
        canon_fams = []
        for fam in request.families:
            canon = canonical_family(fam)
            chash = f"{family_hash(canon, canonicalize=False)}:{request.sampler}"
            if adapt and not canon.swept:
                with self._lock:
                    ast = self._adaptive_state(chash, canon, request.sampler)
                canon_fams.append((ast.chash, ast.family))
            else:
                canon_fams.append((chash, canon))
        return self._submit_canonical(request, canon_fams, block=block,
                                      timeout=timeout)

    def submit_sweep(self, request: SweepRequest, *, block: bool = True,
                     timeout: float | None = None) -> int:
        """Register a parameter sweep; returns a ticket like :meth:`submit`.

        The grid canonicalizes into fixed ``sweep_slice_points``-sized
        slices of swept families (``canonical.sweep_slices``) — each
        slice one cache stream, so counter-space placement, top-up,
        persistence and the STR001–006 invariants apply per slice
        unchanged, and an overlapping sweep from another client dedupes
        onto the shared slices.  When the template names a registered
        kernel form, the (dim, sampler, compactified, sweep) capability
        is checked here, eagerly, with ``registry.lookup(...,
        required=True)`` — a sweep the fused path cannot serve fails at
        submit with the nearest supported combo named, instead of
        silently falling back for 10^5 points.
        """
        with self.obs.span("sweep_plan", template=request.template.name,
                           axes=len(request.grid)):
            fams, shape, axis_names = sweep_slices(
                request.template, request.grid,
                slice_points=self.sweep_slice_points)
            probe = fams[0]
            if probe.kernel is not None:
                from repro.kernels import registry
                if registry.form(probe.kernel) is not None:
                    registry.lookup(probe.kernel, dim=probe.dim,
                                    sampler=request.sampler,
                                    compactified=probe.compact,
                                    sweep=probe.swept,
                                    adapted=bool(probe.adapt_bins),
                                    required=True)
            canon_fams = [
                (f"{family_hash(f, canonicalize=False)}:{request.sampler}", f)
                for f in fams]
        n_points = int(np.prod(shape))
        shared = sum(1 for chash, f in canon_fams
                     if self.cache.get(chash, f) is not None)
        self.obs.m["sweep_submitted"].inc()
        self.obs.m["sweep_points"].inc(n_points)
        if shared:
            self.obs.m["sweep_slices"].inc(shared, outcome="shared")
        if len(canon_fams) - shared:
            self.obs.m["sweep_slices"].inc(len(canon_fams) - shared,
                                           outcome="new")
        sweep = _SweepInfo(grid_shape=shape, axis_names=axis_names,
                           n_points=n_points,
                           slice_sizes=tuple(f.n_fn for f in fams),
                           slice_names=tuple(f.name for f in fams))
        return self._submit_canonical(request, canon_fams, block=block,
                                      timeout=timeout, sweep=sweep)

    def _submit_canonical(self, request, canon_fams, *, block: bool,
                          timeout: float | None,
                          sweep: _SweepInfo | None = None) -> int:
        """Shared tail of :meth:`submit`/:meth:`submit_sweep`: cache-hit
        peek, pending-table admission, allocation."""
        # hit path needs no allocation: all entries must already exist
        # (a persisted stream from a previous process counts — passing
        # the family lets the cache rehydrate it, so a warm *restart*
        # serves satisfied requests with zero launches too)
        peek = [self.cache.get(chash, canon) for chash, canon in canon_fams]
        if all(e is not None for e in peek):
            req = request
            if all(self.cache.meets(e, target_stderr=req.target_stderr,
                                    n_samples=req.n_samples) for e in peek):
                with self._lock:
                    ticket = self._new_ticket()
                    pend = _Pending(ticket=ticket, request=request,
                                    entries=list(peek),
                                    event=threading.Event(), sweep=sweep)
                    self.stats.cache_hits += 1
                    self.obs.m["cache_requests"].inc(outcome="hit")
                    self._finish(pend, served_from_cache=True)
                return ticket

        with self._lock:
            while len(self._pending) >= self.max_pending:
                if not block:
                    raise Backpressure(
                        f"{len(self._pending)} requests pending "
                        f"(max_pending={self.max_pending})")
                if not self._space_cv.wait(timeout=timeout):
                    raise Backpressure("timed out waiting for pending space")
            entries = [self.cache.get_or_allocate(chash, canon)
                       for chash, canon in canon_fams]
            ticket = self._new_ticket()
            budget = getattr(request, "deadline", None)
            pend = _Pending(ticket=ticket, request=request, entries=entries,
                            event=threading.Event(), sweep=sweep,
                            deadline=(None if budget is None
                                      else Deadline(budget)))
            if self._meets(pend):     # became satisfiable while we waited
                self.stats.cache_hits += 1
                self.obs.m["cache_requests"].inc(outcome="hit")
                self._finish(pend, served_from_cache=True)
                return ticket
            self.obs.m["cache_requests"].inc(outcome="miss")
            self._pending[ticket] = pend
            self.obs.m["pending"].set(len(self._pending))
            self._work_cv.notify_all()
        return ticket

    def _new_ticket(self) -> int:
        ticket = self._next_ticket
        self._next_ticket += 1
        self.stats.submitted += 1
        self.obs.m["submitted"].inc()
        return ticket

    def poll(self, ticket: int) -> IntegrationResult | None:
        """Finished result for ``ticket``, or None while in flight.

        Results are retained FIFO up to ``max_retained_results``;
        long-lived clients should :meth:`release` tickets they are done
        with rather than rely on retention.
        """
        with self._lock:
            return self._results.get(ticket)

    def sweep_partial(self, ticket: int,
                      since: np.ndarray | None = None) -> SweepResult:
        """Per-point snapshot of a sweep, streamed as rounds complete.

        Non-blocking: for a finished sweep this is exactly the final
        :class:`SweepResult`; while in flight it carries the current
        estimate of every point whose slice has deposited at least one
        round (``points_done`` marks them; undone points hold NaN means
        and inf stderrs) with ``complete=False``.  Slices finish in
        counter order within a wave, so a client can consume a large
        sweep incrementally instead of blocking for the whole grid.

        ``since`` makes the poll *incremental*: pass the previous
        snapshot's ``points_done`` mask and only slices with points not
        yet covered by it are finalized — an already-reported slice is
        marked done but carries NaN/inf placeholders (the caller keeps
        its previous values).  A poll loop over a large grid then pays
        the per-point finalize cost once per point, not once per poll.
        The mask covers the full grid including any final partial slice
        of a grid that is not a multiple of the slice quantum.
        """
        with self._lock:
            res = self._results.get(ticket)
            if res is None:
                pend = self._pending.get(ticket)
                if pend is None:
                    raise KeyError(f"unknown ticket {ticket}")
                if pend.sweep is None:
                    raise TypeError(f"ticket {ticket} is not a sweep")
                sw = pend.sweep
                if since is not None:
                    since = np.asarray(since, bool)
                    if since.shape != (sw.n_points,):
                        raise ValueError(
                            f"since mask has shape {since.shape}; expected "
                            f"({sw.n_points},) — pass the previous "
                            f"snapshot's points_done unchanged")
                means, errs, done = [], [], []
                offset = 0
                for entry, size in zip(pend.entries, sw.slice_sizes):
                    # explicit per-slice extent: the final slice of a
                    # grid that is not a multiple of the slice quantum
                    # is shorter, and the mask must align point-exactly
                    seen = (since is not None
                            and bool(np.all(since[offset:offset + size])))
                    offset += size
                    if entry.rounds_done > 0:
                        done.append(np.ones(size, bool))
                        if seen:
                            means.append(np.full(size, np.nan, np.float32))
                            errs.append(np.full(size, np.inf, np.float32))
                        else:
                            snap = entry.finalize()
                            means.append(np.asarray(snap.mean))
                            errs.append(np.asarray(snap.stderr))
                    else:
                        means.append(np.full(size, np.nan, np.float32))
                        errs.append(np.full(size, np.inf, np.float32))
                        done.append(np.zeros(size, bool))
                return SweepResult(
                    means=np.concatenate(means),
                    stderrs=np.concatenate(errs),
                    n_per_family=tuple(e.n for e in pend.entries),
                    names=sw.slice_names, served_from_cache=False,
                    ticket=ticket,
                    stream_ids=tuple(e.chash for e in pend.entries),
                    grid_shape=sw.grid_shape, axis_names=sw.axis_names,
                    n_points=sw.n_points,
                    points_done=np.concatenate(done), complete=False)
        if not isinstance(res, SweepResult):
            raise TypeError(f"ticket {ticket} is not a sweep")
        return res

    def release(self, ticket: int) -> None:
        """Drop a finished result the client no longer needs."""
        with self._lock:
            self._results.pop(ticket, None)

    def result(self, ticket: int,
               timeout: float | None = None) -> IntegrationResult:
        """Block until ``ticket`` finishes (worker thread must be running
        or another thread driving :meth:`step`).

        A request that failed permanently (retry budget exhausted,
        deadline expired, stream quarantined) returns its structured
        :class:`~repro.service.api.RequestFailed` — a completed ticket,
        not a hang.
        """
        with self._lock:
            res = self._results.get(ticket)
            if res is not None:
                return res
            pend = self._pending.get(ticket)
        if pend is None:
            raise KeyError(f"unknown ticket {ticket}")
        if not pend.event.wait(timeout=timeout):
            with self._lock:
                state = ("pending" if ticket in self._pending
                         else "completing")
                rounds = [e.rounds_done for e in pend.entries]
            raise TimeoutError(
                f"ticket {ticket} still {state} after {timeout:g}s "
                f"(worker {'running' if self.running else 'NOT running'}, "
                f"rounds folded per stream: {rounds})")
        return pend.result

    # -- the wave loop --------------------------------------------------------
    def step(self) -> bool:
        """Run one batching wave synchronously.

        Returns True when work was executed (or is executing in another
        driver's wave), False when the pending table made no progress
        (empty or already satisfiable).
        """
        with self._lock:
            with self.obs.span("plan", pending=len(self._pending)):
                items = self._plan_wave()
            if not items:
                self._complete_ready()
                if self._awaiting_other_driver_locked():
                    # every remaining round is in another driver's wave;
                    # wait for a deposit instead of claiming deadlock
                    self._deposit_cv.wait(timeout=1.0)
                    return True
                return False
            seq = self._wave_seq
            self._wave_seq += 1

        def wave(attempt: int) -> int:
            if attempt:
                with self._lock:
                    self.stats.restarts += 1
                self.obs.m["retries"].inc(stage="wave")
            self.faults.check("plan")
            with self.watchdog:
                return self.batcher.execute(items)

        t0 = _clock.monotonic()
        stragglers_before = self.watchdog.straggler_count
        try:
            executed = run_with_policy(
                wave, self.retry, stage="wave", counter=seq,
                deadline=self._wave_deadline(items),
                on_retry=self._restart_hook("wave_restart", seq, items))
        except (RetryExhausted, DeadlineExceeded) as exc:
            # the wave is permanently lost: complete its tickets with a
            # structured failure, then surface the error to this
            # synchronous driver (async drivers swallow and move on)
            with self._lock:
                self._retire_items(items)
                self._fail_wave(items, exc)
            raise
        except Exception:
            with self._lock:
                self._retire_items(items)
            raise
        self._note_stragglers(stragglers_before, seq, items)
        self.obs.m["waves"].inc()
        self.obs.m["wave_seconds"].observe(_clock.monotonic() - t0)
        with self._lock:
            self._retire_items(items)
            self.stats.waves += 1
            self.stats.items_executed += executed
            self._complete_ready()
        return True

    # -- telemetry hooks ------------------------------------------------------
    def _restart_hook(self, kind: str, seq: int,
                      items: Sequence[WorkItem]):
        """on_restart callback emitting a structured event carrying the
        wave sequence number and the affected stream identities."""
        def on_restart(attempt: int, exc: Exception) -> None:
            self.obs.m["restarts"].inc()
            self.obs.event(kind, wave=seq, attempt=attempt,
                           error=type(exc).__name__,
                           streams=_wave_streams(items))
        return on_restart

    def _note_stragglers(self, before: int, seq: int,
                         items: Sequence[WorkItem]) -> None:
        """Emit one instant event per watchdog straggler the wave added."""
        new = self.watchdog.straggler_count - before
        if new <= 0:
            return
        self.obs.m["stragglers"].inc(new)
        for ev in self.watchdog.events[-new:]:
            self.obs.event("straggler", wave=seq, step=ev.step,
                           duration=ev.duration, median=ev.median,
                           streams=_wave_streams(items))

    def stderr_trajectory(self, chash: str):
        """Per-stream convergence record: the stderr-vs-rounds trajectory
        observed at deposit time (requires convergence recording, i.e. an
        engine built with ``Observability.enabled()``).  ``chash`` is a
        stream id as reported by ``IntegrationResult.stream_ids``."""
        return self.obs.convergence.trajectory(chash)

    def _awaiting_other_driver_locked(self) -> bool:
        return any(self._inflight.get(e.chash) for p in self._pending.values()
                   for e in p.entries)

    # -- failure surfacing ----------------------------------------------------
    def _wave_deadline(self, items: Sequence[WorkItem]) -> Deadline | None:
        """Tightest remaining per-request deadline riding this wave, as
        a fresh budget for the retry loop (None when no rider has one)."""
        streams = {it.chash for it in items}
        with self._lock:
            remains = [p.deadline.remaining()
                       for p in self._pending.values()
                       if p.deadline is not None
                       and any(e.chash in streams for e in p.entries)]
        if not remains:
            return None
        return Deadline(max(min(remains), 1e-9))

    def _fail_wave(self, items: Sequence[WorkItem], exc: Exception) -> None:
        """Complete the tickets a permanently-failed wave was serving
        with a structured :class:`RequestFailed` (caller holds the lock).

        A :class:`DeadlineExceeded` fails only the riders whose own
        deadline ran out — other requests on the same streams simply get
        rescheduled; :class:`RetryExhausted` fails every rider.
        """
        streams = {it.chash for it in items}
        riders = [p for p in self._pending.values()
                  if any(e.chash in streams for e in p.entries)]
        if isinstance(exc, DeadlineExceeded):
            riders = [p for p in riders
                      if p.deadline is not None and p.deadline.expired]
            reason = "deadline"
        else:
            reason = "retry_exhausted"
        for pend in riders:
            del self._pending[pend.ticket]
            if reason == "deadline":
                self.stats.deadline_expirations += 1
                self.obs.m["deadline_expirations"].inc()
            self._fail(pend, reason=reason,
                       stage=getattr(exc, "stage", None),
                       attempts=getattr(exc, "attempts", 0),
                       message=str(exc))
        if riders:
            self.obs.m["pending"].set(len(self._pending))
            self._space_cv.notify_all()

    def _fail(self, pend: _Pending, *, reason: str, stage: str | None = None,
              attempts: int = 0, message: str = "") -> None:
        """Terminal completion of one ticket as ``RequestFailed``
        (caller holds the lock)."""
        pend.result = RequestFailed(
            ticket=pend.ticket, reason=reason, stage=stage,
            attempts=attempts, message=message,
            stream_ids=tuple(e.chash for e in pend.entries))
        self._results[pend.ticket] = pend.result
        while len(self._results) > self.max_retained_results:
            self._results.popitem(last=False)
        self.stats.failed += 1
        self.obs.event("request_failed", ticket=pend.ticket, reason=reason,
                       stage=stage, streams=[c[:16]
                                             for c in pend.result.stream_ids])
        pend.event.set()

    # -- importance-grid adaptation -------------------------------------------
    def _pilot_key(self, base_chash: str, epoch: int) -> tuple:
        """Counter key of the (base stream, epoch) pilot wave.

        Folded onto a stream id derived from the base hash and the
        epoch being fit, so pilot counters can never collide with the
        engine's main sample streams (which fold on stream 0) and a
        resumed planner re-draws the identical pilot.
        """
        sid = zlib.crc32(f"adapt:{base_chash}:{int(epoch)}".encode())
        return rng_lib.fold_key(self.seed, sid)

    def _adaptive_state(self, base_chash: str, canon,
                        sampler: str) -> _AdaptiveState:
        """Active importance-grid state for one base stream (caller
        holds the lock).

        Resume first: when the WAL/snapshot carries an epoch chain
        rooted at ``base_chash`` the planner adopts its tip — recorded
        chash, recorded edges — so the resumed stream samples through
        exactly the journaled grid (refitting could differ only if the
        code changed; the record is the contract).  Otherwise epoch 1
        is fit here, at submit, from a deterministic pilot, and its
        grid is journaled *before* the child stream's alloc (STR007).
        """
        ast = self._adaptive.get(base_chash)
        if ast is not None:
            return ast
        tip = self.cache.grid_tip(base_chash)
        if tip is not None:
            fam = canon.adapted(tip.edges, epoch=tip.epoch)
            ast = _AdaptiveState(
                base_chash=base_chash, base_family=canon, sampler=sampler,
                epoch=tip.epoch, edges=np.asarray(tip.edges),
                chash=tip.chash, family=fam)
        else:
            edges = adaptive.initial_edges(np.asarray(canon.domains),
                                           self.adapt_bins)
            weights = adaptive.pilot_weights(
                canon, edges, self._pilot_key(base_chash, 1),
                self.adapt_pilot_samples)
            edges = adaptive.refine_edges(edges, weights)
            fam = canon.adapted(edges, epoch=1)
            chash = f"{family_hash(fam, canonicalize=False)}:{sampler}"
            self.cache.register_grid(chash, parent=base_chash, epoch=1,
                                     edges=edges)
            self.obs.m["adapted_streams"].inc()
            ast = _AdaptiveState(
                base_chash=base_chash, base_family=canon, sampler=sampler,
                epoch=1, edges=edges, chash=chash, family=fam)
        self._adaptive[base_chash] = ast
        return ast

    def _maybe_refit_locked(self) -> None:
        """Open the next grid epoch for adapted streams still chasing
        their stderr target (caller holds the lock).

        Every trigger input is durable or deterministic — the current
        epoch stream's ``rounds_done`` (WAL-recovered), the rider's
        target, and a pilot counter-keyed by (seed, base stream,
        epoch) — so a SIGKILLed engine re-decides the identical chain.
        Refits only fire at a wave boundary with nothing in flight on
        the stream; the new epoch is a NEW cache stream (grid
        journaled first — STR007) and every pending holding the old
        entry is swapped to the child, so results finalize from the
        last epoch only.  A refit that reproduces the current edges
        freezes the chain: the grid converged.
        """
        for ast in self._adaptive.values():
            if ast.frozen or ast.epoch >= self.adapt_max_epochs:
                continue
            if self._inflight.get(ast.chash):
                continue
            entry = self.cache.get(ast.chash)
            if entry is None or entry.quarantined:
                continue
            if entry.rounds_done < self.adapt_rounds_per_epoch:
                continue
            targets = [p.request.target_stderr
                       for p in self._pending.values()
                       if p.request.target_stderr is not None
                       and any(e.chash == ast.chash for e in p.entries)]
            if not targets:
                continue    # no rider is still chasing precision
            if self.cache.meets(entry, target_stderr=min(targets),
                                n_samples=None):
                continue    # met — _complete_ready finishes the riders
            epoch = ast.epoch + 1
            weights = adaptive.pilot_weights(
                ast.base_family, ast.edges,
                self._pilot_key(ast.base_chash, epoch),
                self.adapt_pilot_samples)
            edges = adaptive.refine_edges(ast.edges, weights)
            if np.array_equal(edges, ast.edges):
                ast.frozen = True    # a resume re-derives this verdict
                continue
            fam = ast.base_family.adapted(edges, epoch=epoch)
            chash = f"{family_hash(fam, canonicalize=False)}:{ast.sampler}"
            self.cache.register_grid(chash, parent=ast.chash, epoch=epoch,
                                     edges=edges)
            child = self.cache.get_or_allocate(chash, fam)
            for pend in self._pending.values():
                pend.entries = [child if e.chash == ast.chash else e
                                for e in pend.entries]
            self.obs.m["adapted_streams"].inc()
            self.obs.m["grid_refits"].inc()
            self.obs.event("grid_refit", base=ast.base_chash[:16],
                           parent=ast.chash[:16], stream=chash[:16],
                           epoch=epoch)
            ast.chash, ast.edges, ast.epoch, ast.family = \
                chash, edges, epoch, fam

    def _plan_wave(self) -> list[WorkItem]:
        """Assign the wave's round budget fairly across pending requests.

        Needs are computed beyond each stream's fold frontier plus rounds
        already in flight (a pipelined or racing wave).  Allocation is
        round-robin — one round per stream per pass, the starting stream
        rotating every wave — so with a bounded ``max_items_per_wave``
        every pending request makes progress every wave: heavy precision
        asks cannot monopolize the budget.  Scheduled rounds are
        registered in-flight; callers retire them after deposit (or on
        permanent failure).  Caller must hold the engine lock.
        """
        if self._adaptive:
            self._maybe_refit_locked()
        info: dict[str, dict] = {}
        order: list[str] = []
        for pend in self._pending.values():
            if pend.deadline is not None and pend.deadline.expired:
                continue     # _complete_ready fails it; no more rounds
            req = pend.request
            for entry in pend.entries:
                if entry.quarantined:
                    continue  # poison ladder: stream is unschedulable
                inflight = self._inflight.get(entry.chash, 0)
                raw = self.cache.rounds_needed(
                    entry, target_stderr=req.target_stderr,
                    n_samples=req.n_samples, max_rounds=1 << 16)
                need = min(max(0, raw - inflight), self.max_rounds_per_wave)
                if need or inflight:
                    # rounds are being computed on this request's behalf
                    pend.new_rounds_scheduled = True
                self.stats.items_requested += need
                rec = info.get(entry.chash)
                if rec is None:
                    info[entry.chash] = {"entry": entry,
                                         "sampler": req.sampler,
                                         "need": need}
                    order.append(entry.chash)
                else:
                    rec["need"] = max(rec["need"], need)
        if not any(info[c]["need"] for c in order):
            return []

        budget = (self.max_items_per_wave if self.max_items_per_wave
                  else (1 << 62))
        alloc = dict.fromkeys(order, 0)
        start = self._rr_cursor % len(order)
        self._rr_cursor += 1
        progress = True
        while budget > 0 and progress:
            progress = False
            for k in range(len(order)):
                chash = order[(start + k) % len(order)]
                if alloc[chash] < info[chash]["need"] and budget > 0:
                    alloc[chash] += 1
                    budget -= 1
                    progress = True

        items: list[WorkItem] = []
        for chash in order:
            if not alloc[chash]:
                continue
            rec = info[chash]
            frontier = (rec["entry"].rounds_done
                        + self._inflight.get(chash, 0))
            items.extend(
                WorkItem(chash=chash, round_index=r, sampler=rec["sampler"])
                for r in range(frontier, frontier + alloc[chash]))
            self._inflight[chash] = (self._inflight.get(chash, 0)
                                     + alloc[chash])
        self.obs.m["inflight"].set(sum(self._inflight.values()))
        return items

    def _retire_items(self, items: Sequence[WorkItem]) -> None:
        """Drop items from the in-flight table (deposited or abandoned).
        Caller must hold the engine lock."""
        for it in items:
            left = self._inflight.get(it.chash, 0) - 1
            if _analysis.asserts_enabled():
                # a negative in-flight count means a wave was retired
                # twice — the precursor of double-scheduling its rounds
                _analysis.assert_inflight_consistent(it.chash[:16], left)
            if left > 0:
                self._inflight[it.chash] = left
            else:
                self._inflight.pop(it.chash, None)
        self.obs.m["inflight"].set(sum(self._inflight.values()))
        self._deposit_cv.notify_all()

    def _meets(self, pend: _Pending) -> bool:
        req = pend.request
        return all(
            self.cache.meets(e, target_stderr=req.target_stderr,
                             n_samples=req.n_samples)
            for e in pend.entries)

    def _complete_ready(self) -> None:
        done = [p for p in self._pending.values() if self._meets(p)]
        for pend in done:
            del self._pending[pend.ticket]
            self._finish(pend,
                         served_from_cache=not pend.new_rounds_scheduled)
        # graceful degradation, terminal branch: a pending that can
        # never be met — its stream quarantined, or its deadline gone —
        # completes as RequestFailed instead of parking forever
        failed = []
        for pend in self._pending.values():
            bad = [e.chash[:16] for e in pend.entries if e.quarantined]
            if bad:
                failed.append((pend, "quarantined",
                               f"stream(s) {', '.join(bad)} quarantined "
                               f"after repeated non-finite deposits"))
            elif pend.deadline is not None and pend.deadline.expired:
                failed.append((pend, "deadline",
                               f"deadline budget {pend.deadline.budget:g}s "
                               f"expired"))
        for pend, reason, message in failed:
            del self._pending[pend.ticket]
            if reason == "deadline":
                self.stats.deadline_expirations += 1
                self.obs.m["deadline_expirations"].inc()
            self._fail(pend, reason=reason, message=message)
        if done or failed:
            self.obs.m["pending"].set(len(self._pending))
            self._space_cv.notify_all()

    def _finish(self, pend: _Pending, *, served_from_cache: bool) -> None:
        means, errs = [], []
        for entry in pend.entries:
            res = entry.finalize()
            means.append(np.asarray(res.mean))
            errs.append(np.asarray(res.stderr))
        if pend.sweep is not None:
            sw = pend.sweep
            pend.result = SweepResult(
                means=np.concatenate(means), stderrs=np.concatenate(errs),
                n_per_family=tuple(e.n for e in pend.entries),
                names=sw.slice_names,
                served_from_cache=served_from_cache, ticket=pend.ticket,
                stream_ids=tuple(e.chash for e in pend.entries),
                grid_shape=sw.grid_shape, axis_names=sw.axis_names,
                n_points=sw.n_points,
                points_done=np.ones(sw.n_points, bool), complete=True)
        else:
            pend.result = IntegrationResult(
                means=np.concatenate(means), stderrs=np.concatenate(errs),
                n_per_family=tuple(e.n for e in pend.entries),
                names=tuple(f.name for f in pend.request.families),
                served_from_cache=served_from_cache, ticket=pend.ticket,
                stream_ids=tuple(e.chash for e in pend.entries))
        self._results[pend.ticket] = pend.result
        while len(self._results) > self.max_retained_results:
            self._results.popitem(last=False)
        self.stats.served += 1
        self.obs.m["served"].inc()
        if served_from_cache:
            self.obs.m["warm_zero_launch"].inc()
        pend.event.set()

    # -- background worker ----------------------------------------------------
    def start(self) -> None:
        """Spawn the worker thread (idempotent)."""
        with self._lock:
            if self.running:
                return
            self._stop = False
            self._shutdown = False
            self._worker = threading.Thread(
                target=self._run, name="integration-engine", daemon=True)
            self._worker.start()

    def stop(self, timeout: float | None = 30.0) -> None:
        """Stop the worker and snapshot (re-entrant: a second stop()
        after a completed one is a no-op — no double snapshot)."""
        with self._lock:
            if self._shutdown and self._worker is None:
                return
            self._stop = True
            self._work_cv.notify_all()
            worker = self._worker
        if worker is not None:
            worker.join(timeout=timeout)
            if worker.is_alive():
                # mid-wave; keep the handle so running stays True and a
                # start() cannot spawn a second concurrent worker
                raise TimeoutError(
                    "worker still executing a wave; it will exit at the "
                    "wave boundary (retry stop())")
            self._worker = None
        # snapshot-on-shutdown: compact the journal once no worker can
        # deposit anymore (a kill before this point only costs replay)
        with self._lock:
            if self._shutdown:
                return
            self._shutdown = True
        self.checkpoint()

    def checkpoint(self) -> None:
        """Compact accumulated state into an atomic snapshot (no-op
        without a ``state_dir``).  Safe at any wave boundary."""
        if self.store is not None:
            self.cache.snapshot_to_store()

    def close(self, timeout: float | None = 30.0) -> None:
        """Clean shutdown: stop the worker, snapshot, release the store.

        If the worker outlives ``timeout`` the TimeoutError from
        :meth:`stop` still propagates, but the store handle is released
        regardless — the journal already holds every folded round, so
        skipping the shutdown snapshot costs replay time, never data.
        """
        try:
            self.stop(timeout=timeout)
        finally:
            if self.store is not None:
                self.store.close()

    def __enter__(self) -> "IntegrationEngine":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    def drain(self, timeout: float | None = None) -> None:
        """Block until the pending table is empty (worker running)."""
        events = []
        with self._lock:
            events = [p.event for p in self._pending.values()]
        for ev in events:
            if not ev.wait(timeout=timeout):
                raise TimeoutError("pending requests did not drain")

    def _run(self) -> None:
        try:
            if self.pipeline_waves:
                self._run_pipelined()
                return
            while True:
                if self.store is not None:
                    self.store.heartbeat()   # idle engines keep the lease
                self.faults.check("worker_crash")
                with self._lock:
                    while not self._pending and not self._stop:
                        self._work_cv.wait(timeout=0.5)
                    if self._stop:
                        return
                try:
                    self.step()
                except (RetryExhausted, DeadlineExceeded):
                    # step() already completed the affected tickets as
                    # RequestFailed; the worker keeps serving the rest
                    continue
        except InjectedCrash as exc:
            # chaos: the worker dies at a wave boundary like a real
            # thread crash would — durable state is intact, a driver
            # can resume via step() or a fresh start()
            self.obs.event("worker_crash", error=str(exc))

    def _run_pipelined(self) -> None:
        """Double-buffered wave loop: dispatch wave k+1, then deposit
        wave k.

        ``launch`` only enqueues device work (JAX async dispatch), so by
        the time ``deposit`` blocks on wave k's transfer the device is
        already chewing on wave k+1 — host-side folding, group-commit
        journaling and request completion all run off the device
        critical path.  Deposits stay in wave order, so the cache's
        in-order fold and the WAL's crash window are exactly those of
        the serial loop.  On ``stop()`` the tail wave is deposited
        before the worker exits.
        """
        inflight: tuple[InFlightWave, list[WorkItem], float, int] | None = \
            None
        while True:
            if self.store is not None:
                self.store.heartbeat()       # idle engines keep the lease
            if inflight is None:
                # wave boundary with nothing salvageable in flight: the
                # only spot where an injected worker death is loss-free
                self.faults.check("worker_crash")
            with self._lock:
                while (not self._pending and inflight is None
                       and not self._stop):
                    self._work_cv.wait(timeout=0.5)
                if self._stop and inflight is None:
                    return
                if self._stop:
                    items = []
                else:
                    with self.obs.span("plan", pending=len(self._pending)):
                        items = self._plan_wave()
                if not items and inflight is None:
                    self._complete_ready()
                    if self._pending:
                        # nothing plannable here, rounds owed to another
                        # driver's wave: wait for its deposit
                        self._deposit_cv.wait(timeout=0.5)
                    continue
                seq = self._wave_seq
                if items:
                    self._wave_seq += 1

            handle = None
            t0 = _clock.monotonic()
            if items:
                def launch(attempt: int, _items=items) -> InFlightWave:
                    if attempt:
                        with self._lock:
                            self.stats.restarts += 1
                        self.obs.m["retries"].inc(stage="launch")
                    self.faults.check("plan")
                    with self.watchdog:
                        return self.batcher.launch(_items)

                stragglers_before = self.watchdog.straggler_count
                try:
                    handle = run_with_policy(
                        launch, self.retry, stage="launch", counter=seq,
                        deadline=self._wave_deadline(items),
                        on_retry=self._restart_hook(
                            "wave_restart", seq, items))
                except (RetryExhausted, DeadlineExceeded) as exc:
                    # permanent: complete the riders as RequestFailed
                    # and keep serving — the sibling wave deposits below
                    with self._lock:
                        self._retire_items(items)
                        self._fail_wave(items, exc)
                    handle = None
                except Exception:
                    # the worker is about to die: salvage the sibling
                    # wave first (its rounds are real), and make sure no
                    # in-flight registration outlives this thread — a
                    # leaked count would wedge every other driver's
                    # planner forever
                    with self._lock:
                        self._retire_items(items)
                    if inflight is not None:
                        try:
                            self._deposit_wave(*inflight)
                        except Exception:
                            pass   # _deposit_wave retired its items
                    raise
                self._note_stragglers(stragglers_before, seq, items)

            if inflight is not None:
                try:
                    self._deposit_wave(*inflight)
                except Exception:
                    if handle is not None:
                        with self._lock:
                            self._retire_items(items)
                    raise
            inflight = ((handle, items, t0, seq) if handle is not None
                        else None)

    def _deposit_wave(self, wave: InFlightWave, items: list[WorkItem],
                      t_launch: float | None = None, seq: int = 0) -> None:
        """Host side of one pipelined wave: transfer, group-commit, and
        complete ready requests.  A transient failure relaunches the
        wave (counter addressing makes the recomputation bit-identical;
        already-folded rounds are skipped on deposit)."""
        state = {"wave": wave}

        def attempt(k: int) -> int:
            if k:
                with self._lock:
                    self.stats.restarts += 1
                self.obs.m["retries"].inc(stage="deposit")
                state["wave"] = self.batcher.launch(items)
            with self.watchdog:
                return self.batcher.deposit(state["wave"])

        stragglers_before = self.watchdog.straggler_count
        try:
            executed = run_with_policy(
                attempt, self.retry, stage="deposit", counter=seq,
                deadline=self._wave_deadline(items),
                on_retry=self._restart_hook("deposit_retry", seq, items))
        except (RetryExhausted, DeadlineExceeded) as exc:
            # permanent loss of this wave only: fail its riders and let
            # the worker keep serving everything else
            with self._lock:
                self._retire_items(items)
                self._fail_wave(items, exc)
            return
        except Exception:
            with self._lock:
                self._retire_items(items)
            raise
        self._note_stragglers(stragglers_before, seq, items)
        self.obs.m["waves"].inc()
        if t_launch is not None:
            self.obs.m["wave_seconds"].observe(
                _clock.monotonic() - t_launch)
        with self._lock:
            self._retire_items(items)
            self.stats.waves += 1
            self.stats.items_executed += executed
            self._complete_ready()
