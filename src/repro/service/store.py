"""Crash-safe persistence for the service result cache.

The engine's strongest invariant — a cache top-up is *bit-identical* to
an uninterrupted run (``tests/core/test_resume.py``) — is in-process
only as long as the accumulators live in memory.  This module makes it a
cross-process property: every unit of durable state the cache owns is
either journaled or snapshotted, so a SIGKILL at any instant loses at
most the round deposit being written, never a folded one.

Two files under ``state_dir``:

* ``journal.bin`` — an append-only **write-ahead journal**.  Each record
  is ``MAGIC | u32 length | u32 crc32 | payload`` with a JSON payload
  (f32 accumulator arrays base64-encoded raw little-endian, so replay
  folds the *exact bits* the live cache folded).  Two record types:
  ``alloc`` (a stream's counter-space placement: chash, fn_offset,
  n_fn, round size), ``dep`` (one round's ``(s1, s2, n)`` delta) and
  ``grid`` (an adapted stream's importance-grid fit: the child chash,
  its parent stream, the grid epoch and the exact f32 bin edges — a
  grid refit opens a NEW epoch stream rather than mutating history, so
  the record is journaled *before* the child stream's alloc and the
  whole epoch chain replays deterministically).  Records are fsynced by
  default; a record is journaled *before* the in-memory fold it
  describes (WAL ordering).  Whole waves of deposits
  **group-commit** through :meth:`DurableStore.append_deposits` — one
  write + one fsync for the batch; a crash mid-batch tears at a record
  boundary, so the durable prefix is always a prefix of the wave's
  deposits (the per-record crash window, amortized).

* ``snapshot.npz`` — periodic **compaction** of journal + accumulators
  into one atomic npz (tmp + fsync + ``os.replace``), after which the
  journal is reset.  A crash between snapshot commit and journal reset
  is benign: replay skips deposits of rounds the snapshot already folded
  (the same skip rule the live cache applies to replayed waves).

``load()`` restores snapshot then journal, **truncating** a partial or
corrupt journal tail (torn write at the kill instant, garbage append)
instead of crashing — everything before the first bad record survives.
The bump allocator's high-water mark rides along in both formats, so a
reloaded stream resumes at the exact ``sample_offset`` and counter range
it would have had uninterrupted, and new streams never collide with
persisted ones.

``meta.json`` pins the engine configuration a state dir was created
with (seed, round size); reopening with a different configuration is an
error rather than a silently different sample stream.

**Fail-closed appends**: a journal write that errors mid-record (ENOSPC,
failed fsync, torn write) leaves bytes of unknown durability at the
tail.  ``_write`` rewinds the file to the last known-good record
boundary before re-raising, so the *next* append frames correctly and a
retried wave never lands after garbage — the cache acks a deposit only
once its journal record is durably framed.

**Single-writer lease** (``lease.json``): one engine owns a state dir at
a time.  The lease is an fsynced JSON record ``{token, pid, acquired,
expires}`` renewed (heartbeat) on journal activity; a second process
opening the dir takes over only when the lease is *expired*, its holder
process is *dead*, or the holder is this same process (an abandoned
in-process handle).  An unexpired lease with a live foreign holder
raises :class:`LeaseHeld` — the first concrete step of the ROADMAP's
replicated-engine scale-out item.  Heartbeats verify the on-disk token
still matches; a usurped writer gets :class:`LeaseLost` instead of
silently double-writing (fencing).
"""

from __future__ import annotations

import base64
import dataclasses
import errno
import json
import os
import struct
import threading
import zlib

import numpy as np

from repro.obs import clock as _clock

_MAGIC = b"ZMJ1"
_HEADER = struct.Struct("<II")          # payload length, crc32(payload)
_HEADER_BYTES = len(_MAGIC) + _HEADER.size
_SNAPSHOT_VERSION = 1


class LeaseHeld(RuntimeError):
    """The state dir's lease is held by a live process elsewhere."""


class LeaseLost(RuntimeError):
    """Our lease token was usurped — stop writing (fencing)."""


def _pid_alive(pid: int) -> bool:
    """Best-effort liveness probe for a same-host lease holder."""
    try:
        os.kill(int(pid), 0)
    except ProcessLookupError:
        return False
    except OSError:
        return True      # exists but not ours to signal (or unknowable)
    return True


def _encode_f32(arr: np.ndarray) -> str:
    return base64.b64encode(
        np.ascontiguousarray(arr, dtype="<f4").tobytes()).decode("ascii")


def _decode_f32(text: str) -> np.ndarray:
    return np.frombuffer(base64.b64decode(text), dtype="<f4")


@dataclasses.dataclass
class EntryState:
    """Durable image of one cached stream's accumulators + placement."""

    chash: str
    fn_offset: int
    n_fn: int
    round_samples: int
    s1: np.ndarray            # (n_fn,) f32
    s2: np.ndarray            # (n_fn,) f32
    n: int = 0
    rounds_done: int = 0


@dataclasses.dataclass
class GridRecord:
    """Durable image of one adapted stream's importance grid.

    ``chash`` names the adapted (child) stream the grid serves;
    ``parent`` the stream the pilot was fitted against (the previous
    epoch's adapted stream, or the base canonical stream for epoch 1).
    The exact f32 edges ride along so a resumed engine rebuilds the
    adapted family bit-identically instead of refitting.
    """

    chash: str
    parent: str
    epoch: int
    n_fn: int
    dim: int
    n_bins: int
    edges: np.ndarray         # (n_fn, dim, n_bins + 1) f32


@dataclasses.dataclass
class RecoveredState:
    """What ``load()`` reconstructed from disk."""

    entries: dict[str, EntryState]
    next_id: int = 0                  # allocator high-water mark
    round_samples: int | None = None  # None when the dir is fresh
    journal_records: int = 0          # complete records replayed
    dropped_records: int = 0          # valid records that could not fold
    truncated_bytes: int = 0          # corrupt/partial tail removed
    grids: dict[str, GridRecord] = dataclasses.field(default_factory=dict)


def read_journal(path: str) -> tuple[list[dict], int]:
    """Decode every complete record of a journal file, read-only.

    Returns ``(records, bad_tail_bytes)``: the JSON payloads of all
    well-framed records in append order, plus the number of trailing
    bytes that do not form a complete valid record (torn write at a kill
    instant, bit rot).  Never writes — this is the parsing half of
    :meth:`DurableStore._replay_journal`, shared with the offline
    determinism auditor (:mod:`repro.analysis.streams`).
    """
    try:
        with open(path, "rb") as f:
            data = f.read()
    except FileNotFoundError:
        return [], 0
    records: list[dict] = []
    offset = 0
    while True:
        header_end = offset + _HEADER_BYTES
        if header_end > len(data):
            break                               # partial header
        if data[offset:offset + len(_MAGIC)] != _MAGIC:
            break                               # corrupt framing
        length, crc = _HEADER.unpack_from(data, offset + len(_MAGIC))
        end = header_end + length
        if end > len(data):
            break                               # torn payload
        payload = data[header_end:end]
        if zlib.crc32(payload) != crc:
            break                               # bit rot / torn write
        try:
            records.append(json.loads(payload))
        except ValueError:
            break
        offset = end
    return records, len(data) - offset


def read_snapshot(path: str) -> tuple[dict, dict]:
    """Decode a snapshot npz, read-only: ``(meta, arrays)``.

    ``meta`` is the embedded JSON dict (version, next_id, round_samples,
    entries); ``arrays`` maps ``s1_*``/``s2_*`` names to f32 arrays.
    Raises on version mismatch — shared by :meth:`DurableStore.load` and
    the offline auditor.
    """
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(bytes(z["meta"]).decode("utf-8"))
        if meta.get("version") != _SNAPSHOT_VERSION:
            raise ValueError(
                f"snapshot {path!r} has version {meta.get('version')!r}; "
                f"expected {_SNAPSHOT_VERSION}")
        arrays = {name: np.asarray(z[name], np.float32)
                  for name in z.files if name != "meta"}
    return meta, arrays


class DurableStore:
    """Append-only journal + atomic npz snapshots under one directory."""

    JOURNAL = "journal.bin"
    SNAPSHOT = "snapshot.npz"
    META = "meta.json"
    LEASE = "lease.json"

    def __init__(self, state_dir: str, *, fsync: bool = True, obs=None,
                 faults=None, lease_ttl: float | None = 30.0):
        if obs is None:
            from repro.obs import Observability
            obs = Observability.disabled()
        if faults is None:
            from repro.service.faults import NULL_FAULTS
            faults = NULL_FAULTS
        self.obs = obs
        self.faults = faults
        self.state_dir = str(state_dir)
        self.fsync = bool(fsync)
        os.makedirs(self.state_dir, exist_ok=True)
        self.journal_path = os.path.join(self.state_dir, self.JOURNAL)
        self.snapshot_path = os.path.join(self.state_dir, self.SNAPSHOT)
        self.meta_path = os.path.join(self.state_dir, self.META)
        self.lease_path = os.path.join(self.state_dir, self.LEASE)
        self._journal_f = None
        # byte offset of the last durably framed record boundary; a
        # failed append rewinds to it so the journal never grows a
        # torn middle (fail-closed, see module docstring)
        self._good_size = 0
        # serializes appends against each other and against snapshot's
        # journal reset; a caller may hold it across append + its own
        # in-memory apply to stay coherent with a concurrent snapshot
        # (reentrant so such callers can still invoke append/snapshot)
        self.mutex = threading.RLock()
        self.lease_ttl = None if lease_ttl is None else float(lease_ttl)
        self._lease_token = f"{os.getpid()}-{os.urandom(8).hex()}"
        self._lease_renewed: float | None = None
        if self.lease_ttl is not None:
            self._acquire_lease()

    # -- single-writer lease --------------------------------------------------
    def _read_lease(self) -> dict | None:
        try:
            with open(self.lease_path, encoding="utf-8") as f:
                return json.load(f)
        except (FileNotFoundError, ValueError):
            return None

    def _write_lease(self, now: float) -> None:
        record = {"token": self._lease_token, "pid": os.getpid(),
                  "acquired": now, "expires": now + self.lease_ttl}
        tmp = self.lease_path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(record, f, sort_keys=True)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.lease_path)
        self._sync_dir()
        self._lease_renewed = now

    def _acquire_lease(self) -> None:
        """Claim the state dir, taking over a crash-expired lease.

        Takeover conditions (any one suffices): the lease expired, its
        holder process is dead (SIGKILL leaves an unexpired lease
        behind — waiting out the TTL would stall every warm restart),
        or the holder is this same process (an abandoned handle).  A
        live foreign holder raises :class:`LeaseHeld`.
        """
        now = _clock.wall()
        existing = self._read_lease()
        reason = None
        if existing is not None:
            pid = existing.get("pid")
            expires = float(existing.get("expires", 0.0))
            if pid == os.getpid():
                reason = "same_process"
            elif expires <= now:
                reason = "expired"
            elif pid is None or not _pid_alive(pid):
                reason = "holder_dead"
            else:
                raise LeaseHeld(
                    f"state dir {self.state_dir!r} is leased to pid {pid} "
                    f"for another {expires - now:.1f}s; takeover requires "
                    f"expiry or holder death")
        self._write_lease(now)
        if reason is not None:
            self.obs.event("lease_takeover", state_dir=self.state_dir,
                           reason=reason,
                           previous_pid=existing.get("pid"))

    def heartbeat(self, force: bool = False) -> None:
        """Renew the lease once half the TTL has elapsed (cheap to call
        every wave).  Raises :class:`LeaseLost` if another writer took
        the lease over — the fencing check that keeps a paused-then-
        resumed engine from double-writing a usurped dir."""
        if self.lease_ttl is None:
            return
        now = _clock.wall()
        if (not force and self._lease_renewed is not None
                and now - self._lease_renewed < self.lease_ttl / 2.0):
            return
        with self.mutex:
            existing = self._read_lease()
            if (existing is not None
                    and existing.get("token") != self._lease_token):
                raise LeaseLost(
                    f"lease on {self.state_dir!r} now belongs to "
                    f"pid {existing.get('pid')}; this writer must stop")
            self._write_lease(now)

    def _release_lease(self) -> None:
        if self.lease_ttl is None:
            return
        existing = self._read_lease()
        if existing is not None and existing.get("token") == self._lease_token:
            try:
                os.unlink(self.lease_path)
            except OSError:
                pass

    # -- configuration guard --------------------------------------------------
    def ensure_meta(self, meta: dict) -> None:
        """Pin ``meta`` on first use; verify it on every reopen.

        A state dir replays a specific counter stream: reopening it with
        a different seed or round size would top up with *different*
        samples and silently break bit-identity, so mismatches raise.
        """
        if os.path.exists(self.meta_path):
            with open(self.meta_path, encoding="utf-8") as f:
                existing = json.load(f)
            for key, value in meta.items():
                if key in existing and existing[key] != value:
                    raise ValueError(
                        f"state dir {self.state_dir!r} was created with "
                        f"{key}={existing[key]!r}; this engine is configured "
                        f"with {key}={value!r}")
            return
        tmp = self.meta_path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(meta, f, sort_keys=True)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.meta_path)
        self._sync_dir()

    # -- journal appends ------------------------------------------------------
    def append_alloc(self, chash: str, *, fn_offset: int, n_fn: int,
                     round_samples: int) -> None:
        self._append({"t": "alloc", "chash": chash,
                      "fn_offset": int(fn_offset), "n_fn": int(n_fn),
                      "round_samples": int(round_samples)})

    def append_grid(self, chash: str, *, parent: str, epoch: int,
                    edges: np.ndarray) -> None:
        """Journal an adapted stream's importance grid (exact f32 edges).

        Must precede the child stream's ``alloc`` record so replay (and
        the Layer-3 auditor's STR007 chain check) always sees the grid
        an adapted stream samples through before the stream itself.
        """
        edges = np.ascontiguousarray(edges, np.float32)
        n_fn, dim, nb1 = edges.shape
        self._append({"t": "grid", "chash": chash, "parent": parent,
                      "epoch": int(epoch), "n_fn": int(n_fn),
                      "dim": int(dim), "n_bins": int(nb1 - 1),
                      "edges": _encode_f32(edges.ravel())})

    @staticmethod
    def deposit_record(chash: str, round_index: int,
                       s1: np.ndarray, s2: np.ndarray, n: int) -> dict:
        """The journal payload for one round's delta (see
        :meth:`append_deposits` for group commit)."""
        return {"t": "dep", "chash": chash, "round": int(round_index),
                "n": int(n), "s1": _encode_f32(s1), "s2": _encode_f32(s2)}

    def append_deposits(self, payloads) -> None:
        """Group commit: journal a batch of records with ONE fsync.

        The records become durable atomically-in-order: a crash mid-write
        tears at some record boundary and :meth:`load` truncates from the
        first bad frame, so any durable prefix of the batch is exactly a
        prefix of the deposits — the same crash window as per-record
        appends, amortizing the fsync over a whole wave.
        """
        payloads = list(payloads)
        if not payloads:
            return
        self._write(b"".join(self._frame(p) for p in payloads))

    @staticmethod
    def _frame(payload: dict) -> bytes:
        raw = json.dumps(payload, sort_keys=True,
                         separators=(",", ":")).encode("utf-8")
        return _MAGIC + _HEADER.pack(len(raw), zlib.crc32(raw)) + raw

    def _append(self, payload: dict) -> None:
        self._write(self._frame(payload))

    def _write(self, record: bytes) -> None:
        obs = self.obs
        faults = self.faults
        with self.mutex:
            self.heartbeat()
            t0 = _clock.monotonic()
            with obs.span("wal_commit", bytes=len(record)):
                faults.check("wal_commit")
                f = self._journal()
                start = self._good_size
                try:
                    if faults.enabled and faults.fire("wal_torn_write"):
                        # model a torn write: a prefix of the record
                        # reaches the file, then the device dies
                        from repro.service.faults import InjectedIOError
                        f.write(record[:max(1, len(record) // 2)])
                        f.flush()
                        raise InjectedIOError(
                            errno.ENOSPC, "injected torn journal write")
                    f.write(record)
                    f.flush()
                    faults.check("wal_fsync")
                    if self.fsync:
                        os.fsync(f.fileno())
                except OSError:
                    # fail closed: whatever partial/unsynced bytes this
                    # append left must not become a torn *middle* once a
                    # retry appends after them — rewind to the last
                    # known-good record boundary before surfacing
                    self._rewind(start)
                    raise
                self._good_size = start + len(record)
            obs.m["wal_fsync_seconds"].observe(_clock.monotonic() - t0)
            obs.m["wal_bytes"].inc(len(record))
            obs.m["wal_commits"].inc()

    def _rewind(self, good_size: int) -> None:
        """Truncate the journal back to the last durable record boundary
        after a failed append (best-effort: if even the truncate fails,
        ``load()``'s tail truncation still recovers the prefix)."""
        self._close_journal()
        try:
            with open(self.journal_path, "r+b") as f:
                f.truncate(good_size)
                f.flush()
                os.fsync(f.fileno())
        except OSError:
            pass
        self._good_size = good_size

    def _journal(self):
        if self._journal_f is None or self._journal_f.closed:
            created = not os.path.exists(self.journal_path)
            self._journal_f = open(self.journal_path, "ab")
            self._good_size = self.journal_size()
            if created:
                # fsyncing records is useless if the file's own dirent
                # is lost to a power cut; persist it on first creation
                self._sync_dir()
        return self._journal_f

    def journal_size(self) -> int:
        try:
            return os.path.getsize(self.journal_path)
        except OSError:
            return 0

    # -- recovery -------------------------------------------------------------
    def load(self) -> RecoveredState:
        """Snapshot + journal replay; truncates a bad tail, never raises
        for torn/corrupt journal bytes."""
        state = RecoveredState(entries={})
        if os.path.exists(self.snapshot_path):
            self._load_snapshot(state)
        self._replay_journal(state)
        return state

    def _load_snapshot(self, state: RecoveredState) -> None:
        meta, arrays = read_snapshot(self.snapshot_path)
        state.next_id = int(meta["next_id"])
        state.round_samples = int(meta["round_samples"])
        for i, ent in enumerate(meta["entries"]):
            st = EntryState(
                chash=ent["chash"], fn_offset=int(ent["fn_offset"]),
                n_fn=int(ent["n_fn"]),
                round_samples=int(ent["round_samples"]),
                s1=arrays[f"s1_{i:05d}"],
                s2=arrays[f"s2_{i:05d}"],
                n=int(ent["n"]), rounds_done=int(ent["rounds_done"]))
            state.entries[st.chash] = st
        # pre-adaptive snapshots carry no "grids" key; .get keeps them
        # loading unchanged (the snapshot version is unbumped on purpose)
        for i, g in enumerate(meta.get("grids", [])):
            rec = GridRecord(
                chash=g["chash"], parent=g["parent"],
                epoch=int(g["epoch"]), n_fn=int(g["n_fn"]),
                dim=int(g["dim"]), n_bins=int(g["n_bins"]),
                edges=np.asarray(arrays[f"grid_{i:05d}"], np.float32))
            state.grids[rec.chash] = rec

    def _replay_journal(self, state: RecoveredState) -> None:
        records, bad_tail = read_journal(self.journal_path)
        for record in records:
            self._apply(record, state)
            state.journal_records += 1
        if bad_tail:
            # drop the bad tail on disk too, so new appends framing-align
            state.truncated_bytes = bad_tail
            good_end = self.journal_size() - bad_tail
            self._close_journal()
            with open(self.journal_path, "r+b") as f:
                f.truncate(good_end)
                f.flush()
                os.fsync(f.fileno())
            self._good_size = good_end

    def _apply(self, record: dict, state: RecoveredState) -> None:
        kind = record.get("t")
        if kind == "alloc":
            chash = record["chash"]
            n_fn = int(record["n_fn"])
            if chash not in state.entries:
                state.entries[chash] = EntryState(
                    chash=chash, fn_offset=int(record["fn_offset"]),
                    n_fn=n_fn, round_samples=int(record["round_samples"]),
                    s1=np.zeros(n_fn, np.float32),
                    s2=np.zeros(n_fn, np.float32))
            state.next_id = max(state.next_id,
                                int(record["fn_offset"]) + n_fn)
        elif kind == "dep":
            st = state.entries.get(record["chash"])
            if st is None:
                state.dropped_records += 1
                return
            round_index = int(record["round"])
            if round_index < st.rounds_done:
                return       # snapshot already folded it (benign overlap)
            s1 = _decode_f32(record["s1"])
            s2 = _decode_f32(record["s2"])
            if round_index > st.rounds_done or s1.shape != (st.n_fn,):
                state.dropped_records += 1          # can't fold a gap
                return
            # the same f32 left fold the live cache performed
            st.s1 = st.s1 + s1
            st.s2 = st.s2 + s2
            st.n += int(record["n"])
            st.rounds_done += 1
        elif kind == "grid":
            chash = record["chash"]
            if chash not in state.grids:     # first record wins (refits
                n_fn = int(record["n_fn"])   # open new chashes, so a
                dim = int(record["dim"])     # dup is a replayed wave)
                n_bins = int(record["n_bins"])
                state.grids[chash] = GridRecord(
                    chash=chash, parent=record["parent"],
                    epoch=int(record["epoch"]), n_fn=n_fn, dim=dim,
                    n_bins=n_bins,
                    edges=_decode_f32(record["edges"]).reshape(
                        n_fn, dim, n_bins + 1))
        else:
            state.dropped_records += 1

    # -- compaction -----------------------------------------------------------
    def snapshot(self, states: list[EntryState], *, next_id: int,
                 round_samples: int, grids: list[GridRecord] = ()) -> None:
        """Atomically persist all stream states, then reset the journal.

        ``grids`` carries the adapted streams' importance-grid records;
        compaction must never forget one (a forgotten grid would orphan
        its epoch chain on the next restart).
        """
        payload: dict[str, np.ndarray] = {}
        entries_meta = []
        for i, st in enumerate(states):
            payload[f"s1_{i:05d}"] = np.ascontiguousarray(st.s1, "<f4")
            payload[f"s2_{i:05d}"] = np.ascontiguousarray(st.s2, "<f4")
            entries_meta.append({
                "chash": st.chash, "fn_offset": int(st.fn_offset),
                "n_fn": int(st.n_fn),
                "round_samples": int(st.round_samples),
                "n": int(st.n), "rounds_done": int(st.rounds_done)})
        grids_meta = []
        for i, g in enumerate(grids):
            payload[f"grid_{i:05d}"] = np.ascontiguousarray(g.edges, "<f4")
            grids_meta.append({
                "chash": g.chash, "parent": g.parent,
                "epoch": int(g.epoch), "n_fn": int(g.n_fn),
                "dim": int(g.dim), "n_bins": int(g.n_bins)})
        meta = {"version": _SNAPSHOT_VERSION, "next_id": int(next_id),
                "round_samples": int(round_samples), "entries": entries_meta}
        if grids_meta:
            meta["grids"] = grids_meta
        payload["meta"] = np.frombuffer(
            json.dumps(meta, sort_keys=True).encode("utf-8"), np.uint8)

        tmp = self.snapshot_path + ".tmp"
        with self.mutex:
            self.heartbeat()
            with open(tmp, "wb") as f:
                np.savez(f, **payload)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.snapshot_path)
            self._sync_dir()
            # the snapshot supersedes every journal record; reset it (a
            # crash between replace and reset only costs replay skips)
            self._close_journal()
            with open(self.journal_path, "wb") as f:
                f.flush()
                os.fsync(f.fileno())
            self._good_size = 0

    def _sync_dir(self) -> None:
        try:
            fd = os.open(self.state_dir, os.O_RDONLY)
        except OSError:
            return                                  # platform without dir fds
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    def _close_journal(self) -> None:
        if self._journal_f is not None and not self._journal_f.closed:
            self._journal_f.close()
        self._journal_f = None

    def close(self) -> None:
        """Release the journal handle and the lease (idempotent)."""
        self._close_journal()
        self._release_lease()
