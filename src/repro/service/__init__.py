# Integration-as-a-service: the request-serving layer above the MC engine.
#
#   canonical  - deterministic canonicalization + content hashing of requests
#   cache      - stderr-aware result cache with counter-stream top-up
#   batcher    - cross-request coalescing into fused multi-round buckets
#   engine     - continuously-batching submit/poll worker (fair wave
#                planner, double-buffered wave pipeline, backpressure)
#   store      - crash-safe journal + snapshot persistence (warm restarts)
#   api        - request/response dataclasses and the blocking client

from repro.service.api import (Backpressure, IntegrationClient,
                               IntegrationRequest, IntegrationResult,
                               SweepRequest, SweepResult)
from repro.service.cache import CacheEntry, ResultCache
from repro.service.canonical import (canonical_family, family_hash,
                                     spec_hash, sweep_slices)
from repro.service.engine import EngineStats, IntegrationEngine
from repro.service.store import DurableStore, EntryState, RecoveredState

__all__ = [
    "Backpressure",
    "CacheEntry",
    "DurableStore",
    "EngineStats",
    "EntryState",
    "IntegrationClient",
    "IntegrationEngine",
    "IntegrationRequest",
    "IntegrationResult",
    "RecoveredState",
    "ResultCache",
    "SweepRequest",
    "SweepResult",
    "canonical_family",
    "family_hash",
    "spec_hash",
    "sweep_slices",
]
