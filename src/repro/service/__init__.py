# Integration-as-a-service: the request-serving layer above the MC engine.
#
#   canonical  - deterministic canonicalization + content hashing of requests
#   cache      - stderr-aware result cache with counter-stream top-up
#   batcher    - cross-request coalescing into fused multi-round buckets
#   engine     - continuously-batching submit/poll worker (fair wave
#                planner, double-buffered wave pipeline, backpressure)
#   store      - crash-safe journal + snapshot persistence (warm restarts,
#                single-writer lease)
#   api        - request/response dataclasses and the blocking client
#   resilience - the ONE retry/backoff/deadline policy (rule RES001)
#   faults     - deterministic fault injection (chaos harness)

from repro.service.api import (Backpressure, IntegrationClient,
                               IntegrationRequest, IntegrationResult,
                               RequestError, RequestFailed,
                               SweepRequest, SweepResult)
from repro.service.cache import CacheEntry, ResultCache
from repro.service.canonical import (canonical_family, family_hash,
                                     spec_hash, sweep_slices)
from repro.service.engine import EngineStats, IntegrationEngine
from repro.service.faults import (FAULT_POINTS, FaultPlan, InjectedFault,
                                  NullFaultPlan)
from repro.service.resilience import (Deadline, DeadlineExceeded,
                                      RetryExhausted, RetryPolicy,
                                      run_with_policy)
from repro.service.store import (DurableStore, EntryState, LeaseHeld,
                                 LeaseLost, RecoveredState)

__all__ = [
    "Backpressure",
    "CacheEntry",
    "Deadline",
    "DeadlineExceeded",
    "DurableStore",
    "EngineStats",
    "EntryState",
    "FAULT_POINTS",
    "FaultPlan",
    "InjectedFault",
    "IntegrationClient",
    "IntegrationEngine",
    "IntegrationRequest",
    "IntegrationResult",
    "LeaseHeld",
    "LeaseLost",
    "NullFaultPlan",
    "RecoveredState",
    "RequestError",
    "RequestFailed",
    "ResultCache",
    "RetryExhausted",
    "RetryPolicy",
    "SweepRequest",
    "SweepResult",
    "canonical_family",
    "family_hash",
    "run_with_policy",
    "spec_hash",
    "sweep_slices",
]
