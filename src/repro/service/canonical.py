"""Deterministic canonicalization + content hashing of integration requests.

Two clients that ask for the same integral must map to the same cache
entry, even when they built their :class:`IntegrandFamily` objects
independently (fresh closures, different cosmetic names, float64 instead
of float32 parameters).  This module defines what "the same integral"
means to the service:

* the **numerical content** — parameter pytree and domain boxes — is
  serialized leaf-by-leaf (dict keys sorted, dtypes normalized to what
  the engine actually computes in: f32 for floats) and hashed;
* the **code identity** of the integrand is the registered kernel-form
  name when the family declares one (stable across processes and
  machines), otherwise a structural fingerprint of the Python function:
  bytecode, consts (nested code objects fingerprinted recursively — their
  ``repr`` contains memory addresses), names, plus the *values* captured
  in closure cells and defaults.  Two lambdas produced by two calls of
  the same constructor hash identically; capturing a different value
  changes the hash;
* the cosmetic ``name`` is excluded on purpose.

Infinite domains are compactified *before* hashing, mirroring what the
engine does before sampling, so ``gaussian over R^d`` submitted raw and
pre-compactified dedupe to the same entry.

The hash addresses the service's result cache; it is not a security
boundary.
"""

from __future__ import annotations

import hashlib
import types
from typing import Any

import jax
import numpy as np

from repro.core.integrand import IntegrandFamily, MultiFunctionSpec


def _hash_array(h, leaf) -> None:
    arr = np.asarray(leaf)
    # the engine computes in f32; f64 inputs are not a distinct integral
    if arr.dtype.kind == "f":
        arr = arr.astype(np.float32)
    elif arr.dtype.kind in "iu":
        arr = arr.astype(np.int64)
    arr = np.ascontiguousarray(arr)
    h.update(str(arr.dtype).encode())
    h.update(repr(arr.shape).encode())
    h.update(arr.tobytes())


def _hash_code(h, code: types.CodeType) -> None:
    h.update(code.co_code)
    h.update(repr(code.co_names).encode())
    h.update(repr(code.co_varnames).encode())
    for const in code.co_consts:
        if isinstance(const, types.CodeType):
            _hash_code(h, const)
        else:
            h.update(repr(const).encode())


def _hash_value(h, value: Any) -> None:
    """Hash one captured value (closure cell / default / const)."""
    if isinstance(value, (np.ndarray, jax.Array)) or np.isscalar(value):
        _hash_array(h, value)
    elif callable(value) and hasattr(value, "__code__"):
        _hash_callable(h, value)
    elif isinstance(value, (tuple, list)):
        h.update(b"seq")
        for v in value:
            _hash_value(h, v)
    elif isinstance(value, dict):
        h.update(b"map")
        for k in sorted(value, key=repr):
            h.update(repr(k).encode())
            _hash_value(h, value[k])
    else:
        h.update(repr(value).encode())


def _hash_global(h, value: Any) -> None:
    """Hash one module-global an integrand references.

    Data values (arrays, scalars, containers) hash by content — a
    module-level ``SCALE = 2.0`` versus ``3.0`` must produce different
    integrals.  Modules and functions hash by import path (stable across
    processes, and avoids recursing into jnp internals); a referenced
    *helper function's* body changing is therefore not detected — keep
    integrand math in the closure, not in mutable helpers.
    """
    if isinstance(value, types.ModuleType):
        h.update(f"module:{value.__name__}".encode())
    elif callable(value) and hasattr(value, "__code__"):
        h.update(f"fn:{getattr(value, '__module__', '')}."
                 f"{getattr(value, '__qualname__', '')}".encode())
    else:
        _hash_value(h, value)


def _hash_callable(h, fn) -> None:
    _hash_code(h, fn.__code__)
    for cell in fn.__closure__ or ():
        try:
            _hash_value(h, cell.cell_contents)
        except ValueError:  # empty cell (still being defined)
            h.update(b"empty-cell")
    for default in fn.__defaults__ or ():
        _hash_value(h, default)
    for name, default in sorted((fn.__kwdefaults__ or {}).items()):
        h.update(name.encode())
        _hash_value(h, default)
    # globals the code references (co_names covers loads of globals and
    # builtins; unresolvable names are attribute accesses / builtins)
    for name in fn.__code__.co_names:
        if name in fn.__globals__:
            h.update(name.encode())
            _hash_global(h, fn.__globals__[name])


def _hash_pytree(h, tree) -> None:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    h.update(str(treedef).encode())
    for leaf in leaves:
        _hash_array(h, leaf)


def canonical_family(family: IntegrandFamily) -> IntegrandFamily:
    """The form of ``family`` the service evaluates and hashes.

    Identical to what ``ZMCMultiFunctions`` runs: infinite boxes rewritten
    to finite ones.  Idempotent, so pre-canonicalized submissions are
    no-ops.
    """
    return family.compactified()


def family_hash(family: IntegrandFamily, *, canonicalize: bool = True) -> str:
    """Content hash of one integrand family (hex sha256).

    Families that evaluate identical integrals — same code shape, same
    parameters, same domains — hash identically regardless of who built
    them; the label ``name`` does not participate.
    """
    if canonicalize:
        family = canonical_family(family)
    h = hashlib.sha256()
    if family.kernel is not None:
        from repro.kernels import registry
        if registry.form(family.kernel) is not None:
            # registered form: code identity is the (stable) registry name
            h.update(b"form:")
            h.update(family.kernel.encode())
        else:
            h.update(b"code:")
            _hash_callable(h, family.fn)
    else:
        h.update(b"code:")
        _hash_callable(h, family.fn)
    _hash_pytree(h, family.params)
    _hash_array(h, family.domains)
    return h.hexdigest()


def spec_hash(spec: MultiFunctionSpec | Any, *, sampler: str = "mc") -> str:
    """Order-sensitive hash of a whole request spec (family list + sampler)."""
    if isinstance(spec, MultiFunctionSpec):
        families = spec.families
    else:
        families = tuple(spec)
    h = hashlib.sha256()
    h.update(sampler.encode())
    for fam in families:
        h.update(family_hash(fam).encode())
    return h.hexdigest()
