"""Deterministic canonicalization + content hashing of integration requests.

Two clients that ask for the same integral must map to the same cache
entry, even when they built their :class:`IntegrandFamily` objects
independently (fresh closures, different cosmetic names, float64 instead
of float32 parameters).  This module defines what "the same integral"
means to the service:

* the **numerical content** — parameter pytree and domain boxes — is
  serialized leaf-by-leaf (dict keys sorted, dtypes normalized to what
  the engine actually computes in: f32 for floats) and hashed;
* the **code identity** of the integrand is the registered kernel-form
  name when the family declares one (stable across processes and
  machines), otherwise a structural fingerprint of the Python function:
  bytecode, consts (nested code objects fingerprinted recursively — their
  ``repr`` contains memory addresses), names, plus the *values* captured
  in closure cells and defaults.  Two lambdas produced by two calls of
  the same constructor hash identically; capturing a different value
  changes the hash;
* the cosmetic ``name`` is excluded on purpose.

Infinite domains are compactified *before* hashing, mirroring what the
engine does before sampling, so ``gaussian over R^d`` submitted raw and
pre-compactified dedupe to the same entry.

Requests are not per-family-only: a **sweep request** (one template
family × a parameter grid) canonicalizes here too.  The grid spec is
normalized — axes sorted by name, values to f32, points enumerated in
row-major (last-axis-fastest) order — and chunked into fixed-size
*slices* (:data:`DEFAULT_SWEEP_SLICE` points), each an ordinary swept
:class:`IntegrandFamily` that hashes by content like any other.  Cache
streams are therefore keyed per (family, grid-slice), not per point:
two clients sweeping overlapping grids dedupe at the sub-grid level
wherever their canonical slices align (same template, same axis names,
same point values at the same slice offsets), with no sweep-specific
hash scheme.

**Adapted epoch streams** need no hash scheme of their own: an
importance-grid epoch (``IntegrandFamily.adapted``) carries its grid
edges inside ``params``, so :func:`family_hash` keys every epoch to a
distinct stream automatically — a refit opens a new cache entry rather
than mutating history, which is what keeps adapted streams
bit-identically resumable (the chain itself is journaled as ``grid``
records; see ``repro.service.cache.register_grid`` and the Layer-3
STR007 rule).

The hash addresses the service's result cache; it is not a security
boundary.
"""

from __future__ import annotations

import hashlib
import types
from typing import Any

import jax
import numpy as np

from repro.core.integrand import IntegrandFamily, MultiFunctionSpec


def _hash_array(h, leaf) -> None:
    arr = np.asarray(leaf)
    # the engine computes in f32; f64 inputs are not a distinct integral
    if arr.dtype.kind == "f":
        arr = arr.astype(np.float32)
    elif arr.dtype.kind in "iu":
        arr = arr.astype(np.int64)
    arr = np.ascontiguousarray(arr)
    h.update(str(arr.dtype).encode())
    h.update(repr(arr.shape).encode())
    h.update(arr.tobytes())


def _hash_code(h, code: types.CodeType) -> None:
    h.update(code.co_code)
    h.update(repr(code.co_names).encode())
    h.update(repr(code.co_varnames).encode())
    for const in code.co_consts:
        if isinstance(const, types.CodeType):
            _hash_code(h, const)
        else:
            h.update(repr(const).encode())


def _hash_value(h, value: Any) -> None:
    """Hash one captured value (closure cell / default / const)."""
    if isinstance(value, (np.ndarray, jax.Array)) or np.isscalar(value):
        _hash_array(h, value)
    elif callable(value) and hasattr(value, "__code__"):
        _hash_callable(h, value)
    elif isinstance(value, (tuple, list)):
        h.update(b"seq")
        for v in value:
            _hash_value(h, v)
    elif isinstance(value, dict):
        h.update(b"map")
        for k in sorted(value, key=repr):
            h.update(repr(k).encode())
            _hash_value(h, value[k])
    else:
        h.update(repr(value).encode())


def _hash_global(h, value: Any) -> None:
    """Hash one module-global an integrand references.

    Data values (arrays, scalars, containers) hash by content — a
    module-level ``SCALE = 2.0`` versus ``3.0`` must produce different
    integrals.  Modules and functions hash by import path (stable across
    processes, and avoids recursing into jnp internals); a referenced
    *helper function's* body changing is therefore not detected — keep
    integrand math in the closure, not in mutable helpers.
    """
    if isinstance(value, types.ModuleType):
        h.update(f"module:{value.__name__}".encode())
    elif callable(value) and hasattr(value, "__code__"):
        h.update(f"fn:{getattr(value, '__module__', '')}."
                 f"{getattr(value, '__qualname__', '')}".encode())
    else:
        _hash_value(h, value)


def _hash_callable(h, fn) -> None:
    _hash_code(h, fn.__code__)
    for cell in fn.__closure__ or ():
        try:
            _hash_value(h, cell.cell_contents)
        except ValueError:  # empty cell (still being defined)
            h.update(b"empty-cell")
    for default in fn.__defaults__ or ():
        _hash_value(h, default)
    for name, default in sorted((fn.__kwdefaults__ or {}).items()):
        h.update(name.encode())
        _hash_value(h, default)
    # globals the code references (co_names covers loads of globals and
    # builtins; unresolvable names are attribute accesses / builtins)
    for name in fn.__code__.co_names:
        if name in fn.__globals__:
            h.update(name.encode())
            _hash_global(h, fn.__globals__[name])


def _hash_pytree(h, tree) -> None:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    h.update(str(treedef).encode())
    for leaf in leaves:
        _hash_array(h, leaf)


def canonical_family(family: IntegrandFamily) -> IntegrandFamily:
    """The form of ``family`` the service evaluates and hashes.

    Identical to what ``ZMCMultiFunctions`` runs: infinite boxes rewritten
    to finite ones.  Idempotent, so pre-canonicalized submissions are
    no-ops.
    """
    return family.compactified()


def family_hash(family: IntegrandFamily, *, canonicalize: bool = True) -> str:
    """Content hash of one integrand family (hex sha256).

    Families that evaluate identical integrals — same code shape, same
    parameters, same domains — hash identically regardless of who built
    them; the label ``name`` does not participate.
    """
    if canonicalize:
        family = canonical_family(family)
    h = hashlib.sha256()
    if family.kernel is not None:
        from repro.kernels import registry
        if registry.form(family.kernel) is not None:
            # registered form: code identity is the (stable) registry name
            h.update(b"form:")
            h.update(family.kernel.encode())
        else:
            h.update(b"code:")
            _hash_callable(h, family.fn)
    else:
        h.update(b"code:")
        _hash_callable(h, family.fn)
    _hash_pytree(h, family.params)
    _hash_array(h, family.domains)
    return h.hexdigest()


# Points per canonical sweep slice.  Part of the dedupe contract: two
# sweeps share cache streams only where their canonical slices align, so
# every engine must chunk at the same quantum (engines expose it as the
# ``sweep_slice_points`` knob for tests; changing it in production
# orphans — but never corrupts — previously cached sweep streams).
DEFAULT_SWEEP_SLICE = 64


def canonical_grid(grid: dict) -> tuple:
    """Normalize a sweep grid spec to ``((name, f32 values), ...)``.

    Axes are sorted by parameter name; values become f32 arrays with a
    leading point axis (scalars promoted to length-1 axes, trailing
    shape preserved for vector-valued parameters).  Two grid dicts that
    enumerate the same points canonicalize identically regardless of
    insertion order or input dtype.
    """
    if not grid:
        raise ValueError("sweep grid must name at least one axis")
    axes = []
    for name in sorted(grid):
        vals = np.asarray(grid[name], np.float32)
        if vals.ndim == 0:
            vals = vals.reshape(1)
        if vals.shape[0] == 0:
            raise ValueError(f"sweep axis {name!r} is empty")
        axes.append((str(name), vals))
    return tuple(axes)


def grid_table(axes: tuple) -> tuple[dict, tuple[int, ...]]:
    """Row-major point table of a canonical grid.

    Returns ``(table, shape)``: ``table[name]`` holds axis ``name``'s
    value at every grid point (leading axis = flat point index, last
    grid axis fastest — C order, so clients can reshape results to
    ``shape``), ``shape`` the per-axis point counts in sorted-name
    order.
    """
    sizes = [int(v.shape[0]) for _, v in axes]
    idx = np.indices(sizes).reshape(len(sizes), -1)
    table = {name: v[idx[i]] for i, (name, v) in enumerate(axes)}
    return table, tuple(sizes)


def sweep_slices(template: IntegrandFamily, grid: dict, *,
                 slice_points: int = DEFAULT_SWEEP_SLICE) -> tuple:
    """Canonical slice families of one sweep request.

    Chunks the row-major point enumeration into ``slice_points``-sized
    pieces and builds each as a canonical (compactified) swept family —
    the unit the cache keys on.  Deterministic: same template + same
    grid content → byte-identical slice sequence, and a *prefix* grid
    (extending only the slowest-varying axis) reproduces its aligned
    slices exactly, which is what makes overlapping client sweeps
    dedupe below the request level.

    Returns ``(slice_families, grid_shape, axis_names)``.
    """
    if int(slice_points) < 1:
        raise ValueError(f"slice_points must be >= 1, got {slice_points}")
    axes = canonical_grid(grid)
    table, shape = grid_table(axes)
    n_points = 1
    for s in shape:
        n_points *= s
    fams = []
    for start in range(0, n_points, int(slice_points)):
        stop = min(start + int(slice_points), n_points)
        chunk = {name: vals[start:stop] for name, vals in table.items()}
        fam = canonical_family(template.swept_over(chunk))
        fam.name = f"{template.name}:sweep[{start}:{stop}]"
        fams.append(fam)
    return tuple(fams), shape, tuple(name for name, _ in axes)


def spec_hash(spec: MultiFunctionSpec | Any, *, sampler: str = "mc") -> str:
    """Order-sensitive hash of a whole request spec (family list + sampler)."""
    if isinstance(spec, MultiFunctionSpec):
        families = spec.families
    else:
        families = tuple(spec)
    h = hashlib.sha256()
    h.update(sampler.encode())
    for fam in families:
        h.update(family_hash(fam).encode())
    return h.hexdigest()
