"""Cross-request coalescing into fused multi-round dimension buckets.

The unit of work in the service is a **(canonical family, round)** pair:
``round_samples`` samples of one cached stream, addressed purely by
counters (key, fn_offset, round * round_samples).  This module takes the
set of work items one engine wave produced — typically spanning many
client requests at different cache fill levels — and evaluates them in
as few kernel launches as possible:

* per (stream, sampler) the wave's rounds form one contiguous **span**
  ``[start, start + count)`` rooted at the stream's fold frontier;
* spans are grouped by ``(sampler, count)`` and each group's families go
  to the fused multi-round planner (:mod:`repro.kernels.mc_eval.multi`),
  which buckets them by integrand dimension and evaluates ALL ``count``
  rounds of a bucket in ONE ``pallas_call`` (``eval_plan_rounds`` /
  ``sharded_eval_plan_rounds``) — an R-round refinement wave over B
  buckets costs B launches, not R x B.  Spans may start at different
  stream depths (a cold stream and a top-up fuse into the same launch:
  per-function-block ``round_base`` offsets carry each stream's window);
* families whose form is not fusable fall back to the chunked JAX path,
  one round at a time (still counter-addressed, still cacheable).

Evaluation is split into :meth:`RoundBatcher.launch` (device dispatch —
returns an :class:`InFlightWave` whose sums are still device futures
under JAX async dispatch) and :meth:`RoundBatcher.deposit` (host
transfer + one group-committed cache fold per wave).  The engine
pipelines the two: wave k+1's launch overlaps wave k's transfer and
deposit, keeping journaling off the device critical path.
:meth:`RoundBatcher.execute` composes them for synchronous drivers.

Deposits stay **side-effect free until the end of the wave** and are
folded in round order per entry.  Rounds the cache already folded are
skipped (a replayed or racing wave recomputes bit-identical sums), so a
crash-and-restart of a wave (``run_with_restarts``) and concurrent
``step()`` drivers are both safe.

Fusion plans (the packed/concatenated bucket operands) are cached per
(entry set, sampler) with **LRU eviction** — steady-state request mixes
keep their plans hot instead of periodically re-planning everything.
Adapted streams need no special handling here: every importance-grid
epoch is a distinct cache entry (its edges live in the family params and
therefore in the content hash), so an epoch swap changes the entry set
and naturally misses to a fresh plan while the old epoch's plan ages out
of the LRU.
Compiled kernels are reused more broadly still: bucket kernel names
encode only the shape signature, so a *new* entry set whose buckets
match previously-seen shapes reuses the compiled executable (see
:mod:`repro.kernels.mc_eval.multi`).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Sequence

import numpy as np

from repro.analysis import streams as _analysis
from repro.core import direct_mc
from repro.core.direct_mc import SumsState
from repro.core.integrand import MultiFunctionSpec
from repro.service.cache import CacheEntry, ResultCache
from repro.service.faults import NULL_FAULTS


@dataclasses.dataclass(frozen=True)
class WorkItem:
    """One round of one cached stream."""
    chash: str
    round_index: int
    sampler: str


@dataclasses.dataclass(frozen=True)
class _Span:
    """One stream's contiguous slice of a wave: rounds [start, start+count)."""
    entry: CacheEntry
    sampler: str
    start: int
    count: int


@dataclasses.dataclass
class InFlightWave:
    """A dispatched wave whose sums may still be computing on device.

    ``results`` holds ``(entry, round_index, sums)`` with each entry's
    rounds ascending; the arrays inside ``sums`` are jax values — they
    materialize (blocking on the device) in :meth:`RoundBatcher.deposit`.
    """
    results: list[tuple[CacheEntry, int, SumsState]]
    n_items: int


class RoundBatcher:
    """Coalesces work items into fused multi-round launches, one RNG key."""

    def __init__(self, cache: ResultCache, key, *, use_kernel: bool = True,
                 mesh=None, fn_axis: str = "model",
                 sample_axes: Sequence[str] = ("data",), chunk: int = 8192,
                 plan_cache_size: int = 256, obs=None, faults=None):
        if obs is None:
            from repro.obs import Observability
            obs = Observability.disabled()
        self.obs = obs
        self.faults = NULL_FAULTS if faults is None else faults
        self.cache = cache
        self.key = key
        self.use_kernel = bool(use_kernel)
        self.mesh = mesh
        self.fn_axis = fn_axis
        self.sample_axes = tuple(sample_axes)
        self.chunk = int(chunk)
        self.plan_cache_size = int(plan_cache_size)
        # rounds served by the chunked per-round path instead of a fused
        # launch; benchmarks/service_bench.py gates this at 0 for
        # registered-form workloads (compactified families included)
        self.fallback_rounds = 0
        self._plans: collections.OrderedDict[tuple, object] = \
            collections.OrderedDict()

    # -- wave evaluation ------------------------------------------------------
    def execute(self, items: Sequence[WorkItem]) -> int:
        """Launch + deposit one wave synchronously; returns items executed."""
        return self.deposit(self.launch(items))

    def launch(self, items: Sequence[WorkItem]) -> InFlightWave:
        """Dispatch all items to the device; no cache side effects.

        Items are deduplicated (two requests wanting the same round of
        the same stream cost one evaluation), folded into per-stream
        contiguous spans, and spans sharing a round count are evaluated
        by one fused multi-round launch per dimension bucket.
        """
        obs = self.obs
        unique = sorted(set(items),
                        key=lambda it: (it.sampler, it.chash, it.round_index))
        groups: dict[tuple[str, int], list[_Span]] = {}
        for span in self._spans_of(unique):
            groups.setdefault((span.sampler, span.count), []).append(span)

        from repro.kernels import template
        launches_before = template.launch_count()
        results: list[tuple[CacheEntry, int, SumsState]] = []
        with obs.span("launch", items=len(unique), groups=len(groups)):
            self.faults.check("launch")
            for group_key in sorted(groups):
                results.extend(self._launch_group(groups[group_key]))
        obs.m["launches"].inc(template.launch_count() - launches_before)
        return InFlightWave(results=results, n_items=len(unique))

    def deposit(self, wave: InFlightWave) -> int:
        """Materialize a launched wave and group-commit it to the cache.

        Blocks on the device results (wave k's transfer overlaps wave
        k+1's dispatch when the engine pipelines), then folds every round
        through :meth:`ResultCache.deposit_wave` — one WAL fsync for the
        whole wave.  Returns the wave's item count.
        """
        obs = self.obs
        if _analysis.asserts_enabled():
            # STR002 live: no double-deposits or gaps within the wave
            per_stream: dict[str, list[int]] = {}
            for entry, round_index, _ in wave.results:
                per_stream.setdefault(entry.chash[:16],
                                      []).append(round_index)
            _analysis.assert_wave_consistent(per_stream)
        if wave.results:
            with obs.span("device_execute", items=wave.n_items):
                # block on the device futures *before* converting, so
                # the trace splits device wait from host-side transfer
                self.faults.check("device_execute")
                import jax
                jax.block_until_ready([sums.s1 for _, _, sums
                                       in wave.results])
        with obs.span("transfer", items=wave.n_items):
            self.faults.check("transfer")
            deposits = [
                (entry, round_index,
                 SumsState(s1=np.asarray(sums.s1, np.float32),
                           s2=np.asarray(sums.s2, np.float32),
                           n=np.float32(np.asarray(sums.n))))
                for entry, round_index, sums in wave.results]
            if (self.faults.enabled and deposits
                    and self.faults.fire("transfer_nan")):
                # poison the wave's first deposit: the cache's finite
                # check must reject it pre-journal and strike its stream
                entry, ri, sums = deposits[0]
                deposits[0] = (entry, ri, SumsState(
                    s1=np.full_like(sums.s1, np.nan),
                    s2=sums.s2, n=sums.n))
        with obs.span("deposit", items=wave.n_items):
            self.faults.check("deposit")
            self.cache.deposit_wave(deposits)
        return wave.n_items

    # -- wave shaping ---------------------------------------------------------
    def _spans_of(self, unique: Sequence[WorkItem]) -> list[_Span]:
        by_stream: dict[tuple[str, str], list[int]] = {}
        for it in unique:
            by_stream.setdefault((it.chash, it.sampler),
                                 []).append(it.round_index)
        spans = []
        for (chash, sampler) in sorted(by_stream):
            entry = self.cache.get(chash)
            if entry is None:
                raise KeyError(f"work item for unknown entry {chash}")
            rounds = sorted(by_stream[(chash, sampler)])
            if rounds != list(range(rounds[0], rounds[0] + len(rounds))):
                raise ValueError(
                    f"non-contiguous rounds {rounds} for stream "
                    f"{chash[:16]}: the planner must emit gap-free spans")
            spans.append(_Span(entry=entry, sampler=sampler,
                               start=rounds[0], count=len(rounds)))
        return spans

    def _launch_group(self, spans: list[_Span]):
        """One fused multi-round evaluation of same-count spans."""
        n = self.cache.round_samples
        count = spans[0].count
        sampler = spans[0].sampler
        self.obs.m["wave_rounds"].observe(count, sampler=sampler)
        for sp in spans:
            self.obs.m["bucket_rounds"].inc(
                count, dim=sp.entry.family.dim, sampler=sampler)
        # streams the poison ladder degraded leave the fused path: they
        # re-run on the chunked per-round fallback, isolated from the
        # healthy buckets they shared a launch with (counter addressing
        # keeps the chunked recomputation bit-identical to the fused one)
        healthy = [sp for sp in spans if not sp.entry.degraded]
        degraded = [sp for sp in spans if sp.entry.degraded]

        fused: dict[int, tuple] = {}
        if self.use_kernel and healthy:
            entries = [sp.entry for sp in healthy]
            fn_offsets = [e.fn_offset for e in entries]
            spec = MultiFunctionSpec(
                families=tuple(e.family for e in entries))
            from repro.kernels.mc_eval import multi
            self.faults.check("device_error")
            plan = self._plan_for(entries, sampler, spec, fn_offsets)
            start_rounds = {i: sp.start for i, sp in enumerate(healthy)}
            if self.mesh is not None:
                fused = multi.sharded_eval_plan_rounds(
                    plan, n, count, self.key, self.mesh,
                    start_rounds=start_rounds, fn_axis=self.fn_axis,
                    sample_axes=self.sample_axes)
            else:
                fused = multi.eval_plan_rounds(
                    plan, n, count, self.key, start_rounds=start_rounds)

        out = []
        for idx, sp in enumerate(healthy):
            if idx in fused:
                for r in range(count):
                    out.append((sp.entry, sp.start + r, fused[idx][r]))
                continue
            out.extend(self._chunked_rounds(sp, count, n, sampler))
        for sp in degraded:
            out.extend(self._chunked_rounds(sp, count, n, sampler))
        return out

    def _chunked_rounds(self, sp: _Span, count: int, n: int, sampler: str):
        """Chunked fallback: one counter-addressed eval per round."""
        self.fallback_rounds += count
        self.obs.m["fallback_rounds"].inc(count)
        out = []
        for r in range(count):
            sample_offset = (sp.start + r) * n
            if self.mesh is not None:
                sums, _ = direct_mc.sharded_family_sums(
                    sp.entry.family, n, self.key, self.mesh,
                    fn_axis=self.fn_axis, sample_axes=self.sample_axes,
                    fn_offset=sp.entry.fn_offset,
                    sample_offset=sample_offset, chunk=self.chunk,
                    use_kernel=self.use_kernel, sampler=sampler)
                sums = SumsState(s1=sums.s1[: sp.entry.n_fn],
                                 s2=sums.s2[: sp.entry.n_fn], n=sums.n)
            else:
                sums = direct_mc.family_sums(
                    sp.entry.family, n, self.key,
                    fn_offset=sp.entry.fn_offset,
                    sample_offset=sample_offset, chunk=self.chunk,
                    use_kernel=self.use_kernel, sampler=sampler)
            out.append((sp.entry, sp.start + r, sums))
        return out

    def _plan_for(self, entries: list[CacheEntry], sampler: str, spec,
                  fn_offsets):
        """LRU-cached fusion plan for this exact entry set.

        The plan holds packed per-entry operands, so the cache key is the
        entry identity tuple; eviction is least-recently-used (a full
        cache drops only the coldest mix, never the working set).  The
        *compiled* kernel behind a plan is shared by shape signature —
        see the module docstring.
        """
        from repro.kernels.mc_eval import multi
        plan_key = (tuple(e.chash for e in entries), sampler)
        plan = self._plans.get(plan_key)
        if plan is not None:
            self._plans.move_to_end(plan_key)
            return plan
        plan = multi.plan_spec(spec, sampler=sampler, fn_offsets=fn_offsets)
        self._plans[plan_key] = plan
        while len(self._plans) > self.plan_cache_size:
            self._plans.popitem(last=False)
        return plan
