"""Cross-request coalescing into fused dimension buckets.

The unit of work in the service is a **(canonical family, round)** pair:
``round_samples`` samples of one cached stream, addressed purely by
counters (key, fn_offset, round * round_samples).  This module takes the
set of work items one engine wave produced — typically spanning many
client requests at different cache fill levels — and evaluates them in
as few kernel launches as possible:

* items are grouped by ``(round_index, sampler)`` — every item in a
  group shares the same sample window and therefore the same kernel
  scalars;
* each group's families are handed to the fused multi-family planner
  (:mod:`repro.kernels.mc_eval.multi`), which buckets them by integrand
  dimension and runs each bucket in ONE ``pallas_call`` — so one launch
  serves every request that contributed a same-dimension family, exactly
  mirroring the single-spec fusion of PR 1;
* families whose form is not fusable fall back to the chunked JAX path,
  one at a time (still counter-addressed, still cacheable).

Evaluation is **side-effect free until the end of the wave**: all sums
are computed first and deposited into the cache afterwards, in round
order.  Deposits of rounds the cache already folded are skipped by the
cache (a replayed or racing wave recomputes bit-identical sums), so a
crash-and-restart of a wave (``run_with_restarts``) and concurrent
``step()`` drivers are both safe.

Fusion plans are cached per (entry set, sampler): the packed/concatenated
bucket operands depend only on the families and their counter offsets,
so a multi-round refinement re-launches the same plan with new scalars
instead of rebuilding it every wave.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core import direct_mc
from repro.core.direct_mc import SumsState
from repro.core.integrand import MultiFunctionSpec
from repro.service.cache import CacheEntry, ResultCache


@dataclasses.dataclass(frozen=True)
class WorkItem:
    """One round of one cached stream."""
    chash: str
    round_index: int
    sampler: str


class RoundBatcher:
    """Coalesces work items into fused launches against one RNG key."""

    def __init__(self, cache: ResultCache, key, *, use_kernel: bool = True,
                 mesh=None, fn_axis: str = "model",
                 sample_axes: Sequence[str] = ("data",), chunk: int = 8192):
        self.cache = cache
        self.key = key
        self.use_kernel = bool(use_kernel)
        self.mesh = mesh
        self.fn_axis = fn_axis
        self.sample_axes = tuple(sample_axes)
        self.chunk = int(chunk)
        self._plans: dict[tuple, object] = {}

    # -- wave evaluation ------------------------------------------------------
    def execute(self, items: Sequence[WorkItem]) -> int:
        """Evaluate all items, then deposit; returns items executed.

        Items are deduplicated (two requests wanting the same round of
        the same stream cost one evaluation) and deposits happen only
        after every group evaluated, keeping the wave restartable.
        """
        unique = sorted(set(items),
                        key=lambda it: (it.round_index, it.sampler, it.chash))
        groups: dict[tuple[int, str], list[WorkItem]] = {}
        for it in unique:
            groups.setdefault((it.round_index, it.sampler), []).append(it)

        results: list[tuple[CacheEntry, int, SumsState]] = []
        for (round_index, sampler) in sorted(groups):
            batch = groups[(round_index, sampler)]
            entries = [self.cache.get(it.chash) for it in batch]
            for it, entry in zip(batch, entries):
                if entry is None:
                    raise KeyError(f"work item for unknown entry {it.chash}")
            results.extend(
                (entry, round_index, sums)
                for entry, sums in self._eval_group(entries, round_index,
                                                    sampler))

        # in-order left fold: per entry, rounds arrive ascending because
        # groups were processed in round order
        for entry, round_index, sums in results:
            self.cache.deposit(entry, round_index, sums)
        return len(unique)

    def _eval_group(self, entries: list[CacheEntry], round_index: int,
                    sampler: str):
        """One fused evaluation of same-round entries. No side effects."""
        n = self.cache.round_samples
        sample_offset = round_index * n
        families = tuple(e.family for e in entries)
        fn_offsets = [e.fn_offset for e in entries]
        spec = MultiFunctionSpec(families=families)

        fused: dict[int, SumsState] = {}
        if self.use_kernel:
            from repro.kernels.mc_eval import multi
            plan_key = (tuple(e.chash for e in entries), sampler)
            plan = self._plans.get(plan_key)
            if plan is None:
                if len(self._plans) >= 256:   # bound stale entry-set combos
                    self._plans.clear()
                plan = multi.plan_spec(spec, sampler=sampler,
                                       fn_offsets=fn_offsets)
                self._plans[plan_key] = plan
            if self.mesh is not None:
                fused = multi.sharded_eval_plan(
                    plan, n, self.key, self.mesh, fn_axis=self.fn_axis,
                    sample_axes=self.sample_axes,
                    sample_offset=sample_offset)
            else:
                fused = multi.eval_plan(plan, n, self.key,
                                        sample_offset=sample_offset)

        out = []
        for idx, entry in enumerate(entries):
            if idx in fused:
                sums = fused[idx]
            elif self.mesh is not None:
                sums, _ = direct_mc.sharded_family_sums(
                    entry.family, n, self.key, self.mesh,
                    fn_axis=self.fn_axis, sample_axes=self.sample_axes,
                    fn_offset=entry.fn_offset, sample_offset=sample_offset,
                    chunk=self.chunk, use_kernel=self.use_kernel,
                    sampler=sampler)
                sums = SumsState(s1=sums.s1[: entry.n_fn],
                                 s2=sums.s2[: entry.n_fn], n=sums.n)
            else:
                sums = direct_mc.family_sums(
                    entry.family, n, self.key, fn_offset=entry.fn_offset,
                    sample_offset=sample_offset, chunk=self.chunk,
                    use_kernel=self.use_kernel, sampler=sampler)
            out.append((entry, sums))
        return out
