"""Stderr-aware result cache with counter-stream top-up.

A cache entry stores the *raw accumulators* ``(s1, s2, n)`` of a
canonical family, not the finished estimate.  That choice buys two
things:

* **hit** — when the cached sample count already yields a standard error
  at or below the requested precision, the result is finalized straight
  from the accumulators: zero new kernel launches;
* **top-up** — when it does not, the engine *resumes* the counter-based
  sample stream at ``sample_offset = n`` instead of recomputing from
  scratch: the cached work is never wasted, and the merged accumulators
  are bit-identical to an uninterrupted run of the same total budget
  (asserted by ``tests/core/test_resume.py``).

Bit-identity needs a fixed association order for the f32 merges, so all
accumulation is quantized into fixed-size **rounds** of
``round_samples`` each, deposited strictly in order and left-folded one
round at a time — the same fold an uninterrupted service evaluation
performs.  A replayed round (same index deposited twice — restarted
waves, racing wave drivers) is skipped, which is exact: the counters
make any recomputation of a round bit-identical to the folded one.
``rounds_needed`` converts a stderr target into additional rounds using
the cached variance estimate (stderr shrinks as 1/sqrt(n)).

Entries also own the family's **counter-space offset**: the service
allocates each distinct integral a disjoint global function-id range (a
bump allocator over the 2^24-id space of ``rng.DIM_STRIDE``), so every
Threefry counter of every cached stream stays addressable and collision
free no matter which batch the family first arrived in.

Parameter sweeps add no machinery here: a sweep request canonicalizes
into fixed-size slices of *swept* families
(``repro.service.canonical.sweep_slices``), each an ordinary entry —
content-hashed, allocated its own counter range, topped up and
journaled exactly like a single-family stream — so overlapping sweeps
from different clients share streams wherever their canonical slices
align, and every guarantee above applies per slice.

Concurrency: an entry's mutable accumulator state lives in ONE tuple,
swapped atomically under the cache lock by :meth:`deposit`; readers
(``stderr``/``finalize``/``meets``) work from a single snapshot, so a
submit racing a worker deposit sees either the old or the new round —
never half of one.

Durability: with a :class:`~repro.service.store.DurableStore` attached,
every allocation and deposit is journaled *before* the in-memory fold
(write-ahead), and persisted streams from a previous process live in a
**dormant** table until a request re-asks for them — rehydration
restores the exact ``(s1, s2, n, rounds_done)`` accumulators and the
original counter-space ``fn_offset``, so a warm restart serves satisfied
requests with zero launches and tops up partial ones bit-identically.
Dormant streams survive compaction: :meth:`snapshot_to_store` persists
them alongside the live entries.
"""

from __future__ import annotations

import math
import threading

import numpy as np

from repro.analysis import streams as _analysis
from repro.core import direct_mc
from repro.core.direct_mc import SumsState
from repro.core.integrand import IntegrandFamily
from repro.service.store import DurableStore, EntryState, GridRecord

# id space addressable by the counter layout: fn_id * DIM_STRIDE + dim
# must fit u32, so fn_id < 2**24 (DIM_STRIDE = 256)
_ID_SPACE = 1 << 24


class CacheEntry:
    """Accumulated sample stream of one canonical family."""

    def __init__(self, chash: str, family: IntegrandFamily, fn_offset: int):
        self.chash = chash
        self.family = family         # canonical (compactified) representative
        self.fn_offset = fn_offset   # allocated global function-id range start
        self.hits = 0
        n_fn = family.n_fn
        # box volume cached as numpy so the precision checks the engine
        # runs under its lock every wave stay off the device
        from repro.core.domains import box_volume
        self._vol = np.asarray(box_volume(family.domains), np.float32)
        # (s1, s2, n, rounds_done): replaced wholesale, never mutated
        self._state = (np.zeros(n_fn, np.float32),
                       np.zeros(n_fn, np.float32), 0, 0)
        # poison ladder (non-finite deposits, see deposit_wave): strikes
        # count consecutive poisoned waves; `degraded` routes the stream
        # off the fused path, `quarantined` stops scheduling it at all
        self.poison_strikes = 0
        self.degraded = False
        self.quarantined = False

    @property
    def n_fn(self) -> int:
        return self.family.n_fn

    def snapshot(self) -> tuple[np.ndarray, np.ndarray, int, int]:
        """One consistent (s1, s2, n, rounds_done) view."""
        return self._state

    @property
    def s1(self) -> np.ndarray:
        return self._state[0]

    @property
    def s2(self) -> np.ndarray:
        return self._state[1]

    @property
    def n(self) -> int:
        return self._state[2]

    @property
    def rounds_done(self) -> int:
        return self._state[3]

    def sums(self) -> SumsState:
        s1, s2, n, _ = self.snapshot()
        return SumsState(s1=s1, s2=s2, n=np.float32(n))

    def finalize(self) -> direct_mc.MCResult:
        s1, s2, n, _ = self.snapshot()
        return direct_mc.finalize(
            self.family, SumsState(s1=s1, s2=s2, n=np.float32(n)))

    def stderr(self) -> np.ndarray:
        """Current per-function standard error (inf before any round)."""
        return self._stderr_of(self.snapshot())

    def _stderr_of(self, state) -> np.ndarray:
        # numpy mirror of direct_mc.finalize's stderr (hot path: called
        # per pending request per wave, often under the engine lock)
        s1, s2, n, _ = state
        if n == 0:
            return np.full(self.n_fn, np.inf, np.float32)
        nf = np.float32(n)
        mean_f = s1 / nf
        var_f = np.maximum(s2 / nf - np.square(mean_f), np.float32(0.0))
        return self._vol * np.sqrt(var_f / nf)


class ResultCache:
    """In-memory cache of canonical-family accumulators (thread-safe)."""

    def __init__(self, round_samples: int = 65536,
                 store: DurableStore | None = None, obs=None,
                 degrade_after: int = 2, quarantine_after: int = 3):
        if round_samples <= 0:
            raise ValueError("round_samples must be positive")
        if not 1 <= degrade_after <= quarantine_after:
            raise ValueError("need 1 <= degrade_after <= quarantine_after")
        if obs is None:
            from repro.obs import Observability
            obs = Observability.disabled()
        self.obs = obs
        self.round_samples = int(round_samples)
        # poison-ladder thresholds, in consecutive poisoned waves: at
        # `degrade_after` strikes a stream leaves the fused path (a
        # fused-kernel bug must not condemn the integrand), at
        # `quarantine_after` it stops being scheduled at all
        self.degrade_after = int(degrade_after)
        self.quarantine_after = int(quarantine_after)
        self._entries: dict[str, CacheEntry] = {}
        self._next_id = 0
        self._lock = threading.Lock()
        self.store = store
        self._dormant: dict[str, EntryState] = {}
        # adapted streams' importance grids, keyed by the child chash —
        # persisted alongside the accumulators so a resumed engine
        # rebuilds the exact epoch chain instead of refitting
        self._grids: dict[str, GridRecord] = {}
        self.recovered = None
        if store is not None:
            state = store.load()
            if (state.round_samples is not None
                    and state.round_samples != self.round_samples):
                raise ValueError(
                    f"state dir holds streams quantized into rounds of "
                    f"{state.round_samples} samples; this cache is "
                    f"configured with round_samples={self.round_samples}")
            self._dormant = dict(state.entries)
            self._next_id = max(self._next_id, state.next_id)
            self._grids = dict(state.grids)
            self.recovered = state

    # -- lookup / allocation --------------------------------------------------
    def get(self, chash: str,
            family: IntegrandFamily | None = None) -> CacheEntry | None:
        """Entry for ``chash`` if it exists — in memory, or (when the
        canonical ``family`` is supplied) rehydrated from persisted
        state.  Never allocates a new counter range."""
        entry = self._entries.get(chash)
        if entry is not None or family is None:
            return entry
        if not self._dormant:     # only ever shrinks: cold misses stay
            return None           # lock-free (every store-less engine)
        with self._lock:
            return self._rehydrate_locked(chash, family)

    def _rehydrate_locked(self, chash: str,
                          family: IntegrandFamily) -> CacheEntry | None:
        entry = self._entries.get(chash)
        if entry is not None:
            return entry
        st = self._dormant.pop(chash, None)
        if st is None:
            return None
        if st.n_fn != family.n_fn:
            raise ValueError(
                f"persisted stream {chash[:16]} has n_fn={st.n_fn} but the "
                f"submitted family has n_fn={family.n_fn}")
        if st.round_samples != self.round_samples:
            raise ValueError(
                f"persisted stream {chash[:16]} was quantized into rounds "
                f"of {st.round_samples}; cache uses {self.round_samples}")
        entry = CacheEntry(chash=chash, family=family,
                           fn_offset=st.fn_offset)
        entry._state = (np.asarray(st.s1, np.float32),
                        np.asarray(st.s2, np.float32),
                        int(st.n), int(st.rounds_done))
        self._entries[chash] = entry
        return entry

    def get_or_allocate(self, chash: str, family: IntegrandFamily) -> CacheEntry:
        """Existing entry for ``chash`` (rehydrating persisted state if
        needed), or a fresh one with its own counter-space range.
        ``family`` must already be canonical."""
        with self._lock:
            entry = self._rehydrate_locked(chash, family)
            if entry is not None:
                entry.hits += 1
                return entry
            n_fn = family.n_fn
            if self._next_id + n_fn > _ID_SPACE:
                raise RuntimeError(
                    f"counter id space exhausted ({_ID_SPACE} function ids)")
            if _analysis.asserts_enabled():
                # STR001 live: live + dormant streams all own disjoint
                # counter ranges the new allocation must clear
                _analysis.assert_disjoint_allocation(
                    [(c, e.fn_offset, e.n_fn)
                     for c, e in self._entries.items()]
                    + [(c, st.fn_offset, st.n_fn)
                       for c, st in self._dormant.items()],
                    chash, self._next_id, n_fn)
            entry = CacheEntry(chash=chash, family=family,
                               fn_offset=self._next_id)
            self._next_id += n_fn
            self._entries[chash] = entry
        if self.store is not None:
            # journaled outside the cache lock (disk I/O must not stall
            # readers; lock order is always store.mutex -> cache lock).
            # Should a crash land in this gap, any deposit journaled for
            # the missing alloc is dropped on replay and recomputed —
            # counter addressing makes that recomputation bit-identical.
            self.store.append_alloc(chash, fn_offset=entry.fn_offset,
                                    n_fn=n_fn,
                                    round_samples=self.round_samples)
        return entry

    # -- importance-grid epoch chains -----------------------------------------
    def register_grid(self, chash: str, *, parent: str, epoch: int,
                      edges) -> GridRecord:
        """Record an adapted stream's importance grid, journal-first.

        A grid refit opens a NEW epoch stream (``chash``) keyed by its
        edges rather than mutating history, so accumulators stay
        bit-identically resumable; this registers the edges the child
        samples through and their position in the epoch chain.  Call it
        *before* ``get_or_allocate(chash, ...)`` — the WAL must carry
        the grid ahead of the child's alloc (the Layer-3 STR007
        ordering rule).  Idempotent: a re-registration (resume replays
        the planner's decisions) returns the existing record unjournaled.
        """
        edges = np.ascontiguousarray(edges, np.float32)
        with self._lock:
            rec = self._grids.get(chash)
            if rec is not None:
                return rec
            rec = GridRecord(
                chash=chash, parent=parent, epoch=int(epoch),
                n_fn=int(edges.shape[0]), dim=int(edges.shape[1]),
                n_bins=int(edges.shape[2]) - 1, edges=edges)
            self._grids[chash] = rec
        if self.store is not None:
            # journaled outside the cache lock, same discipline (and
            # crash window) as get_or_allocate: a grid record with no
            # child alloc is benign on replay
            self.store.append_grid(chash, parent=parent, epoch=int(epoch),
                                   edges=edges)
        return rec

    def grid_for(self, chash: str) -> GridRecord | None:
        """The importance-grid record of an adapted stream (or None)."""
        with self._lock:
            return self._grids.get(chash)

    def grid_chain(self, chash: str) -> list[GridRecord]:
        """Grid records from epoch 1 up to ``chash``'s epoch, in order
        (empty for an unadapted stream)."""
        chain: list[GridRecord] = []
        with self._lock:
            rec = self._grids.get(chash)
            while rec is not None:
                chain.append(rec)
                rec = self._grids.get(rec.parent)
        chain.reverse()
        return chain

    def grid_tip(self, base_chash: str) -> GridRecord | None:
        """Deepest journaled epoch of the chain rooted at ``base_chash``
        (None when the base stream was never adapted).  A resumed
        planner adopts the tip — recorded chash, recorded edges — rather
        than refitting, so the resume samples through exactly the grid
        the interrupted run journaled.  Deterministic fits give each
        parent at most one child; should duplicates ever appear, the
        lexicographically-smallest chash wins so resume stays stable."""
        with self._lock:
            children: dict[str, list[GridRecord]] = {}
            for rec in self._grids.values():
                children.setdefault(rec.parent, []).append(rec)
        tip = None
        cur = base_chash
        while cur in children:
            rec = min(children[cur], key=lambda r: r.chash)
            tip = rec
            cur = rec.chash
        return tip

    # -- precision logic ------------------------------------------------------
    def rounds_for_budget(self, n_samples: int) -> int:
        """Rounds needed to cover an ``n_samples`` budget (quantized up)."""
        return max(1, math.ceil(int(n_samples) / self.round_samples))

    def meets(self, entry: CacheEntry, *, target_stderr: float | None,
              n_samples: int | None) -> bool:
        """Does the cached stream already satisfy the request?"""
        state = entry.snapshot()
        if state[2] == 0:
            return False
        if n_samples is not None and state[3] < self.rounds_for_budget(n_samples):
            return False
        if target_stderr is not None and not np.all(
                entry._stderr_of(state) <= target_stderr):
            return False
        return True

    def rounds_needed(self, entry: CacheEntry, *, target_stderr: float | None,
                      n_samples: int | None, max_rounds: int = 1 << 16) -> int:
        """Additional rounds to schedule for this entry (0 = cache hit).

        Budget requests are exact; stderr targets are predicted from the
        cached variance (stderr ~ 1/sqrt(n)), with one bootstrap round
        when no variance estimate exists yet.  The engine re-checks after
        every wave, so an under-prediction just schedules another wave.
        """
        state = entry.snapshot()
        _, _, n, rounds_done = state
        need = 0
        if n_samples is not None:
            need = max(need, self.rounds_for_budget(n_samples) - rounds_done)
        if target_stderr is not None:
            if n == 0:
                need = max(need, 1)
            else:
                err = entry._stderr_of(state)
                if np.any(err > target_stderr):
                    # n_target / n_now = (err_now / target)^2, per function
                    ratio = float(np.max(err / max(target_stderr, 1e-30))) ** 2
                    total = math.ceil(ratio * n / self.round_samples)
                    need = max(need, total - rounds_done)
        return int(min(max(need, 0), max_rounds))

    # -- deposits -------------------------------------------------------------
    def deposit(self, entry: CacheEntry, round_index: int,
                sums: SumsState) -> bool:
        """Fold one round of sums into the entry, strictly in order.

        Returns True when the round was folded, False when it was
        already present (a replayed wave or a racing wave driver
        recomputed it — bit-identical by counter addressing, so skipping
        is exact).  A round *beyond* the fold frontier is a planner bug
        and raises: folding it would skip samples.
        """
        return self.deposit_wave([(entry, round_index, sums)],
                                 on_ahead="raise") == 1

    def deposit_wave(self, deposits, *, on_ahead: str = "skip") -> int:
        """Group-commit a whole wave of round deposits: ONE journal fsync.

        ``deposits`` is a sequence of ``(entry, round_index, sums)`` with
        each entry's rounds in ascending order (the batcher emits them
        that way).  Rounds already folded are skipped unjournaled (exact:
        counter addressing makes any recomputation bit-identical).  The
        accepted records are journaled in one batch write + fsync
        (:meth:`DurableStore.append_deposits`) *before* any of them
        folds, preserving WAL ordering: a crash can lose a suffix of the
        wave, never a folded round.  Returns the number of rounds folded.

        Rounds *beyond* an entry's fold frontier are, by default, also
        skipped (unfolded, unjournaled): a wave racing another driver can
        legitimately carry rounds whose predecessors are still in the
        other driver's in-flight wave — folding them would skip samples,
        so they are dropped and the planner re-schedules them once the
        frontier catches up.  ``on_ahead="raise"`` turns that into an
        error (the single-round :meth:`deposit` contract, where an
        ahead-of-frontier round can only be a planner bug).

        Durable path locking: the store mutex is held across journal +
        fold so the write-ahead batch and the in-memory folds are one
        atomic unit w.r.t. concurrent deposits and snapshot compaction —
        while the fsync runs OUTSIDE the cache lock, leaving readers
        (submit peeks, meets, stats) unblocked.  Lock order everywhere:
        store.mutex -> cache lock, never the reverse.
        """
        recs = [(entry, int(round_index),
                 np.asarray(sums.s1, np.float32),
                 np.asarray(sums.s2, np.float32),
                 int(np.asarray(sums.n)))
                for entry, round_index, sums in deposits]
        # per-round finite check BEFORE journaling: a NaN/Inf deposit is
        # never written ahead (it would poison every future replay) and
        # never folded — the stream takes a poison strike instead, and
        # its un-deposited rounds go back to the planner.  Checking per
        # round means one bad integrand quarantines only its own stream,
        # not the fused bucket it rode in.
        poisoned: list = []
        seen_poison: set[int] = set()
        if recs:
            clean = []
            for rec in recs:
                if np.isfinite(rec[2]).all() and np.isfinite(rec[3]).all():
                    clean.append(rec)
                elif id(rec[0]) not in seen_poison:
                    seen_poison.add(id(rec[0]))
                    poisoned.append(rec[0])
            recs = clean
        if self.store is None:
            with self._lock:
                accepted = self._admit_locked(recs, on_ahead)
                folded, states = self._fold_batch_locked(accepted)
        else:
            with self.store.mutex:
                with self._lock:
                    accepted = self._admit_locked(recs, on_ahead)
                self.store.append_deposits(
                    self.store.deposit_record(entry.chash, ri, s1, s2, n)
                    for entry, ri, s1, s2, n in accepted)
                with self._lock:
                    folded, states = self._fold_batch_locked(accepted)
        if poisoned:
            self._note_poison(poisoned)
        if folded:
            # a clean folded wave resets the strike count of streams it
            # covered (transient device/transfer glitches must not creep
            # a healthy stream toward quarantine); degradation and
            # quarantine themselves stay sticky
            with self._lock:
                for entry, *_ in accepted:
                    if id(entry) not in seen_poison and entry.poison_strikes:
                        entry.poison_strikes = 0
        self._observe_deposits(folded, states)
        return folded

    def _note_poison(self, entries) -> None:
        """Advance the poison ladder for streams whose wave deposited
        non-finite sums: reschedule (strike 1+) -> degrade off the fused
        path (``degrade_after``) -> quarantine (``quarantine_after``)."""
        degraded, quarantined = [], []
        with self._lock:
            for entry in entries:
                entry.poison_strikes += 1
                if (entry.poison_strikes >= self.degrade_after
                        and not entry.degraded):
                    entry.degraded = True
                    degraded.append(entry)
                if (entry.poison_strikes >= self.quarantine_after
                        and not entry.quarantined):
                    entry.quarantined = True
                    quarantined.append(entry)
        for entry in entries:
            self.obs.event("poison_deposit", stream=entry.chash[:16],
                           strikes=entry.poison_strikes,
                           degraded=entry.degraded,
                           quarantined=entry.quarantined)
        for entry in degraded:
            self.obs.event("degrade", stream=entry.chash[:16],
                           strikes=entry.poison_strikes)
        for entry in quarantined:
            self.obs.m["quarantined_streams"].inc()
            self.obs.event("quarantine", stream=entry.chash[:16],
                           strikes=entry.poison_strikes)

    def quarantined_streams(self) -> list[str]:
        """chashes of quarantined streams (stable order, observables
        for the metrics-agreement gate)."""
        with self._lock:
            return sorted(c for c, e in self._entries.items()
                          if e.quarantined)

    def _admit_locked(self, recs, on_ahead: str):
        """Filter a deposit batch against a local frontier image.

        The frontier advances per accepted record, so consecutive rounds
        of one entry in the same wave chain correctly.  Caller must hold
        the cache lock; in the durable path the store mutex additionally
        keeps the admitted set valid until the folds land (no other
        depositor can move a frontier in between).
        """
        frontier = {id(e): e._state[3] for e, *_ in recs}
        accepted = []
        for entry, ri, s1, s2, n in recs:
            done = frontier[id(entry)]
            if ri < done:
                continue               # replayed round: exact, unjournaled
            if ri > done:
                if on_ahead == "raise":
                    raise ValueError(
                        f"deposit gap: round {ri} into entry at "
                        f"round {done}")
                continue               # predecessors still in flight
            accepted.append((entry, ri, s1, s2, n))
            frontier[id(entry)] = done + 1
        return accepted

    def _fold_batch_locked(self, accepted):
        """Fold an admitted batch; returns (rounds folded, post-fold
        (entry, state) snapshots for telemetry).  Caller holds the cache
        lock (and, on the durable path, the store mutex)."""
        folded = 0
        states = []
        for entry, ri, s1, s2, n in accepted:
            if self._fold_locked(entry, ri, s1, s2, n):
                folded += 1
                states.append((entry, entry._state))
        return folded, states

    def _observe_deposits(self, folded: int, states) -> None:
        """Telemetry for a committed wave, outside every lock: the
        deposit-round counter and (when enabled) one convergence
        trajectory point per folded round — the stderr-vs-rounds data
        the adaptive planner consumes (:mod:`repro.obs.convergence`).
        States are immutable snapshots, so reading them lock-free is
        exact."""
        obs = self.obs
        if folded:
            obs.m["deposit_rounds"].inc(folded)
        if obs.record_convergence:
            for entry, state in states:
                err = entry._stderr_of(state)
                obs.convergence.record(
                    entry.chash, rounds_done=state[3], n=state[2],
                    stderr_max=float(err.max()),
                    stderr_mean=float(err.mean()))

    def _fold_locked(self, entry: CacheEntry, round_index: int,
                     s1_delta, s2_delta, n_delta: int) -> bool:
        s1, s2, n, done = entry._state
        if round_index < done:
            return False
        if round_index > done:
            raise ValueError(
                f"deposit gap: round {round_index} into entry at "
                f"round {done}")
        entry._state = (
            np.asarray(s1 + s1_delta),
            np.asarray(s2 + s2_delta),
            n + n_delta,
            done + 1,
        )
        return True

    # -- persistence ----------------------------------------------------------
    def snapshot_to_store(self) -> None:
        """Compact journal + accumulators into one atomic npz snapshot.

        Includes dormant persisted streams no request has re-asked for
        yet — compaction must never forget a stream.
        """
        if self.store is None:
            raise RuntimeError("cache has no DurableStore attached")
        # mutex first (same order as deposit): no deposit can journal or
        # fold between state collection and the journal reset, so the
        # snapshot + fresh journal always cover every folded round.  The
        # npz write itself runs outside the cache lock — readers proceed.
        with self.store.mutex:
            with self._lock:
                states = []
                for chash, entry in self._entries.items():
                    s1, s2, n, done = entry.snapshot()
                    states.append(EntryState(
                        chash=chash, fn_offset=entry.fn_offset,
                        n_fn=entry.n_fn, round_samples=self.round_samples,
                        s1=np.asarray(s1, np.float32),
                        s2=np.asarray(s2, np.float32),
                        n=int(n), rounds_done=int(done)))
                states.extend(self._dormant.values())
                grids = [self._grids[c] for c in sorted(self._grids)]
                next_id = self._next_id
            self.store.snapshot(states, next_id=next_id,
                                round_samples=self.round_samples,
                                grids=grids)

    # -- stats ----------------------------------------------------------------
    @property
    def n_entries(self) -> int:
        return len(self._entries)

    @property
    def total_samples(self) -> int:
        return sum(e.n for e in self._entries.values())

    def stats(self) -> dict:
        return {
            "entries": self.n_entries,
            "dormant": len(self._dormant),
            "function_ids_allocated": self._next_id,
            "total_samples": self.total_samples,
            "hits": sum(e.hits for e in self._entries.values()),
        }
