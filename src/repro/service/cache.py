"""Stderr-aware result cache with counter-stream top-up.

A cache entry stores the *raw accumulators* ``(s1, s2, n)`` of a
canonical family, not the finished estimate.  That choice buys two
things:

* **hit** — when the cached sample count already yields a standard error
  at or below the requested precision, the result is finalized straight
  from the accumulators: zero new kernel launches;
* **top-up** — when it does not, the engine *resumes* the counter-based
  sample stream at ``sample_offset = n`` instead of recomputing from
  scratch: the cached work is never wasted, and the merged accumulators
  are bit-identical to an uninterrupted run of the same total budget
  (asserted by ``tests/core/test_resume.py``).

Bit-identity needs a fixed association order for the f32 merges, so all
accumulation is quantized into fixed-size **rounds** of
``round_samples`` each, deposited strictly in order and left-folded one
round at a time — the same fold an uninterrupted service evaluation
performs.  A replayed round (same index deposited twice — restarted
waves, racing wave drivers) is skipped, which is exact: the counters
make any recomputation of a round bit-identical to the folded one.
``rounds_needed`` converts a stderr target into additional rounds using
the cached variance estimate (stderr shrinks as 1/sqrt(n)).

Entries also own the family's **counter-space offset**: the service
allocates each distinct integral a disjoint global function-id range (a
bump allocator over the 2^24-id space of ``rng.DIM_STRIDE``), so every
Threefry counter of every cached stream stays addressable and collision
free no matter which batch the family first arrived in.

Concurrency: an entry's mutable accumulator state lives in ONE tuple,
swapped atomically under the cache lock by :meth:`deposit`; readers
(``stderr``/``finalize``/``meets``) work from a single snapshot, so a
submit racing a worker deposit sees either the old or the new round —
never half of one.
"""

from __future__ import annotations

import math
import threading

import numpy as np

from repro.core import direct_mc
from repro.core.direct_mc import SumsState
from repro.core.integrand import IntegrandFamily

# id space addressable by the counter layout: fn_id * DIM_STRIDE + dim
# must fit u32, so fn_id < 2**24 (DIM_STRIDE = 256)
_ID_SPACE = 1 << 24


class CacheEntry:
    """Accumulated sample stream of one canonical family."""

    def __init__(self, chash: str, family: IntegrandFamily, fn_offset: int):
        self.chash = chash
        self.family = family         # canonical (compactified) representative
        self.fn_offset = fn_offset   # allocated global function-id range start
        self.hits = 0
        n_fn = family.n_fn
        # box volume cached as numpy so the precision checks the engine
        # runs under its lock every wave stay off the device
        from repro.core.domains import box_volume
        self._vol = np.asarray(box_volume(family.domains), np.float32)
        # (s1, s2, n, rounds_done): replaced wholesale, never mutated
        self._state = (np.zeros(n_fn, np.float32),
                       np.zeros(n_fn, np.float32), 0, 0)

    @property
    def n_fn(self) -> int:
        return self.family.n_fn

    def snapshot(self) -> tuple[np.ndarray, np.ndarray, int, int]:
        """One consistent (s1, s2, n, rounds_done) view."""
        return self._state

    @property
    def s1(self) -> np.ndarray:
        return self._state[0]

    @property
    def s2(self) -> np.ndarray:
        return self._state[1]

    @property
    def n(self) -> int:
        return self._state[2]

    @property
    def rounds_done(self) -> int:
        return self._state[3]

    def sums(self) -> SumsState:
        s1, s2, n, _ = self.snapshot()
        return SumsState(s1=s1, s2=s2, n=np.float32(n))

    def finalize(self) -> direct_mc.MCResult:
        s1, s2, n, _ = self.snapshot()
        return direct_mc.finalize(
            self.family, SumsState(s1=s1, s2=s2, n=np.float32(n)))

    def stderr(self) -> np.ndarray:
        """Current per-function standard error (inf before any round)."""
        return self._stderr_of(self.snapshot())

    def _stderr_of(self, state) -> np.ndarray:
        # numpy mirror of direct_mc.finalize's stderr (hot path: called
        # per pending request per wave, often under the engine lock)
        s1, s2, n, _ = state
        if n == 0:
            return np.full(self.n_fn, np.inf, np.float32)
        nf = np.float32(n)
        mean_f = s1 / nf
        var_f = np.maximum(s2 / nf - np.square(mean_f), np.float32(0.0))
        return self._vol * np.sqrt(var_f / nf)


class ResultCache:
    """In-memory cache of canonical-family accumulators (thread-safe)."""

    def __init__(self, round_samples: int = 65536):
        if round_samples <= 0:
            raise ValueError("round_samples must be positive")
        self.round_samples = int(round_samples)
        self._entries: dict[str, CacheEntry] = {}
        self._next_id = 0
        self._lock = threading.Lock()

    # -- lookup / allocation --------------------------------------------------
    def get(self, chash: str) -> CacheEntry | None:
        return self._entries.get(chash)

    def get_or_allocate(self, chash: str, family: IntegrandFamily) -> CacheEntry:
        """Existing entry for ``chash``, or a fresh one with its own
        counter-space range.  ``family`` must already be canonical."""
        with self._lock:
            entry = self._entries.get(chash)
            if entry is not None:
                entry.hits += 1
                return entry
            n_fn = family.n_fn
            if self._next_id + n_fn > _ID_SPACE:
                raise RuntimeError(
                    f"counter id space exhausted ({_ID_SPACE} function ids)")
            entry = CacheEntry(chash=chash, family=family,
                               fn_offset=self._next_id)
            self._next_id += n_fn
            self._entries[chash] = entry
            return entry

    # -- precision logic ------------------------------------------------------
    def rounds_for_budget(self, n_samples: int) -> int:
        """Rounds needed to cover an ``n_samples`` budget (quantized up)."""
        return max(1, math.ceil(int(n_samples) / self.round_samples))

    def meets(self, entry: CacheEntry, *, target_stderr: float | None,
              n_samples: int | None) -> bool:
        """Does the cached stream already satisfy the request?"""
        state = entry.snapshot()
        if state[2] == 0:
            return False
        if n_samples is not None and state[3] < self.rounds_for_budget(n_samples):
            return False
        if target_stderr is not None and not np.all(
                entry._stderr_of(state) <= target_stderr):
            return False
        return True

    def rounds_needed(self, entry: CacheEntry, *, target_stderr: float | None,
                      n_samples: int | None, max_rounds: int = 1 << 16) -> int:
        """Additional rounds to schedule for this entry (0 = cache hit).

        Budget requests are exact; stderr targets are predicted from the
        cached variance (stderr ~ 1/sqrt(n)), with one bootstrap round
        when no variance estimate exists yet.  The engine re-checks after
        every wave, so an under-prediction just schedules another wave.
        """
        state = entry.snapshot()
        _, _, n, rounds_done = state
        need = 0
        if n_samples is not None:
            need = max(need, self.rounds_for_budget(n_samples) - rounds_done)
        if target_stderr is not None:
            if n == 0:
                need = max(need, 1)
            else:
                err = entry._stderr_of(state)
                if np.any(err > target_stderr):
                    # n_target / n_now = (err_now / target)^2, per function
                    ratio = float(np.max(err / max(target_stderr, 1e-30))) ** 2
                    total = math.ceil(ratio * n / self.round_samples)
                    need = max(need, total - rounds_done)
        return int(min(max(need, 0), max_rounds))

    # -- deposits -------------------------------------------------------------
    def deposit(self, entry: CacheEntry, round_index: int,
                sums: SumsState) -> bool:
        """Fold one round of sums into the entry, strictly in order.

        Returns True when the round was folded, False when it was
        already present (a replayed wave or a racing wave driver
        recomputed it — bit-identical by counter addressing, so skipping
        is exact).  A round *beyond* the fold frontier is a planner bug
        and raises: folding it would skip samples.
        """
        with self._lock:
            s1, s2, n, done = entry._state
            if round_index < done:
                return False
            if round_index > done:
                raise ValueError(
                    f"deposit gap: round {round_index} into entry at "
                    f"round {done}")
            entry._state = (
                np.asarray(s1 + np.asarray(sums.s1, np.float32)),
                np.asarray(s2 + np.asarray(sums.s2, np.float32)),
                n + int(np.asarray(sums.n)),
                done + 1,
            )
            return True

    # -- stats ----------------------------------------------------------------
    @property
    def n_entries(self) -> int:
        return len(self._entries)

    @property
    def total_samples(self) -> int:
        return sum(e.n for e in self._entries.values())

    def stats(self) -> dict:
        return {
            "entries": self.n_entries,
            "function_ids_allocated": self._next_id,
            "total_samples": self.total_samples,
            "hits": sum(e.hits for e in self._entries.values()),
        }
