"""Deterministic fault injection for the wave pipeline (chaos harness).

The service promises that the STR001-006 invariants — bit-identical
resume, gap-free folds, disjoint counter ranges — survive a failure at
*any* pipeline stage.  Happy-path tests cannot prove that; this module
makes the failure part of the test input.  A :class:`FaultPlan` is a
set of **counted-down trigger points**: each fault point carries the
0-based hit index at which its hook fires, so "the third wal fsync
fails" is a pure function of the plan, reproducible from a seed across
processes and CI reruns.

Fault points (see :data:`FAULT_POINTS`):

* the six trace stages — ``plan``, ``launch``, ``device_execute``,
  ``transfer``, ``deposit``, ``wal_commit`` — each modeling a crash at
  that stage of a wave (raises :class:`InjectedCrash`);
* ``wal_fsync`` — the journal write's fsync fails with
  :class:`InjectedIOError` (ENOSPC / dying disk) *after* the bytes hit
  the file, exercising the store's fail-closed rewind;
* ``wal_torn_write`` — only a prefix of the record reaches the file
  before the error, modeling a torn write at the kill instant;
* ``device_error`` — a launch group's dispatch raises
  :class:`InjectedDeviceError` (lost accelerator);
* ``transfer_nan`` — one deposit's transferred sums are poisoned to
  NaN, exercising the cache's finite checks and quarantine ladder;
* ``worker_crash`` — the engine's background worker thread dies at a
  wave boundary (state is salvaged; a driver can resume via ``step()``).

Hooks are threaded through :mod:`repro.service.store`,
:mod:`repro.service.cache`, :mod:`repro.service.batcher` and
:mod:`repro.service.engine`; every call site holds :data:`NULL_FAULTS`
by default, whose hooks are constant-return no-ops — an engine without
a plan pays one attribute test per hook, nothing else.

Every fired fault is recorded (``plan.fired``) and counted into
``zmc_faults_injected_total{stage=...}`` once the plan is bound to an
:class:`~repro.obs.Observability` bundle, so the chaos bench can assert
the injected set *exactly* against the metrics contract.
"""

from __future__ import annotations

import threading
import zlib
from typing import Mapping, Sequence

from repro.obs.trace import STAGES

# Every trigger point a FaultPlan may name.
FAULT_POINTS: tuple[str, ...] = STAGES + (
    "wal_fsync", "wal_torn_write", "device_error", "transfer_nan",
    "worker_crash")


class InjectedFault(Exception):
    """Mixin marking an exception as deliberately injected chaos."""


class InjectedCrash(InjectedFault, RuntimeError):
    """A stage-level crash (plan/launch/transfer/... or worker death)."""


class InjectedDeviceError(InjectedFault, RuntimeError):
    """A lost/odd accelerator at dispatch time."""


class InjectedIOError(InjectedFault, OSError):
    """A failed journal write or fsync (ENOSPC, dying disk)."""


class NullFaultPlan:
    """The default: injection disabled, hooks constant no-ops."""

    enabled = False

    def bind(self, obs) -> "NullFaultPlan":
        return self

    def fire(self, point: str) -> bool:
        return False

    def check(self, point: str) -> None:
        return None


NULL_FAULTS = NullFaultPlan()


class FaultPlan:
    """Counted-down fault triggers, replayable from ``(seed, points)``.

    ``triggers`` maps fault-point names to the 0-based hit index at
    which the hook fires (or a collection of indices to fire several
    times).  Hit counting is per point and thread-safe; the plan is
    exhausted once every trigger has fired.  Exception *types* are
    fixed per point (see the module docstring), so a caller's retry
    policy sees exactly what the real failure would raise.
    """

    enabled = True

    def __init__(self, triggers: Mapping[str, int | Sequence[int]]):
        self.triggers: dict[str, frozenset[int]] = {}
        for point, at in dict(triggers).items():
            if point not in FAULT_POINTS:
                raise ValueError(
                    f"unknown fault point {point!r}; valid points: "
                    f"{', '.join(FAULT_POINTS)}")
            hits = (at,) if isinstance(at, int) else tuple(at)
            if any(h < 0 for h in hits):
                raise ValueError(f"trigger indices must be >= 0: {hits}")
            self.triggers[point] = frozenset(hits)
        self.hits: dict[str, int] = dict.fromkeys(self.triggers, 0)
        self.fired: list[tuple[str, int]] = []
        self.obs = None
        self._lock = threading.Lock()

    @classmethod
    def from_seed(cls, seed: int, points: Sequence[str],
                  max_countdown: int = 4) -> "FaultPlan":
        """One trigger per point, its hit index derived from ``seed`` —
        the same seed always reproduces the same plan."""
        return cls({
            p: zlib.crc32(f"{int(seed)}:{p}".encode()) % int(max_countdown)
            for p in points})

    def spec(self) -> dict:
        """JSON-able description of the plan (bench artifacts, replay)."""
        return {p: sorted(hits) for p, hits in sorted(self.triggers.items())}

    def bind(self, obs) -> "FaultPlan":
        """Attach the telemetry bundle that counts fired faults."""
        self.obs = obs
        return self

    def fire(self, point: str) -> bool:
        """Count one hit of ``point``; True when this hit is a trigger.

        Call sites that need a *behavior* (poison values, tear a write)
        branch on the return; call sites that need an *exception* use
        :meth:`check`.
        """
        hits = self.triggers.get(point)
        if hits is None:
            return False
        with self._lock:
            k = self.hits[point]
            self.hits[point] = k + 1
            if k not in hits:
                return False
            self.fired.append((point, k))
        if self.obs is not None:
            self.obs.m["faults_injected"].inc(stage=point)
            self.obs.event("fault_injected", point=point, hit=k)
        return True

    def check(self, point: str) -> None:
        """Raise this point's exception type if its trigger fires."""
        if not self.fire(point):
            return
        if point in ("wal_fsync", "wal_torn_write"):
            import errno
            raise InjectedIOError(errno.ENOSPC,
                                  f"injected {point} failure")
        if point == "device_error":
            raise InjectedDeviceError("injected device error at dispatch")
        raise InjectedCrash(f"injected crash at {point}")

    @property
    def exhausted(self) -> bool:
        """True once every configured trigger has fired."""
        with self._lock:
            fired = {(p, k) for p, k in self.fired}
        return all((p, k) in fired
                   for p, hits in self.triggers.items() for k in hits)
