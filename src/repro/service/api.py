"""Public request/response surface of the integration service.

A request names *what* to integrate and *how well*: a sample budget, a
standard-error target, or both.  The engine decides everything else —
batching, caching, counter-space placement, kernel dispatch.  Two
request shapes exist:

* :class:`IntegrationRequest` — a list of
  :class:`~repro.core.integrand.IntegrandFamily` (the original shape);
* :class:`SweepRequest` — ONE single-function template family × a
  parameter grid.  The service canonicalizes the grid into fixed-size
  slices of swept families (``repro.service.canonical.sweep_slices``),
  so a 10^5-point scan costs slice-count cache entries and one fused
  launch per (dim, sampler) bucket per wave — not 10^5 of each — and
  overlapping sweeps from different clients share streams at the
  sub-grid level.  Results stream back per point as rounds complete
  (``engine.sweep_partial``).

``IntegrationClient`` is the blocking convenience wrapper: it submits,
drives the engine if no background worker is running, and returns the
finished result.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.integrand import IntegrandFamily, MultiFunctionSpec


class Backpressure(RuntimeError):
    """Raised by non-blocking submit when the pending table is full."""


class RequestError(RuntimeError):
    """Raised by the blocking client when a ticket completed as a
    :class:`RequestFailed`; carries the structured failure as
    ``.failure``."""

    def __init__(self, failure: "RequestFailed"):
        super().__init__(
            f"request {failure.ticket} failed ({failure.reason}"
            f"{f', stage {failure.stage}' if failure.stage else ''}): "
            f"{failure.message}")
        self.failure = failure


@dataclasses.dataclass(frozen=True)
class IntegrationRequest:
    """One client ask: evaluate these families to this precision.

    Attributes:
      families: the integrands; a ``MultiFunctionSpec`` is accepted too.
      n_samples: minimum sample budget per function (quantized up to the
        engine's round size).
      target_stderr: serve once every function's standard error is at or
        below this.  With both set, both must hold.
      sampler: "mc" | "sobol" — selects the sample stream (and therefore
        the cache entry: the two streams never mix).
      deadline: optional wall-time budget in seconds, measured from
        submit.  When it expires before the precision is reached the
        ticket *completes* with a :class:`RequestFailed` (reason
        ``"deadline"``) instead of hanging; retry backoff sleeps are
        clamped to the remaining budget.
      adaptive: opt in to VEGAS importance-grid adaptation
        (``docs/adaptive.md``): the engine fits a per-stream grid from a
        deterministic pilot and samples subsequent waves through its
        inverse-CDF map, refitting between waves until ``target_stderr``
        is met or the grid converges.  Requires ``target_stderr`` (a
        pure sample budget has nothing to adapt toward — the flag is
        then ignored); still deterministic and bit-identically resumable
        (grid epochs are journaled).
    """

    families: tuple[IntegrandFamily, ...]
    n_samples: int | None = None
    target_stderr: float | None = None
    sampler: str = "mc"
    deadline: float | None = None
    adaptive: bool = False

    @classmethod
    def make(cls, families: Sequence[IntegrandFamily] | MultiFunctionSpec,
             *, n_samples: int | None = None,
             target_stderr: float | None = None,
             sampler: str = "mc",
             deadline: float | None = None,
             adaptive: bool = False) -> "IntegrationRequest":
        if isinstance(families, MultiFunctionSpec):
            families = families.families
        families = tuple(f.validate() for f in families)
        if not families:
            raise ValueError("request needs at least one family")
        if n_samples is None and target_stderr is None:
            raise ValueError("request needs n_samples or target_stderr")
        if n_samples is not None and n_samples <= 0:
            raise ValueError("n_samples must be positive")
        if target_stderr is not None and target_stderr <= 0:
            raise ValueError("target_stderr must be positive")
        if sampler not in ("mc", "sobol"):
            raise ValueError(f"unknown sampler {sampler!r}")
        if deadline is not None and deadline <= 0:
            raise ValueError("deadline must be positive (seconds)")
        return cls(families=families, n_samples=n_samples,
                   target_stderr=target_stderr, sampler=sampler,
                   deadline=deadline, adaptive=bool(adaptive))


@dataclasses.dataclass(frozen=True)
class SweepRequest:
    """One client ask: scan a template integrand over a parameter grid.

    Attributes:
      template: a single-function (``n_fn == 1``) family whose dict
        params the grid overrides by name.
      grid: ``{param name: axis values}``; the swept points are the
        row-major cartesian product over axes in sorted-name order
        (last axis fastest).  Axis values may be vectors per point
        (e.g. a dim-wide ``k``) — leading axis is the point axis.
      n_samples / target_stderr / sampler / deadline: as on
        :class:`IntegrationRequest`, applied to every grid point.
    """

    template: IntegrandFamily
    grid: dict
    n_samples: int | None = None
    target_stderr: float | None = None
    sampler: str = "mc"
    deadline: float | None = None

    @classmethod
    def make(cls, template: IntegrandFamily, grid: dict, *,
             n_samples: int | None = None,
             target_stderr: float | None = None,
             sampler: str = "mc",
             deadline: float | None = None) -> "SweepRequest":
        template = template.validate()
        if template.n_fn != 1:
            raise ValueError(
                f"sweep template must be a single function (n_fn == 1); "
                f"got n_fn={template.n_fn}")
        if not isinstance(template.params, dict):
            raise ValueError("sweep template needs dict params")
        if not grid:
            raise ValueError("sweep grid must name at least one axis")
        missing = [k for k in grid if k not in template.params]
        if missing:
            raise ValueError(f"sweep grid names {sorted(missing)} not in "
                             f"template params {sorted(template.params)}")
        if n_samples is None and target_stderr is None:
            raise ValueError("request needs n_samples or target_stderr")
        if n_samples is not None and n_samples <= 0:
            raise ValueError("n_samples must be positive")
        if target_stderr is not None and target_stderr <= 0:
            raise ValueError("target_stderr must be positive")
        if sampler not in ("mc", "sobol"):
            raise ValueError(f"unknown sampler {sampler!r}")
        if deadline is not None and deadline <= 0:
            raise ValueError("deadline must be positive (seconds)")
        return cls(template=template, grid=dict(grid), n_samples=n_samples,
                   target_stderr=target_stderr, sampler=sampler,
                   deadline=deadline)


@dataclasses.dataclass(frozen=True)
class IntegrationResult:
    """Finished estimates, in the request's family-by-family order."""

    means: np.ndarray            # (n_fn_total,)
    stderrs: np.ndarray          # (n_fn_total,)
    n_per_family: tuple[int, ...]  # samples accumulated per family stream
    names: tuple[str, ...]
    served_from_cache: bool      # True -> zero new launches were needed
    ticket: int
    # cache stream ids backing each family, in request order; keys for
    # engine.stderr_trajectory() / the /convergence exposition
    stream_ids: tuple[str, ...] = ()

    @property
    def n_fn_total(self) -> int:
        return int(self.means.shape[0])

    @property
    def failed(self) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class RequestFailed:
    """Terminal failure of a ticket — a *completed* result, not a hang.

    Produced by the engine when a request can no longer succeed: its
    wave's retry budget is exhausted (``reason="retry_exhausted"``), its
    deadline ran out (``"deadline"``), or every path to it runs through
    a quarantined stream (``"quarantined"``).  Polling/result calls
    return it like any result; the blocking client raises
    :class:`RequestError` around it.
    """

    ticket: int
    reason: str                      # retry_exhausted | deadline | quarantined
    stage: str | None = None         # pipeline stage that exhausted, if any
    attempts: int = 0                # attempts the retry policy ran
    message: str = ""
    stream_ids: tuple[str, ...] = ()

    @property
    def failed(self) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class SweepResult(IntegrationResult):
    """Per-point estimates of a sweep, in row-major grid order.

    ``means``/``stderrs`` are flat over grid points; reshape to
    ``grid_shape`` to index by axis value (``axis_names`` gives the
    axis order — sorted parameter names).  ``n_per_family`` /
    ``names`` / ``stream_ids`` are per canonical *slice*, the unit the
    cache keys on.  A partial snapshot (``engine.sweep_partial``)
    carries ``complete=False`` and a ``points_done`` boolean mask over
    points whose slice has at least one finished round (undone points
    hold NaN means / inf stderrs).
    """

    grid_shape: tuple[int, ...] = ()
    axis_names: tuple[str, ...] = ()
    n_points: int = 0
    points_done: np.ndarray | None = None
    complete: bool = True


class IntegrationClient:
    """Blocking client over an :class:`~repro.service.engine.IntegrationEngine`.

    When the engine runs a background worker, ``integrate`` just waits;
    otherwise it drives ``engine.step()`` itself — handy for tests,
    benchmarks and single-process batch jobs where determinism matters.

    Usable as a context manager: ``with IntegrationClient(engine) as c:``
    closes the engine on exit — for an engine with a ``state_dir`` that
    is the snapshot-on-shutdown path (journal compacted into one npz).
    """

    def __init__(self, engine):
        self.engine = engine

    def close(self) -> None:
        """Shut the engine down cleanly (snapshots persistent state)."""
        self.engine.close()

    def __enter__(self) -> "IntegrationClient":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    def submit(self, families, **kwargs) -> int:
        return self.engine.submit(IntegrationRequest.make(families, **kwargs))

    def integrate(self, families, **kwargs) -> IntegrationResult:
        ticket = self.submit(families, **kwargs)
        return self.wait(ticket)

    def submit_sweep(self, template, grid, **kwargs) -> int:
        return self.engine.submit(SweepRequest.make(template, grid, **kwargs))

    def sweep(self, template, grid, **kwargs) -> "SweepResult":
        """Scan ``template`` over ``grid`` and block for every point."""
        ticket = self.submit_sweep(template, grid, **kwargs)
        return self.wait(ticket)

    def sweep_partial(self, ticket: int,
                      since: np.ndarray | None = None) -> "SweepResult":
        """Current per-point snapshot of an in-flight sweep (non-blocking):
        finished points carry real estimates, pending ones NaN/inf —
        see :class:`SweepResult`.``points_done``.  Pass the previous
        snapshot's ``points_done`` as ``since`` to have only the newly
        completed points recomputed (an incremental poll loop over a
        large grid then pays per-point cost once, not per poll)."""
        return self.engine.sweep_partial(ticket, since=since)

    def wait(self, ticket: int, timeout: float | None = None) -> IntegrationResult:
        if self.engine.running:
            return self._unwrap(self.engine.result(ticket, timeout=timeout))
        from repro.service.resilience import (DeadlineExceeded,
                                              RetryExhausted)
        while (res := self.engine.poll(ticket)) is None:
            try:
                stepped = self.engine.step()
            except (RetryExhausted, DeadlineExceeded):
                # the wave this step drove failed permanently; its riders
                # (possibly including our ticket) were completed as
                # RequestFailed — keep driving the remaining pendings
                continue
            if not stepped:
                res = self.engine.poll(ticket)
                if res is None:
                    raise RuntimeError(f"ticket {ticket} cannot make progress")
                return self._unwrap(res)
        return self._unwrap(res)

    @staticmethod
    def _unwrap(res):
        if isinstance(res, RequestFailed):
            raise RequestError(res)
        return res
