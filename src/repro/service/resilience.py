"""The service's ONE retry/backoff/deadline policy (rule RES001).

Before this module, failure handling was scattered: ``max_restarts``
ints threaded into ``run_with_restarts`` call sites, bare ``timeout=``
floats on ``result``/``drain``, and no deadline concept at all — a
request whose wave kept failing simply hung its ticket.  This module
centralizes all of it:

* :class:`RetryPolicy` — capped exponential backoff with
  **deterministic jitter**: the jitter fraction is a pure function of
  ``(seed, counter, attempt)`` (the counter is the wave sequence
  number), so a replayed chaos run waits the exact same intervals —
  no wall-clock RNG, nothing to flake.
* :class:`Deadline` — a per-request time budget measured on the
  monotonic clock shim.  Retry sleeps are clamped to the remaining
  budget and an expired deadline stops the attempt loop with
  :class:`DeadlineExceeded` instead of burning the tail of the budget
  on a doomed retry.
* :func:`run_with_policy` — the one attempt loop.  Exhaustion raises
  :class:`RetryExhausted` (a ``RuntimeError`` carrying the attempt
  count, stage and last cause); the engine converts that into a
  structured :class:`~repro.service.api.RequestFailed` result so a
  ticket *completes* with a diagnosis rather than hanging.

RES001 (:mod:`repro.analysis.boundary`) enforces the centralization
the same way OBS001 enforces the clock shim: under ``repro/service/``,
ad-hoc retry loops (``run_with_restarts``) and raw ``sleep`` calls are
lint errors everywhere but here.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Callable

from repro.obs import clock as _clock

# Re-exported so service code needs no direct fault_tolerance import
# (RES001 flags the ad-hoc retry entry point there, not the watchdog).
from repro.distributed.fault_tolerance import StepWatchdog  # noqa: F401

__all__ = ["RetryPolicy", "Deadline", "RetryExhausted",
           "DeadlineExceeded", "run_with_policy", "StepWatchdog"]


class RetryExhausted(RuntimeError):
    """Every attempt the policy allowed failed.

    Carries the diagnosis the engine folds into ``RequestFailed``:
    ``stage`` (which pipeline step), ``attempts`` (how many ran) and
    ``last`` (the final cause, also the ``__cause__``).
    """

    def __init__(self, stage: str, attempts: int, last: Exception):
        super().__init__(
            f"{stage} failed after {attempts} attempt"
            f"{'s' if attempts != 1 else ''}: "
            f"{type(last).__name__}: {last}")
        self.stage = stage
        self.attempts = attempts
        self.last = last


class DeadlineExceeded(TimeoutError):
    """A deadline budget ran out before the work completed."""


class Deadline:
    """A time budget anchored at construction (monotonic clock shim).

    ``budget=None`` means unbounded — ``remaining()`` is ``inf`` and
    the deadline never expires, so call sites need no None-branches.
    """

    def __init__(self, budget: float | None):
        if budget is not None and budget <= 0:
            raise ValueError("deadline budget must be positive (or None)")
        self.budget = None if budget is None else float(budget)
        self._t0 = _clock.monotonic()

    def remaining(self) -> float:
        if self.budget is None:
            return float("inf")
        return self.budget - (_clock.monotonic() - self._t0)

    @property
    def expired(self) -> bool:
        return self.remaining() <= 0.0

    def __repr__(self) -> str:
        if self.budget is None:
            return "Deadline(unbounded)"
        return f"Deadline({self.budget:g}s, {self.remaining():.3g}s left)"


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff with deterministic jitter.

    ``max_attempts`` counts *total* attempts (1 = never retry);
    the pre-retry-k sleep is ``backoff(k) = min(base_delay *
    multiplier**(k-1), max_delay)`` shrunk by a jitter fraction in
    ``[0, jitter)`` derived from ``(seed, counter, attempt)`` — jittered
    delays never exceed the capped backoff, and a replay with the same
    wave counter sleeps identically.
    """

    max_attempts: int = 3
    base_delay: float = 0.05
    max_delay: float = 2.0
    multiplier: float = 2.0
    jitter: float = 0.25
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.base_delay < 0 or self.max_delay < 0:
            raise ValueError("delays must be non-negative")
        if self.multiplier < 1.0:
            raise ValueError("multiplier must be >= 1")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError("jitter must be in [0, 1]")

    def backoff(self, attempt: int) -> float:
        """Un-jittered delay before retry ``attempt`` (1-based):
        monotone non-decreasing in ``attempt``, capped at
        ``max_delay``."""
        if attempt < 1:
            raise ValueError("attempt is 1-based")
        return min(self.base_delay * self.multiplier ** (attempt - 1),
                   self.max_delay)

    def delay(self, attempt: int, counter: int = 0) -> float:
        """The actual (jittered) sleep before retry ``attempt``; in
        ``(backoff * (1 - jitter), backoff]`` and a pure function of
        ``(seed, counter, attempt)``."""
        b = self.backoff(attempt)
        return b * (1.0 - self.jitter * self._unit(attempt, counter))

    def _unit(self, attempt: int, counter: int) -> float:
        """Deterministic uniform-ish value in [0, 1)."""
        h = zlib.crc32(f"{self.seed}:{int(counter)}:{int(attempt)}"
                       .encode("ascii"))
        return (h & 0xFFFFFF) / float(1 << 24)


def run_with_policy(body: Callable[[int], object], policy: RetryPolicy, *,
                    stage: str = "wave", counter: int = 0,
                    deadline: Deadline | None = None,
                    on_retry: Callable[[int, Exception], None] | None = None):
    """Run ``body(attempt)`` under the policy; the service's only
    retry loop.

    ``on_retry`` is called with ``(attempt, exc)`` for every failed
    attempt (including the final one), mirroring the old
    ``run_with_restarts`` hook so telemetry events/counters stay
    comparable.  Exhaustion raises :class:`RetryExhausted`; an expired
    ``deadline`` raises :class:`DeadlineExceeded` *before* starting an
    attempt (a started attempt is never interrupted — waves must reach
    their deposit boundary or be retired whole).
    """
    last: Exception | None = None
    for attempt in range(policy.max_attempts):
        if deadline is not None and deadline.expired:
            raise DeadlineExceeded(
                f"{stage} deadline expired after {attempt} attempt"
                f"{'s' if attempt != 1 else ''} "
                f"(budget {deadline.budget:g}s)") from last
        try:
            return body(attempt)
        except Exception as exc:  # noqa: BLE001 - the policy IS the catch
            last = exc
            if on_retry is not None:
                on_retry(attempt, exc)
            if attempt + 1 >= policy.max_attempts:
                raise RetryExhausted(stage, attempt + 1, exc) from exc
            pause = policy.delay(attempt + 1, counter)
            if deadline is not None:
                pause = min(pause, max(deadline.remaining(), 0.0))
            if pause > 0:
                _clock.sleep(pause)
    raise RetryExhausted(stage, policy.max_attempts, last)  # unreachable
