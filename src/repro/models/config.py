"""Model configuration + parameter-definition machinery.

One :class:`ModelConfig` describes any architecture in the assigned pool
(dense GQA, MLA, MoE, Mamba-2 SSD, hybrid, encoder-only, VLM backbone).
Parameters are declared as trees of :class:`PSpec` (shape + logical axis
names + init); the same declaration drives

* ``init_params``     — RNG initialisation at the right dtype,
* ``logical_specs``   — the logical-axis tree consumed by
  ``repro.distributed.sharding`` to build NamedShardings,
* ``abstract_params`` — ShapeDtypeStructs for the dry-run (no allocation).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    # attention
    attn_type: str = "gqa"         # gqa | mla | none
    rope_theta: float = 10000.0
    rope_style: str = "standard"   # standard | 2d | mrope | none
    qkv_bias: bool = False
    causal: bool = True
    # MLA (DeepSeek)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # hybrid (zamba2-style: shared attention block every k SSM blocks)
    shared_attn_every: int = 0
    # encoder / multimodal stubs
    is_encoder: bool = False
    frontend_dim: int = 0          # stub modality frontend embedding width
    mtp_depth: int = 0             # DeepSeek-V3 multi-token prediction
    # numerics / memory
    sp_activations: bool = False   # sequence-shard the residual stream over
                                   # 'model' (Megatron-SP): /16 activation
                                   # saves at the cost of per-layer AG/RS
    sharding_profile: str = "default"   # default | small_dp (see sharding.py)
    attn_q_chunk_threshold: int = 8192  # q-chunk attention above this seq len
    norm_eps: float = 1e-5
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    opt_dtype: str = "float32"     # optimizer moment dtype (bf16 for dsv3)
    remat: str = "full"            # none | full
    scan_layers: bool = True
    tie_embeddings: bool = False
    # long-context capability flag (sub-quadratic serving path exists)
    subquadratic: bool = False

    @property
    def q_per_kv(self) -> int:
        return max(1, self.n_heads // max(self.n_kv_heads, 1))

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to 256 so the 'vocab' axis shards on any mesh
        (50280, 65024, ... are not 16-divisible); logits over the padding
        columns are masked to -inf in lm_head."""
        return -(-self.vocab_size // 256) * 256

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def dtype(self, which: str):
        return jnp.dtype(getattr(self, which + "_dtype"))


# ---------------------------------------------------------------------------
# Parameter declaration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PSpec:
    """Declares one parameter leaf: shape, logical axes, initialiser."""
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]   # logical names, len == len(shape)
    init: str = "normal"           # normal | zeros | ones
    scale: float | None = None     # normal stddev; default fan-in

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


def stack_defs(defs: Any, n: int) -> Any:
    """Prepend a ('layers', n) axis to every PSpec (for scanned stacks)."""
    def one(p: PSpec) -> PSpec:
        return PSpec(shape=(n,) + p.shape, axes=("layers",) + p.axes,
                     init=p.init, scale=p.scale)
    return jax.tree.map(one, defs,
                        is_leaf=lambda x: isinstance(x, PSpec))


def _init_leaf(p: PSpec, key, dtype):
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "normal":
        fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
        std = p.scale if p.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
        return (std * jax.random.normal(key, p.shape)).astype(dtype)
    raise ValueError(f"unknown init {p.init!r}")


def init_params(defs: Any, key, dtype) -> Any:
    """Materialise a PSpec tree into parameter arrays."""
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, PSpec))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(p, k, dtype) for p, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(defs: Any, dtype) -> Any:
    """ShapeDtypeStruct tree — used by the dry-run (no allocation)."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype), defs,
        is_leaf=lambda x: isinstance(x, PSpec))


def logical_specs(defs: Any) -> Any:
    """Tree of logical-axis tuples, mirroring the params tree."""
    return jax.tree.map(lambda p: p.axes, defs,
                        is_leaf=lambda x: isinstance(x, PSpec))


def count_params(defs: Any) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, PSpec))
    return int(sum(np.prod(p.shape) for p in leaves))
