"""Multi-head Latent Attention (DeepSeek-V2/V3).

Queries and keys/values are projected through low-rank latents; the decode
cache stores only the compressed KV latent (kv_lora) plus the shared RoPE
key — 576 floats per token for V3 instead of n_heads*head_dim*2 = 32768,
a 57x cache compression.  Two evaluation paths:

* train/prefill: expand k_nope/v from the latent and run standard MHA;
* decode: the **absorbed** formulation — fold W_uk into the query and
  W_uv into the output so attention runs directly in the 512-d latent
  space against the compressed cache (never materialising per-head keys
  for 32k cached tokens).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.config import ModelConfig, PSpec
from repro.models import layers


def mla_defs(cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    vh, kvl, ql = cfg.v_head_dim, cfg.kv_lora_rank, cfg.q_lora_rank
    defs = {
        "wkv_a": PSpec((d, kvl + rope), ("embed", "kv_lora")),
        "kv_norm": PSpec((kvl,), ("kv_lora",), init="ones"),
        "wk_b": PSpec((kvl, h, nope), ("kv_lora", "heads", "head_dim")),
        "wv_b": PSpec((kvl, h, vh), ("kv_lora", "heads", "head_dim")),
        "wo": PSpec((h, vh, d), ("heads", "head_dim", "embed")),
    }
    if ql:
        defs["wq_a"] = PSpec((d, ql), ("embed", "q_lora"))
        defs["q_norm"] = PSpec((ql,), ("q_lora",), init="ones")
        defs["wq_b"] = PSpec((ql, h, nope + rope),
                             ("q_lora", "heads", "head_dim"))
    else:
        defs["wq"] = PSpec((d, h, nope + rope),
                           ("embed", "heads", "head_dim"))
    return defs


def _q_proj(x, p, cfg: ModelConfig):
    cd = cfg.dtype("compute")
    if cfg.q_lora_rank:
        cq = jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(cd))
        cq = layers.rmsnorm(cq, {"scale": p["q_norm"]}, cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"].astype(cd))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    return q  # (B,S,H,nope+rope)


def _kv_latent(x, p, cfg: ModelConfig, positions):
    """Compressed latent + roped shared key. Returns (c_kv, k_rope)."""
    cd = cfg.dtype("compute")
    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(cd))
    c_kv = kv_a[..., : cfg.kv_lora_rank]
    k_rope = kv_a[..., cfg.kv_lora_rank:]
    c_kv = layers.rmsnorm(c_kv, {"scale": p["kv_norm"]}, cfg.norm_eps)
    angles = layers.rope_angles(positions, cfg.qk_rope_dim, cfg.rope_theta)
    k_rope = layers.apply_rope(k_rope[:, :, None, :], angles)[:, :, 0, :]
    c_kv = constrain(c_kv, ("batch", "seq", "kv_lora"))
    return c_kv, k_rope


def mla_attention(x, p, cfg: ModelConfig, positions):
    """Training / prefill path (expanded MHA). Returns (out, (c_kv, k_rope))."""
    cd = cfg.dtype("compute")
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = _q_proj(x, p, cfg)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    angles = layers.rope_angles(positions, rope, cfg.rope_theta)
    q_rope = layers.apply_rope(q_rope, angles)

    c_kv, k_rope = _kv_latent(x, p, cfg, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wk_b"].astype(cd))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wv_b"].astype(cd))

    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  k_nope.shape[:-1] + (rope,))], axis=-1)
    qf = constrain(qf, ("batch", "seq", "heads", "head_dim"))
    kf = constrain(kf, ("batch", "seq", "heads", "head_dim"))
    o = layers.sdpa(qf, kf, v, cfg, causal=cfg.causal)
    o = constrain(o, ("batch", "seq", "heads", "head_dim"))
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(cd))
    return constrain(out, ("batch", "seq", "embed")), (c_kv, k_rope)


def mla_decode(x, p, cfg: ModelConfig, cache, pos):
    """Absorbed decode step.

    x: (B, 1, d); cache: {"c_kv": (B, S, kvl), "k_rope": (B, S, rope)};
    pos: scalar int32 — current write index (same for the whole batch).
    Returns (out, new_cache).
    """
    cd = cfg.dtype("compute")
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    b = x.shape[0]
    positions = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)

    q = _q_proj(x, p, cfg)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    angles = layers.rope_angles(positions, rope, cfg.rope_theta)
    q_rope = layers.apply_rope(q_rope, angles)       # (B,1,H,rope)

    c_new, kr_new = _kv_latent(x, p, cfg, positions)  # (B,1,kvl), (B,1,rope)
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), pos, axis=1)
    c_kv = constrain(c_kv, ("batch", "cache_seq", "kv_lora"))
    k_rope = constrain(k_rope, ("batch", "cache_seq", None))

    # absorb W_uk into the query: score in latent space
    q_c = jnp.einsum("bqhn,rhn->bqhr", q_nope, p["wk_b"].astype(cd))
    s_latent = jnp.einsum("bqhr,bsr->bhqs", q_c, c_kv.astype(cd))
    s_rope = jnp.einsum("bqhn,bsn->bhqs", q_rope, k_rope.astype(cd))
    scale = 1.0 / math.sqrt(nope + rope)
    scores = (s_latent + s_rope).astype(jnp.float32) * scale
    mask = jnp.arange(c_kv.shape[1]) <= pos
    scores = jnp.where(mask[None, None, None, :], scores, -1e30)
    # softmax over the (model-sharded) cache axis: XLA lowers the row max /
    # sum to tiny all-reduces = flash-decoding's LSE merge, for free
    probs = jax.nn.softmax(scores, axis=-1).astype(cd)
    ctx_c = jnp.einsum("bhqs,bsr->bqhr", probs, c_kv.astype(cd))
    # absorb W_uv on the way out
    ctx_v = jnp.einsum("bqhr,rhk->bqhk", ctx_c, p["wv_b"].astype(cd))
    out = jnp.einsum("bqhk,hkd->bqd", ctx_v, p["wo"].astype(cd))
    return constrain(out, ("batch", "seq", "embed")), \
        {"c_kv": c_kv, "k_rope": k_rope}


def mla_cache_defs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """Abstract cache layout (per layer) for init/dry-run."""
    return {
        "c_kv": (PSpec((batch, seq, cfg.kv_lora_rank),
                       ("batch", "cache_seq", "kv_lora"), init="zeros")),
        "k_rope": (PSpec((batch, seq, cfg.qk_rope_dim),
                         ("batch", "cache_seq", None), init="zeros")),
    }
