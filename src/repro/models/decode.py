"""Decode-step machinery: KV caches and single-token attention.

The KV cache stores its **sequence axis sharded over the 'model' mesh axis**
('cache_seq' rule).  Decode attention is written as plain einsums + softmax
over that sharded axis; XLA's SPMD partitioner turns the row max/sum and the
context contraction into three tiny all-reduces — exactly the
flash-decoding LSE-merge schedule, but derived from the sharding rather
than hand-written.  (The hand-written shard_map variant measured identical
collective bytes; see EXPERIMENTS.md §Perf.)

Positions are a single scalar `pos` (all sequences in the decode batch are
aligned — the serving driver pads to alignment, as vLLM-style continuous
batching does per decoding wave).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.config import ModelConfig, PSpec
from repro.models import layers


def gqa_cache_defs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": PSpec((batch, seq, kv, hd),
                   ("batch", "cache_seq", "kv_heads", "head_dim"),
                   init="zeros"),
        "v": PSpec((batch, seq, kv, hd),
                   ("batch", "cache_seq", "kv_heads", "head_dim"),
                   init="zeros"),
    }


def gqa_decode(x, p, cfg: ModelConfig, cache, pos):
    """One-token GQA attention against a (model-sharded) KV cache.

    x: (B, 1, d); cache: {"k","v"}: (B, S, KV, hd); pos: scalar int32.
    Returns (out (B,1,d), new_cache).
    """
    b = x.shape[0]
    positions = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)
    q, k_new, v_new = layers.qkv_proj(x, p, cfg, positions)

    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1)
    k = constrain(k, ("batch", "cache_seq", "kv_heads", "head_dim"))
    v = constrain(v, ("batch", "cache_seq", "kv_heads", "head_dim"))

    h, kv_heads, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kv_heads
    qg = q.reshape(b, 1, kv_heads, g, hd)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqhgd,bshd->bhgqs", qg, k.astype(q.dtype))
    scores = scores.astype(jnp.float32) * scale
    mask = jnp.arange(k.shape[1]) <= pos
    scores = jnp.where(mask[None, None, None, None, :], scores, -1e30)
    # softmax over the sharded cache axis -> distributed LSE merge
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhgqs,bshd->bqhgd", probs, v.astype(q.dtype))
    o = o.reshape(b, 1, h, hd)
    out = layers.attn_out(o, p, cfg)
    return out, {"k": k, "v": v}


def prefill_kv(k, v, seq_cap: int):
    """Pad prefill K/V to the cache capacity and apply cache sharding."""
    s = k.shape[1]
    if seq_cap > s:
        pad = [(0, 0), (0, seq_cap - s), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    k = constrain(k, ("batch", "cache_seq", "kv_heads", "head_dim"))
    v = constrain(v, ("batch", "cache_seq", "kv_heads", "head_dim"))
    return {"k": k, "v": v}
