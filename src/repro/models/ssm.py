"""Mamba-2 (SSD — state-space duality) block, pure JAX.

Implements the chunked SSD algorithm (Dao & Gu 2024, arXiv:2405.21060):
within a chunk the recurrence is evaluated as a masked "attention" product
(MXU-friendly), between chunks a tiny sequential scan carries the
(H, P, N) state.  Decode is the O(1) recurrent step on the same state —
this is what makes the `long_500k` shape tractable for the SSM/hybrid
architectures (constant-size cache vs a 500k-token KV cache).

Sharding: the inner width (d_inner = heads * head_dim) shards over 'mlp'
(= model axis), so each shard owns a contiguous group of SSM heads; the
state never crosses shards and the block needs no collectives beyond the
in/out projections (Megatron-style).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.config import ModelConfig, PSpec
from repro.models import layers


def ssm_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.ssm_d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    w = cfg.ssm_conv_width
    return {
        "wz": PSpec((d, di), ("embed", "mlp")),
        "wx": PSpec((d, di), ("embed", "mlp")),
        "wB": PSpec((d, n), ("embed", "ssm_state")),
        "wC": PSpec((d, n), ("embed", "ssm_state")),
        "wdt": PSpec((d, h), ("embed", "ssm_heads")),
        "conv_x": PSpec((w, di), ("conv", "mlp"), scale=0.5),
        "conv_B": PSpec((w, n), ("conv", "ssm_state"), scale=0.5),
        "conv_C": PSpec((w, n), ("conv", "ssm_state"), scale=0.5),
        "A_log": PSpec((h,), ("ssm_heads",), init="zeros"),
        "D": PSpec((h,), ("ssm_heads",), init="ones"),
        "dt_bias": PSpec((h,), ("ssm_heads",), init="zeros"),
        "gate_norm": PSpec((di,), ("mlp",), init="ones"),
        "out": PSpec((di, d), ("mlp", "embed")),
    }


def _causal_conv(u, w, cache=None):
    """Depthwise causal conv via shift-sum (width <= 8).

    u: (B, L, C); w: (W, C). cache: (B, W-1, C) previous context or None.
    Returns (y, new_cache) where new_cache is the last W-1 inputs.
    """
    width = w.shape[0]
    if cache is None:
        pad = jnp.zeros(u.shape[:1] + (width - 1,) + u.shape[2:], u.dtype)
    else:
        pad = cache.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)       # (B, W-1+L, C)
    y = sum(full[:, i:i + u.shape[1]] * w[i] for i in range(width))
    new_cache = full[:, -(width - 1):]
    return jax.nn.silu(y), new_cache


def _ssd_chunked(x, dt, a_log, bmat, cmat, chunk: int):
    """Chunked SSD scan.

    x: (B, L, H, P); dt: (B, L, H) (post-softplus); a_log: (H,);
    bmat/cmat: (B, L, N) (single group, broadcast over heads).
    Returns (y (B,L,H,P), final_state (B,H,P,N)).
    """
    b, l, h, p = x.shape
    n = bmat.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    f32 = jnp.float32

    a = -jnp.exp(a_log.astype(f32))                      # (H,) negative
    da = dt.astype(f32) * a                              # (B,L,H) <= 0
    xr = x.reshape(b, nc, chunk, h, p)
    dtr = dt.reshape(b, nc, chunk, h).astype(f32)
    dar = da.reshape(b, nc, chunk, h)
    br = bmat.reshape(b, nc, chunk, n).astype(f32)
    cr = cmat.reshape(b, nc, chunk, n).astype(f32)

    cum = jnp.cumsum(dar, axis=2)                        # (B,nc,Q,H)
    total = cum[:, :, -1]                                # (B,nc,H)

    # ---- intra-chunk (quadratic, per chunk) ----
    cb = jnp.einsum("bcqn,bckn->bcqk", cr, br)           # (B,nc,Q,Q)
    # decay(q,k,h) = exp(cum_q - cum_k), causal-masked
    decay = jnp.exp(jnp.clip(cum[:, :, :, None, :] - cum[:, :, None, :, :],
                             -60.0, 0.0))                # (B,nc,Q,Q,H)
    qi = jnp.arange(chunk)
    causal = (qi[:, None] >= qi[None, :]).astype(f32)
    scores = cb[..., None] * decay * causal[None, None, :, :, None]
    scores = scores * dtr[:, :, None, :, :]              # fold in dt_k
    # materialise the (B,nc,Q,Q,H) score tensor at compute precision: the
    # f32 elementwise chain above fuses into this cast, halving the largest
    # live buffer of the whole block (see EXPERIMENTS.md §Perf)
    scores = scores.astype(x.dtype)
    y_diag = jnp.einsum("bcqkh,bckhp->bcqhp", scores, xr)

    # ---- chunk states ----
    decay_end = jnp.exp(jnp.clip(total[:, :, None, :] - cum, -60.0, 0.0))
    # S_c = sum_k B_k (decay to end) dt_k x_k : (B,nc,H,P,N)
    s_chunk = jnp.einsum("bckn,bckh,bckhp->bchpn",
                         br, decay_end * dtr, xr.astype(f32))

    # ---- inter-chunk recurrence ----
    def step(s_prev, inp):
        s_c, tot_c, c_c, cum_c = inp
        y_off = jnp.einsum("bqn,bqh,bhpn->bqhp",
                           c_c, jnp.exp(jnp.clip(cum_c, -60.0, 0.0)), s_prev)
        s_next = s_prev * jnp.exp(jnp.clip(tot_c, -60.0, 0.0))[:, :, None, None] + s_c
        return s_next, y_off

    s0 = jnp.zeros((b, h, p, n), f32)
    xs = (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(total, 1, 0),
          jnp.moveaxis(cr, 1, 0), jnp.moveaxis(cum, 1, 0))
    s_final, y_off = jax.lax.scan(step, s0, xs)
    y_off = jnp.moveaxis(y_off, 0, 1)                    # (B,nc,Q,H,P)

    y = (y_diag + y_off).reshape(b, l, h, p).astype(x.dtype)
    return y, s_final


def mamba2_forward(x, p, cfg: ModelConfig, conv_cache=None, ssm_state=None):
    """Full-sequence Mamba-2 block (train / prefill).

    Returns (out (B,L,d), cache dict with final conv + SSM state).
    """
    cd = cfg.dtype("compute")
    b, l, d = x.shape
    h, pn, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state

    z = jnp.einsum("bld,de->ble", x, p["wz"].astype(cd))
    xin = jnp.einsum("bld,de->ble", x, p["wx"].astype(cd))
    bmat = jnp.einsum("bld,dn->bln", x, p["wB"].astype(cd))
    cmat = jnp.einsum("bld,dn->bln", x, p["wC"].astype(cd))
    dt = jnp.einsum("bld,dh->blh", x, p["wdt"].astype(cd))
    xin = constrain(xin, ("batch", "seq", "mlp"))
    z = constrain(z, ("batch", "seq", "mlp"))

    xin, conv_x_new = _causal_conv(xin, p["conv_x"].astype(cd))
    bmat, conv_b_new = _causal_conv(bmat, p["conv_B"].astype(cd))
    cmat, conv_c_new = _causal_conv(cmat, p["conv_C"].astype(cd))

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    xh = xin.reshape(b, l, h, pn)
    # pad to a chunk multiple: padded steps get dt = 0 => decay exp(0) = 1
    # and zero state contribution, so the final state is unaffected
    chunk = cfg.ssm_chunk
    lp_ = -(-l // chunk) * chunk
    if lp_ != l:
        padc = [(0, 0), (0, lp_ - l)]
        xh_p = jnp.pad(xh, padc + [(0, 0), (0, 0)])
        dt_p = jnp.pad(dt, padc + [(0, 0)])
        b_p = jnp.pad(bmat, padc + [(0, 0)])
        c_p = jnp.pad(cmat, padc + [(0, 0)])
    else:
        xh_p, dt_p, b_p, c_p = xh, dt, bmat, cmat
    y, s_final = _ssd_chunked(xh_p, dt_p, p["A_log"], b_p, c_p, chunk)
    y = y[:, :l] + p["D"].astype(cd)[None, None, :, None] * xh
    y = y.reshape(b, l, h * pn)

    y = y * jax.nn.silu(z)
    y = layers.rmsnorm(y, {"scale": p["gate_norm"]}, cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, p["out"].astype(cd))
    out = constrain(out, ("batch", "seq", "embed"))
    cache = {
        "conv_x": conv_x_new, "conv_B": conv_b_new, "conv_C": conv_c_new,
        "state": s_final.astype(cd),
    }
    return out, cache


def mamba2_decode(x, p, cfg: ModelConfig, cache):
    """O(1) recurrent decode step. x: (B, 1, d). Returns (out, new_cache)."""
    cd = cfg.dtype("compute")
    b = x.shape[0]
    h, pn, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state

    z = jnp.einsum("bld,de->ble", x, p["wz"].astype(cd))
    xin = jnp.einsum("bld,de->ble", x, p["wx"].astype(cd))
    bmat = jnp.einsum("bld,dn->bln", x, p["wB"].astype(cd))
    cmat = jnp.einsum("bld,dn->bln", x, p["wC"].astype(cd))
    dt = jnp.einsum("bld,dh->blh", x, p["wdt"].astype(cd))

    xin, cx = _causal_conv(xin, p["conv_x"].astype(cd), cache["conv_x"])
    bmat, cb = _causal_conv(bmat, p["conv_B"].astype(cd), cache["conv_B"])
    cmat, cc = _causal_conv(cmat, p["conv_C"].astype(cd), cache["conv_C"])

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (B,1,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    da = jnp.exp(dt[:, 0] * a)                                 # (B,H)

    xh = xin.reshape(b, h, pn).astype(jnp.float32)
    state = cache["state"].astype(jnp.float32)                 # (B,H,P,N)
    contrib = jnp.einsum("bhp,bn,bh->bhpn", xh, bmat[:, 0].astype(jnp.float32),
                         dt[:, 0])
    state = state * da[:, :, None, None] + contrib
    y = jnp.einsum("bhpn,bn->bhp", state, cmat[:, 0].astype(jnp.float32))
    y = y.astype(cd) + p["D"].astype(cd)[None, :, None] * xh.astype(cd)
    y = y.reshape(b, 1, h * pn)

    y = y * jax.nn.silu(z)
    y = layers.rmsnorm(y, {"scale": p["gate_norm"]}, cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, p["out"].astype(cd))
    new_cache = {"conv_x": cx, "conv_B": cb, "conv_C": cc,
                 "state": state.astype(cd)}
    return out, new_cache


def ssm_cache_defs(cfg: ModelConfig, batch: int) -> dict:
    """Abstract decode-cache layout (per layer)."""
    w = cfg.ssm_conv_width
    return {
        "conv_x": PSpec((batch, w - 1, cfg.ssm_d_inner),
                        ("batch", None, "mlp"), init="zeros"),
        "conv_B": PSpec((batch, w - 1, cfg.ssm_state),
                        ("batch", None, "ssm_state"), init="zeros"),
        "conv_C": PSpec((batch, w - 1, cfg.ssm_state),
                        ("batch", None, "ssm_state"), init="zeros"),
        "state": PSpec((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                        cfg.ssm_state),
                       ("batch", "ssm_heads", None, None), init="zeros"),
    }
