"""Model assembly: stages of scanned blocks + train/prefill/decode entries.

A model is a sequence of *stages*; each stage is a stack of identical blocks
executed with ``lax.scan`` over stacked parameters (keeping the HLO small —
one block body per stage regardless of depth — which is what makes 61-layer
dry-run compiles tractable).  Heterogeneous architectures decompose into
homogeneous stages:

  dense / encoder / vlm : [dense x L]
  moe (deepseek)        : [dense x first_dense, moe x rest]
  ssm (mamba2)          : [ssm x L]
  hybrid (zamba2)       : [group(ssm x E -> shared attn) x G, ssm x rem]

The zamba2 'shared attention' block has ONE set of weights applied after
every E mamba blocks (weights closed over by the group scan body), but each
invocation carries its own KV cache during serving.

Outputs: ``forward`` (train logits), ``prefill`` (last-token logits +
caches), ``decode_step`` (one token, updated caches).  Cross-entropy is
evaluated in sequence chunks so the peak live logits tensor is
(B, CE_CHUNK, vocab) rather than (B, S, vocab) — at qwen2.5's 152k vocab
that is the difference between 1.2 GB and 40 MB per device.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.config import (ModelConfig, PSpec, abstract_params,
                                 init_params, logical_specs, stack_defs)
from repro.models import blocks, layers

CE_CHUNK = 1024


@dataclasses.dataclass(frozen=True)
class StageDesc:
    name: str
    kind: str        # dense | moe | ssm | hybrid
    n_layers: int    # total layers in the stage (G*E for hybrid groups)
    group: int = 0   # hybrid: blocks per group


def _stages_for(cfg: ModelConfig) -> list[StageDesc]:
    if cfg.family in ("dense", "encoder", "vlm"):
        return [StageDesc("layers", "dense", cfg.n_layers)]
    if cfg.family == "moe":
        out = []
        if cfg.first_dense_layers:
            out.append(StageDesc("dense_layers", "dense",
                                 cfg.first_dense_layers))
        out.append(StageDesc("moe_layers", "moe",
                             cfg.n_layers - cfg.first_dense_layers))
        return out
    if cfg.family == "ssm":
        return [StageDesc("layers", "ssm", cfg.n_layers)]
    if cfg.family == "hybrid":
        e = cfg.shared_attn_every
        g = cfg.n_layers // e
        rem = cfg.n_layers - g * e
        out = [StageDesc("groups", "hybrid", g * e, group=e)]
        if rem:
            out.append(StageDesc("tail", "ssm", rem))
        return out
    raise ValueError(cfg.family)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.stages = _stages_for(cfg)

    # -- parameter declaration -------------------------------------------------
    def param_defs(self) -> dict:
        cfg = self.cfg
        defs: dict[str, Any] = {"embed": layers.embed_defs(cfg)}
        st: dict[str, Any] = {}
        for s in self.stages:
            if s.kind == "dense":
                st[s.name] = stack_defs(blocks.dense_block_defs(cfg), s.n_layers)
            elif s.kind == "moe":
                st[s.name] = stack_defs(
                    blocks.dense_block_defs(cfg, use_moe=True), s.n_layers)
            elif s.kind == "ssm":
                st[s.name] = stack_defs(blocks.ssm_block_defs(cfg), s.n_layers)
            elif s.kind == "hybrid":
                st[s.name] = stack_defs(blocks.ssm_block_defs(cfg), s.n_layers)
        defs["stages"] = st
        if cfg.family == "hybrid":
            defs["shared_attn"] = blocks.dense_block_defs(cfg)
        defs["final_norm"] = layers.rmsnorm_defs(cfg.d_model)
        defs.update(layers.head_defs(cfg) and {"head": layers.head_defs(cfg)})
        if cfg.mtp_depth:
            defs["mtp"] = {
                "proj": PSpec((2 * cfg.d_model, cfg.d_model),
                              (None, "embed")),
                "ln_h": layers.rmsnorm_defs(cfg.d_model),
                "ln_e": layers.rmsnorm_defs(cfg.d_model),
                "block": blocks.dense_block_defs(cfg),
            }
        return defs

    def init(self, key, dtype=None):
        dtype = dtype if dtype is not None else self.cfg.dtype("param")
        return init_params(self.param_defs(), key, dtype)

    def abstract(self, dtype=None):
        dtype = dtype if dtype is not None else self.cfg.dtype("param")
        return abstract_params(self.param_defs(), dtype)

    def specs(self):
        return logical_specs(self.param_defs())

    # -- input embedding ---------------------------------------------------------
    def embed_input(self, params, batch):
        cfg = self.cfg
        cd = cfg.dtype("compute")
        if "frames" in batch:                     # audio stub frontend
            x = jnp.einsum("btf,fd->btd", batch["frames"].astype(cd),
                           params["embed"]["frontend_proj"].astype(cd))
            b, s = x.shape[:2]
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        elif "vision_embeds" in batch:            # VLM stub frontend
            tok = layers.embed(batch["tokens"], params["embed"], cfg)
            vis = jnp.einsum("bvf,fd->bvd", batch["vision_embeds"].astype(cd),
                             params["embed"]["frontend_proj"].astype(cd))
            nv = vis.shape[1]
            x = jnp.concatenate([vis, tok[:, nv:]], axis=1)
            positions = batch["positions"]        # (3, B, S) M-RoPE
        else:
            x = layers.embed(batch["tokens"], params["embed"], cfg)
            b, s = x.shape[:2]
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        return constrain(x.astype(cd), ("batch", "seq", "embed")), positions

    # -- stage execution ----------------------------------------------------------
    def _block_fns(self, kind: str):
        cfg = self.cfg
        if kind == "dense":
            return functools.partial(blocks.dense_block, cfg=cfg)
        if kind == "moe":
            return functools.partial(blocks.dense_block, cfg=cfg, use_moe=True)
        if kind == "ssm":
            return functools.partial(blocks.ssm_block, cfg=cfg)
        raise ValueError(kind)

    def _run_stage(self, desc: StageDesc, stacked, x, positions):
        cfg = self.cfg
        if desc.kind == "hybrid":
            return self._run_hybrid(desc, stacked, x, positions)
        fn = self._block_fns(desc.kind)

        def body_fn(h, lp):
            h = fn(h, lp, positions=positions)
            if cfg.sp_activations:
                # the scan saves this carry per layer for backward; shard
                # its sequence dim over 'model' (Megatron-SP layout)
                h = constrain(h, ("batch", "attn_q_seq", "embed"))
            return h

        if cfg.remat == "full":
            body_fn = jax.checkpoint(body_fn)

        def body(h, lp):
            return body_fn(h, lp), None

        x, _ = jax.lax.scan(body, x, stacked)
        return x

    def _run_hybrid(self, desc: StageDesc, stacked, x, positions,
                    shared_params=None):
        cfg = self.cfg
        e = desc.group
        g = desc.n_layers // e
        grouped = jax.tree.map(
            lambda a: a.reshape((g, e) + a.shape[1:]), stacked)
        shared = shared_params if shared_params is not None else self._shared

        def group_body_fn(h, gp):
            def inner(hh, lp):
                return blocks.ssm_block(hh, lp, cfg), None
            h, _ = jax.lax.scan(inner, h, gp)
            h = blocks.dense_block(h, shared, cfg, positions)
            return h

        if cfg.remat == "full":
            group_body_fn = jax.checkpoint(group_body_fn)

        def group_body(h, gp):
            return group_body_fn(h, gp), None

        x, _ = jax.lax.scan(group_body, x, grouped)
        return x

    # -- forward (train) ------------------------------------------------------------
    def forward(self, params, batch, return_hidden: bool = False):
        cfg = self.cfg
        self._shared = params.get("shared_attn")
        x, positions = self.embed_input(params, batch)
        for desc in self.stages:
            x = self._run_stage(desc, params["stages"][desc.name], x, positions)
        x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        if return_hidden:
            return x
        return layers.lm_head(x, params.get("head"), params["embed"], cfg)

    # -- loss ------------------------------------------------------------------------
    def _ce_chunked(self, hidden, params, labels, shift: int):
        """Chunked cross-entropy: scan over sequence chunks.

        shift=1: next-token LM. shift=0: same-position (encoder) prediction.
        Returns mean CE over predicted positions.
        """
        cfg = self.cfg
        b, s, d = hidden.shape
        if shift:
            hidden = hidden[:, :-shift]
            labels = labels[:, shift:]
        t = hidden.shape[1]
        chunk = min(CE_CHUNK, t)
        n = t // chunk
        head = params.get("head")

        def chunk_ce(hs, ls):
            logits = layers.lm_head(hs, head, params["embed"], cfg)
            logits = logits.astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
            return jnp.sum(lse - gold)

        # remat: backward recomputes each chunk's logits instead of saving
        # (B, chunk, vocab) per chunk — the peak-memory win of chunked CE
        chunk_ce = jax.checkpoint(chunk_ce)

        def body(acc, i):
            hs = jax.lax.dynamic_slice_in_dim(hidden, i * chunk, chunk, axis=1)
            ls = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
            return acc + chunk_ce(hs, ls), None

        total, _ = jax.lax.scan(body, jnp.float32(0.0), jnp.arange(n))
        count = b * n * chunk
        rem = t - n * chunk
        if rem:  # tail (static)
            total = total + chunk_ce(hidden[:, n * chunk:],
                                     labels[:, n * chunk:])
            count += b * rem
        return total / count

    def loss(self, params, batch):
        cfg = self.cfg
        hidden = self.forward(params, batch, return_hidden=True)
        shift = 0 if cfg.is_encoder else 1
        loss = self._ce_chunked(hidden, params, batch["labels"], shift)
        metrics = {"ce": loss}
        if cfg.mtp_depth and "tokens" in batch:
            mp = params["mtp"]
            cd = cfg.dtype("compute")
            h = layers.rmsnorm(hidden[:, :-1], mp["ln_h"], cfg.norm_eps)
            e = layers.embed(batch["tokens"][:, 1:], params["embed"], cfg)
            e = layers.rmsnorm(e, mp["ln_e"], cfg.norm_eps)
            x = jnp.einsum("bsd,dm->bsm", jnp.concatenate([h, e], axis=-1),
                           mp["proj"].astype(cd))
            b, s2 = x.shape[:2]
            positions = jnp.broadcast_to(jnp.arange(s2, dtype=jnp.int32), (b, s2))
            x = blocks.dense_block(x, mp["block"], cfg, positions)
            mtp_loss = self._ce_chunked(x, params, batch["labels"][:, 1:], 1)
            metrics["mtp"] = mtp_loss
            loss = loss + 0.3 * mtp_loss
        metrics["loss"] = loss
        return loss, metrics

    # -- serving -----------------------------------------------------------------------
    def cache_defs(self, batch: int, seq_cap: int) -> dict:
        cfg = self.cfg
        out: dict[str, Any] = {}
        st: dict[str, Any] = {}
        for s in self.stages:
            if s.kind in ("dense", "moe"):
                st[s.name] = stack_defs(
                    blocks.dense_cache_defs(cfg, batch, seq_cap), s.n_layers)
            elif s.kind == "ssm":
                st[s.name] = stack_defs(
                    blocks.ssm_cache_defs(cfg, batch), s.n_layers)
            elif s.kind == "hybrid":
                st[s.name] = stack_defs(
                    blocks.ssm_cache_defs(cfg, batch), s.n_layers)
        out["stages"] = st
        if cfg.family == "hybrid":
            g = self.stages[0].n_layers // self.stages[0].group
            out["shared_attn"] = stack_defs(
                blocks.dense_cache_defs(cfg, batch, seq_cap), g)
        return out

    def abstract_cache(self, batch: int, seq_cap: int):
        return abstract_params(self.cache_defs(batch, seq_cap),
                               self.cfg.dtype("compute"))

    def cache_specs(self, batch: int, seq_cap: int):
        return logical_specs(self.cache_defs(batch, seq_cap))

    def init_cache(self, batch: int, seq_cap: int):
        return init_params(self.cache_defs(batch, seq_cap),
                           jax.random.key(0), self.cfg.dtype("compute"))

    def prefill(self, params, batch, seq_cap: int):
        """Full-sequence forward building caches. Returns (last_logits, cache)."""
        cfg = self.cfg
        self._shared = params.get("shared_attn")
        x, positions = self.embed_input(params, batch)
        caches: dict[str, Any] = {"stages": {}}
        shared_caches = None
        for desc in self.stages:
            stacked = params["stages"][desc.name]
            if desc.kind == "hybrid":
                x, st_cache, shared_caches = self._prefill_hybrid(
                    desc, stacked, x, positions, seq_cap)
            else:
                x, st_cache = self._prefill_stage(desc, stacked, x, positions,
                                                  seq_cap)
            caches["stages"][desc.name] = st_cache
        if shared_caches is not None:
            caches["shared_attn"] = shared_caches
        x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = layers.lm_head(x[:, -1:], params.get("head"),
                                params["embed"], cfg)
        return logits[:, 0], caches

    def _prefill_stage(self, desc, stacked, x, positions, seq_cap):
        cfg = self.cfg
        if desc.kind in ("dense", "moe"):
            fn = functools.partial(blocks.dense_block_prefill, cfg=cfg,
                                   positions=positions, seq_cap=seq_cap,
                                   use_moe=desc.kind == "moe")
        else:
            fn = functools.partial(blocks.ssm_block_prefill, cfg=cfg)
        if cfg.remat == "full":
            fn = jax.checkpoint(fn)

        def body(h, lp):
            h, cache = fn(h, lp)
            return h, cache

        return jax.lax.scan(body, x, stacked)

    def _prefill_hybrid(self, desc, stacked, x, positions, seq_cap):
        cfg = self.cfg
        e = desc.group
        g = desc.n_layers // e
        grouped = jax.tree.map(
            lambda a: a.reshape((g, e) + a.shape[1:]), stacked)
        shared = self._shared

        def group_body(h, gp):
            def inner(hh, lp):
                return blocks.ssm_block_prefill(hh, lp, cfg)
            h, ssm_caches = jax.lax.scan(inner, h, gp)
            h, attn_cache = blocks.dense_block_prefill(
                h, shared, cfg, positions, seq_cap)
            return h, (ssm_caches, attn_cache)

        if cfg.remat == "full":
            group_body = jax.checkpoint(group_body)
        x, (ssm_caches, attn_caches) = jax.lax.scan(group_body, x, grouped)
        # ssm_caches: (G, E, ...) -> flatten to (G*E, ...)
        ssm_caches = jax.tree.map(
            lambda a: a.reshape((g * e,) + a.shape[2:]), ssm_caches)
        return x, ssm_caches, attn_caches

    def decode_step(self, params, cache, tokens, pos):
        """One decode step. tokens: (B, 1) int32; pos: scalar int32.

        Returns (logits (B, vocab), new_cache).
        """
        cfg = self.cfg
        x = layers.embed(tokens, params["embed"], cfg)
        new_caches: dict[str, Any] = {"stages": {}}
        shared_new = None
        for desc in self.stages:
            stacked = params["stages"][desc.name]
            st_cache = cache["stages"][desc.name]
            if desc.kind == "hybrid":
                x, new_st, shared_new = self._decode_hybrid(
                    desc, stacked, x, st_cache, cache["shared_attn"],
                    params["shared_attn"], pos)
            else:
                x, new_st = self._decode_stage(desc, stacked, x, st_cache, pos)
            new_caches["stages"][desc.name] = new_st
        if shared_new is not None:
            new_caches["shared_attn"] = shared_new
        x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = layers.lm_head(x, params.get("head"), params["embed"], cfg)
        return logits[:, 0], new_caches

    def _decode_stage(self, desc, stacked, x, st_cache, pos):
        cfg = self.cfg
        if desc.kind in ("dense", "moe"):
            fn = functools.partial(blocks.dense_block_decode, cfg=cfg, pos=pos,
                                   use_moe=desc.kind == "moe")
        else:
            fn = functools.partial(blocks.ssm_block_decode, cfg=cfg, pos=pos)

        def body(h, inp):
            lp, lc = inp
            h, nc = fn(h, lp, cache=lc)
            return h, nc

        return jax.lax.scan(body, x, (stacked, st_cache))

    def _decode_hybrid(self, desc, stacked, x, ssm_cache, attn_cache,
                       shared, pos):
        cfg = self.cfg
        e = desc.group
        g = desc.n_layers // e
        grouped_p = jax.tree.map(
            lambda a: a.reshape((g, e) + a.shape[1:]), stacked)
        grouped_c = jax.tree.map(
            lambda a: a.reshape((g, e) + a.shape[1:]), ssm_cache)

        def group_body(h, inp):
            gp, gc, ac = inp

            def inner(hh, inp2):
                lp, lc = inp2
                hh, nc = blocks.ssm_block_decode(hh, lp, cfg, lc, pos)
                return hh, nc

            h, new_gc = jax.lax.scan(inner, h, (gp, gc))
            h, new_ac = blocks.dense_block_decode(h, shared, cfg, ac, pos)
            return h, (new_gc, new_ac)

        x, (new_ssm, new_attn) = jax.lax.scan(
            group_body, x, (grouped_p, grouped_c, attn_cache))
        new_ssm = jax.tree.map(
            lambda a: a.reshape((g * e,) + a.shape[2:]), new_ssm)
        return x, new_ssm, new_attn
