"""Decoder blocks: parameter declarations + forward/decode functions.

Each block kind (dense, moe, ssm) exposes:
  *_block_defs(cfg)                  -> PSpec tree for ONE layer
  *_block(x, p, cfg, positions)      -> x                       (train/fwd)
  *_block_prefill(...)               -> (x, layer_cache)        (prefill)
  *_block_decode(x, p, cfg, cache, pos) -> (x, new_cache)       (decode)

`repro.models.model` stacks these into scanned stages.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, PSpec
from repro.models import decode as dec
from repro.models import layers, mla, moe, ssm


# ---------------------------------------------------------------------------
# Dense (GQA or MLA attention + gated MLP)
# ---------------------------------------------------------------------------

def dense_block_defs(cfg: ModelConfig, use_moe: bool = False) -> dict:
    attn = mla.mla_defs(cfg) if cfg.attn_type == "mla" else layers.attn_defs(cfg)
    ffn = moe.moe_defs(cfg) if use_moe else layers.mlp_defs(cfg)
    return {
        "ln1": layers.rmsnorm_defs(cfg.d_model),
        "attn": attn,
        "ln2": layers.rmsnorm_defs(cfg.d_model),
        "ffn": ffn,
    }


def _attn_fwd(x, p, cfg, positions):
    if cfg.attn_type == "mla":
        out, kv = mla.mla_attention(x, p, cfg, positions)
        return out, kv
    out = layers.attention(x, p, cfg, positions)
    return out, None


def _attn_fwd_with_kv(x, p, cfg, positions):
    """Like _attn_fwd but always returns prefill KV for the cache."""
    if cfg.attn_type == "mla":
        return mla.mla_attention(x, p, cfg, positions)
    q, k, v = layers.qkv_proj(x, p, cfg, positions)
    o = layers.sdpa(q, k, v, cfg, causal=cfg.causal and not cfg.is_encoder)
    return layers.attn_out(o, p, cfg), (k, v)


def dense_block(x, p, cfg: ModelConfig, positions, use_moe: bool = False):
    a, _ = _attn_fwd(layers.rmsnorm(x, p["ln1"], cfg.norm_eps), p["attn"],
                     cfg, positions)
    x = x + a
    h = layers.rmsnorm(x, p["ln2"], cfg.norm_eps)
    f = moe.moe_ffn(h, p["ffn"], cfg) if use_moe else layers.mlp(h, p["ffn"], cfg)
    return x + f


def dense_block_prefill(x, p, cfg: ModelConfig, positions, seq_cap: int,
                        use_moe: bool = False):
    a, kv = _attn_fwd_with_kv(layers.rmsnorm(x, p["ln1"], cfg.norm_eps),
                              p["attn"], cfg, positions)
    x = x + a
    h = layers.rmsnorm(x, p["ln2"], cfg.norm_eps)
    f = moe.moe_ffn(h, p["ffn"], cfg) if use_moe else layers.mlp(h, p["ffn"], cfg)
    x = x + f
    if cfg.attn_type == "mla":
        c_kv, k_rope = kv
        s = c_kv.shape[1]
        if seq_cap > s:
            c_kv = jnp.pad(c_kv, [(0, 0), (0, seq_cap - s), (0, 0)])
            k_rope = jnp.pad(k_rope, [(0, 0), (0, seq_cap - s), (0, 0)])
        cache = {"c_kv": c_kv, "k_rope": k_rope}
    else:
        cache = dec.prefill_kv(*kv, seq_cap)
    return x, cache


def dense_block_decode(x, p, cfg: ModelConfig, cache, pos,
                       use_moe: bool = False):
    h = layers.rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.attn_type == "mla":
        a, new_cache = mla.mla_decode(h, p["attn"], cfg, cache, pos)
    else:
        a, new_cache = dec.gqa_decode(h, p["attn"], cfg, cache, pos)
    x = x + a
    h = layers.rmsnorm(x, p["ln2"], cfg.norm_eps)
    f = moe.moe_ffn(h, p["ffn"], cfg) if use_moe else layers.mlp(h, p["ffn"], cfg)
    return x + f, new_cache


def dense_cache_defs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    if cfg.attn_type == "mla":
        return mla.mla_cache_defs(cfg, batch, seq)
    return dec.gqa_cache_defs(cfg, batch, seq)


# ---------------------------------------------------------------------------
# SSM (Mamba-2)
# ---------------------------------------------------------------------------

def ssm_block_defs(cfg: ModelConfig) -> dict:
    return {
        "ln": layers.rmsnorm_defs(cfg.d_model),
        "mixer": ssm.ssm_defs(cfg),
    }


def ssm_block(x, p, cfg: ModelConfig, positions=None):
    h, _ = ssm.mamba2_forward(layers.rmsnorm(x, p["ln"], cfg.norm_eps),
                              p["mixer"], cfg)
    return x + h


def ssm_block_prefill(x, p, cfg: ModelConfig, positions=None, seq_cap=None):
    h, cache = ssm.mamba2_forward(layers.rmsnorm(x, p["ln"], cfg.norm_eps),
                                  p["mixer"], cfg)
    return x + h, cache


def ssm_block_decode(x, p, cfg: ModelConfig, cache, pos=None):
    h, new_cache = ssm.mamba2_decode(
        layers.rmsnorm(x, p["ln"], cfg.norm_eps), p["mixer"], cfg, cache)
    return x + h, new_cache


def ssm_cache_defs(cfg: ModelConfig, batch: int) -> dict:
    return ssm.ssm_cache_defs(cfg, batch)
