"""Mixture-of-Experts FFN with expert parallelism (DeepSeek-style).

Routing uses softmax -> top-k -> renormalise (DeepSeek-V2/V3 convention)
with shared experts computed densely alongside.

The routed path is a **shard_map island** inside the otherwise auto-sharded
step: experts live on the 'model' mesh axis, tokens on ('pod','data').
Dispatch is index-based (sort + capacity-bounded scatter — never a
(T, E, C) one-hot), then a single tiled ``all_to_all`` moves token copies
to their expert shards and back.  Communication per MoE layer is exactly
``2 * T_local * top_k * d_model`` bytes per device — independent of E —
which is what keeps DeepSeek-V3's 256 experts viable on a 16-way EP axis.

Token chunking (``lax.scan`` over MOE_CHUNK-token slices) bounds the live
dispatch buffer; for deepseek-v3 train_4k this is the difference between a
4.7 GB and a ~0.6 GB transient per layer (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.distributed import sharding
from repro.models.config import ModelConfig, PSpec
from repro.models import layers

MOE_CHUNK = 4096   # tokens per dispatch chunk (per device)


def moe_defs(cfg: ModelConfig) -> dict:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    defs = {
        "router": PSpec((d, e), ("embed", "experts"), scale=0.02),
        "wg": PSpec((e, d, ff), ("experts", "embed", "expert_mlp")),
        "wu": PSpec((e, d, ff), ("experts", "embed", "expert_mlp")),
        "wd": PSpec((e, ff, d), ("experts", "expert_mlp", "embed")),
    }
    if cfg.n_shared_experts:
        defs["shared"] = layers.mlp_defs(
            cfg, d_ff=cfg.n_shared_experts * cfg.moe_d_ff,
            mlp_axis="shared_mlp")
    return defs


def _route(x_flat, router_w, cfg: ModelConfig):
    """softmax -> top-k -> renormalise. x_flat: (T, d)."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.top_k)          # (T, k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    return weights.astype(x_flat.dtype), idx


def _dispatch_compute_combine(x_flat, weights, idx, wg, wu, wd,
                              cfg: ModelConfig, ep_axis: str | None,
                              ep_size: int):
    """Capacity dispatch -> (optional a2a) -> expert FFN -> combine.

    x_flat: (T, d) local tokens. wg/wu/wd: local expert slices
    (E_local, d, ff) etc. Returns (T, d).
    """
    t, d = x_flat.shape
    e = cfg.n_experts
    k = cfg.top_k
    cap = int(math.ceil(t * k * cfg.capacity_factor / e))
    cap = max(8, -(-cap // 8) * 8)   # round up to 8 for tiling

    e_flat = idx.reshape(-1)                            # (T*k,)
    w_flat = weights.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(t), k)

    order = jnp.argsort(e_flat)                         # stable
    e_sort = e_flat[order]
    tok_sort = tok_flat[order]
    w_sort = w_flat[order]

    counts = jnp.bincount(e_flat, length=e)             # (E,)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k) - starts[e_sort]            # rank within expert
    keep = pos < cap
    slot = jnp.where(keep, e_sort * cap + pos, e * cap)  # overflow slot

    buf = jnp.zeros((e * cap + 1, d), x_flat.dtype)
    buf = buf.at[slot].set(x_flat[tok_sort])
    buf = buf[:-1].reshape(e, cap, d)                   # (E, C, d)

    if ep_axis is not None and ep_size > 1:
        # (E, C, d) -> (E/ep, ep*C, d): rows of my local experts, gathered
        # from every token shard
        buf = jax.lax.all_to_all(buf, ep_axis, split_axis=0,
                                 concat_axis=1, tiled=True)

    cd = cfg.dtype("compute")
    g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(cd))
    u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(cd))
    h = jax.nn.silu(g) * u
    y = jnp.einsum("ecf,efd->ecd", h, wd.astype(cd))

    if ep_axis is not None and ep_size > 1:
        # reverse: (E/ep, ep*C, d) -> (E, C, d)
        y = jax.lax.all_to_all(y, ep_axis, split_axis=1,
                               concat_axis=0, tiled=True)

    y_flat = y.reshape(e * cap, d)
    y_tok = jnp.where(keep[:, None], y_flat[jnp.clip(slot, 0, e * cap - 1)],
                      0.0)
    y_tok = y_tok * w_sort[:, None].astype(y_tok.dtype)
    out = jax.ops.segment_sum(y_tok, tok_sort, num_segments=t)
    return out.astype(x_flat.dtype)


def _moe_tokens(x_flat, router_w, wg, wu, wd, cfg: ModelConfig,
                ep_axis: str | None, ep_size: int):
    """Routed experts over a flat (T, d) token slice, chunked."""
    t, d = x_flat.shape
    n_chunks = max(1, -(-t // MOE_CHUNK))
    if n_chunks == 1:
        w, idx = _route(x_flat, router_w, cfg)
        return _dispatch_compute_combine(x_flat, w, idx, wg, wu, wd, cfg,
                                         ep_axis, ep_size)
    pad = n_chunks * MOE_CHUNK - t
    xp = jnp.pad(x_flat, ((0, pad), (0, 0)))
    xc = xp.reshape(n_chunks, MOE_CHUNK, d)

    def body(_, xi):
        w, idx = _route(xi, router_w, cfg)
        yi = _dispatch_compute_combine(xi, w, idx, wg, wu, wd, cfg,
                                       ep_axis, ep_size)
        return None, yi

    _, yc = jax.lax.scan(body, None, xc)
    return yc.reshape(n_chunks * MOE_CHUNK, d)[:t]


def _moe_local(x, router_w, wg, wu, wd, cfg: ModelConfig,
               ep_axis: str | None, ep_size: int):
    """Per-shard routed-expert computation. x: (B_loc, S, d).

    Inside the island, x is *replicated* over the EP axis (TP-style
    activations).  Each EP shard takes a disjoint 1/ep_size slice of the
    local tokens — so expert compute and dispatch buffers split over the
    model axis instead of being duplicated — and an all_gather at the end
    restores the replicated layout.
    """
    b, s, d = x.shape
    t = b * s
    x_flat = x.reshape(t, d)
    if ep_axis is None or ep_size == 1:
        return _moe_tokens(x_flat, router_w, wg, wu, wd, cfg,
                           ep_axis, ep_size).reshape(b, s, d)

    t_pad = -(-t // ep_size) * ep_size
    if t_pad != t:
        x_flat = jnp.pad(x_flat, ((0, t_pad - t), (0, 0)))
    t_m = t_pad // ep_size
    m = jax.lax.axis_index(ep_axis)
    x_m = jax.lax.dynamic_slice_in_dim(x_flat, m * t_m, t_m, axis=0)
    y_m = _moe_tokens(x_m, router_w, wg, wu, wd, cfg, ep_axis, ep_size)
    y = jax.lax.all_gather(y_m, ep_axis, axis=0, tiled=True)  # (t_pad, d)
    return y[:t].reshape(b, s, d)


def moe_ffn(x, params, cfg: ModelConfig):
    """Routed experts (+ shared experts) for a (B, S, d) activation."""
    mesh = sharding.current_mesh()
    ep_axis = None
    ep_size = 1
    if (mesh is not None and "model" in mesh.axis_names
            and cfg.n_experts % mesh.shape["model"] == 0
            and mesh.shape["model"] > 1):
        ep_axis = "model"
        ep_size = mesh.shape["model"]

    if ep_axis is None:
        routed = _moe_local(x, params["router"], params["wg"], params["wu"],
                            params["wd"], cfg, None, 1)
    else:
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        x_spec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0],
                   None, None)
        # expert weights arrive 2D-sharded: experts over 'model' (EP) and
        # d_model over 'data' (FSDP); the island gathers the FSDP axis once
        # per call — the expert-FSDP + EP combination of production MoE.
        fsdp = "data" in mesh.axis_names and mesh.shape["data"] > 1
        e_spec_gu = P("model", "data" if fsdp else None, None)
        e_spec_d = P("model", None, "data" if fsdp else None)

        def island(xl, rw, wg, wu, wd):
            with sharding.no_constraints():
                if fsdp:
                    wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
                    wu = jax.lax.all_gather(wu, "data", axis=1, tiled=True)
                    wd = jax.lax.all_gather(wd, "data", axis=2, tiled=True)
                return _moe_local(xl, rw, wg, wu, wd, cfg, ep_axis, ep_size)

        # check_vma=False: the output IS replicated over 'model' by
        # construction (trailing all_gather over the EP axis), which the
        # varying-axes checker cannot prove through the gather+slice.
        routed = shard_map(
            island, mesh=mesh,
            in_specs=(x_spec, P(None, None), e_spec_gu, e_spec_gu, e_spec_d),
            out_specs=x_spec, check_vma=False,
        )(x, params["router"], params["wg"], params["wu"], params["wd"])

    out = routed
    if cfg.n_shared_experts:
        out = out + layers.mlp(x, params["shared"], cfg)
    return sharding.constrain(out, ("batch", "seq", "embed"))
