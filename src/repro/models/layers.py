"""Core transformer layers: norms, RoPE variants, GQA attention, gated MLP.

Everything is pure-JAX (einsum-based) with logical sharding constraints —
the ten assigned architectures differ only in configuration.  Tensor
parallelism follows the Megatron pattern expressed through logical axes:
q/k/v/o projections shard over 'heads', the MLP over 'mlp', embeddings over
'vocab'; XLA's SPMD partitioner inserts the corresponding collectives.

Attention has two memory regimes:
* full-score path for short sequences (train_4k),
* an exact q-chunked path (scan over query blocks, row softmax against all
  keys) for 32k prefill, bounding the live score block at
  (B, H, q_chunk, S) — the pure-JAX analogue of FlashAttention's tiling,
  chosen because XLA:TPU fuses the inner block well and the dry-run needs
  an HLO-analysable path.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain
from repro.models.config import ModelConfig, PSpec

# q-chunking kicks in above this sequence length
Q_CHUNK_THRESHOLD = 8192
Q_CHUNK = 1024


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_defs(d: int) -> dict:
    return {"scale": PSpec((d,), ("embed",), init="ones")}


def rmsnorm(x, params, eps: float):
    # statistics in f32, but the normalisation multiply stays in x.dtype:
    # a full f32 copy of x here would be hoisted out of the layer loop by
    # XLA (convert of the whole saved residual stack -> +10 GB/device on
    # qwen2.5-32b train; see EXPERIMENTS.md §Perf iteration 2)
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * params["scale"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard / partial "2d" / M-RoPE)
# ---------------------------------------------------------------------------

def _inv_freq(n: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, n, dtype=np.float32) / n))


def rope_angles(positions, rot_dim: int, theta: float, mrope_sections=None):
    """Angles (.., seq, rot_dim/2) for the given positions.

    positions: (B, S) int32, or (3, B, S) for M-RoPE (t/h/w components).
    """
    half = rot_dim // 2
    inv = jnp.asarray(_inv_freq(half, theta))          # (half,)
    if mrope_sections is None:
        return positions[..., None].astype(jnp.float32) * inv  # (B,S,half)
    # M-RoPE: split the half-dim into sections, each driven by one
    # position component (temporal / height / width).
    assert positions.ndim == 3 and positions.shape[0] == len(mrope_sections)
    parts = []
    start = 0
    for comp, sec in enumerate(mrope_sections):
        inv_sec = inv[start:start + sec]
        parts.append(positions[comp][..., None].astype(jnp.float32) * inv_sec)
        start += sec
    return jnp.concatenate(parts, axis=-1)             # (B,S,half)


def apply_rope(x, angles):
    """Rotate the first 2*angles.shape[-1] dims of the head vectors.

    x: (B, S, H, D); angles: (B, S, half) with 2*half <= D (partial rotary
    covers chatglm's '2d' RoPE where only half the head dims rotate).
    """
    half = angles.shape[-1]
    rot, rest = x[..., : 2 * half], x[..., 2 * half:]
    x1, x2 = rot[..., :half], rot[..., half:]
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    return jnp.concatenate([r1, r2, rest], axis=-1)


def rope_for(cfg: ModelConfig, positions, head_dim: int | None = None):
    """Config-dispatched angles; returns None for rope_style == 'none'."""
    hd = head_dim if head_dim is not None else cfg.head_dim
    if cfg.rope_style == "none":
        return None
    if cfg.rope_style == "standard":
        return rope_angles(positions, hd, cfg.rope_theta)
    if cfg.rope_style == "2d":
        # chatglm: rotary on the first half of the head dims only
        return rope_angles(positions, hd // 2, cfg.rope_theta)
    if cfg.rope_style == "mrope":
        half = hd // 2
        # qwen2-vl sections (t, h, w) = (2/8, 3/8, 3/8) of the half dim
        sec_t = half // 4
        sec_h = (half - sec_t) // 2
        sections = [sec_t + (half - sec_t - 2 * sec_h), sec_h, sec_h]
        if positions.ndim == 2:      # text-only fallback: same pos for t/h/w
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        return rope_angles(positions, hd, cfg.rope_theta,
                           mrope_sections=sections)
    raise ValueError(cfg.rope_style)


# ---------------------------------------------------------------------------
# Embeddings / output head
# ---------------------------------------------------------------------------

def embed_defs(cfg: ModelConfig) -> dict:
    d = {"tok": PSpec((cfg.vocab_padded, cfg.d_model), ("vocab", "embed"),
                      scale=0.02)}
    if cfg.frontend_dim:
        d["frontend_proj"] = PSpec((cfg.frontend_dim, cfg.d_model),
                                   ("frontend", "embed"))
    return d


def embed(tokens, params, cfg: ModelConfig):
    out = jnp.take(params["tok"], tokens, axis=0).astype(cfg.dtype("compute"))
    return constrain(out, ("batch", "seq", "embed"))


def head_defs(cfg: ModelConfig) -> dict:
    if cfg.tie_embeddings:
        return {}
    return {"out": PSpec((cfg.d_model, cfg.vocab_padded), ("embed", "vocab"),
                         scale=0.02)}


def lm_head(x, params, embed_params, cfg: ModelConfig):
    """Logits over the padded vocab; padding columns masked to -inf."""
    if cfg.tie_embeddings:
        w = embed_params["tok"].astype(cfg.dtype("compute")).T
    else:
        w = params["out"].astype(cfg.dtype("compute"))
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    if cfg.vocab_padded != cfg.vocab_size:
        mask = jnp.arange(cfg.vocab_padded) < cfg.vocab_size
        logits = jnp.where(mask, logits, jnp.asarray(-1e30, logits.dtype))
    return constrain(logits, ("batch", "seq", "vocab"))


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def attn_defs(cfg: ModelConfig) -> dict:
    h, kv, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    defs = {
        "wq": PSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": PSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": PSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": PSpec((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        defs["bq"] = PSpec((h, hd), ("heads", "head_dim"), init="zeros")
        defs["bk"] = PSpec((kv, hd), ("kv_heads", "head_dim"), init="zeros")
        defs["bv"] = PSpec((kv, hd), ("kv_heads", "head_dim"), init="zeros")
    return defs


def qkv_proj(x, params, cfg: ModelConfig, positions):
    """Project + rope. Returns q (B,S,H,D), k/v (B,S,KV,D)."""
    cd = cfg.dtype("compute")
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(cd)
        k = k + params["bk"].astype(cd)
        v = v + params["bv"].astype(cd)
    angles = rope_for(cfg, positions)
    if angles is not None:
        q = apply_rope(q, angles)
        k = apply_rope(k, angles)
    q = constrain(q, ("batch", "seq", "heads", "head_dim"))
    k = constrain(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = constrain(v, ("batch", "seq", "kv_heads", "head_dim"))
    return q, k, v


def _score_axes(n_kv_heads: int, group: int):
    """How to shard the (B, KV, G, Sq, Sk) score tensor over 'model'.

    Preference order: KV heads (plain head parallelism) > the GQA group
    dim (q-head parallelism with replicated K/V — e.g. chatglm3's kv=2,
    g=16, where forcing q-seq sharding made the partitioner fall back to
    full 8 GiB score all-gathers in the backward; §Perf iteration 10) >
    the q-sequence dim (context parallelism, e.g. qwen2.5's kv=8, g=5).
    """
    from repro.distributed.sharding import current_mesh
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return ("batch", "kv_heads", "qgroup", None, None)
    m = mesh.shape["model"]
    if n_kv_heads % m == 0:
        return ("batch", "kv_heads", "qgroup", None, None)
    if group % m == 0:
        # 'heads' -> model applied to the group dim (q heads sharded)
        return ("batch", None, "heads", None, None)
    return ("batch", None, "qgroup", "attn_q_seq", None)


def _sdpa_full(q, k, v, *, causal: bool, q_offset: int = 0):
    """Grouped scores over the whole (q_len, kv_len) rectangle.

    q: (B, Sq, KV, G, D); k/v: (B, Sk, KV, D). Returns (B, Sq, KV, G, D).
    """
    b, sq, kv, g, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(jnp.float32) * scale
    scores = constrain(scores, _score_axes(kv, g))
    if causal:
        qi = jnp.arange(sq) + q_offset
        ki = jnp.arange(sk)
        mask = qi[:, None] >= ki[None, :]
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return constrain(out, ("batch", None, None, "heads", None))


def sdpa(q, k, v, cfg: ModelConfig, *, causal: bool):
    """Dispatch full vs q-chunked attention; GQA grouping handled here.

    q: (B, S, H, D) -> out (B, S, H, D).
    """
    b, s, h, d = q.shape
    kv = k.shape[2]
    dv = v.shape[-1]           # may differ from d (MLA)
    g = h // kv
    qg = q.reshape(b, s, kv, g, d)
    threshold = min(Q_CHUNK_THRESHOLD, cfg.attn_q_chunk_threshold)
    if s <= threshold:
        out = _sdpa_full(qg, k, v, causal=causal)
        return out.reshape(b, s, h, dv)
    # q-chunked path; ragged tails (e.g. the MTP block's S-1) are padded
    # on the q axis only and sliced off after
    n_blocks = -(-s // Q_CHUNK)
    s_pad = n_blocks * Q_CHUNK
    qp = jnp.pad(qg, [(0, 0), (0, s_pad - s)] + [(0, 0)] * 3) \
        if s_pad != s else qg

    def block(carry, i):
        qb = jax.lax.dynamic_slice_in_dim(qp, i * Q_CHUNK, Q_CHUNK, axis=1)
        ob = _sdpa_full(qb, k, v, causal=causal, q_offset=i * Q_CHUNK)
        return carry, ob

    _, blocks = jax.lax.scan(block, None, jnp.arange(n_blocks))
    # blocks: (n_blocks, B, Q_CHUNK, KV, G, DV)
    out = jnp.moveaxis(blocks, 0, 1).reshape(b, s_pad, kv, g, dv)[:, :s]
    return out.reshape(b, s, h, dv)


def attn_out(o, params, cfg: ModelConfig):
    cd = cfg.dtype("compute")
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(cd))
    return constrain(out, ("batch", "seq", "embed"))


def attention(x, params, cfg: ModelConfig, positions):
    """Full training/prefill attention (causal unless encoder)."""
    q, k, v = qkv_proj(x, params, cfg, positions)
    o = sdpa(q, k, v, cfg, causal=cfg.causal and not cfg.is_encoder)
    o = constrain(o, ("batch", "seq", "heads", "head_dim"))
    return attn_out(o, params, cfg)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_defs(cfg: ModelConfig, d_ff: int | None = None,
             mlp_axis: str = "mlp") -> dict:
    ff = d_ff if d_ff is not None else cfg.d_ff
    d = cfg.d_model
    return {
        "wg": PSpec((d, ff), ("embed", mlp_axis)),
        "wu": PSpec((d, ff), ("embed", mlp_axis)),
        "wd": PSpec((ff, d), (mlp_axis, "embed")),
    }


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def mlp(x, params, cfg: ModelConfig, act: str = "silu"):
    cd = cfg.dtype("compute")
    g = jnp.einsum("bsd,df->bsf", x, params["wg"].astype(cd))
    u = jnp.einsum("bsd,df->bsf", x, params["wu"].astype(cd))
    h = _act(act)(g) * u
    h = constrain(h, ("batch", "seq", "mlp"))
    out = jnp.einsum("bsf,fd->bsd", h, params["wd"].astype(cd))
    return constrain(out, ("batch", "seq", "embed"))
