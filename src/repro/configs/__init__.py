"""Architecture registry: the 10 assigned configs + the paper's workload.

``get_config(name)`` returns the full published configuration;
``reduced(cfg)`` shrinks it to a CPU-smoke-testable size *of the same
family* (same stage structure, same attention type, same routing — only
widths/depths/vocab shrink), which is what the per-arch smoke tests run.
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCH_NAMES = (
    "zamba2_7b",
    "chatglm3_6b",
    "minitron_4b",
    "qwen2_5_32b",
    "stablelm_3b",
    "mamba2_130m",
    "deepseek_v2_lite_16b",
    "deepseek_v3_671b",
    "hubert_xlarge",
    "qwen2_vl_7b",
)

# assignment ids -> module names
ALIASES = {
    "zamba2-7b": "zamba2_7b",
    "chatglm3-6b": "chatglm3_6b",
    "minitron-4b": "minitron_4b",
    "qwen2.5-32b": "qwen2_5_32b",
    "stablelm-3b": "stablelm_3b",
    "mamba2-130m": "mamba2_130m",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "hubert-xlarge": "hubert_xlarge",
    "qwen2-vl-7b": "qwen2_vl_7b",
}


def get_config(name: str) -> ModelConfig:
    mod_name = ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    if mod_name not in ARCH_NAMES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Same-family shrink for CPU smoke tests."""
    kw = dict(
        n_layers=4,
        d_model=64,
        vocab_size=256,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
    )
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2) or 2,
                  head_dim=16)
        if cfg.n_kv_heads == cfg.n_heads:
            kw["n_kv_heads"] = 4
    if cfg.d_ff:
        kw.update(d_ff=128)
    if cfg.attn_type == "mla":
        kw.update(q_lora_rank=32 if cfg.q_lora_rank else 0, kv_lora_rank=32,
                  qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
    if cfg.n_experts:
        # capacity_factor = E/k makes the reduced config dropless, so
        # decode-vs-prefill consistency tests are exact (capacity dropping
        # is load-dependent by design; see DESIGN.md)
        kw.update(n_experts=8, top_k=2, moe_d_ff=32,
                  n_shared_experts=min(cfg.n_shared_experts, 1),
                  first_dense_layers=min(cfg.first_dense_layers, 1),
                  capacity_factor=4.0)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_head_dim=8, ssm_chunk=8)
    if cfg.family == "hybrid":
        kw.update(n_layers=5, shared_attn_every=2)   # 2 groups + 1 tail
    if cfg.frontend_dim:
        kw.update(frontend_dim=32)
    if cfg.mtp_depth:
        kw.update(mtp_depth=1)
    return cfg.with_overrides(**kw)
