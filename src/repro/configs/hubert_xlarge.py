"""hubert-xlarge [audio]: encoder-only transformer backbone.

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (masked-unit targets).
[arXiv:2106.07447; unverified]

The 7-layer strided conv frontend is a STUB per the assignment:
``input_specs`` provides precomputed 512-d frame embeddings; the model
projects them to d_model.  Bidirectional attention; no decode shapes.
Deviations noted in DESIGN.md: RoPE replaces HuBERT's conv positional
embedding, gated-SiLU MLP replaces plain GELU.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    attn_type="gqa",
    rope_style="standard",
    causal=False,
    is_encoder=True,
    frontend_dim=512,
)
