"""qwen2.5-32b [dense]: GQA kv=8 with QKV bias.

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064.
[hf:Qwen/Qwen2.5-0.5B family; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152064,
    attn_type="gqa",
    rope_style="standard",
    qkv_bias=True,
    rope_theta=1000000.0,
    # >=6B params: store bf16 (f32 Adam moments retained) so the FSDP
    # all-gather of the scanned weight stack costs half the VMEM/HBM
    param_dtype="bfloat16",
)
