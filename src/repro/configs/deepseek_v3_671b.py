"""deepseek-v3-671b [moe]: MLA + 256-expert MoE + multi-token prediction.

61L d_model=7168 128H d_ff(expert)=2048 vocab=129280; 1 shared + 256 routed
top-8; MLA kv_lora=512 q_lora=1536; first 3 layers dense (d_ff=18432);
MTP depth 1.  [arXiv:2412.19437; hf]

Memory plan for 512 x 16 GB v5e (verified by the dry-run memory analysis):
bf16 params (~2.7 GB/chip) + bf16 grads + Adafactor factored moments
(~MBs) + remat'd activations with 8-way grad accumulation.  f32 AdamW
would need ~21 GB/chip — see EXPERIMENTS.md §Dry-run.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=192,          # qk_nope + qk_rope
    d_ff=18432,            # dense first layers
    vocab_size=129280,
    attn_type="mla",
    rope_style="standard",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    n_experts=256,
    n_shared_experts=1,
    top_k=8,
    moe_d_ff=2048,
    first_dense_layers=3,
    mtp_depth=1,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    opt_dtype="bfloat16",
    sp_activations=True,   # sequence-sharded residual saves (Megatron-SP)
    # §Perf iteration 7b: q-chunk attention already at train length — the
    # (B_mb, H/16, S, S) f32 score transient quarters, buying the headroom
    # that lets grad_accum drop to 4 (fewer FSDP weight gathers per step)
    attn_q_chunk_threshold=2048,
)
