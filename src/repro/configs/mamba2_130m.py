"""mamba2-130m [ssm]: attention-free SSD (state-space duality).

24L d_model=768 (attn-free) vocab=50280 ssm_state=128.
[arXiv:2405.21060; unverified]

O(1)-state decode -> runs the `long_500k` shape.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    d_ff=0,
    vocab_size=50280,
    attn_type="none",
    rope_style="none",
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,     # d_inner 1536 -> 24 SSM heads
    ssm_conv_width=4,
    ssm_chunk=128,
    tie_embeddings=True,
    subquadratic=True,
    # §Perf iteration 8: a 130M model on a 256-chip mesh is pure-DP —
    # replicating 0.5 GB of weights beats paying TP=16 activation psums
    sharding_profile="small_dp",
)
