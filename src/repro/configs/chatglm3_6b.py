"""chatglm3-6b [dense]: GQA kv=2, 2d (partial) RoPE, qkv bias.

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
[arXiv:2406.12793; hf]

Note: kv=2 does not divide the 16-way model axis; the sharding rules'
divisibility fallback leaves K/V projections replicated while Q/O shard —
recorded in the roofline table (extra K/V weight memory, no extra comm).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    attn_type="gqa",
    rope_style="2d",
    qkv_bias=True,
    # >=6B params: store bf16 (f32 Adam moments retained) so the FSDP
    # all-gather of the scanned weight stack costs half the VMEM/HBM
    param_dtype="bfloat16",
)
