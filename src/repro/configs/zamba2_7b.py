"""zamba2-7b [hybrid]: Mamba-2 backbone + shared attention block.

81L d_model=3584 32H (kv=32 -> full MHA in the shared block) d_ff=14336
vocab=32000 ssm_state=64.  [arXiv:2411.15242; unverified]

Zamba2 applies ONE shared transformer block (attention + MLP) repeatedly —
here after every 6 Mamba-2 blocks (13 invocations + 3 tail Mamba layers),
with per-invocation KV caches during serving.  Sub-quadratic decode: the
Mamba state is O(1) and only the 13 shared-block invocations touch the long
KV cache, so `long_500k` runs for this arch.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    attn_type="gqa",
    rope_style="standard",
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,     # d_inner 7168 -> 112 SSM heads
    ssm_conv_width=4,
    ssm_chunk=128,
    shared_attn_every=6,
    subquadratic=True,
    # >=6B params: store bf16 (f32 Adam moments retained) so the FSDP
    # all-gather of the scanned weight stack costs half the VMEM/HBM
    param_dtype="bfloat16",
)
