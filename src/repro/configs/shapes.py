"""Assigned input shapes and (arch x shape) cell validity.

Four shapes per LM architecture; ``decode_*``/``long_*`` lower
``serve_step`` (one new token against a seq_len cache), NOT ``train_step``.
Skips (recorded in DESIGN.md §Arch-applicability and emitted by dryrun.py):

* ``long_500k`` needs a sub-quadratic serving path -> only SSM/hybrid run it;
* encoder-only archs (hubert) have no decode step -> skip decode shapes.
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def cell_status(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch, shape) cell."""
    if cfg.is_encoder and shape.kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch: 500k decode needs a "
                       "sub-quadratic path (skip per assignment)")
    return True, ""


def runnable_cells(configs: dict[str, ModelConfig]):
    """All (arch_name, shape_name) cells that must pass the dry-run."""
    out = []
    for arch, cfg in configs.items():
        for sname, shape in SHAPES.items():
            ok, _ = cell_status(cfg, shape)
            if ok:
                out.append((arch, sname))
    return out
