"""qwen2-vl-7b [vlm]: M-RoPE backbone, dynamic-resolution vision stub.

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
[arXiv:2409.12191; hf]

Only the transformer BACKBONE per the assignment: the ViT frontend is a
STUB — ``input_specs`` provides precomputed 1176-d patch embeddings plus
(3, B, S) M-RoPE position ids (temporal/height/width components); the
model projects patches to d_model and splices them ahead of the text
embeddings.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    attn_type="gqa",
    rope_style="mrope",
    qkv_bias=True,
    rope_theta=1000000.0,
    frontend_dim=1176,
    # >=6B params: store bf16 (f32 Adam moments retained) so the FSDP
    # all-gather of the scanned weight stack costs half the VMEM/HBM
    param_dtype="bfloat16",
)
