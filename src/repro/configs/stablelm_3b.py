"""stablelm-3b [dense]: MHA (kv=32).

32L d_model=2560 32H (GQA kv=32) d_ff=6912 vocab=50304.
[hf:stabilityai/stablelm-2-1_6b family; unverified]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab_size=50304,
    attn_type="gqa",
    rope_style="standard",
)
