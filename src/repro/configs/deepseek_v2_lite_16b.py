"""deepseek-v2-lite-16b [moe]: MLA attention + fine-grained MoE.

27L d_model=2048 16H d_ff(expert)=1408 vocab=102400; MoE 64 routed experts
top-6 + 2 shared; MLA kv_lora=512 (no q-lora on the lite model);
first layer dense (d_ff=10944).  [arXiv:2405.04434; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=192,          # qk_nope + qk_rope
    d_ff=10944,            # dense first layer
    vocab_size=102400,
    attn_type="mla",
    rope_style="standard",
    q_lora_rank=0,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    # >=6B params: store bf16 (f32 Adam moments retained) so the FSDP
    # all-gather of the scanned weight stack costs half the VMEM/HBM
    param_dtype="bfloat16",
)
