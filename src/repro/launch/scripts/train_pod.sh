#!/usr/bin/env bash
# Per-host training entrypoint for multi-host (pod) deployments.
#
# Run once on every host of the pod (e.g. via `gcloud compute tpus tpu-vm
# ssh --worker=all`).  REPRO_MULTIHOST=1 makes repro.launch.train call
# repro.launch.multihost.initialize_if_needed() before any other jax use,
# which welds the hosts into one runtime from either
#   * the Cloud TPU / GKE metadata (autodetected), or
#   * explicit REPRO_COORD / REPRO_NUM_PROCS / REPRO_PROC_ID env vars.
#
# Example (2-host generic cluster):
#   REPRO_COORD=10.0.0.1:8476 REPRO_NUM_PROCS=2 REPRO_PROC_ID=0 \
#     ./train_pod.sh --arch stablelm-3b --steps 1000 --ckpt-dir /ckpt
set -euo pipefail

cd "$(dirname "$0")/../../../.."

export REPRO_MULTIHOST=1
export PYTHONPATH="${PWD}/src${PYTHONPATH:+:$PYTHONPATH}"

exec python -m repro.launch.train "$@"
