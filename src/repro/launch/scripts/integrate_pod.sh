#!/usr/bin/env bash
# Per-host integration entrypoint: the paper's multi-function workload as
# a fault-tolerant pod job (checkpointed rounds + restart-on-failure).
#
# Same multi-host wiring as train_pod.sh: REPRO_MULTIHOST=1 routes through
# repro.launch.multihost.initialize_if_needed() before jax comes up, so
# `--mesh` sees every chip in the pod.
#
# Example:
#   REPRO_COORD=10.0.0.1:8476 REPRO_NUM_PROCS=2 REPRO_PROC_ID=0 \
#     ./integrate_pod.sh --n-functions 1000 --samples 1000000 \
#       --mesh --use-kernel --ckpt-dir /ckpt
set -euo pipefail

cd "$(dirname "$0")/../../../.."

export REPRO_MULTIHOST=1
export PYTHONPATH="${PWD}/src${PYTHONPATH:+:$PYTHONPATH}"

exec python -m repro.launch.integrate "$@"
