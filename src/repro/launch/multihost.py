"""Multi-host initialisation for real TPU pods.

On a v5e pod each host sees 4 chips; `jax.distributed.initialize` welds the
hosts into one runtime so `jax.devices()` returns all 256 (or 512) chips
and `make_production_mesh()` works unchanged.  This module reads the
standard TPU/GKE environment (or explicit flags) and must be imported
before any other jax usage by the pod entrypoints
(`launch/scripts/*.sh`).

Supported environments:
  * Cloud TPU VMs / GKE: coordinator + process id from the TPU metadata
    (jax.distributed.initialize() with no args autodetects).
  * Generic MPI-ish: REPRO_COORD, REPRO_NUM_PROCS, REPRO_PROC_ID env vars.

Elastic note: on restart with a different number of hosts, initialise with
the new topology and call `repro.distributed.elastic.elastic_restore` —
checkpoints are mesh-independent (full arrays + logical re-derivation).
"""

from __future__ import annotations

import os


def initialize_if_needed(verbose: bool = True) -> bool:
    """Initialise jax.distributed from the environment. Returns True if a
    multi-host runtime was set up, False for single-process runs."""
    import jax

    coord = os.environ.get("REPRO_COORD")
    nprocs = os.environ.get("REPRO_NUM_PROCS")
    pid = os.environ.get("REPRO_PROC_ID")
    try:
        if coord and nprocs and pid:
            jax.distributed.initialize(
                coordinator_address=coord,
                num_processes=int(nprocs),
                process_id=int(pid))
        elif os.environ.get("TPU_WORKER_HOSTNAMES") or \
                os.environ.get("MEGASCALE_COORDINATOR_ADDRESS"):
            jax.distributed.initialize()   # TPU metadata autodetect
        else:
            return False
    except Exception as e:  # single-host fallback keeps dev loops working
        if verbose:
            print(f"[multihost] distributed init skipped: {e}")
        return False
    if verbose:
        print(f"[multihost] process {jax.process_index()}/"
              f"{jax.process_count()}: {jax.local_device_count()} local / "
              f"{jax.device_count()} global devices")
    return True


def host_batch_rows(global_batch: int) -> "slice":
    """The rows of the global batch this host should materialise
    (feeds TokenStream.next_batch(rows=...))."""
    import jax
    per = global_batch // jax.process_count()
    start = jax.process_index() * per
    return slice(start, start + per)
