"""Integration launcher: the paper's workload as a production job.

``python -m repro.launch.integrate`` evaluates a multi-function spec with
checkpointed rounds, the straggler watchdog and restart-on-failure — the
fault-tolerant driver that a cluster deployment would run per pod, with the
mesh handling intra-pod distribution (functions x model, samples x data).
"""

from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro.core import (MultiFunctionSpec, ZMCMultiFunctions,
                        harmonic_analytic, harmonic_family)
from repro.distributed.fault_tolerance import StepWatchdog, run_with_restarts


def main():
    if os.environ.get("REPRO_MULTIHOST"):
        from repro.launch.multihost import initialize_if_needed
        initialize_if_needed()
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-functions", type=int, default=100)
    ap.add_argument("--dim", type=int, default=4)
    ap.add_argument("--samples", type=int, default=10**6)
    ap.add_argument("--trials", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--use-kernel", action="store_true",
                    help="Pallas fused sampler (interpret mode off-TPU)")
    ap.add_argument("--mesh", action="store_true",
                    help="shard over all local devices")
    args = ap.parse_args()

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_mesh_for
        import jax
        n = len(jax.devices())
        mp = 2 if n % 2 == 0 and n > 1 else 1
        mesh = make_mesh_for(model_parallel=mp)

    spec = MultiFunctionSpec.from_families(
        [harmonic_family(args.n_functions, args.dim)])
    zmc = ZMCMultiFunctions(spec, n_samples=args.samples, seed=args.seed,
                            mesh=mesh, use_kernel=args.use_kernel)

    watchdog = StepWatchdog()

    def body(attempt: int):
        means, stds = [], []
        for t in range(args.trials):
            with watchdog:
                r = zmc.evaluate_resumable(rounds=args.rounds,
                                           checkpoint_dir=args.ckpt_dir,
                                           trial=t)
            means.append(r.means[0])
            stds.append(r.stderrs[0])
        return np.stack(means), np.stack(stds)

    t0 = time.time()
    means, stds = run_with_restarts(body, max_restarts=2)
    dt = time.time() - t0

    exact = harmonic_analytic(args.n_functions, args.dim)
    fbar = means.mean(0)
    dfn = means.std(0, ddof=1) if args.trials > 1 else stds.mean(0)
    within = np.abs(fbar - exact) <= 2 * np.maximum(dfn, 1e-12)
    print(f"{args.n_functions} integrands x {args.samples:.0e} samples "
          f"x {args.trials} trials in {dt:.1f}s "
          f"({dt / max(args.trials, 1):.1f}s per trial)")
    print(f"|F_bar - exact| <= 2*dF for {within.sum()}/{len(within)} "
          f"integrands; stragglers: {watchdog.straggler_count}")
    worst = np.argmax(np.abs(fbar - exact) / np.maximum(dfn, 1e-12))
    print(f"worst pull at n={worst + 1}: est {fbar[worst]:+.5f} "
          f"exact {exact[worst]:+.5f} (dF {dfn[worst]:.2e})")


if __name__ == "__main__":
    main()
