"""ShapeDtypeStruct stand-ins for every model input (dry-run pattern).

``input_specs(cfg, shape)`` returns weak-type-correct, shardable abstract
inputs — no device allocation — for the step function that the given shape
lowers (train / prefill / decode).  ``concrete_batch`` materialises small
real batches for smoke tests with the same structure.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.shapes import ShapeSpec
from repro.models.config import ModelConfig

# stub-frontend sizing: fraction of the sequence that is vision tokens
VISION_FRAC = 8  # 1/8 of the sequence


def _batch_struct(cfg: ModelConfig, batch: int, seq: int, *, train: bool):
    i32 = jnp.int32
    cd = cfg.dtype("compute")
    if cfg.family == "encoder":
        d = {"frames": jax.ShapeDtypeStruct((batch, seq, cfg.frontend_dim), cd)}
        if train:
            d["labels"] = jax.ShapeDtypeStruct((batch, seq), i32)
        return d
    if cfg.family == "vlm":
        nv = max(1, seq // VISION_FRAC)
        d = {
            "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
            "vision_embeds": jax.ShapeDtypeStruct((batch, nv, cfg.frontend_dim), cd),
            "positions": jax.ShapeDtypeStruct((3, batch, seq), i32),
        }
        if train:
            d["labels"] = jax.ShapeDtypeStruct((batch, seq), i32)
        return d
    d = {"tokens": jax.ShapeDtypeStruct((batch, seq), i32)}
    if train:
        d["labels"] = jax.ShapeDtypeStruct((batch, seq), i32)
    return d


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Abstract inputs for the step this shape lowers."""
    if shape.kind == "train":
        return _batch_struct(cfg, shape.global_batch, shape.seq_len, train=True)
    if shape.kind == "prefill":
        return _batch_struct(cfg, shape.global_batch, shape.seq_len, train=False)
    if shape.kind == "decode":
        return {
            "tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
    raise ValueError(shape.kind)


def batch_logical_axes(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Logical sharding axes matching :func:`input_specs`."""
    if shape.kind == "decode":
        return {"tokens": ("batch", None), "pos": ()}
    ax: dict = {}
    if cfg.family == "encoder":
        ax["frames"] = ("batch", "seq", "frontend")
    elif cfg.family == "vlm":
        ax["tokens"] = ("batch", "seq")
        ax["vision_embeds"] = ("batch", None, "frontend")
        ax["positions"] = (None, "batch", "seq")
    else:
        ax["tokens"] = ("batch", "seq")
    if shape.kind == "train":
        ax["labels"] = ("batch", "seq")
    return ax


def concrete_batch(cfg: ModelConfig, batch: int, seq: int, *, train: bool,
                   seed: int = 0) -> dict:
    """Small real batch with the input_specs structure (smoke tests)."""
    rng = np.random.default_rng(seed)
    structs = _batch_struct(cfg, batch, seq, train=train)
    out = {}
    for k, sds in structs.items():
        if jnp.issubdtype(sds.dtype, jnp.integer):
            if k == "positions":
                pos = np.broadcast_to(np.arange(seq, dtype=np.int32),
                                      (3, batch, seq)).copy()
                out[k] = jnp.asarray(pos)
            else:
                out[k] = jnp.asarray(
                    rng.integers(0, cfg.vocab_size, sds.shape, dtype=np.int32))
        else:
            out[k] = jnp.asarray(
                rng.standard_normal(sds.shape).astype(np.float32), sds.dtype)
    return out
