"""Training: state construction, the jitted train step, and a CLI driver.

``make_train_step`` builds the full production step:
  microbatched grad accumulation (lax.scan)  ->  global-norm clipping
  ->  optional int8 error-feedback grad compression  ->  AdamW / Adafactor.

The driver (``python -m repro.launch.train --arch ... --steps N``) wires in
the deterministic data pipeline, async checkpointing, the step watchdog and
restart-on-failure — the same loop the multi-pod launch scripts invoke.
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import os
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import TokenStream
from repro.distributed import checkpoint as ckpt
from repro.distributed import compression
from repro.distributed.fault_tolerance import StepWatchdog, run_with_restarts
from repro.distributed.sharding import (logical_sharding, rules_for,
                                        tree_shardings)
from repro.models.config import ModelConfig
from repro.models.model import Model
from repro.optim import make_optimizer, opt_state_specs, warmup_cosine


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    optimizer: str = "adamw"          # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    grad_accum: int = 1
    grad_compression: bool = False    # int8 EF on grads


def default_hparams_for(cfg: ModelConfig, *, global_batch: int = 256,
                        seq_len: int = 4096, data_shards: int = 16) -> TrainHParams:
    """Production defaults sized for the assignment's train_4k shape.

    grad_accum is chosen so the remat-saved per-layer residual stream
    (n_layers x B_loc x S x d bytes, the dominant activation term under
    full remat) stays under ~6 GB/device on the 16x16 mesh; Adafactor
    replaces AdamW where f32 moments cannot fit (deepseek-v3).
    """
    if cfg.name == "deepseek-v3-671b":
        # §Perf iteration 7: sp_activations freed residual memory, so fewer
        # accumulation rounds gather the FSDP weights fewer times per step
        # (the dominant collective). accum=2 overflowed the attention
        # transients (52 GiB/dev); accum=4 is the measured sweet spot.
        return TrainHParams(optimizer="adafactor", grad_accum=4)
    b_loc = max(1, global_batch // data_shards)
    resid = cfg.n_layers * b_loc * seq_len * cfg.d_model * 2  # bf16
    accum = 1
    while resid / accum > 6e9 and accum < 16:
        accum *= 2
    return TrainHParams(grad_accum=accum)


def _split_microbatches(batch: dict, accum: int) -> dict:
    """Reshape every input's batch axis B -> (accum, B/accum)."""
    def one(k, v):
        axis = 1 if k == "positions" else 0
        b = v.shape[axis]
        assert b % accum == 0, (k, b, accum)
        new_shape = (v.shape[:axis] + (accum, b // accum)
                     + v.shape[axis + 1:])
        v = v.reshape(new_shape)
        return jnp.moveaxis(v, axis, 0) if axis != 0 else v
    return {k: one(k, v) for k, v in batch.items()}


def _global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def make_train_state(model: Model, hp: TrainHParams, key):
    optimizer = _make_opt(model.cfg, hp)
    params = model.init(key)
    state = {"params": params, "opt": optimizer.init(params),
             "step": jnp.zeros((), jnp.int32)}
    if hp.grad_compression:
        state["ef_err"] = compression.init_error_tree(params)
    return state


def abstract_train_state(model: Model, hp: TrainHParams):
    return jax.eval_shape(
        lambda: make_train_state(model, hp, jax.random.key(0)))


def train_state_specs(model: Model, hp: TrainHParams):
    """Logical-axes tree matching make_train_state's output."""
    p_specs = model.specs()
    abstract = model.abstract()
    specs: dict[str, Any] = {
        "params": p_specs,
        "opt": opt_state_specs(hp.optimizer, abstract, p_specs),
        "step": (),
    }
    if hp.grad_compression:
        specs["ef_err"] = p_specs
    return specs


def _make_opt(cfg: ModelConfig, hp: TrainHParams):
    sched = warmup_cosine(hp.lr, hp.warmup_steps, hp.total_steps)
    if hp.optimizer == "adamw":
        return make_optimizer("adamw", sched, weight_decay=hp.weight_decay,
                              moment_dtype=cfg.dtype("opt"))
    return make_optimizer("adafactor", sched,
                          weight_decay=hp.weight_decay * 0.0)


def make_train_step(model: Model, hp: TrainHParams):
    """Returns step(state, batch) -> (state, metrics). Jit/lower-ready."""
    optimizer = _make_opt(model.cfg, hp)

    def loss_fn(params, mb):
        return model.loss(params, mb)

    def step(state, batch):
        params = state["params"]
        if hp.grad_accum > 1:
            mbs = _split_microbatches(batch, hp.grad_accum)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                return (g_acc, l_acc + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, p.dtype), params)
            (grads, loss_sum), _ = jax.lax.scan(
                acc_body, (zeros, jnp.float32(0.0)), mbs)
            inv = 1.0 / hp.grad_accum
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss = loss_sum * inv
            metrics = {"loss": loss}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)

        gnorm = _global_norm(grads)
        scale = jnp.minimum(1.0, hp.grad_clip / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                        ).astype(g.dtype), grads)

        new_state = dict(state)
        if hp.grad_compression:
            grads, new_err = compression.compress_tree(grads, state["ef_err"])
            new_state["ef_err"] = new_err

        new_params, new_opt = optimizer.update(grads, state["opt"], params,
                                               state["step"])
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        new_state["step"] = state["step"] + 1
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return new_state, metrics

    return step


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def train_loop(cfg: ModelConfig, hp: TrainHParams, *, batch: int, seq: int,
               steps: int, mesh=None, ckpt_dir: str | None = None,
               ckpt_every: int = 50, seed: int = 0, log_every: int = 10,
               fail_at_step: int | None = None):
    """Run (or resume) a training loop; returns (state, losses, watchdog)."""
    model = Model(cfg)
    step_fn = jax.jit(make_train_step(model, hp))
    stream = TokenStream(cfg, batch, seq, seed=seed)

    start = 0
    state = None
    writer = ckpt.AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    if ckpt_dir is not None:
        latest = ckpt.latest_step(ckpt_dir)
        if latest is not None:
            abstract = abstract_train_state(model, hp)
            shardings = None
            if mesh is not None:
                shardings = tree_shardings(
                    abstract, train_state_specs(model, hp), mesh)
            state, manifest = ckpt.restore(ckpt_dir, latest, abstract,
                                           shardings=shardings)
            start = latest
            stream.restore({"step": manifest["extra"]["data_step"]})
    if state is None:
        state = make_train_state(model, hp, jax.random.key(seed))

    losses = []
    watchdog = StepWatchdog()
    try:
        with logical_sharding(mesh, rules=rules_for(cfg)):
            for i in range(start, steps):
                batch_i = stream.next_batch()
                with watchdog:
                    state, metrics = step_fn(state, batch_i)
                if fail_at_step is not None and i == fail_at_step:
                    raise RuntimeError(f"injected failure at step {i}")
                loss = float(metrics["loss"])
                losses.append(loss)
                if i % log_every == 0:
                    print(f"step {i:5d} loss {loss:.4f} "
                          f"gnorm {float(metrics['grad_norm']):.3f}")
                if writer and (i + 1) % ckpt_every == 0:
                    writer.save(i + 1, state,
                                extra={"data_step": stream.snapshot()["step"]})
    except BaseException:
        # Crash path: drain the async queue so every checkpoint enqueued
        # *before* the failure is durable by the time the exception
        # propagates — otherwise an immediate restart races the writer
        # thread, sees no checkpoint, and silently replays completed steps
        # from scratch.  Writer errors must not mask the original failure.
        if writer:
            try:
                writer.close()
            except Exception:
                pass
        raise
    if writer:
        writer.close()
    return state, losses, watchdog


def main():
    if os.environ.get("REPRO_MULTIHOST"):
        from repro.launch.multihost import initialize_if_needed
        initialize_if_needed()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced (CPU-sized) config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--grad-accum", type=int, default=None)
    args = ap.parse_args()

    from repro.configs import get_config, reduced as reduce_cfg
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    hp = default_hparams_for(cfg)
    if args.optimizer:
        hp = dataclasses.replace(hp, optimizer=args.optimizer)
    if args.grad_accum:
        hp = dataclasses.replace(hp, grad_accum=args.grad_accum)
    hp = dataclasses.replace(hp, total_steps=args.steps,
                             warmup_steps=max(1, args.steps // 10))

    t0 = time.time()
    state, losses, wd = train_loop(cfg, hp, batch=args.batch, seq=args.seq,
                                   steps=args.steps, ckpt_dir=args.ckpt_dir)
    dt = time.time() - t0
    print(f"done: {args.steps} steps in {dt:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
          f"stragglers: {wd.straggler_count}")


if __name__ == "__main__":
    main()
