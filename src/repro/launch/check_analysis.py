"""Invariant-checker launcher: ``python -m repro.launch.check_analysis``.

A thin alias for ``python -m repro.analysis`` so the analysis gate sits
next to the other launchers (``integrate``, ``serve_integrals``, ...).
Same arguments, same exit codes; see :mod:`repro.analysis.__main__`.
"""

from __future__ import annotations

import sys

from repro.analysis.__main__ import main

if __name__ == "__main__":
    sys.exit(main())
