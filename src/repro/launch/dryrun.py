import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each runnable cell this script

  1. builds the production mesh (16x16 single-pod, 2x16x16 multi-pod),
  2. lowers the appropriate step (train_step / prefill / serve_step) with
     ShapeDtypeStruct inputs and NamedShardings derived from the logical
     rules (NO device allocation anywhere),
  3. ``.compile()``s it — a sharding mismatch, an unsupported collective or
     a compile-time OOM is a bug in the framework and fails the run,
  4. records ``memory_analysis()`` / ``cost_analysis()`` plus a parse of
     the optimized HLO's collectives into benchmarks/artifacts/*.json —
     the inputs to the roofline analysis (EXPERIMENTS.md §Roofline).

The paper's own workload rides along as a pseudo-arch: the sharded
multi-function MC engine (10k integrands x 1M samples) is lowered on the
same meshes, proving the integration engine's collective schedule at
production scale.

Usage:
  python -m repro.launch.dryrun --all
  python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k --multi-pod
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ALIASES, all_configs, get_config
from repro.configs.shapes import SHAPES, cell_status
from repro.distributed.sharding import (logical_sharding, rules_for,
                                        tree_shardings)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import batch_logical_axes, input_specs
from repro.launch.train import (abstract_train_state, default_hparams_for,
                                make_train_step, train_state_specs)
from repro.models.model import Model

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}


def _shape_bytes(type_str: str) -> int:
    """bytes of one HLO result type like 'bf16[8,4096,7168]' or a tuple."""
    total = 0
    for m in re.finditer(r"(\w+)\[([0-9,]*)\]", type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum result bytes per collective kind from optimized HLO."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        if line.startswith("ROOT "):
            line = line[5:]
        # "%name = TYPE all-reduce(...)" / all-reduce-start(...)
        m = re.match(r"%?\S+\s*=\s*(\([^)]*\)|\S+)\s+([a-z0-9-]+)", line)
        if not m:
            continue
        op = m.group(2)
        for kind in _COLLECTIVES:
            if op == kind or op == kind + "-start":
                out[kind]["count"] += 1
                out[kind]["bytes"] += _shape_bytes(m.group(1))
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def _memory_stats(compiled) -> dict:
    ma = compiled.memory_analysis()
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    stats = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            stats[k] = int(v)
    return stats


def _cost_stats(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in dict(ca).items()
            if isinstance(v, (int, float))}


def model_flops_estimate(cfg, shape) -> dict:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params."""
    from repro.models.config import count_params
    model = Model(cfg)
    defs = model.param_defs()
    n_total = count_params(defs)
    n_active = n_total
    if cfg.n_experts and cfg.top_k:
        # routed experts: only top_k of n_experts are active per token
        moe_all = count_params(defs["stages"].get("moe_layers", {}))
        # wg/wu/wd dominate; router is negligible
        n_moe_layers = cfg.n_layers - cfg.first_dense_layers
        routed = 3 * cfg.n_experts * cfg.d_model * cfg.moe_d_ff * n_moe_layers
        active_routed = routed * cfg.top_k / cfg.n_experts
        n_active = n_total - routed + active_routed
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        flops = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        flops = 2.0 * n_active * tokens
    return {"n_params": float(n_total), "n_active": float(n_active),
            "tokens": float(tokens), "model_flops": float(flops)}


def lower_cell(arch: str, shape_name: str, mesh):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = Model(cfg)
    hp = default_hparams_for(cfg)

    with logical_sharding(mesh, rules=rules_for(cfg)):
        if shape.kind == "train":
            step = make_train_step(model, hp)
            state_abs = abstract_train_state(model, hp)
            state_sh = tree_shardings(state_abs, train_state_specs(model, hp),
                                      mesh)
            batch_abs = input_specs(cfg, shape)
            batch_sh = tree_shardings(batch_abs,
                                      batch_logical_axes(cfg, shape), mesh)
            lowered = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            ).lower(state_abs, batch_abs)
        elif shape.kind == "prefill":
            params_abs = model.abstract()
            params_sh = tree_shardings(params_abs, model.specs(), mesh)
            batch_abs = input_specs(cfg, shape)
            batch_sh = tree_shardings(batch_abs,
                                      batch_logical_axes(cfg, shape), mesh)
            cache_abs = model.abstract_cache(shape.global_batch, shape.seq_len)
            cache_sh = tree_shardings(
                cache_abs, model.cache_specs(shape.global_batch, shape.seq_len),
                mesh)

            def prefill_step(params, batch):
                return model.prefill(params, batch, seq_cap=shape.seq_len)

            lowered = jax.jit(
                prefill_step,
                in_shardings=(params_sh, batch_sh),
                out_shardings=(None, cache_sh),
            ).lower(params_abs, batch_abs)
        else:  # decode
            params_abs = model.abstract()
            params_sh = tree_shardings(params_abs, model.specs(), mesh)
            cache_abs = model.abstract_cache(shape.global_batch, shape.seq_len)
            cache_sh = tree_shardings(
                cache_abs, model.cache_specs(shape.global_batch, shape.seq_len),
                mesh)
            inp = input_specs(cfg, shape)
            inp_ax = batch_logical_axes(cfg, shape)
            tok_sh = tree_shardings({"tokens": inp["tokens"]},
                                    {"tokens": inp_ax["tokens"]},
                                    mesh)["tokens"]

            def serve_step(params, cache, tokens, pos):
                return model.decode_step(params, cache, tokens, pos)

            lowered = jax.jit(
                serve_step,
                in_shardings=(params_sh, cache_sh, tok_sh, None),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,),
            ).lower(params_abs, cache_abs, inp["tokens"], inp["pos"])
    return lowered, cfg, shape


def lower_zmc(mesh, n_fn: int = 10000, n_samples: int = 1 << 20):
    """The paper's workload on the production mesh (pseudo-arch cell)."""
    from repro.core import harmonic_family
    from repro.core.direct_mc import sharded_family_sums

    fam = harmonic_family(n_fn, 4)
    sample_axes = tuple(a for a in mesh.axis_names if a != "model")

    def run(params, domains):
        import dataclasses as _d
        f = _d.replace(fam, params=params, domains=domains)
        sums, _ = sharded_family_sums(
            f, n_samples, (jnp.uint32(1), jnp.uint32(2)), mesh,
            fn_axis="model", sample_axes=sample_axes, chunk=16384)
        return sums.s1, sums.s2

    from jax.sharding import NamedSharding, PartitionSpec as P
    fn_sh = NamedSharding(mesh, P("model"))
    params_abs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), fam.params)
    dom_abs = jax.ShapeDtypeStruct(fam.domains.shape, fam.domains.dtype)
    params_sh = jax.tree.map(lambda _: fn_sh, params_abs)
    lowered = jax.jit(run, in_shardings=(params_sh, fn_sh),
                      out_shardings=(fn_sh, fn_sh)).lower(params_abs, dom_abs)
    return lowered


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             force: bool = False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    key = f"{arch}__{shape_name}__{mesh_name}".replace(".", "_")
    path = os.path.join(out_dir, key + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 512 if multi_pod else 256
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "n_chips": n_chips, "status": "ok"}
    t0 = time.time()
    try:
        if arch == "zmc_multifunctions":
            lowered = lower_zmc(mesh)
            cfg = shape = None
        else:
            lowered, cfg, shape = lower_cell(arch, shape_name, mesh)
        record["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 1)
        record["memory"] = _memory_stats(compiled)
        record["cost"] = _cost_stats(compiled)
        record["collectives"] = parse_collectives(compiled.as_text())
        if cfg is not None:
            record["model"] = model_flops_estimate(cfg, shape)
        print(compiled.memory_analysis())
    except Exception as e:
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--include-zmc", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    out_dir = args.out or os.path.normpath(
        os.path.join(os.path.dirname(os.path.abspath(__file__)),
                     "..", "..", "..", "benchmarks", "artifacts"))

    cells: list[tuple[str, str]] = []
    if args.all:
        configs = all_configs()
        for alias, mod in ALIASES.items():
            cfg = get_config(alias)
            for sname, sh in SHAPES.items():
                ok, reason = cell_status(cfg, sh)
                if ok:
                    cells.append((alias, sname))
                else:
                    print(f"SKIP {alias} x {sname}: {reason}")
        cells.append(("zmc_multifunctions", "mc_10k_fns"))
    else:
        if args.arch is None:
            ap.error("--arch required unless --all")
        cells.append((args.arch, args.shape or "train_4k"))
        if args.include_zmc:
            cells.append(("zmc_multifunctions", "mc_10k_fns"))

    meshes = [args.multi_pod]
    if args.both_meshes or args.all:
        meshes = [False, True]

    failures = 0
    for multi_pod in meshes:
        for arch, sname in cells:
            rec = run_cell(arch, sname, multi_pod, out_dir, force=args.force)
            status = rec["status"]
            mesh_name = rec["mesh"]
            if status == "ok":
                mem = rec["memory"].get("temp_size_in_bytes", 0) / 2**30
                coll = rec["collectives"]["total_bytes"] / 2**30
                print(f"OK   {arch:24s} {sname:12s} {mesh_name:10s} "
                      f"compile={rec.get('compile_s', 0):7.1f}s "
                      f"temp/dev={mem:7.2f}GiB coll={coll:8.2f}GiB")
            else:
                failures += 1
                print(f"FAIL {arch:24s} {sname:12s} {mesh_name:10s} "
                      f"{rec['error']}")
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")
    print("all dry-run cells passed")


if __name__ == "__main__":
    main()
