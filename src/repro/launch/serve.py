"""Serving: batched prefill + decode driver (vLLM-style decode waves).

``python -m repro.launch.serve --arch stablelm-3b --reduced`` runs a small
end-to-end generation on CPU; on a mesh the same code paths lower to the
decode_32k / long_500k dry-run cells (sharded KV cache, flash-decoding
softmax over the model axis).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import logical_sharding
from repro.launch.specs import concrete_batch
from repro.models.config import ModelConfig
from repro.models.model import Model


class Server:
    """Minimal batched generation engine over Model prefill/decode."""

    def __init__(self, cfg: ModelConfig, params=None, mesh=None, seed: int = 0):
        self.cfg = cfg
        self.model = Model(cfg)
        self.mesh = mesh
        if params is None:
            params = self.model.init(jax.random.key(seed))
        self.params = params
        self._prefill = jax.jit(
            lambda p, b, cap: self.model.prefill(p, b, seq_cap=cap),
            static_argnums=(2,))
        self._decode = jax.jit(self.model.decode_step)

    def generate(self, batch: dict, max_new_tokens: int, seq_cap: int,
                 temperature: float = 0.0, seed: int = 0):
        """Greedy/temperature generation. Returns (B, max_new_tokens)."""
        with logical_sharding(self.mesh):
            logits, cache = self._prefill(self.params, batch, seq_cap)
            prompt_len = (batch["tokens"].shape[1] if "tokens" in batch
                          else batch["frames"].shape[1])
            out = []
            key = jax.random.key(seed)
            tok = self._sample(logits, temperature, key)
            for i in range(max_new_tokens):
                out.append(tok)
                pos = jnp.int32(prompt_len + i)
                logits, cache = self._decode(self.params, cache, tok, pos)
                key, sub = jax.random.split(key)
                tok = self._sample(logits, temperature, sub)
        return jnp.concatenate(out, axis=1)

    @staticmethod
    def _sample(logits, temperature, key):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        probs_logits = logits / temperature
        return jax.random.categorical(key, probs_logits, axis=-1)[:, None] \
            .astype(jnp.int32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    from repro.configs import get_config, reduced as reduce_cfg
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    if cfg.is_encoder:
        raise SystemExit("encoder-only arch has no decode step")

    server = Server(cfg)
    batch = concrete_batch(cfg, args.batch, args.prompt_len, train=False)
    t0 = time.time()
    toks = server.generate(batch, args.new_tokens,
                           seq_cap=args.prompt_len + args.new_tokens,
                           temperature=args.temperature)
    dt = time.time() - t0
    print(f"generated {toks.shape} in {dt:.1f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print(np.asarray(toks)[:, :12])


if __name__ == "__main__":
    main()
