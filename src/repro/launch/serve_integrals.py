"""Integration-as-a-service launcher.

``python -m repro.launch.serve_integrals --requests 64`` stands up the
continuously-batching :class:`~repro.service.engine.IntegrationEngine`,
feeds it a mixed-dimension grid-scan workload (the ZMCintegral-v5 usage
pattern: many clients asking for related parameter sweeps), and reports
throughput, launch counts and cache behavior.  ``--thread`` exercises
the async submit/poll worker; the default drives waves synchronously.

This is the service-layer sibling of ``repro.launch.integrate`` (the
one-shot fault-tolerant job): same kernels, same counters, but requests
arrive over time, dedupe against each other and top up cached streams.

**Wave pipeline**: each wave fuses its rounds into multi-round kernels —
an R-round refinement over B dimension buckets costs B launches instead
of R x B — and with ``--thread`` the worker double-buffers waves
(wave k+1 dispatches while wave k's results transfer, deposit and
group-commit to the WAL; ``--no-pipeline`` serializes them).
``--max-rounds-per-wave`` caps rounds per stream per wave (the fused
kernel's R); ``--max-items-per-wave`` bounds the whole wave, with the
budget assigned round-robin across requests so heavy precision asks
cannot starve small latency-sensitive ones.

**Warm starts**: pass ``--state-dir PATH`` and the engine journals every
round deposit to disk (crash-safe, checksummed) and snapshots on clean
shutdown.  Re-launching against the same state dir — even after a
SIGKILL — resumes every cached stream at its exact ``sample_offset``:
requests the previous process already satisfied are served with zero
kernel launches, partially-met ones only pay for the missing rounds, and
all results are bit-identical to an uninterrupted run.  ``--state-dir``
pins the seed and round size (stored in ``meta.json``); reopening with
different values is refused.  ``--compact-on-start`` folds the replayed
journal into one npz snapshot before serving:

    python -m repro.launch.serve_integrals --requests 64 --state-dir /tmp/zmc
    # ... kill -9 it, then:
    python -m repro.launch.serve_integrals --requests 64 --state-dir /tmp/zmc \\
        --compact-on-start      # -> 64 pure cache hits, 0 launches

**Telemetry** (:mod:`repro.obs`): ``--trace-out trace.json`` records a
span per wave-pipeline stage (plan / launch / device_execute / transfer
/ deposit / wal_commit) in Chrome-trace format — open the file directly
in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.
``--jax-trace`` additionally wraps spans in ``jax.profiler``
annotations so they land in XLA profiler timelines.  ``--metrics-port
P`` serves Prometheus text at ``http://127.0.0.1:P/metrics`` (plus
``/metrics.json`` and ``/convergence``) while the workload runs;
``--metrics-json PATH`` writes a final metrics + convergence snapshot
on exit.  Any telemetry flag also turns on per-stream convergence
accounting: the run reports each stream's stderr-vs-rounds trajectory,
queryable afterwards via ``engine.stderr_trajectory(stream_id)``.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.obs import clock as _clock

from repro.core import abs_sum_family, gaussian_family, harmonic_family
from repro.core import genz
from repro.service.api import IntegrationRequest, SweepRequest


def demo_workload(n_requests: int, *, n_fn: int = 8,
                  n_samples: int | None = 16384,
                  target_stderr: float | None = None,
                  duplicate_every: int = 4,
                  sweeps: int = 0) -> list:
    """A mixed-dimension request stream with deliberate overlap.

    Cycles through the registered forms at dims 2-4 (so batching has
    buckets to fuse) and re-issues every ``duplicate_every``-th request
    verbatim, modeling distinct clients scanning overlapping grids — the
    canonicalizer must dedupe those into shared cache entries.  The mix
    includes infinite-domain Gaussians (over R^d and the positive
    orthant): compactified families ride the same fused buckets, cache
    streams and persistence digests as finite ones.

    With ``sweeps=k``, appends ``k`` sweep requests
    (:class:`SweepRequest`) — each a
    harmonic template scanned over a deterministic 2-D (a, b) grid, the
    grids overlapping pairwise along the slowest axis — so persistence
    and restart drills cover sweep cache streams too (``SweepResult``
    exposes the same ``means``/``served_from_cache`` surface the drills
    digest).
    """
    reqs: list = []
    makers = [
        lambda i: harmonic_family(n_fn, 2 + i % 3),
        lambda i: abs_sum_family(n_fn, 2 + i % 3,
                                 np.linspace(0.5, 2.0, n_fn), ),
        lambda i: gaussian_family(n_fn, 2 + i % 3),
        lambda i: genz.oscillatory(n_fn, 2 + i % 3, seed=i % 5)[0],
        lambda i: genz.corner_peak(n_fn, 2 + i % 3, seed=i % 5)[0],
        lambda i: gaussian_family(n_fn, 2 + i % 3, lo=-np.inf, hi=np.inf),
        lambda i: gaussian_family(n_fn, 2 + i % 3, lo=0.0, hi=np.inf),
    ]
    for i in range(n_requests):
        if duplicate_every and i % duplicate_every == duplicate_every - 1:
            # verbatim re-ask of an earlier request (different client)
            src = reqs[i // 2]
            fams = src.families
        else:
            fams = (makers[i % len(makers)](i),)
        reqs.append(IntegrationRequest.make(
            fams, n_samples=n_samples, target_stderr=target_stderr))
    for j in range(sweeps):
        # consecutive sweeps extend the slowest-varying axis, so their
        # canonical slice prefixes align and dedupe at the cache
        grid = {"a": np.linspace(0.5, 2.0, 4 + 2 * j),
                "b": np.linspace(-1.0, 1.0, 8)}
        reqs.append(SweepRequest.make(
            harmonic_family(1, 2 + j % 3), grid,
            n_samples=n_samples, target_stderr=target_stderr))
    return reqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--n-fn", type=int, default=8,
                    help="functions per requested family")
    ap.add_argument("--samples", type=int, default=16384)
    ap.add_argument("--target-stderr", type=float, default=None,
                    help="serve to precision instead of a fixed budget")
    ap.add_argument("--round-samples", type=int, default=8192)
    ap.add_argument("--max-rounds-per-wave", type=int, default=8,
                    help="rounds per stream per wave — the R of each "
                         "fused multi-round launch")
    ap.add_argument("--max-items-per-wave", type=int, default=None,
                    help="total round budget per wave, assigned "
                         "round-robin across pending requests (fairness "
                         "under load); default unbounded")
    ap.add_argument("--no-pipeline", action="store_true",
                    help="serialize waves instead of double-buffering "
                         "dispatch against host deposits (--thread mode)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-kernel", action="store_true",
                    help="chunked JAX path instead of fused Pallas")
    ap.add_argument("--mesh", action="store_true",
                    help="shard over all local devices")
    ap.add_argument("--thread", action="store_true",
                    help="run the async worker thread (submit/poll mode)")
    ap.add_argument("--state-dir", default=None,
                    help="persist the cache here (journal + snapshots); "
                         "re-launching against it warm-starts every stream")
    ap.add_argument("--compact-on-start", action="store_true",
                    help="fold the replayed journal into one npz snapshot "
                         "before serving")
    ap.add_argument("--audit-state", action="store_true",
                    help="audit --state-dir against the determinism "
                         "invariants (repro.analysis Layer 3) and exit; "
                         "serves nothing")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto span timeline of "
                         "every wave-pipeline stage here")
    ap.add_argument("--jax-trace", action="store_true",
                    help="wrap pipeline spans in jax.profiler annotations "
                         "(visible in XLA profiler timelines)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve Prometheus metrics on this port while the "
                         "workload runs (/metrics, /metrics.json, "
                         "/convergence); 0 picks a free port")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write a final metrics + convergence snapshot "
                         "here on exit")
    args = ap.parse_args()

    if args.audit_state:
        if not args.state_dir:
            ap.error("--audit-state requires --state-dir")
        from repro.analysis import render
        from repro.analysis.streams import audit_state_dir
        report = audit_state_dir(args.state_dir)
        if report.violations:
            print(render(report.violations))
        print(report.summary())
        raise SystemExit(0 if report.ok else 1)

    from repro.kernels import template
    from repro.service import IntegrationEngine

    mesh = None
    if args.mesh:
        import jax
        from repro.launch.mesh import make_mesh_for
        n = len(jax.devices())
        mp = 2 if n % 2 == 0 and n > 1 else 1
        mesh = make_mesh_for(model_parallel=mp)

    telemetry = (args.trace_out is not None or args.jax_trace
                 or args.metrics_port is not None
                 or args.metrics_json is not None)
    obs = None
    metrics_server = None
    if telemetry:
        from repro.obs import Observability
        obs = Observability.enabled(trace_path=args.trace_out,
                                    jax_annotations=args.jax_trace)
        if args.metrics_port is not None:
            from repro.obs.export import MetricsServer
            metrics_server = MetricsServer(obs.metrics,
                                           port=args.metrics_port,
                                           convergence=obs.convergence)
            print(f"metrics: http://127.0.0.1:{metrics_server.port}/metrics")

    engine = IntegrationEngine(
        seed=args.seed, round_samples=args.round_samples,
        use_kernel=not args.no_kernel, mesh=mesh,
        max_rounds_per_wave=args.max_rounds_per_wave,
        max_items_per_wave=args.max_items_per_wave,
        pipeline_waves=not args.no_pipeline,
        state_dir=args.state_dir, compact_on_start=args.compact_on_start,
        obs=obs)
    if engine.cache.recovered is not None:
        rec = engine.cache.recovered
        print(f"warm start: {len(rec.entries)} persisted streams "
              f"({rec.journal_records} journal records replayed, "
              f"{rec.truncated_bytes} corrupt tail bytes truncated)")
    reqs = demo_workload(
        args.requests, n_fn=args.n_fn,
        n_samples=None if args.target_stderr else args.samples,
        target_stderr=args.target_stderr)

    template.reset_launch_count()
    t0 = _clock.monotonic()
    if args.thread:
        engine.start()
        tickets = [engine.submit(r) for r in reqs]
        results = [engine.result(t, timeout=600.0) for t in tickets]
        engine.stop()
    else:
        tickets = [engine.submit(r) for r in reqs]
        while engine.step():
            pass
        results = [engine.poll(t) for t in tickets]
    dt = _clock.monotonic() - t0
    launches = template.launch_count()

    n_fn_total = sum(r.n_fn_total for r in results)
    hits = sum(r.served_from_cache for r in results)
    print(f"served {len(results)} requests ({n_fn_total} integrands) "
          f"in {dt:.1f}s -> {len(results) / dt:.1f} req/s, "
          f"{launches} kernel launches "
          f"({engine.batcher.fallback_rounds} chunked fallback rounds), "
          f"{hits} pure cache hits")
    print(f"engine: {engine.stats}")
    print(f"cache:  {engine.cache.stats()}")
    print(f"stragglers: {engine.watchdog.straggler_count}")
    worst = max(float(r.stderrs.max()) for r in results)
    print(f"worst stderr served: {worst:.3e}")
    engine.close()   # snapshot-on-shutdown when --state-dir is set
    if args.state_dir:
        print(f"state snapshotted to {args.state_dir} "
              f"(journal compacted to {engine.store.journal_size()} bytes)")

    if obs is not None:
        streams = obs.convergence.streams()
        if streams:
            print(f"convergence: {len(streams)} streams tracked; "
                  "final stderr per stream:")
            for sid in streams:
                traj = obs.convergence.trajectory(sid)
                last = traj[-1]
                print(f"  {sid[:16]}  rounds={last.rounds_done:4d} "
                      f"n={last.n:9d}  stderr_max={last.stderr_max:.3e}")
        if args.metrics_json:
            from repro.obs.export import write_snapshot
            write_snapshot(args.metrics_json, obs.metrics,
                           convergence=obs.convergence)
            print(f"metrics snapshot written to {args.metrics_json}")
        if metrics_server is not None:
            metrics_server.close()
        obs.close()
        if args.trace_out:
            print(f"trace written to {args.trace_out} "
                  "(open in https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
