"""Mesh construction for single-pod / multi-pod execution.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, and nothing here may run earlier.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """The assignment's production mesh: 16x16 per pod, 2 pods multi-pod.

    When more devices exist than the mesh needs (the dry-run forces 512
    host devices; single-pod uses 256), the first prod(shape) devices are
    used — matching how a per-pod launch sees only its pod's chips.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devices)} "
            "(the dry-run must set XLA_FLAGS before any jax import)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_mesh_for(n_devices: int | None = None, model_parallel: int = 1,
                  pods: int = 1) -> Mesh:
    """Elastic variant: build a (pod, data, model) mesh from whatever devices
    are available (used by tests and the elastic-resume path)."""
    n = n_devices if n_devices is not None else len(jax.devices())
    if n % (model_parallel * pods):
        raise ValueError(f"{n} devices not divisible by "
                         f"model={model_parallel} x pods={pods}")
    data = n // (model_parallel * pods)
    if pods > 1:
        return jax.make_mesh((pods, data, model_parallel),
                             ("pod", "data", "model"))
    return jax.make_mesh((data, model_parallel), ("data", "model"))


def mesh_info(mesh: Mesh) -> dict:
    return {
        "axis_names": tuple(mesh.axis_names),
        "shape": dict(mesh.shape),
        "n_devices": int(np.prod(list(mesh.shape.values()))),
    }
