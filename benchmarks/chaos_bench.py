"""Chaos certification: the fault-matrix sweep behind the resilience
claims (doubles as the CI gate via ``--smoke``).

One workload, one seed, many injected failures.  A fault-free reference
run pins the result digest; then every scenario in the matrix re-runs
the identical workload with one :class:`~repro.service.FaultPlan`
armed — a crash at each of the six pipeline stages, a failed WAL fsync,
a torn journal write, a lost accelerator at dispatch, a NaN-poisoned
transfer, a worker-thread death, and a stale-lease takeover — and must
produce

* the **bit-identical digest**: counter-addressed rounds make every
  retried/salvaged wave recompute exactly what was lost, so chaos is
  invisible in the estimates;
* a **clean Layer-3 audit** (``repro.analysis.streams``): the state dir
  the faulted run leaves behind passes the same determinism audit CI
  runs on post-SIGKILL dirs (STR001-006);
* **exact telemetry agreement**: ``zmc_faults_injected_total`` equals
  the plan's fired-trigger count, ``zmc_retries_total`` summed over
  stages equals ``EngineStats.restarts``, and
  ``zmc_quarantined_streams_total`` equals the cache's quarantine list.

Two scenarios gate *graceful degradation* rather than transparency:

* ``quarantine`` — a stream poisoned three waves running must complete
  its ticket as ``RequestFailed(reason="quarantined")`` while a healthy
  sibling request in the same batch still serves bit-identically;
* ``deadline`` — a request with a microscopic deadline budget must
  complete as ``RequestFailed(reason="deadline")`` within a bounded
  wall-clock multiple of the budget: failure is a *result*, never a
  hung ticket.

Wall-clock numbers are incidental here; the certification is the
digest/audit/agreement triple per scenario, written as ``BENCH_9.json``.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import shutil
import sys
import tempfile
import time

import numpy as np

from repro.core import gaussian_family, harmonic_family
from repro.service import (FaultPlan, IntegrationEngine, IntegrationRequest,
                           RequestFailed, RetryPolicy)
from repro.service.resilience import DeadlineExceeded, RetryExhausted
from repro.service.store import DurableStore

# a retried wave should not serialize the bench on real backoff sleeps
FAST_RETRY = RetryPolicy(max_attempts=3, base_delay=0.001, max_delay=0.01)


def _workload(n_fn: int, rounds: int, round_samples: int):
    return [
        IntegrationRequest.make([harmonic_family(n_fn, 2)],
                                n_samples=rounds * round_samples),
        IntegrationRequest.make([gaussian_family(n_fn, 3)],
                                n_samples=rounds * round_samples),
        IntegrationRequest.make([harmonic_family(n_fn, 4)],
                                n_samples=rounds * round_samples),
    ]


def _drive(engine, tickets, max_steps=500):
    """Step-drive to completion; permanent failures complete tickets
    (they surface to the sync driver too — swallow and keep going)."""
    for _ in range(max_steps):
        if all(engine.poll(t) is not None for t in tickets):
            return [engine.poll(t) for t in tickets]
        try:
            engine.step()
        except (RetryExhausted, DeadlineExceeded):
            continue
    raise AssertionError("workload did not complete (hung ticket?)")


def _digest(results) -> str:
    h = hashlib.sha256()
    for res in results:
        assert not res.failed, f"unexpected failure: {res}"
        h.update(np.asarray(res.means).astype("<f4").tobytes())
        h.update(np.asarray(res.stderrs).astype("<f4").tobytes())
    return h.hexdigest()


def _audit(state_dir: str) -> str:
    from repro.analysis.streams import audit_state_dir
    report = audit_state_dir(state_dir)
    assert report.ok, (f"state dir {state_dir} failed the determinism "
                       f"audit after chaos: {report.summary()}")
    return report.summary()


def _agreement(engine, plan) -> dict:
    """The exact counter-vs-observable contracts, asserted."""
    m = engine.obs.m
    injected = sum(m["faults_injected"].value(stage=p)
                   for p in dict.fromkeys(p for p, _ in plan.fired))
    assert injected == len(plan.fired), \
        f"faults_injected {injected} != fired {len(plan.fired)}"
    retries = sum(m["retries"].value(stage=s)
                  for s in ("wave", "launch", "deposit"))
    assert retries == engine.stats.restarts, \
        f"sum(retries) {retries} != stats.restarts {engine.stats.restarts}"
    quarantined = m["quarantined_streams"].value()
    assert quarantined == len(engine.cache.quarantined_streams()), \
        "quarantine counter disagrees with the cache"
    return {"faults_injected": injected, "retries": retries,
            "restarts": engine.stats.restarts,
            "quarantined": quarantined}


def _run_scenario(name, plan, *, workdir, reqs, round_samples, seed,
                  use_worker=False, stale_lease=False):
    state = os.path.join(workdir, f"state_{name}")
    if stale_lease:
        # a crashed previous holder: unexpired leases from dead pids and
        # expired leases are both taken over; model the expired case
        os.makedirs(state, exist_ok=True)
        with open(os.path.join(state, DurableStore.LEASE), "w",
                  encoding="utf-8") as f:
            json.dump({"token": "crashed-writer", "pid": 1,
                       "acquired": time.time() - 7200,
                       "expires": time.time() - 3600}, f)
    eng = IntegrationEngine(seed=seed, round_samples=round_samples,
                            max_rounds_per_wave=2, state_dir=state,
                            retry_policy=FAST_RETRY, faults=plan)
    t0 = time.time()
    tickets = [eng.submit(r) for r in reqs]
    if use_worker:
        eng.start()
        eng._worker.join(timeout=120.0)
        assert not eng.running, "worker_crash fault never fired"
    results = _drive(eng, tickets)
    dt = time.time() - t0
    digest = _digest(results)
    agreement = _agreement(eng, plan)
    assert plan.exhausted, \
        f"{name}: not every configured trigger fired: {plan.spec()}"
    if stale_lease:
        with open(os.path.join(state, DurableStore.LEASE),
                  encoding="utf-8") as f:
            assert json.load(f)["pid"] == os.getpid(), "lease not taken over"
    eng.stop()
    audit = _audit(state)
    return {"fault_plan": plan.spec(), "fired": sorted(plan.fired),
            "digest": digest, "restarts": eng.stats.restarts,
            "agreement": agreement, "audit": audit,
            "wall_seconds": round(dt, 3)}


def _quarantine_scenario(workdir, *, n_fn, round_samples, seed):
    """A poisoned stream fails alone; its healthy sibling still serves."""
    plan = FaultPlan({"transfer_nan": [0, 1, 2, 3, 4]})
    state = os.path.join(workdir, "state_quarantine")
    eng = IntegrationEngine(seed=seed, round_samples=round_samples,
                            state_dir=state, retry_policy=FAST_RETRY,
                            faults=plan)
    poisoned = eng.submit(IntegrationRequest.make(
        [harmonic_family(n_fn, 2)], n_samples=round_samples))
    healthy = eng.submit(IntegrationRequest.make(
        [gaussian_family(n_fn, 3)], n_samples=round_samples))
    res_p, res_h = _drive(eng, [poisoned, healthy])
    assert isinstance(res_p, RequestFailed) and res_p.reason == "quarantined"
    assert not res_h.failed and np.isfinite(res_h.means).all()
    agreement = _agreement(eng, plan)
    assert agreement["quarantined"] == 1
    eng.stop()
    return {"fault_plan": plan.spec(), "failed_reason": res_p.reason,
            "healthy_sibling_served": True, "agreement": agreement,
            "audit": _audit(state)}


def _deadline_scenario(workdir, *, n_fn, round_samples, seed):
    """A doomed deadline completes as a failure, never a hung ticket."""
    eng = IntegrationEngine(seed=seed, round_samples=round_samples,
                            max_rounds_per_wave=1, retry_policy=FAST_RETRY)
    req = IntegrationRequest.make([harmonic_family(n_fn, 2)],
                                  n_samples=8 * round_samples,
                                  deadline=0.001)
    t0 = time.time()
    res = _drive(eng, [eng.submit(req)])[0]
    dt = time.time() - t0
    assert isinstance(res, RequestFailed) and res.reason == "deadline"
    assert eng.stats.deadline_expirations >= 1
    # "no ticket hangs past its deadline": completion is bounded by the
    # in-flight wave it had to finish, not by the remaining budget
    assert dt < 60.0, f"deadline failure took {dt:.1f}s to surface"
    return {"failed_reason": res.reason, "wall_seconds": round(dt, 3),
            "deadline_expirations": eng.stats.deadline_expirations}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-fn", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=3,
                    help="rounds per request (waves = rounds / 2)")
    ap.add_argument("--round-samples", type=int, default=4096)
    ap.add_argument("--seed", type=int, default=11)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + assert every gate (the CI mode)")
    ap.add_argument("--json-out", default=None,
                    help="write the certification record (BENCH_9.json)")
    args = ap.parse_args()
    if args.smoke:
        args.n_fn, args.rounds, args.round_samples = 4, 3, 2048

    workdir = tempfile.mkdtemp(prefix="chaos_bench_")
    reqs = _workload(args.n_fn, args.rounds, args.round_samples)
    run = dict(workdir=workdir, reqs=reqs, seed=args.seed,
               round_samples=args.round_samples)

    # the fault-free reference pins the digest every scenario must hit
    baseline = _run_scenario("baseline", FaultPlan({}), **run)
    print(f"baseline digest {baseline['digest'][:16]}...")

    # 3 streams journal 3 alloc records before the first wave commit;
    # WAL triggers index past them so the fault lands on deposit frames
    matrix = {
        "stage_plan": FaultPlan({"plan": 0}),
        "stage_launch": FaultPlan({"launch": 0}),
        "stage_device_execute": FaultPlan({"device_execute": 0}),
        "stage_transfer": FaultPlan({"transfer": 1}),
        "stage_deposit": FaultPlan({"deposit": 0}),
        "stage_wal_commit": FaultPlan({"wal_commit": 3}),
        "wal_fsync": FaultPlan({"wal_fsync": 3}),
        "wal_torn_write": FaultPlan({"wal_torn_write": 3}),
        "device_error": FaultPlan({"device_error": 0}),
        "transfer_nan_transient": FaultPlan({"transfer_nan": 0}),
    }
    scenarios = {"baseline": baseline}
    for name, plan in matrix.items():
        scenarios[name] = _run_scenario(name, plan, **run)
        ok = scenarios[name]["digest"] == baseline["digest"]
        print(f"{name:24s} restarts={scenarios[name]['restarts']} "
              f"digest {'==' if ok else '!='} baseline")
        assert ok, f"{name}: digest diverged from the fault-free run"

    scenarios["worker_crash"] = _run_scenario(
        "worker_crash", FaultPlan({"worker_crash": 0}), use_worker=True,
        **run)
    assert scenarios["worker_crash"]["digest"] == baseline["digest"], \
        "worker_crash: step()-salvaged digest diverged"
    print("worker_crash             salvaged by step(), digest == baseline")

    scenarios["lease_takeover"] = _run_scenario(
        "lease_takeover", FaultPlan({}), stale_lease=True, **run)
    assert scenarios["lease_takeover"]["digest"] == baseline["digest"]
    print("lease_takeover           stale lease reclaimed, digest == baseline")

    scenarios["quarantine"] = _quarantine_scenario(
        workdir, n_fn=args.n_fn, round_samples=args.round_samples,
        seed=args.seed)
    print("quarantine               poisoned stream failed alone")

    scenarios["deadline"] = _deadline_scenario(
        workdir, n_fn=args.n_fn, round_samples=args.round_samples,
        seed=args.seed)
    print(f"deadline                 failed structured in "
          f"{scenarios['deadline']['wall_seconds']}s")

    payload = {"bench": "chaos", "seed": args.seed,
               "round_samples": args.round_samples,
               "rounds": args.rounds, "n_fn": args.n_fn,
               "scenarios": scenarios}
    if args.json_out:
        with open(args.json_out, "w", encoding="utf-8") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {args.json_out}")
    shutil.rmtree(workdir, ignore_errors=True)
    print(f"chaos certification PASSED: {len(scenarios) - 1} fault "
          f"scenarios, all digests bit-identical, all audits clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
