"""Linear multi-device scaling of multi-function integration (paper claim).

On this 1-core container wall-clock cannot demonstrate scaling, so the
claim is verified STRUCTURALLY, the same way the dry-run proves the LM
cells: for device counts {1, 4, 16, 64, 256} the sharded MC program is
lowered and its per-device sample count, per-device FLOPs and collective
bytes are extracted.  Linear scaling == per-device compute ~ 1/P with
collective bytes independent of N (only O(n_fn) for the final psum), which
is exactly what the table shows.
"""

from __future__ import annotations

import json
import subprocess
import sys
import os

PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(n)d"
import sys, json
sys.path.insert(0, %(src)r)
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import harmonic_family
from repro.core.direct_mc import sharded_family_sums

n_dev = %(n)d
model = 4 if n_dev >= 16 else 1   # keep a function-sharding axis at scale
data = n_dev // model
mesh = jax.make_mesh((data, model), ("data", "model"))
fam = harmonic_family(64, 4)
N = 1 << 20

def run(params, domains):
    import dataclasses
    f = dataclasses.replace(fam, params=params, domains=domains)
    s, _ = sharded_family_sums(f, N, (jnp.uint32(1), jnp.uint32(2)), mesh,
                               sample_axes=("data",), chunk=16384)
    return s.s1, s.s2

fn_sh = NamedSharding(mesh, P("model"))
p_abs = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                     fam.params)
lowered = jax.jit(run, in_shardings=(jax.tree.map(lambda _: fn_sh, p_abs),
                                     fn_sh),
                  out_shardings=(fn_sh, fn_sh)).lower(
    p_abs, jax.ShapeDtypeStruct(fam.domains.shape, fam.domains.dtype))
compiled = lowered.compile()
ca = compiled.cost_analysis()
if isinstance(ca, (list, tuple)):
    ca = ca[0]
import re
coll_bytes = 0
for line in compiled.as_text().splitlines():
    line = line.strip()
    if line.startswith("ROOT "):
        line = line[5:]
    m = re.match(r"\s*%%?\S+\s*=\s*((?:\([^)]*\)|\S+))\s+([a-z0-9-]+)", line)
    if m and m.group(2).startswith(("all-reduce", "all-gather",
                                    "reduce-scatter", "all-to-all")):
        for t in re.finditer(r"(\w+)\[([0-9,]*)\]", m.group(1)):
            dims = [int(d) for d in t.group(2).split(",") if d]
            import numpy as np
            coll_bytes += int(np.prod(dims)) * 4
print(json.dumps({
    "devices": n_dev,
    "samples_per_device": N // data,
    "flops_per_device": float(ca.get("flops", -1)),
    "collective_bytes": coll_bytes,
}))
"""

SRC = os.path.normpath(os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                    "..", "src"))


def run_scaling(device_counts=(1, 4, 16, 64, 256)) -> list[dict]:
    rows = []
    for n in device_counts:
        code = PROG % {"n": n, "src": SRC}
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, timeout=600,
                             env=env)
        if out.returncode != 0:
            raise RuntimeError(out.stderr[-2000:])
        rows.append(json.loads(out.stdout.strip().splitlines()[-1]))
    return rows


def main():
    rows = run_scaling()
    print("devices, samples/dev, flops/dev(hlo), collective_bytes")
    base = rows[0]
    for r in rows:
        speedup = base["samples_per_device"] / r["samples_per_device"]
        print(f"{r['devices']:7d}, {r['samples_per_device']:11d}, "
              f"{r['flops_per_device']:.3e}, {r['collective_bytes']:9d}  "
              f"(work/dev 1/{speedup:.0f})")
    print("# per-device work scales 1/P; collective bytes stay O(n_fn) -> "
          "linear scaling, the paper's multi-GPU claim, as a compile-time "
          "property")


if __name__ == "__main__":
    main()
