"""Genz accuracy benchmark: MC vs randomised-Sobol across all six families.

Extends the paper's single harmonic validation to the standard cubature
test suite (Genz 1984): per family, the RMS relative error over n random
instances at equal sample budget, plus the RQMC gain factor.  This is the
accuracy-per-flop side of the §Perf story — a TPU pod running the fused
RQMC kernel gets BOTH the hardware scaling and these gains.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core import ZMCMultiFunctions
from repro.core import genz


def run(samples: int = 65536, n: int = 8, dim: int = 4, trials: int = 4,
        seed: int = 0) -> list[dict]:
    rows = []
    for name, ctor in genz.ALL.items():
        d = min(dim, 3) if name == "corner_peak" else dim  # 2^d inc-exc
        fam, exact = ctor(n, d)
        out = {"family": name, "dim": d}
        for sampler in ("mc", "sobol"):
            z = ZMCMultiFunctions([fam], n_samples=samples, seed=seed,
                                  sampler=sampler)
            r = z.evaluate(num_trials=trials)
            rel = np.abs(r.trial_mean - exact) / np.maximum(np.abs(exact),
                                                            1e-12)
            out[f"rms_rel_{sampler}"] = float(np.sqrt((rel ** 2).mean()))
            out[f"stderr_{sampler}"] = float(np.median(r.trial_std))
        out["rqmc_gain"] = out["stderr_mc"] / max(out["stderr_sobol"], 1e-15)
        rows.append(out)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=65536)
    ap.add_argument("--n", type=int, default=8)
    ap.add_argument("--dim", type=int, default=4)
    args = ap.parse_args()
    rows = run(samples=args.samples, n=args.n, dim=args.dim)
    print(f"# Genz suite, N={args.samples}, {args.n} instances/family")
    print(f"{'family':14s} {'rms_rel MC':>11s} {'rms_rel RQMC':>13s} "
          f"{'stderr gain':>12s}")
    for r in rows:
        print(f"{r['family']:14s} {r['rms_rel_mc']:11.2e} "
              f"{r['rms_rel_sobol']:13.2e} {r['rqmc_gain']:12.1f}x")


if __name__ == "__main__":
    main()
