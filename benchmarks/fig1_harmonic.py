"""Paper Fig. 1 reproduction: F_n = Int a_n cos(k_n.x) + b_n sin(k_n.x).

n = 1..100, x in [0,1]^4, k_n = ((n+50)/2pi)(1,1,1,1), 10 independent
trials.  The paper uses 10^6 samples per integrand (~1 min/trial on a
V100); the default here is 10^5 on CPU — pass ``--full`` for the exact
paper protocol.  Output: per-n (F_bar, dF) vs the closed form, the
coverage fraction |F_bar - exact| <= 2 dF, and a timing row.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import (ZMCMultiFunctions, harmonic_analytic,
                        harmonic_family)


def run(n_fns=100, dim=4, samples=10**5, trials=10, seed=0,
        use_kernel=False, verbose=True):
    fam = harmonic_family(n_fns, dim)
    z = ZMCMultiFunctions([fam], n_samples=samples, seed=seed,
                          use_kernel=use_kernel)
    t0 = time.time()
    r = z.evaluate(num_trials=trials)
    dt = time.time() - t0
    exact = harmonic_analytic(n_fns, dim)
    fbar, dfn = r.trial_mean, np.maximum(r.trial_std, 1e-12)
    cover2 = float((np.abs(fbar - exact) <= 2 * dfn).mean())
    cover3 = float((np.abs(fbar - exact) <= 3 * dfn).mean())
    if verbose:
        print(f"# Fig.1: {n_fns} integrands, dim={dim}, N={samples:.0e}, "
              f"{trials} trials, kernel={use_kernel}")
        print(f"coverage |F-exact|<=2dF: {cover2:.2f}   <=3dF: {cover3:.2f} "
              f"(expect ~0.95 / ~0.997)")
        print(f"wall: {dt:.1f}s total, {dt/trials:.2f}s per trial "
              f"(paper: ~60 s/trial at N=1e6 on V100)")
        print("n, F_bar, dF, exact")
        for i in range(0, n_fns, max(1, n_fns // 10)):
            print(f"{i+1:3d}, {fbar[i]:+.6f}, {dfn[i]:.2e}, {exact[i]:+.6f}")
    return {"coverage_2sigma": cover2, "coverage_3sigma": cover3,
            "seconds_per_trial": dt / trials, "n_fns": n_fns,
            "samples": samples}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper protocol: 1e6 samples x 10 trials")
    ap.add_argument("--samples", type=int, default=None)
    ap.add_argument("--trials", type=int, default=None)
    ap.add_argument("--use-kernel", action="store_true")
    args = ap.parse_args()
    samples = args.samples or (10**6 if args.full else 10**5)
    trials = args.trials or 10
    run(samples=samples, trials=trials, use_kernel=args.use_kernel)


if __name__ == "__main__":
    main()
