"""MC kernel microbenchmark + VMEM/block-shape table.

On CPU the Pallas kernel runs in interpret mode (Python-level, orders of
magnitude slower than compiled XLA) so wall-clock here compares the
pure-JAX engine against itself at different chunkings, and the kernel's
TPU characteristics are reported analytically: VMEM footprint and
arithmetic intensity per (F_BLK, S_BLK) tile choice — the §Perf block-shape
sweep. The kernel/oracle equivalence is asserted by the test suite.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import family_sums, harmonic_family
from repro.core import rng as rng_lib

THREEFRY_FLOPS = 110          # u32 ops per 32-bit draw (20 rounds)
EVAL_FLOPS = 20               # affine + fma + cos/sin amortised


def vmem_table():
    print("# mc_eval block-shape table (per grid instance, dim=4)")
    print("F_BLK, S_BLK, vmem_KiB, flop_per_byte_out")
    for f_blk in (8, 16, 32):
        for s_rows in (8, 16, 32):
            s_blk = s_rows * 128
            tiles = 6 * s_blk * 4                   # live u32/f32 tiles
            params = f_blk * (2 + 3 * 4) * 4
            out = f_blk * 2 * 4
            vmem = (tiles + params + out) / 1024
            flops = f_blk * 4 * (THREEFRY_FLOPS + EVAL_FLOPS) * s_blk
            print(f"{f_blk:5d}, {s_blk:5d}, {vmem:8.1f}, "
                  f"{flops / max(out, 1):10.0f}")


def engine_bench():
    fam = harmonic_family(100, 4)
    key = rng_lib.fold_key(0, 0)
    print("name,us_per_call,derived")
    for chunk in (4096, 16384, 65536):
        family_sums(fam, 200_000, key, chunk=chunk).s1.block_until_ready()
        t0 = time.time()
        family_sums(fam, 200_000, key, chunk=chunk).s1.block_until_ready()
        dt = time.time() - t0
        rate = 100 * 200_000 / dt
        print(f"engine_chunk{chunk},{dt*1e6:.0f},{rate:.3e} samples/s")


def main():
    vmem_table()
    engine_bench()


if __name__ == "__main__":
    main()
