"""MC kernel microbenchmark: fused multi-family dispatch + block-shape table.

Three sections:

* ``fused_bench`` — the tentpole demonstration: a heterogeneous,
  multi-dimension ``MultiFunctionSpec`` (mixed harmonic / |sum| / gaussian
  forms; ``--fig1`` sizes it to the paper's 10^3-integrand Fig.-1
  workload) evaluated three ways: fused multi-family kernels (one
  pallas_call per dim bucket), the per-family kernel loop (one pallas_call
  per family), and the chunked pure-JAX engine.  Asserts the estimates
  agree within MC tolerance and reports the launch counts — the fused path
  must launch strictly fewer kernels than the per-family loop.

* ``vmem_table`` — the kernel's TPU characteristics reported analytically
  (VMEM footprint and arithmetic intensity per (F_BLK, S_BLK) tile choice;
  the §Perf block-shape sweep).

* ``engine_bench`` — pure-JAX engine at different chunkings.

On CPU the Pallas kernels run in interpret mode (Python-level, orders of
magnitude slower than compiled XLA) so kernel wall-clock here is not
meaningful; launch counts and estimate agreement are.  The kernel/oracle
equivalence is asserted by the test suite.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import (MultiFunctionSpec, ZMCMultiFunctions, abs_sum_family,
                        family_sums, gaussian_family, harmonic_family)
from repro.core import rng as rng_lib
from repro.kernels import template
from repro.kernels.mc_eval import multi

THREEFRY_FLOPS = 110          # u32 ops per 32-bit draw (20 rounds)
EVAL_FLOPS = 20               # affine + fma + cos/sin amortised


def _spec(fig1: bool) -> MultiFunctionSpec:
    if fig1:
        # Fig.-1 scale: 10^3 integrands across three dims and three forms.
        fams = [
            harmonic_family(500, 4),                       # the paper's Eq. (1)
            harmonic_family(200, 2),
            abs_sum_family(49, 2, np.ones(49)),            # Eq. (2), n < 50
            abs_sum_family(151, 3, np.ones(151), sign_last=-1.0),
            gaussian_family(100, 4),
        ]
    else:
        fams = [
            harmonic_family(40, 4),
            harmonic_family(24, 2),
            abs_sum_family(17, 2, np.linspace(0.5, 2.0, 17)),
            abs_sum_family(10, 3, np.ones(10), sign_last=-1.0),
            gaussian_family(12, 4),
        ]
    return MultiFunctionSpec.from_families(fams)


def fused_bench(fig1: bool = False, n_samples: int | None = None):
    spec = _spec(fig1)
    n_samples = n_samples or 2 * template.S_BLK
    n_fn = spec.n_fn_total
    print(f"# fused multi-family dispatch: {n_fn} integrands, "
          f"{len(spec.families)} families, dims "
          f"{sorted({f.dim for f in spec.families})}, N={n_samples}")

    plan = multi.plan_spec(spec)
    key = rng_lib.fold_key(0, 0)

    # 1) fused: one launch per (dim, sampler) bucket for the whole spec
    template.reset_launch_count()
    t0 = time.time()
    zk = ZMCMultiFunctions(spec, n_samples=n_samples, seed=0, use_kernel=True)
    rk = zk.evaluate(num_trials=1)
    dt_fused = time.time() - t0
    fused_launches = template.launch_count()

    # 2) per-family kernel loop (what _trial_sums did before fusion)
    template.reset_launch_count()
    t0 = time.time()
    loop_means = []
    for fam, off in zip(spec.families, spec.offsets()):
        from repro.core import finalize
        sums = family_sums(fam, n_samples, key, fn_offset=off,
                           use_kernel=True)
        loop_means.append(np.asarray(finalize(fam, sums).mean))
    dt_loop = time.time() - t0
    loop_launches = template.launch_count()
    loop_means = np.concatenate(loop_means)

    # 3) chunked pure-JAX engine (reference)
    zj = ZMCMultiFunctions(spec, n_samples=n_samples, seed=0,
                           use_kernel=False)
    rj = zj.evaluate(num_trials=1)

    # same Threefry counters everywhere -> agreement far inside MC stderr
    tol = 3.0 * np.maximum(rj.stderrs[0], 1e-6)
    diff = np.abs(rk.means[0] - rj.means[0])
    assert np.all(diff <= tol), (diff.max(), tol.min())
    assert fused_launches < loop_launches, (fused_launches, loop_launches)

    print("path,kernel_launches,seconds,max|mean-engine|")
    print(f"fused_buckets,{fused_launches},{dt_fused:.2f},{diff.max():.2e}")
    print(f"per_family_loop,{loop_launches},{dt_loop:.2f},"
          f"{np.abs(loop_means - rj.means[0]).max():.2e}")
    print(f"-> {loop_launches} family launches fused into "
          f"{fused_launches} bucket launches "
          f"({len(plan.unfused)} families unfusable)")


def vmem_table():
    print("# mc_eval block-shape table (per grid instance, dim=4)")
    print("F_BLK, S_BLK, vmem_KiB, flop_per_byte_out")
    for f_blk in (8, 16, 32):
        for s_rows in (8, 16, 32):
            s_blk = s_rows * 128
            tiles = 6 * s_blk * 4                   # live u32/f32 tiles
            params = f_blk * (2 + 3 * 4) * 4
            out = f_blk * 2 * 4
            vmem = (tiles + params + out) / 1024
            flops = f_blk * 4 * (THREEFRY_FLOPS + EVAL_FLOPS) * s_blk
            print(f"{f_blk:5d}, {s_blk:5d}, {vmem:8.1f}, "
                  f"{flops / max(out, 1):10.0f}")


def engine_bench():
    fam = harmonic_family(100, 4)
    key = rng_lib.fold_key(0, 0)
    print("name,us_per_call,derived")
    for chunk in (4096, 16384, 65536):
        family_sums(fam, 200_000, key, chunk=chunk).s1.block_until_ready()
        t0 = time.time()
        family_sums(fam, 200_000, key, chunk=chunk).s1.block_until_ready()
        dt = time.time() - t0
        rate = 100 * 200_000 / dt
        print(f"engine_chunk{chunk},{dt*1e6:.0f},{rate:.3e} samples/s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fig1", action="store_true",
                    help="size the fused bench to the paper's 10^3-integrand "
                         "Fig.-1 workload (slow under interpret mode)")
    ap.add_argument("--skip-engine", action="store_true")
    args = ap.parse_args()
    fused_bench(fig1=args.fig1)
    vmem_table()
    if not args.skip_engine:
        engine_bench()


if __name__ == "__main__":
    main()
