"""Roofline analysis: three terms per (arch x shape x mesh) cell.

    compute    = FLOPs            / (chips * 197 TFLOP/s bf16)
    memory     = HBM bytes        / (chips * 819 GB/s)
    collective = collective bytes / (chips * 50 GB/s ICI)

Sources.  ``cost_analysis()`` on the XLA:CPU backend does NOT multiply
``while``-loop trip counts (layer scans, grad-accum scans count once), so
raw HLO numbers underestimate looped programs; we therefore derive the
terms **analytically** from the model/shape/parallelism math below and use
the dry-run artifacts two ways: (a) the parsed collective mix as a
structural check that exactly the expected collectives were compiled, and
(b) raw cost/memory numbers for the scan-free graphs (decode steps), where
they are trustworthy.  Every formula is stated next to its code.

Run:  PYTHONPATH=src python -m benchmarks.roofline [--mesh pod16x16]
reads benchmarks/artifacts/*.json, writes benchmarks/artifacts/roofline.md
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12          # bf16 / chip (v5e)
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / chip (per the assignment's constant)

ART_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "artifacts")


def _cfg(arch: str):
    from repro.configs import get_config
    return get_config(arch)


def _hp(cfg):
    from repro.launch.train import default_hparams_for
    return default_hparams_for(cfg)


def _param_bytes(cfg, n_params: float) -> float:
    return n_params * (2 if cfg.param_dtype == "bfloat16" else 4)


def analytic_terms(arch: str, shape_name: str, n_chips: int,
                   model: dict) -> dict:
    """The three roofline terms (seconds) for one cell."""
    from repro.configs.shapes import SHAPES
    cfg = _cfg(arch)
    shape = SHAPES[shape_name]
    n_act = model["n_active"]
    n_tot = model["n_params"]
    tokens = model["tokens"]
    p_bytes = _param_bytes(cfg, n_tot)
    pods = 2 if n_chips == 512 else 1
    small_dp = getattr(cfg, "sharding_profile", "default") == "small_dp"
    if small_dp:
        # §Perf iteration 8: batch over (data x model), weights replicated
        data, tp = 256, 1
        dp = data  # pod axis idle for batch 256 on the 512-chip mesh
    else:
        data, tp = 16, 16
        dp = pods * data

    # ---- attention FLOPs (full-attention archs; SSD counted via d_inner) --
    hd_qk = cfg.qk_nope_dim + cfg.qk_rope_dim if cfg.attn_type == "mla" \
        else cfg.head_dim
    hd_v = cfg.v_head_dim if cfg.attn_type == "mla" else cfg.head_dim
    n_attn_layers = cfg.n_layers if cfg.family != "hybrid" else \
        cfg.n_layers // max(cfg.shared_attn_every, 1)
    if cfg.family == "ssm":
        n_attn_layers = 0
    s = shape.seq_len
    b = shape.global_batch
    causal = 0.5 if (cfg.causal and not cfg.is_encoder) else 1.0

    if shape.kind == "train":
        weight_flops = 6.0 * n_act * tokens
        attn_flops = (6.0 * b * s * s * cfg.n_heads * (hd_qk + hd_v)
                      * causal * n_attn_layers)
        bwd_mult = 3.0
    elif shape.kind == "prefill":
        weight_flops = 2.0 * n_act * tokens
        attn_flops = (2.0 * b * s * s * cfg.n_heads * (hd_qk + hd_v)
                      * causal * n_attn_layers)
        bwd_mult = 1.0
    else:  # decode: 1 token vs s-long cache
        weight_flops = 2.0 * n_act * b
        attn_flops = 2.0 * b * s * cfg.n_heads * (hd_qk + hd_v) * n_attn_layers
        bwd_mult = 1.0
    total_flops = weight_flops + attn_flops
    compute = total_flops / (n_chips * PEAK_FLOPS)

    # ---- HBM bytes per chip --------------------------------------------------
    hp = _hp(cfg)
    accum = hp.grad_accum if shape.kind == "train" else 1
    c_bytes = 2  # bf16 compute
    if shape.kind == "train":
        # weights: fwd read + bwd read per microbatch; grads + opt update once
        w_traffic = 2 * accum * p_bytes / (dp * tp) * dp  # per chip shard*AG
        # ^ each chip reads its (1/(dp*tp)) shard and receives the gathered
        #   remainder via ICI (counted under collectives); HBM side sees the
        #   full gathered weights streamed per microbatch:
        w_traffic = 2 * accum * p_bytes / tp
        opt_bytes_per_chip = (4 * 4 if hp.optimizer == "adamw" else 6) \
            * n_tot / (dp * tp)
        act_saves = (cfg.n_layers * (b / dp) * s * cfg.d_model * c_bytes
                     / (tp if cfg.sp_activations else 1))
        act_traffic = 3 * act_saves  # write + 2 reads (remat fwd + bwd)
        scores = 0.0
        if n_attn_layers:
            blocal = max(b / dp / accum, 1)
            scores = (4 * n_attn_layers * accum * blocal * cfg.n_heads / tp
                      * s * s * causal * 4)  # f32 score read+write fwd+bwd
        hbm = w_traffic + opt_bytes_per_chip + act_traffic + scores
    elif shape.kind == "prefill":
        w_traffic = p_bytes / tp
        act_traffic = (cfg.n_layers * (b / dp) * s * cfg.d_model * c_bytes)
        cache_w = 2 * (b / dp) * s * _cache_row_bytes(cfg)
        hbm = w_traffic + act_traffic + cache_w
    else:  # decode: weights + full cache read per token
        w_traffic = p_bytes / tp
        cache_r = (b / dp) * s * _cache_row_bytes(cfg) / \
            (tp if cfg.family in ("dense", "vlm", "moe", "encoder") else 1)
        if cfg.family == "ssm":
            cache_r = (b / dp) * cfg.n_layers * cfg.ssm_heads \
                * cfg.ssm_head_dim * cfg.ssm_state * 4
        hbm = w_traffic + cache_r
    memory = hbm / HBM_BW

    # ---- collective bytes per chip --------------------------------------------
    if shape.kind == "train":
        # TP: 2 AR of (b_mb_local, s, d) per layer per microbatch (fwd),
        # x2 for bwd; ring AR moves ~2x payload
        b_mb_local = max(b / dp / accum, 1)
        tp_bytes = (2 * 2 * 2 * cfg.n_layers * accum
                    * b_mb_local * s * cfg.d_model * c_bytes)
        if cfg.sp_activations:
            tp_bytes /= 2   # AG+RS instead of 2xAR halves the volume
        if small_dp:
            tp_bytes = 0.0  # no tensor parallelism at all
        # FSDP weight AG per microbatch + DP grad AR (ring, 2x)
        fsdp_bytes = accum * p_bytes / tp if not small_dp else 0.0
        grad_bytes = n_tot * (2 if cfg.param_dtype == "bfloat16" else 4)
        dp_bytes = 2 * grad_bytes / tp
        moe_bytes = 0.0
        if cfg.n_experts:
            moe_layers = cfg.n_layers - cfg.first_dense_layers
            t_local = b / dp * s / tp  # tokens per EP shard
            moe_bytes = (2 * 2 * moe_layers * t_local * cfg.top_k
                         * cfg.d_model * c_bytes)  # a2a there+back, fwd+bwd
        coll = tp_bytes + fsdp_bytes + dp_bytes + moe_bytes
    elif shape.kind == "prefill":
        tp_bytes = (2 * 2 * cfg.n_layers * (b / dp) * s * cfg.d_model
                    * c_bytes)
        coll = tp_bytes + p_bytes / tp
    else:
        # decode: per layer, psum of (b_local, d) + LSE merge scalars
        tp_bytes = 2 * 2 * cfg.n_layers * (b / dp) * cfg.d_model * c_bytes
        coll = tp_bytes
    collective = coll / ICI_BW  # per-chip bytes over per-chip ICI BW

    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])[0]
    model_flops = model["model_flops"]
    return {
        "compute_s": compute, "memory_s": memory, "collective_s": collective,
        "dominant": dominant,
        "analytic_flops": total_flops,
        "model_flops": model_flops,
        "useful_ratio": model_flops / total_flops,
        "roofline_fraction": compute / max(compute, memory, collective),
    }


# ---- MC integration kernels (the service's fused multi-round buckets) -----
#
# One fused launch of a (dim, sampler) bucket evaluates ``rounds`` rounds
# x ``round_samples`` samples x ``n_fn`` functions in a single
# ``pallas_call``.  Per (sample, function):
#
#   draws   = dim counter-based uniforms  (threefry2x32: ~36 flop/draw,
#             the standard estimate for 20 rounds of add/xor/rotate)
#   eval    = ~8 flop/dim for a registered-form body (poly/trig/exp mix)
#   accum   = 4 flop (s1 += v, s2 += v*v)
#
# and the only HBM traffic is the operand read + (s1, s2) f32 deposit
# per (round, fn) — samples never round-trip (drawn in registers/VMEM),
# which is why the fused path is compute-bound at any realistic shape.

MC_RNG_FLOPS_PER_DRAW = 36.0
MC_EVAL_FLOPS_PER_DIM = 8.0
MC_ACCUM_FLOPS = 4.0


def mc_kernel_terms(*, dim: int, n_fn: int, rounds: int,
                    round_samples: int, n_chips: int = 1,
                    param_bytes: float = 0.0) -> dict:
    """Analytic roofline terms (seconds) for one fused MC bucket launch."""
    evals = float(rounds) * round_samples * n_fn
    draws = float(rounds) * round_samples * dim  # draws shared across fns
    flops = (draws * MC_RNG_FLOPS_PER_DRAW
             + evals * (MC_EVAL_FLOPS_PER_DIM * dim + MC_ACCUM_FLOPS))
    # operands in, (s1, s2) per (round, fn) out, all f32
    hbm = param_bytes + 2.0 * 4.0 * rounds * n_fn
    compute = flops / (n_chips * PEAK_FLOPS)
    memory = hbm / (n_chips * HBM_BW)
    return {
        "dim": dim, "n_fn": n_fn, "rounds": rounds,
        "round_samples": round_samples,
        "flops": flops, "hbm_bytes": hbm,
        "compute_s": compute, "memory_s": memory,
        "dominant": "compute" if compute >= memory else "memory",
        "intensity": flops / max(hbm, 1.0),   # flop/byte
    }


def mc_bucket_table(buckets: list[dict]) -> list[dict]:
    """Analytic terms for each measured (dim, sampler) bucket.

    ``buckets`` rows need dim / n_fn / rounds / round_samples (e.g. from
    the ``zmc_fused_bucket_rounds_total`` metric labels plus the bench
    shape); each comes back with the analytic columns merged in, for
    embedding alongside measured per-stage timings in bench JSON.
    """
    out = []
    for b in buckets:
        terms = mc_kernel_terms(
            dim=int(b["dim"]), n_fn=int(b["n_fn"]),
            rounds=int(b["rounds"]), round_samples=int(b["round_samples"]),
            n_chips=int(b.get("n_chips", 1)),
            param_bytes=float(b.get("param_bytes", 0.0)))
        row = dict(b)
        row.update(terms)
        out.append(row)
    return out


def _cache_row_bytes(cfg) -> float:
    """Decode-cache bytes per token per sequence (all layers)."""
    if cfg.attn_type == "mla":
        per = cfg.kv_lora_rank + cfg.qk_rope_dim
    elif cfg.family == "ssm":
        per = 0
    else:
        per = 2 * cfg.n_kv_heads * cfg.head_dim
    n_attn = cfg.n_layers
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // max(cfg.shared_attn_every, 1)
    return per * n_attn * 2  # bf16


def fix_hint(arch: str, shape: str, dom: str) -> str:
    hints = {
        "compute": "compute-bound: raise MXU utilisation (fusion, larger "
                   "microbatch, bf16 scores) - already the roofline regime",
        "memory": "memory-bound: shard/shrink the dominant resident "
                  "(weights via FSDP axis, cache via cache_seq, activations "
                  "via sp_activations) or raise arithmetic intensity "
                  "(bigger decode batch)",
        "collective": "collective-bound: cut TP volume (sp_activations "
                      "AG/RS, fewer psums via fused projections) or overlap "
                      "(async collectives along scan)",
    }
    return hints[dom]


def build_table(mesh_filter: str | None = None) -> str:
    rows = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok" or "model" not in rec:
            continue
        if mesh_filter and rec["mesh"] != mesh_filter:
            continue
        terms = analytic_terms(rec["arch"], rec["shape"], rec["n_chips"],
                               rec["model"])
        rows.append((rec, terms))

    lines = [
        "| arch | shape | mesh | compute(s) | memory(s) | collective(s) | "
        "dominant | MODEL/HLO flops | roofline frac | HLO collectives "
        "(struct.) |",
        "|---|---|---|---|---|---|---|---|---|---|"[:-4] + "|",
    ]
    for rec, t in rows:
        coll = rec["collectives"]
        mix = ",".join(f"{k.split('-')[0][:2]}{v['count']}"
                       for k, v in coll.items()
                       if isinstance(v, dict) and v["count"])
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
            f"| {t['compute_s']:.3e} | {t['memory_s']:.3e} "
            f"| {t['collective_s']:.3e} | **{t['dominant']}** "
            f"| {t['useful_ratio']:.2f} | {t['roofline_fraction']:.2f} "
            f"| {mix} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--out", default=os.path.join(ART_DIR, "roofline.md"))
    args = ap.parse_args()
    table = build_table(args.mesh)
    print(table)
    with open(args.out, "w") as f:
        f.write(table + "\n")


if __name__ == "__main__":
    main()
