# One function per paper table/claim. Prints ``name,us_per_call,derived``
# CSV rows plus section headers; `python -m benchmarks.run --fast` trims
# sample counts for CI.
from __future__ import annotations

import argparse
import sys
import time


def table_fig1(fast: bool) -> None:
    """Paper Fig. 1: 100 harmonic integrands, band vs analytic."""
    from benchmarks.fig1_harmonic import run
    r = run(samples=20_000 if fast else 10**5,
            trials=4 if fast else 10, verbose=False)
    print(f"fig1_coverage_2sigma,{r['seconds_per_trial']*1e6:.0f},"
          f"{r['coverage_2sigma']:.3f}")
    print(f"fig1_coverage_3sigma,{r['seconds_per_trial']*1e6:.0f},"
          f"{r['coverage_3sigma']:.3f}")


def table_multifunction_throughput(fast: bool) -> None:
    """Paper claim: 10^3 integrands (<5 dim) in <10 min on one V100."""
    from benchmarks.throughput import bench
    n = 200 if fast else 1000
    r = bench(n, 20_000 if fast else 50_000)
    print(f"throughput_{n}fns,{r['seconds']*1e6:.0f},"
          f"{r['samples_per_s']:.3e} samples/s; "
          f"v5e projection {r['v5e_projection_s']:.2f}s")


def table_eq2_heterogeneous(fast: bool) -> None:
    """Paper Eq. (2): mixed-dim families in one evaluation."""
    import numpy as np
    from repro.core import (MultiFunctionSpec, ZMCMultiFunctions,
                            abs_sum_family)
    spec = MultiFunctionSpec.from_families([
        abs_sum_family(49, 2, np.ones(49)),
        abs_sum_family(51, 3, np.ones(51), sign_last=-1.0),
    ])
    z = ZMCMultiFunctions(spec, n_samples=20_000 if fast else 100_000, seed=0)
    t0 = time.time()
    r = z.evaluate(num_trials=2)
    dt = time.time() - t0
    # dim-2 family: exact integral == 1 for every n
    err2 = float(np.abs(r.trial_mean[:49] - 1.0).max())
    print(f"eq2_mixed_dims,{dt*1e6:.0f},max_err_dim2={err2:.4f}")


def table_tree_search(fast: bool) -> None:
    """ZMCintegral_normal: adaptive refinement beats flat stratification."""
    import jax.numpy as jnp
    from repro.core import ZMCNormal
    f = lambda x: jnp.exp(-60.0 * jnp.sum(jnp.square(x - 0.85), axis=-1))
    flat = ZMCNormal(f, [[0, 1]] * 3, seed=1, splits_per_dim=4,
                     n_per_stratum=256, depth=0, k_split=16)
    deep = ZMCNormal(f, [[0, 1]] * 3, seed=1, splits_per_dim=4,
                     n_per_stratum=256, depth=8, k_split=16)
    t0 = time.time()
    r_flat = flat.evaluate(num_trials=2)
    r_deep = deep.evaluate(num_trials=2)
    dt = time.time() - t0
    gain = r_flat.stderr / max(r_deep.stderr, 1e-12)
    print(f"tree_search_stderr_gain,{dt*1e6:.0f},{gain:.2f}x")


def table_genz(fast: bool) -> None:
    """Beyond-paper: MC vs RQMC across the Genz cubature suite."""
    from benchmarks.genz_accuracy import run
    rows = run(samples=8192 if fast else 32768, n=4 if fast else 8,
               trials=3 if fast else 4)
    for r in rows:
        print(f"genz_{r['family']},0,rms_mc={r['rms_rel_mc']:.2e} "
              f"rms_rqmc={r['rms_rel_sobol']:.2e} "
              f"gain={r['rqmc_gain']:.0f}x")


def table_kernel(fast: bool) -> None:
    from benchmarks.kernel_bench import engine_bench, vmem_table
    vmem_table()
    engine_bench()


def table_roofline(fast: bool) -> None:
    """Aggregate the dry-run artifacts into the roofline table."""
    import glob
    import os
    from benchmarks.roofline import ART_DIR, build_table
    if not glob.glob(os.path.join(ART_DIR, "*.json")):
        print("roofline,0,SKIPPED (run `python -m repro.launch.dryrun --all`"
              " first)")
        return
    table = build_table()
    n_rows = len(table.splitlines()) - 2
    out = os.path.join(ART_DIR, "roofline.md")
    with open(out, "w") as f:
        f.write(table + "\n")
    print(f"roofline_cells,0,{n_rows} rows -> {out}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced sample counts (CI sizing)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    tables = {
        "fig1": table_fig1,
        "throughput": table_multifunction_throughput,
        "eq2": table_eq2_heterogeneous,
        "tree_search": table_tree_search,
        "genz": table_genz,
        "kernel": table_kernel,
        "roofline": table_roofline,
    }
    print("name,us_per_call,derived")
    for name, fn in tables.items():
        if args.only and name != args.only:
            continue
        print(f"# --- {name} ---")
        try:
            fn(args.fast)
        except Exception as e:  # keep the harness going; fail at exit
            print(f"{name},0,ERROR {type(e).__name__}: {e}")
            main.failed = True
    if getattr(main, "failed", False):
        sys.exit(1)


if __name__ == "__main__":
    main()
