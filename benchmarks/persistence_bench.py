"""Persistence benchmark: SIGKILL the engine mid-stream, restart, measure.

The durable store's promise is that process death costs *replay only*,
never recomputation: a request satisfied before the kill is served after
restart with zero kernel launches and a bit-identical result, and a
partially-met request tops up from its persisted ``sample_offset``
paying only for the missing rounds.  This benchmark proves both with a
real ``SIGKILL`` — no atexit hooks, no clean shutdown — and doubles as
the CI regression gate via ``--smoke``:

* **warm replay** — a child process serves the full request batch
  against a state dir and is SIGKILLed while still alive (the journal
  is its only legacy; the snapshot compactor never ran).  A second
  child replays the identical batch: asserts **0 launches** and a
  byte-identical result digest;

* **mid-stream kill** — a child is SIGKILLed after a single wave of a
  multi-round workload.  The restarted child finishes the job: asserts
  the digest matches an uninterrupted single-process reference run
  bit-for-bit, with strictly fewer launches than that reference (only
  the missing rounds are paid for).

The workload (``demo_workload``) includes infinite-domain Gaussian
requests, so the digest-equality assertions also pin the compactified
fused-kernel path across process death: an integral over R^d served
before the SIGKILL replays and tops up bit-identically, exactly like a
finite-box one.  It also includes parameter-sweep requests (two
overlapping 2-D grids): sweep cache streams are keyed per canonical
grid slice, so the same warm-replay / mid-kill-resume assertions prove
that a SIGKILLed sweep restarts from its persisted slice streams with
zero recomputation and bit-identical per-point results.

After each kill — before any restart can repair what it reads — the
parent runs the Layer-3 determinism auditor (``repro.analysis.streams``)
over the state dir and requires it clean: disjoint counter ranges,
gap-free deposit rounds, a single round quantum, no orphans.  A torn
tail record is expected post-SIGKILL and is reported, not flagged.

``--json-out`` writes the measurements (including the audit summaries)
as ``BENCH_persistence.json`` so CI can archive the perf trajectory per
commit.

Wall-clock numbers matter on real accelerators; on CPU the kernels run
interpreted and only launch counts + digests are meaningful.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import signal
import subprocess
import sys
import tempfile
import time

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")


# -- child: one engine process against a state dir ---------------------------

def child_main(args) -> int:
    import numpy as np  # noqa: F401  (jax import below pulls it anyway)

    from repro.kernels import template
    from repro.launch.serve_integrals import demo_workload
    from repro.service import IntegrationEngine

    engine = IntegrationEngine(
        seed=args.seed, round_samples=args.round_samples,
        max_rounds_per_wave=args.max_rounds_per_wave,
        state_dir=args.state_dir, compact_on_start=args.compact_on_start)
    reqs = demo_workload(args.requests, n_fn=args.n_fn,
                         n_samples=args.samples, sweeps=args.sweeps)

    template.reset_launch_count()
    t0 = time.time()
    tickets = [engine.submit(r) for r in reqs]

    if args.waves >= 0:
        # serve exactly N waves, then hang so the parent can SIGKILL us
        # mid-stream — the pending requests stay partially met
        for _ in range(args.waves):
            engine.step()
        print("KILLME", flush=True)
        time.sleep(600)
        return 1     # unreachable when the parent does its job

    while engine.step():
        pass
    dt = time.time() - t0
    results = [engine.poll(t) for t in tickets]
    assert all(r is not None for r in results), "unserved requests"

    digest = hashlib.sha256()
    for res in results:
        digest.update(res.means.astype("<f4").tobytes())
        digest.update(res.stderrs.astype("<f4").tobytes())
    print("DIGEST " + json.dumps({
        "digest": digest.hexdigest(),
        "launches": template.launch_count(),
        "served": len(results),
        "from_cache": sum(r.served_from_cache for r in results),
        "seconds": round(dt, 3),
    }), flush=True)

    if args.linger:
        # stay alive *without* shutting down: the parent's SIGKILL models
        # a crash where snapshot-on-shutdown never ran (journal-only)
        print("KILLME", flush=True)
        time.sleep(600)
        return 1
    engine.close()
    return 0


# -- parent: orchestrate children, deliver SIGKILLs ---------------------------

def _audit(state_dir: str, label: str) -> dict:
    """Run the Layer-3 determinism auditor (read-only) over a state dir.

    Called on the exact bytes a SIGKILL left behind — before any restart
    touches them — so a violation here means the WAL protocol itself is
    broken, not that recovery papered over it.  A torn tail record is
    expected after a kill and is reported, not flagged.
    """
    if _SRC not in sys.path:
        sys.path.insert(0, _SRC)
    from repro.analysis.streams import audit_state_dir
    from repro.analysis.violations import render

    report = audit_state_dir(state_dir)
    if report.violations:
        print(render(report.violations))
    assert report.ok, f"{label}: state dir failed the determinism audit"
    print(f"audit {label}: {report.summary()}")
    return {"ok": True, "streams": report.streams,
            "journal_records": report.journal_records,
            "deposits_folded": report.deposits_folded,
            "deposits_replayed": report.deposits_replayed,
            "truncated_tail_bytes": report.truncated_tail_bytes}


def _run_child(state_dir: str, cfg, *, waves: int = -1, linger: bool = False,
               compact_on_start: bool = False) -> dict | None:
    """Run one engine process; SIGKILL it when it prints KILLME.

    Returns the child's DIGEST payload, or None for a mid-stream kill
    (no digest was reached).
    """
    env = os.environ.copy()
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    cmd = [sys.executable, os.path.abspath(__file__), "--child",
           "--state-dir", state_dir,
           "--requests", str(cfg.requests), "--n-fn", str(cfg.n_fn),
           "--samples", str(cfg.samples),
           "--round-samples", str(cfg.round_samples),
           "--max-rounds-per-wave", str(cfg.max_rounds_per_wave),
           "--seed", str(cfg.seed), "--waves", str(waves),
           "--sweeps", str(cfg.sweeps)]
    if linger:
        cmd.append("--linger")
    if compact_on_start:
        cmd.append("--compact-on-start")

    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE, text=True, env=env)
    digest = None
    killed = False
    try:
        for line in proc.stdout:
            line = line.strip()
            if line.startswith("DIGEST "):
                digest = json.loads(line[len("DIGEST "):])
            elif line == "KILLME":
                os.kill(proc.pid, signal.SIGKILL)
                killed = True
                break
    finally:
        proc.stdout.close()
        proc.wait()
    if not killed and proc.returncode != 0:
        raise RuntimeError(f"child exited with {proc.returncode}")
    if not killed and (waves >= 0 or linger):
        raise RuntimeError("child was supposed to be killed but exited")
    return digest


def run(cfg) -> int:
    print(f"# {cfg.requests} requests, budget {cfg.samples} samples in "
          f"rounds of {cfg.round_samples} "
          f"({cfg.samples // cfg.round_samples} rounds/stream)")
    report: dict = {"bench": "persistence", "requests": cfg.requests,
                    "samples": cfg.samples,
                    "round_samples": cfg.round_samples, "phases": {}}

    with tempfile.TemporaryDirectory(prefix="zmc-persist-") as root:
        # -- phase 1: cold serve, then SIGKILL before any clean shutdown
        state_a = os.path.join(root, "warm")
        cold = _run_child(state_a, cfg, linger=True)
        print(f"cold:         {cold['launches']} launches, "
              f"{cold['seconds']}s  (then SIGKILLed, journal-only state)")
        audits = {"journal_only_post_sigkill":
                  _audit(state_a, "journal-only post-SIGKILL")}

        # -- phase 2: restart against the journal -> zero launches
        warm = _run_child(state_a, cfg)
        print(f"warm restart: {warm['launches']} launches, "
              f"{warm['from_cache']}/{warm['served']} pure cache hits, "
              f"{warm['seconds']}s")
        assert warm["launches"] == 0, \
            f"warm replay launched kernels: {warm['launches']}"
        assert warm["from_cache"] == warm["served"], warm
        assert warm["digest"] == cold["digest"], \
            "restarted results differ from the pre-kill results"

        # -- phase 3: SIGKILL mid-stream (after one wave of a
        # multi-round budget), restart, finish -> only delta rounds paid
        state_b = os.path.join(root, "midkill")
        _run_child(state_b, cfg, waves=1)
        audits["midwave_post_sigkill"] = \
            _audit(state_b, "mid-wave post-SIGKILL")
        resumed = _run_child(state_b, cfg)
        state_c = os.path.join(root, "reference")
        reference = _run_child(state_c, cfg)
        print(f"mid-kill resume: {resumed['launches']} launches vs "
              f"{reference['launches']} uninterrupted, "
              f"{resumed['seconds']}s vs {reference['seconds']}s")
        assert resumed["digest"] == reference["digest"], \
            "resumed stream is not bit-identical to the uninterrupted run"
        assert 0 < resumed["launches"] < reference["launches"], \
            (resumed["launches"], reference["launches"])

        audits["midkill_post_resume"] = _audit(state_b, "post-resume")
        report["phases"] = {"cold": cold, "warm_restart": warm,
                            "midkill_resume": resumed,
                            "uninterrupted_reference": reference}
        report["audits"] = audits
        saved = reference["launches"] - resumed["launches"]
        print(f"-> SIGKILL cost zero recomputation: warm replay 0 launches; "
              f"mid-stream kill saved {saved} of {reference['launches']} "
              f"launches on resume")

    if cfg.json_out:
        with open(cfg.json_out, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"wrote {cfg.json_out}")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true",
                    help="internal: run one engine process")
    ap.add_argument("--state-dir", default=None)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--n-fn", type=int, default=8)
    ap.add_argument("--samples", type=int, default=3 * 8192)
    ap.add_argument("--round-samples", type=int, default=8192)
    ap.add_argument("--max-rounds-per-wave", type=int, default=1,
                    help="1 -> one round per stream per wave, so a kill "
                         "after wave k leaves streams k rounds deep")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sweeps", type=int, default=2,
                    help="append N overlapping parameter-sweep requests "
                         "to the workload (sweep slice streams must "
                         "survive SIGKILL like any other)")
    ap.add_argument("--waves", type=int, default=-1,
                    help="child: serve N waves then await SIGKILL (-1: all)")
    ap.add_argument("--linger", action="store_true",
                    help="child: after serving, await SIGKILL instead of "
                         "shutting down cleanly")
    ap.add_argument("--compact-on-start", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run with the same assertions")
    ap.add_argument("--json-out", default=None,
                    help="write measurements as JSON (BENCH_*.json)")
    args = ap.parse_args()

    if args.child:
        if not args.state_dir:
            ap.error("--child requires --state-dir")
        return child_main(args)
    if args.smoke:
        args.requests, args.n_fn = 12, 4
        args.round_samples, args.samples = 4096, 3 * 4096
    return run(args)


if __name__ == "__main__":
    sys.exit(main())
