"""Multi-function throughput (paper: 10^3 integrands of dim<5 in <10 min
on one V100).

Measures integrands/second and samples/second on this host for growing
function counts, plus the v5e roofline projection: the fused Pallas sampler
is compute-bound at ~130 flop per (sample, dim) Threefry+eval, so one v5e
chip at 197 TF bf16 (~25 Tflop/s attainable on the u32-heavy mix, see
EXPERIMENTS.md §Perf) projects to ~10^3 4-d integrands x 1e6 samples in
well under a minute — the 256-chip pod splits that linearly (§scaling).
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import ZMCMultiFunctions, harmonic_family

# measured kernel cost model: ~flops per (sample, dim) for threefry+eval
FLOP_PER_SAMPLE_DIM = 130.0
V5E_ATTAINABLE = 25e12   # u32/transcendental mix, not MXU matmul peak


def bench(n_fns: int, samples: int, dim: int = 4, use_kernel=False,
          seed=0) -> dict:
    z = ZMCMultiFunctions([harmonic_family(n_fns, dim)], n_samples=samples,
                          seed=seed, use_kernel=use_kernel, chunk=16384)
    # warmup (compile)
    z.evaluate(num_trials=1)
    t0 = time.time()
    z.evaluate(num_trials=1)
    dt = time.time() - t0
    total_samples = n_fns * samples
    return {
        "n_fns": n_fns, "samples": samples, "seconds": dt,
        "integrands_per_s": n_fns / dt,
        "samples_per_s": total_samples / dt,
        "v5e_projection_s": total_samples * dim * FLOP_PER_SAMPLE_DIM
                            / V5E_ATTAINABLE,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=50_000)
    ap.add_argument("--max-fns", type=int, default=1000)
    ap.add_argument("--use-kernel", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for n in (100, 300, args.max_fns):
        r = bench(n, args.samples, use_kernel=args.use_kernel)
        print(f"throughput_fns{n},{r['seconds']*1e6:.0f},"
              f"{r['samples_per_s']:.3e} samples/s "
              f"(v5e projection {r['v5e_projection_s']:.2f}s/chip)")


if __name__ == "__main__":
    main()
