"""Service-layer benchmark: batched vs sequential, cold vs warm cache,
multi-round waves vs per-round waves.

Measures the properties the service exists for, and asserts them (this
doubles as the CI regression gate via ``--smoke``):

* **batching** — a stream of >= 64 mixed-dimension requests served by
  the continuously-batching engine must issue *strictly fewer* kernel
  launches than evaluating each request sequentially with its own
  ``ZMCMultiFunctions`` (the engine coalesces same-round work across
  requests into one fused launch per dimension bucket);

* **caching** — replaying the identical request stream against the warm
  engine must return meeting-precision results with *zero* new launches,
  and topping up to a larger budget must only pay for the delta rounds;

* **wave pipeline** — an R-round refinement wave over B dimension
  buckets must run in at most **B** fused multi-round launches (the
  per-round path pays R x B), with per-round deposited sums
  *bit-identical* to the per-round path (digest equality on the final
  estimates), reported as launches-per-wave and wall-clock-per-wave;

* **infinite domains** (``BENCH_5.json``) — a mixed batch of finite and
  compactified infinite-domain requests must be served *entirely* by
  fused kernels: launches per wave <= the number of (dim, sampler)
  buckets and ZERO chunked fallback rounds
  (``RoundBatcher.fallback_rounds``), with the R^d / half-infinite
  Gaussian estimates hitting their analytic values and a warm replay
  costing zero launches;

* **telemetry / host-per-wave cost** (``BENCH_7.json``) — the same
  workload served with full telemetry (:mod:`repro.obs`: tracing +
  metrics + convergence accounting) must (a) stay within 5% (+0.25 s
  noise epsilon) of the telemetry-off wall clock, best-of-N each; (b)
  produce a Perfetto-loadable trace covering all six pipeline stages,
  from which the phase isolates *host* time (plan / launch dispatch /
  transfer / deposit / wal_commit) from *device* time (device_execute)
  per wave — the microbenchmark the ROADMAP's device-resident
  refinement item needs; (c) export metrics that agree *exactly* with
  the engine's own observables (``template.launch_count``,
  ``RoundBatcher.fallback_rounds``, wave/request counts); and (d)
  record a stderr-vs-rounds trajectory for every stream served.  The
  per-(dim, sampler)-bucket analytic roofline terms
  (:func:`benchmarks.roofline.mc_kernel_terms`) are emitted alongside
  the measured stage timings;

* **parameter sweeps** (``BENCH_8.json``) — a 64-point parameter-grid
  sweep must run as one *swept* family (launches per wave <= the single
  (dim, sampler) bucket, not 64 per-point launches) with per-point
  means bit-identical to 64 separate requests, a warm resubmit costing
  zero launches, and an overlapping sweep deduping at the sub-grid
  slice level (only new canonical slices are computed);

* **adaptive variance reduction** (``BENCH_10.json``) — VEGAS
  importance grids (``adaptive=True``) must reach a fixed stderr
  target with >= 5x fewer samples than the fixed-allocation path on
  peaked workloads (Genz corner-peak, narrow Gaussians over R^d), with
  at least one grid refit fired, pilot cost charged against the
  adaptive budget, post-SIGKILL resume bit-identical to an
  uninterrupted run and the Layer-3 audit (including the STR007 grid
  epoch chain) clean.

Wall-clock numbers are reported but only meaningful on a real
accelerator; on CPU the Pallas kernels run interpreted.  Launch counts
and estimate agreement are platform-independent.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

from repro.core import ZMCMultiFunctions
from repro.kernels import template
from repro.launch.serve_integrals import demo_workload
from repro.service import FAULT_POINTS, IntegrationEngine


def _sequential(reqs, *, seed: int):
    """Per-request evaluation: what clients did before the service."""
    template.reset_launch_count()
    t0 = time.time()
    results = []
    for req in reqs:
        zmc = ZMCMultiFunctions(list(req.families), n_samples=req.n_samples,
                                seed=seed, use_kernel=True,
                                sampler=req.sampler)
        results.append(zmc.evaluate(num_trials=1))
    return results, template.launch_count(), time.time() - t0


def _batched(engine, reqs):
    template.reset_launch_count()
    t0 = time.time()
    tickets = [engine.submit(r) for r in reqs]
    while engine.step():
        pass
    results = [engine.poll(t) for t in tickets]
    assert all(r is not None for r in results), "unserved requests"
    return results, template.launch_count(), time.time() - t0


def _refinement_wave(reqs, *, seed: int, round_samples: int, rounds: int):
    """R-round refinement: one multi-round wave vs R per-round waves.

    Returns the comparison dict (also asserts the CI gate: launches for
    the fused wave <= B buckets, and final estimates bit-identical to
    the per-round path — same per-round sums, same fold order).
    """
    big = [type(r).make(r.families, n_samples=rounds * round_samples)
           for r in reqs]
    buckets = len({f.dim for r in reqs for f in r.families})

    fused_engine = IntegrationEngine(seed=seed, round_samples=round_samples,
                                     max_rounds_per_wave=rounds)
    fused_res, fused_launches, fused_dt = _batched(fused_engine, big)
    fused_waves = fused_engine.stats.waves

    per_engine = IntegrationEngine(seed=seed, round_samples=round_samples,
                                   max_rounds_per_wave=1)
    per_res, per_launches, per_dt = _batched(per_engine, big)
    per_waves = per_engine.stats.waves

    for f, p in zip(fused_res, per_res):
        assert f.means.tobytes() == p.means.tobytes(), \
            "multi-round wave is not bit-identical to the per-round path"
        assert f.stderrs.tobytes() == p.stderrs.tobytes()
    assert fused_launches <= buckets, (
        f"an {rounds}-round wave over {buckets} buckets took "
        f"{fused_launches} launches (gate: <= {buckets})")
    assert per_launches == rounds * fused_launches, \
        (per_launches, rounds, fused_launches)

    print(f"refinement wave: {rounds} rounds x {buckets} buckets -> "
          f"{fused_launches} launches in {fused_waves} wave(s) "
          f"(per-round path: {per_launches} launches in {per_waves} waves); "
          f"{per_launches / fused_launches:.1f}x fewer, bit-identical")
    return {
        "rounds": rounds, "buckets": buckets,
        "fused": {"launches": int(fused_launches), "waves": int(fused_waves),
                  "launches_per_wave": fused_launches / max(fused_waves, 1),
                  "seconds": round(fused_dt, 3),
                  "seconds_per_wave": round(fused_dt / max(fused_waves, 1),
                                            3)},
        "per_round": {"launches": int(per_launches), "waves": int(per_waves),
                      "launches_per_wave": per_launches / max(per_waves, 1),
                      "seconds": round(per_dt, 3),
                      "seconds_per_wave": round(per_dt / max(per_waves, 1),
                                                3)},
    }


def _infinite_phase(*, n_fn: int, round_samples: int, rounds: int,
                    seed: int, json_out: str | None):
    """Mixed finite/infinite batch: entirely fused, launches <= buckets.

    Per dim in {2, 3, 4}: a finite Gaussian, a Gaussian over R^d, one
    over [0, inf)^d and a finite harmonic — all with the same budget, so
    one wave covers the batch.  Gates (the BENCH_5 CI contract):
    launches per wave <= B dimension buckets, zero chunked fallback
    rounds, analytic Gaussian values within stderr, warm replay free.
    """
    from repro.core import gaussian_analytic, gaussian_family, harmonic_family
    from repro.service.api import IntegrationRequest

    dims = (2, 3, 4)
    budget = rounds * round_samples
    reqs = []
    for dim in dims:
        reqs += [
            IntegrationRequest.make([gaussian_family(n_fn, dim)],
                                    n_samples=budget),
            IntegrationRequest.make(
                [gaussian_family(n_fn, dim, lo=-np.inf, hi=np.inf)],
                n_samples=budget),
            IntegrationRequest.make(
                [gaussian_family(n_fn, dim, lo=0.0, hi=np.inf)],
                n_samples=budget),
            IntegrationRequest.make([harmonic_family(n_fn, dim)],
                                    n_samples=budget),
        ]
    buckets = len(dims)

    engine = IntegrationEngine(seed=seed, round_samples=round_samples,
                               max_rounds_per_wave=rounds)
    res, launches, dt = _batched(engine, reqs)
    waves = engine.stats.waves
    fallbacks = engine.batcher.fallback_rounds
    launches_per_wave = launches / max(waves, 1)
    assert launches_per_wave <= buckets, (
        f"mixed finite/infinite wave took {launches_per_wave:.1f} launches "
        f"per wave over {buckets} buckets (gate: <= {buckets})")
    assert fallbacks == 0, (
        f"{fallbacks} rounds fell back to the chunked path — compactified "
        f"requests must stay on the fused kernels")

    # the improper integrals are *right*, not just fused
    for i, dim in enumerate(dims):
        r_full, r_half = res[4 * i + 1], res[4 * i + 2]
        assert np.all(np.abs(r_full.means - gaussian_analytic(n_fn, dim))
                      <= 6 * r_full.stderrs + 1e-3), f"R^{dim} gaussian off"
        assert np.all(np.abs(r_half.means
                             - gaussian_analytic(n_fn, dim, half=True))
                      <= 6 * r_half.stderrs + 1e-3), f"[0,inf)^{dim} off"

    # warm replay of the infinite-domain asks: pure cache hits
    warm_res, warm_launches, _ = _batched(engine, reqs)
    assert warm_launches == 0 and all(r.served_from_cache for r in warm_res)

    print(f"infinite domains: {len(reqs)} mixed finite/infinite requests, "
          f"{rounds} rounds x {buckets} buckets -> {launches} launches in "
          f"{waves} wave(s), {fallbacks} chunked fallbacks, warm replay "
          f"{warm_launches} launches")
    payload = {
        "bench": "service_infinite", "requests": len(reqs),
        "rounds": rounds, "buckets": buckets, "round_samples": round_samples,
        "launches": int(launches), "waves": int(waves),
        "launches_per_wave": launches_per_wave,
        "fallback_rounds": int(fallbacks),
        "warm_launches": int(warm_launches),
        "seconds": round(dt, 3),
    }
    if json_out:
        import json
        with open(json_out, "w", encoding="utf-8") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {json_out}")
    return payload


def _telemetry_phase(*, n_requests: int, n_fn: int, n_samples: int,
                     round_samples: int, rounds: int, seed: int,
                     reps: int = 2, json_out: str | None = None,
                     trace_out: str | None = None,
                     metrics_out: str | None = None):
    """Telemetry-overhead gate + host-per-wave cost split (BENCH_7)."""
    import json
    import shutil
    import tempfile

    from repro.obs import STAGES, Observability, load_trace, span_totals
    from repro.obs.export import write_snapshot
    try:
        from benchmarks.roofline import mc_bucket_table
    except ImportError:          # run as a script: benchmarks/ is sys.path[0]
        from roofline import mc_bucket_table

    work = tempfile.mkdtemp(prefix="zmc_bench7_")

    def one_run(tag: str, obs):
        # fresh engine + fresh state dir per rep: every run is a cold
        # cache paying identical WAL/fsync costs — only telemetry varies
        engine = IntegrationEngine(
            seed=seed, round_samples=round_samples,
            max_rounds_per_wave=rounds,
            state_dir=os.path.join(work, tag), obs=obs)
        reqs = demo_workload(n_requests, n_fn=n_fn, n_samples=n_samples)
        template.reset_launch_count()
        t0 = time.time()
        tickets = [engine.submit(r) for r in reqs]
        while engine.step():
            pass
        dt = time.time() - t0
        results = [engine.poll(t) for t in tickets]
        assert all(r is not None for r in results), "unserved requests"
        launches = template.launch_count()
        engine.close()
        return engine, results, launches, dt

    off_times = [one_run(f"off{k}", None)[3] for k in range(reps)]

    on_times = []
    last = None
    for k in range(reps):
        trace_path = os.path.join(work, f"trace{k}.json")
        obs = Observability.enabled(trace_path=trace_path)
        engine, results, launches, dt = one_run(f"on{k}", obs)
        obs.close()
        on_times.append(dt)
        last = (engine, results, launches, obs, trace_path)
    engine, results, launches, obs, trace_path = last

    # (b) the trace is loadable and covers every pipeline stage
    totals = span_totals(load_trace(trace_path))
    missing = [s for s in STAGES if s not in totals]
    assert not missing, f"trace missing pipeline stages: {missing}"
    waves = max(engine.stats.waves, 1)
    host_stages = ("plan", "launch", "transfer", "deposit", "wal_commit")
    host_s = sum(totals[s] for s in host_stages)
    device_s = totals["device_execute"]

    # (c) metrics agree exactly with the engine's own observables
    snap = obs.metrics.snapshot()
    agreement = {
        "zmc_kernel_launches_total": (launches, "template.launch_count"),
        "zmc_fallback_rounds_total": (engine.batcher.fallback_rounds,
                                      "RoundBatcher.fallback_rounds"),
        "zmc_waves_total": (engine.stats.waves, "EngineStats.waves"),
        "zmc_requests_served_total": (engine.stats.served,
                                      "EngineStats.served"),
        "zmc_requests_submitted_total": (engine.stats.submitted,
                                         "EngineStats.submitted"),
    }
    for name, (observable, source) in agreement.items():
        metric = snap[name]["value"]
        assert metric == observable, (
            f"{name}={metric} disagrees with {source}={observable}")

    # the resilience counters hold the same exactness contract (read
    # through the handles: labelled series that never fired need no
    # snapshot entry).  A fault-free run pins them all at zero except
    # retries, which must equal the engine's own restart count.
    m = obs.m
    retries = sum(m["retries"].value(stage=s)
                  for s in ("wave", "launch", "deposit"))
    assert retries == engine.stats.restarts, (
        f"zmc_retries_total={retries} disagrees with "
        f"EngineStats.restarts={engine.stats.restarts}")
    assert m["quarantined_streams"].value() == \
        len(engine.cache.quarantined_streams()), \
        "zmc_quarantined_streams_total disagrees with the cache"
    assert m["deadline_expirations"].value() == \
        engine.stats.deadline_expirations, \
        "zmc_deadline_expirations_total disagrees with EngineStats"
    fired = len(getattr(engine.faults, "fired", ()))
    injected = sum(m["faults_injected"].value(stage=p)
                   for p in FAULT_POINTS)
    assert injected == fired, (
        f"zmc_faults_injected_total={injected} disagrees with the "
        f"fault plan's fired count {fired}")

    # (d) a stderr trajectory exists for every stream served
    for res in results:
        assert res.stream_ids, "result carries no stream ids"
        for sid in res.stream_ids:
            assert obs.convergence.trajectory(sid), \
                f"no stderr trajectory for stream {sid[:16]}"

    # (a) the overhead gate: 5% relative + a small absolute epsilon
    # (interpret-mode CPU waves jitter by tens of ms run to run)
    off_best, on_best = min(off_times), min(on_times)
    budget = off_best * 1.05 + 0.25
    assert on_best <= budget, (
        f"telemetry overhead gate: on={on_best:.3f}s > "
        f"off*1.05+0.25={budget:.3f}s (off best {off_best:.3f}s)")

    # analytic roofline terms per measured (dim, sampler) bucket
    bucket_rounds = snap["zmc_bucket_rounds_total"]["value"]
    buckets = []
    for key, total in sorted(bucket_rounds.items()):
        dim_s, sampler = key.split(",")
        buckets.append({"dim": int(dim_s), "sampler": sampler,
                        "n_fn": n_fn, "rounds": int(total),
                        "round_samples": round_samples})
    roofline_rows = mc_bucket_table(buckets)

    print(f"telemetry: host {host_s / waves * 1e3:.1f} ms/wave "
          f"(plan+dispatch+transfer+deposit+wal) vs device "
          f"{device_s / waves * 1e3:.1f} ms/wave over {waves} wave(s)")
    for s in STAGES:
        print(f"  {s:<15} {totals[s]:8.3f}s total  "
              f"{totals[s] / waves * 1e3:9.1f} ms/wave")
    print(f"telemetry overhead: off {off_best:.2f}s vs on {on_best:.2f}s "
          f"best-of-{reps} ({on_best / max(off_best, 1e-9):.3f}x; "
          f"gate <= 1.05x + 0.25s)")
    print("roofline (analytic, per measured bucket):")
    for row in roofline_rows:
        print(f"  dim={row['dim']} {row['sampler']}: {row['rounds']} rounds"
              f" -> {row['flops']:.2e} flop, compute {row['compute_s']:.2e}s"
              f" / memory {row['memory_s']:.2e}s ({row['dominant']}-bound,"
              f" {row['intensity']:.0f} flop/B)")

    payload = {
        "bench": "service_telemetry",
        "requests": n_requests, "n_fn": n_fn, "n_samples": n_samples,
        "round_samples": round_samples, "waves": int(engine.stats.waves),
        "stage_seconds": {s: round(totals[s], 6) for s in STAGES},
        "host_seconds_per_wave": round(host_s / waves, 6),
        "device_seconds_per_wave": round(device_s / waves, 6),
        "overhead": {"off_best_s": round(off_best, 3),
                     "on_best_s": round(on_best, 3), "reps": reps,
                     "ratio": round(on_best / max(off_best, 1e-9), 4),
                     "gate": "on_best <= off_best * 1.05 + 0.25"},
        "counter_agreement": {
            name: {"value": snap[name]["value"], "observable": source}
            for name, (_, source) in agreement.items()},
        "roofline": roofline_rows,
        "convergence_streams": len(obs.convergence.streams()),
    }
    if json_out:
        with open(json_out, "w", encoding="utf-8") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {json_out}")
    if trace_out:
        shutil.copyfile(trace_path, trace_out)
        print(f"wrote {trace_out}")
    if metrics_out:
        write_snapshot(metrics_out, obs.metrics,
                       convergence=obs.convergence)
        print(f"wrote {metrics_out}")
    shutil.rmtree(work, ignore_errors=True)
    return payload


def _sweep_phase(*, round_samples: int, rounds: int, seed: int,
                 json_out: str | None):
    """Parameter-grid sweep vs per-point requests (the BENCH_8 gate).

    A 64-point harmonic ``a x b`` sweep in dim 3 must be served as ONE
    swept family: launches bounded by (dim, sampler) buckets per wave —
    one here — not by grid points, with per-point means *bit-identical*
    to 64 separate single-function requests (same global function ids
    on a fresh engine, and counters address by function id, so identity
    is structural).  A warm resubmit costs zero launches, and a second
    sweep that extends the slowest axis dedupes at the sub-grid level:
    it pays only for the new canonical slices and returns bit-identical
    means on the shared prefix.
    """
    import json

    from repro.core import harmonic_family
    from repro.obs import Observability
    from repro.service import SweepRequest
    from repro.service.api import IntegrationRequest

    dim = 3
    budget = rounds * round_samples
    a = np.linspace(0.5, 2.0, 8).astype(np.float32)
    b = np.linspace(-1.0, 1.0, 8).astype(np.float32)
    n_points = a.size * b.size          # 64 = one canonical slice

    obs = Observability.enabled()
    engine = IntegrationEngine(seed=seed, round_samples=round_samples,
                               max_rounds_per_wave=rounds, obs=obs)
    template.reset_launch_count()
    t0 = time.time()
    ticket = engine.submit(SweepRequest.make(
        harmonic_family(1, dim), {"a": a, "b": b}, n_samples=budget))
    while engine.step():
        pass
    sweep_res = engine.poll(ticket)
    sweep_dt = time.time() - t0
    sweep_launches = template.launch_count()
    sweep_waves = max(engine.stats.waves, 1)
    assert sweep_res is not None and sweep_res.complete
    assert sweep_res.n_points == n_points and not np.isnan(
        sweep_res.means).any()
    assert sweep_launches <= sweep_waves, (
        f"a {n_points}-point sweep of one (dim, sampler) bucket took "
        f"{sweep_launches} launches over {sweep_waves} wave(s) "
        f"(gate: <= 1 per bucket per wave)")
    assert sweep_launches < n_points, (sweep_launches, n_points)

    # the per-point path: 64 sequential single-function requests on a
    # fresh engine with the same seed draw the same global function ids
    # 0..63 -> the estimates must agree bit for bit, not statistically
    per_engine = IntegrationEngine(seed=seed, round_samples=round_samples,
                                   max_rounds_per_wave=rounds)
    template.reset_launch_count()
    t0 = time.time()
    per_means = []
    for ai in a:                        # sorted axes: "a" slowest
        for bi in b:
            fam = harmonic_family(1, dim,
                                  a=np.asarray([ai], np.float32),
                                  b=np.asarray([bi], np.float32))
            tk = per_engine.submit(
                IntegrationRequest.make([fam], n_samples=budget))
            while per_engine.step():
                pass
            per_means.append(per_engine.poll(tk).means[0])
    per_dt = time.time() - t0
    per_launches = template.launch_count()
    assert per_launches >= n_points, (per_launches, n_points)
    np.testing.assert_array_equal(
        np.asarray(per_means, dtype=sweep_res.means.dtype), sweep_res.means,
        err_msg="fused sweep is not bit-identical to the per-point path")

    # warm resubmit of the identical sweep: pure cache hit, zero launches
    template.reset_launch_count()
    warm_ticket = engine.submit(SweepRequest.make(
        harmonic_family(1, dim), {"a": a, "b": b}, n_samples=budget))
    while engine.step():
        pass
    warm_res = engine.poll(warm_ticket)
    warm_launches = template.launch_count()
    assert warm_launches == 0 and warm_res.served_from_cache
    np.testing.assert_array_equal(warm_res.means, sweep_res.means)

    # overlapping sweep: extend the slowest axis -> the first 64 points
    # reproduce sweep A's canonical slice exactly, so only the new
    # slice(s) are computed and the shared prefix stays bit-identical
    a2 = np.concatenate([a, np.linspace(2.5, 4.0, 8, dtype=np.float32)])
    template.reset_launch_count()
    big_ticket = engine.submit(SweepRequest.make(
        harmonic_family(1, dim), {"a": a2, "b": b}, n_samples=budget))
    while engine.step():
        pass
    big_res = engine.poll(big_ticket)
    big_launches = template.launch_count()
    big_waves = max(engine.stats.waves - sweep_waves, 1)
    assert big_res.n_points == 2 * n_points
    assert big_launches <= big_waves, (
        f"overlap sweep recomputed shared slices: {big_launches} launches "
        f"over {big_waves} wave(s) for one new slice")
    np.testing.assert_array_equal(
        big_res.means[:n_points], sweep_res.means,
        err_msg="overlapping sweep broke bit-identity on the shared slice")

    slices = obs.metrics.snapshot()["zmc_sweep_slices_total"]["value"]
    shared = int(slices.get("shared", 0))
    assert shared >= 1, f"sub-grid dedupe never hit: {slices}"

    print(f"sweep: {n_points} points -> {sweep_launches} launches in "
          f"{sweep_waves} wave(s) vs {per_launches} per-point "
          f"({per_launches / max(sweep_launches, 1):.0f}x fewer, "
          f"bit-identical); warm {warm_launches} launches; overlap "
          f"{2 * n_points} points -> {big_launches} launches "
          f"(slices: {slices})")
    payload = {
        "bench": "service_sweep", "dim": dim, "grid": [len(a2), len(b)],
        "points": n_points, "rounds": rounds,
        "round_samples": round_samples,
        "sweep": {"launches": int(sweep_launches), "waves": int(sweep_waves),
                  "seconds": round(sweep_dt, 3)},
        "per_point": {"launches": int(per_launches),
                      "seconds": round(per_dt, 3)},
        "warm_launches": int(warm_launches),
        "overlap": {"points": int(big_res.n_points),
                    "launches": int(big_launches),
                    "slices": {k: int(v) for k, v in slices.items()}},
        "bit_identical": True,
    }
    if json_out:
        with open(json_out, "w", encoding="utf-8") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {json_out}")
    return payload


def _adaptive_phase(*, round_samples: int, seed: int,
                    json_out: str | None):
    """Adaptive variance reduction vs fixed allocation (the BENCH_10 gate).

    Two peaked workloads — a Genz corner-peak batch in dim 3 and a
    narrow-sigma Gaussian mix over R^2 (compactified) — are driven to
    the same stderr target twice: once on the fixed-allocation path and
    once with ``adaptive=True`` (VEGAS importance grids, refit in the
    wave loop; ``docs/adaptive.md``).  Gates:

    * >= 5x fewer samples on the adaptive path, with the pilot cost
      charged against it;
    * at least one grid refit fired (the epoch chain is real, not just
      epoch 1);
    * estimates still agree with the analytic values / the fixed path;
    * an adapted run SIGKILLed mid-flight and resumed from its state
      dir finishes with results *bit-identical* to an uninterrupted
      run, and the Layer-3 audit (STR001-007, including the grid epoch
      chain) is clean on both state dirs.
    """
    import json
    import shutil
    import tempfile

    from repro.analysis.streams import audit_state_dir
    from repro.core import gaussian_family
    from repro.core.genz import corner_peak
    from repro.obs import Observability
    from repro.service.api import IntegrationClient, IntegrationRequest

    def mk(state_dir=None, obs=None):
        # one refit opportunity per wave keeps the epoch chain short and
        # the phase affordable; knobs are part of the replay contract
        return IntegrationEngine(
            seed=seed, round_samples=round_samples, state_dir=state_dir,
            obs=obs if obs is not None else Observability.enabled(),
            pipeline_waves=False, adapt_rounds_per_epoch=1,
            adapt_max_epochs=3, adapt_pilot_samples=2048)

    def solve(fams, target, adaptive):
        engine = mk()
        t0 = time.time()
        res = IntegrationClient(engine).integrate(
            fams, target_stderr=target, adaptive=adaptive)
        dt = time.time() - t0
        samples = int(sum(res.n_per_family))
        if adaptive:
            # charge every pilot against the adaptive budget: one per
            # opened epoch plus at most one frozen refit attempt per
            # base stream, each adapt_pilot_samples draws per function
            epochs = int(engine.obs.m["adapted_streams"].value())
            n_fn = sum(f.n_fn for f in fams)
            samples += (epochs + len(fams)) * \
                engine.adapt_pilot_samples * n_fn
        refits = int(engine.obs.m["grid_refits"].value())
        return res, samples, refits, dt

    corner, corner_exact = corner_peak(2, 3, difficulty=4.0)
    gauss = gaussian_family(2, 2, sigma=[0.2, 0.35],
                            lo=-np.inf, hi=np.inf)
    workloads = [("genz_corner_3d", [corner], 5e-5, corner_exact),
                 ("gaussian_r2", [gauss], 5e-4, None)]

    rows = []
    for name, fams, target, exact in workloads:
        fixed_res, fixed_n, _, fixed_dt = solve(fams, target, False)
        adapt_res, adapt_n, refits, adapt_dt = solve(fams, target, True)
        ratio = fixed_n / max(adapt_n, 1)
        assert ratio >= 5.0, (
            f"{name}: adaptive path took {adapt_n} samples (incl. "
            f"pilots) vs {fixed_n} fixed — {ratio:.1f}x, gate >= 5x")
        assert refits >= 1, (
            f"{name}: no grid refit fired — the epoch chain never "
            f"advanced beyond epoch 1")
        assert np.all(adapt_res.stderrs <= target)
        if exact is not None:
            assert np.all(np.abs(adapt_res.means - exact)
                          <= 6 * adapt_res.stderrs + 1e-5), \
                f"{name}: adapted estimate off its analytic value"
        tol = 6 * (adapt_res.stderrs + fixed_res.stderrs) + 1e-6
        assert np.all(np.abs(adapt_res.means - fixed_res.means) <= tol), \
            f"{name}: adaptive and fixed paths disagree"
        print(f"adaptive[{name}]: {fixed_n} fixed vs {adapt_n} adapted "
              f"samples to stderr<={target:g} ({ratio:.1f}x fewer, "
              f"{refits} refit(s); {fixed_dt:.1f}s vs {adapt_dt:.1f}s)")
        rows.append({
            "workload": name, "target_stderr": target,
            "fixed_samples": fixed_n, "adaptive_samples": adapt_n,
            "sample_ratio": round(ratio, 2), "grid_refits": refits,
            "fixed_seconds": round(fixed_dt, 3),
            "adaptive_seconds": round(adapt_dt, 3),
        })

    # SIGKILL resume: an interrupted adapted run must finish
    # bit-identically to an uninterrupted one, with clean audits
    work = tempfile.mkdtemp(prefix="zmc_bench10_")
    resume_target = 2e-4
    try:
        dir_a = os.path.join(work, "uninterrupted")
        eng = mk(state_dir=dir_a)
        r_a = IntegrationClient(eng).integrate(
            [corner], target_stderr=resume_target, adaptive=True)
        eng.close()

        dir_b = os.path.join(work, "interrupted")
        eng = mk(state_dir=dir_b)
        eng.submit(IntegrationRequest.make(
            [corner], target_stderr=resume_target, adaptive=True))
        for _ in range(3):
            eng.step()
        del eng     # abandoned mid-flight: no close(), no snapshot

        eng = mk(state_dir=dir_b)
        r_b = IntegrationClient(eng).integrate(
            [corner], target_stderr=resume_target, adaptive=True)
        eng.close()

        digest_a = (r_a.means.tobytes(), r_a.stderrs.tobytes(),
                    r_a.n_per_family, r_a.stream_ids)
        digest_b = (r_b.means.tobytes(), r_b.stderrs.tobytes(),
                    r_b.n_per_family, r_b.stream_ids)
        assert digest_a == digest_b, (
            "resumed adapted run is not bit-identical to the "
            "uninterrupted run")
        audits = {}
        for tag, d in (("uninterrupted", dir_a), ("interrupted", dir_b)):
            report = audit_state_dir(d)
            assert report.ok, (
                f"{tag} state dir failed the Layer-3 audit: "
                f"{[str(v) for v in report.violations]}")
            audits[tag] = {"violations": 0, "streams": report.streams}
        print(f"adaptive resume: SIGKILL mid-flight -> bit-identical "
              f"result after resume (final epoch stream "
              f"{r_a.stream_ids[0][:16]}), audits clean on both dirs")
    finally:
        shutil.rmtree(work, ignore_errors=True)

    payload = {
        "bench": "service_adaptive", "round_samples": round_samples,
        "gate": "fixed_samples >= 5 * adaptive_samples (pilots charged)",
        "workloads": rows,
        "resume": {"target_stderr": resume_target,
                   "bit_identical": True, "audits": audits},
    }
    if json_out:
        with open(json_out, "w", encoding="utf-8") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {json_out}")
    return payload


def run(n_requests: int, n_fn: int, n_samples: int, round_samples: int,
        seed: int = 0, json_out: str | None = None,
        refine_rounds: int = 4, infinite_json_out: str | None = None,
        telemetry_json_out: str | None = None,
        trace_out: str | None = None,
        metrics_out: str | None = None,
        sweep_json_out: str | None = None,
        adaptive_json_out: str | None = None) -> int:
    reqs = demo_workload(n_requests, n_fn=n_fn, n_samples=n_samples)
    n_fams = sum(len(r.families) for r in reqs)
    dims = sorted({f.dim for r in reqs for f in r.families})
    print(f"# {n_requests} requests, {n_fams} families, dims {dims}, "
          f"budget {n_samples} samples, rounds of {round_samples}")

    seq_res, seq_launches, seq_dt = _sequential(reqs, seed=seed)

    engine = IntegrationEngine(seed=seed, round_samples=round_samples)
    cold_res, cold_launches, cold_dt = _batched(engine, reqs)

    # batched and sequential draw different counter ranges (the service
    # allocates canonical offsets) -> agreement is statistical
    for req, bres, sres in zip(reqs, cold_res, seq_res):
        tol = 6.0 * (bres.stderrs + sres.stderrs[0]) + 1e-6
        assert np.all(np.abs(bres.means - sres.means[0]) <= tol), req
    assert cold_launches < seq_launches, (cold_launches, seq_launches)

    # warm cache: identical stream replayed -> zero new launches
    warm_res, warm_launches, warm_dt = _batched(engine, reqs)
    assert warm_launches == 0, warm_launches
    assert all(r.served_from_cache for r in warm_res)
    for req, w in zip(reqs, warm_res):
        rounds = engine.cache.rounds_for_budget(req.n_samples)
        assert all(n >= rounds * round_samples for n in w.n_per_family)

    # top-up: double the budget -> only the delta rounds are computed
    top_reqs = [type(r).make(r.families, n_samples=2 * n_samples)
                for r in reqs]
    top_res, top_launches, top_dt = _batched(engine, top_reqs)
    assert 0 < top_launches <= cold_launches, (top_launches, cold_launches)

    # R-round refinement wave: R x B launches -> B, bit-identical
    refinement = _refinement_wave(reqs, seed=seed,
                                  round_samples=round_samples,
                                  rounds=refine_rounds)

    # mixed finite/infinite batch: fused end to end (BENCH_5 gate)
    infinite = _infinite_phase(n_fn=n_fn, round_samples=round_samples,
                               rounds=refine_rounds, seed=seed,
                               json_out=infinite_json_out)

    # telemetry on vs off + host-per-wave cost split (BENCH_7 gate);
    # a quarter of the request stream keeps the 4 cold reps affordable
    telemetry = _telemetry_phase(
        n_requests=max(16, n_requests // 4), n_fn=n_fn,
        n_samples=n_samples, round_samples=round_samples,
        rounds=refine_rounds, seed=seed, json_out=telemetry_json_out,
        trace_out=trace_out, metrics_out=metrics_out)

    # parameter-grid sweeps: fused vs per-point, dedupe (BENCH_8 gate)
    sweep = _sweep_phase(round_samples=round_samples, rounds=refine_rounds,
                         seed=seed, json_out=sweep_json_out)

    # adaptive variance reduction vs fixed allocation (BENCH_10 gate)
    adaptive = _adaptive_phase(round_samples=round_samples, seed=seed,
                               json_out=adaptive_json_out)

    rows = []
    print("path,requests,launches,seconds,req_per_s")
    for name, res, launches, dt in [
            ("sequential", seq_res, seq_launches, seq_dt),
            ("batched_cold", cold_res, cold_launches, cold_dt),
            ("batched_warm", warm_res, warm_launches, warm_dt),
            ("batched_topup", top_res, top_launches, top_dt)]:
        print(f"{name},{len(res)},{launches},{dt:.2f},"
              f"{len(res) / max(dt, 1e-9):.1f}")
        rows.append({"path": name, "requests": len(res),
                     "launches": int(launches), "seconds": round(dt, 3)})
    print(f"-> {seq_launches} sequential launches vs {cold_launches} "
          f"batched ({seq_launches / max(cold_launches, 1):.1f}x fewer); "
          f"warm cache: 0 launches; "
          f"dedup saved {engine.stats.items_deduped} round evaluations")
    print(f"cache: {engine.cache.stats()}")
    if json_out:
        import json
        with open(json_out, "w", encoding="utf-8") as f:
            json.dump({"bench": "service", "requests": n_requests,
                       "n_fn": n_fn, "n_samples": n_samples,
                       "round_samples": round_samples, "rows": rows,
                       "refinement_wave": refinement,
                       "infinite_domains": infinite,
                       "telemetry": telemetry,
                       "sweep": sweep,
                       "adaptive": adaptive,
                       "items_deduped": engine.stats.items_deduped,
                       "cache": engine.cache.stats()},
                      f, indent=2, sort_keys=True)
        print(f"wrote {json_out}")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--n-fn", type=int, default=8)
    ap.add_argument("--samples", type=int, default=16384)
    ap.add_argument("--round-samples", type=int, default=8192)
    ap.add_argument("--refine-rounds", type=int, default=4,
                    help="R of the refinement-wave phase (R x B -> B "
                         "launch gate)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (still >= 64 requests, smaller "
                         "families and budgets)")
    ap.add_argument("--json-out", default=None,
                    help="write measurements as JSON (BENCH_*.json)")
    ap.add_argument("--infinite-json-out", default=None,
                    help="write the mixed finite/infinite-domain phase "
                         "as its own JSON artifact (BENCH_5.json)")
    ap.add_argument("--telemetry-json-out", default=None,
                    help="write the telemetry-overhead / host-per-wave "
                         "phase as its own JSON artifact (BENCH_7.json)")
    ap.add_argument("--trace-out", default=None,
                    help="keep the telemetry phase's Perfetto trace here")
    ap.add_argument("--metrics-out", default=None,
                    help="write the telemetry phase's metrics+convergence "
                         "snapshot here")
    ap.add_argument("--sweep-json-out", default=None,
                    help="write the parameter-grid sweep phase as its own "
                         "JSON artifact (BENCH_8.json)")
    ap.add_argument("--adaptive-json-out", default=None,
                    help="write the adaptive variance-reduction phase as "
                         "its own JSON artifact (BENCH_10.json)")
    args = ap.parse_args()
    if args.smoke:
        return run(max(64, args.requests), n_fn=4, n_samples=8192,
                   round_samples=4096, json_out=args.json_out,
                   refine_rounds=args.refine_rounds,
                   infinite_json_out=args.infinite_json_out,
                   telemetry_json_out=args.telemetry_json_out,
                   trace_out=args.trace_out, metrics_out=args.metrics_out,
                   sweep_json_out=args.sweep_json_out,
                   adaptive_json_out=args.adaptive_json_out)
    return run(args.requests, n_fn=args.n_fn, n_samples=args.samples,
               round_samples=args.round_samples, json_out=args.json_out,
               refine_rounds=args.refine_rounds,
               infinite_json_out=args.infinite_json_out,
               telemetry_json_out=args.telemetry_json_out,
               trace_out=args.trace_out, metrics_out=args.metrics_out,
               sweep_json_out=args.sweep_json_out,
               adaptive_json_out=args.adaptive_json_out)


if __name__ == "__main__":
    sys.exit(main())
