"""End-to-end training driver example.

Default: a reduced model for a quick CPU run.  The real ~130M-parameter
configuration (mamba2-130m, the assigned arch of that size) runs with
``--arch mamba2-130m --no-reduced --steps 300`` — identical code path, just
bigger; on a TPU mesh the same driver is what launch/train.py invokes via
the production launch scripts.

    PYTHONPATH=src python examples/train_lm.py --steps 100
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import dataclasses

from repro.configs import get_config, reduced
from repro.launch.train import TrainHParams, default_hparams_for, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--no-reduced", action="store_true",
                    help="run the FULL config (slow on CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.no_reduced:
        cfg = reduced(cfg)
    hp = dataclasses.replace(
        default_hparams_for(cfg, global_batch=args.batch, data_shards=1),
        total_steps=args.steps, warmup_steps=max(1, args.steps // 10),
        grad_accum=2)

    state, losses, wd = train_loop(
        cfg, hp, batch=args.batch, seq=args.seq, steps=args.steps,
        ckpt_dir=args.ckpt_dir, ckpt_every=max(10, args.steps // 5),
        log_every=max(1, args.steps // 20))
    print(f"loss {losses[0]:.4f} -> {losses[-1]:.4f} over {args.steps} steps"
          f"; stragglers {wd.straggler_count}; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
