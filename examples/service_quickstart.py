"""Service quickstart: integrals as requests against a caching engine.

    PYTHONPATH=src python examples/service_quickstart.py

Where ``examples/quickstart.py`` evaluates one spec in one shot, this
drives the request-serving layer (``repro.service``): clients submit
*requests* — families plus a precision ask — and the engine batches
pending work across clients into fused kernel launches, dedupes
equivalent integrals via content hashing, and serves repeats straight
from its stderr-aware cache.  Six things to notice below:

1. two clients asking for the same integral share one evaluation;
2. re-asking to the *same or looser* precision costs zero launches;
3. asking for *more* precision resumes the cached counter stream
   (top-up) — the result is bit-identical to having run the bigger
   budget from the start, and all the delta rounds of a wave ride in
   ONE multi-round fused kernel launch per dimension bucket (an R-round
   refinement costs B launches, not R x B);
4. with a ``state_dir`` all of the above survives process death: the
   cache journals every round to disk — one group-committed fsync per
   wave — so a brand-new process (or one recovering from a SIGKILL)
   warm-starts the same streams;
5. the whole pipeline is observable (``repro.obs``): pass an
   ``Observability`` bundle and every wave traces its six stages
   (plan / launch / device_execute / transfer / deposit / wal_commit)
   to a Perfetto-loadable file, ``zmc_*`` metrics count what the
   engine did, and each stream records its stderr-vs-rounds
   trajectory.  ``serve_integrals --trace-out/--metrics-port`` exposes
   the same thing on the CLI;
6. a parameter *sweep* is one request, not one request per point: the
   engine canonicalizes the grid into fixed-size slices of swept
   families, runs the whole scan fused (launches scale with waves and
   (dim, sampler) buckets, not grid points), and keys cache streams
   per grid-slice — so overlapping sweeps dedupe below the request
   level and a re-ask at a bigger budget tops the slices up.

Engine knobs this example leaves at their defaults:
``max_rounds_per_wave`` (the R of each fused multi-round launch),
``max_items_per_wave`` (total wave budget, shared round-robin across
requests so heavy asks can't starve small ones), and
``pipeline_waves`` (the background worker dispatches wave k+1 while
wave k's results deposit — see ``engine.start()``).

Every invariant named above is machine-checked: ``python -m
repro.analysis`` lints the tree, traces every registered kernel form's
contract, and (with ``--state-dir``) audits a durable state dir —
``serve_integrals --audit-state`` wraps the same auditor.  If a rule
genuinely doesn't apply to a line you're writing, silence that one
rule with ``# analysis: ignore[RULE]`` *and a comment saying why* —
a bare ignore hides exactly the class of bug the checker exists to
catch, and review should treat an unexplained one as a defect.
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (harmonic_analytic, harmonic_family,
                        gaussian_analytic, gaussian_family)
from repro.kernels import template
from repro.service import IntegrationClient, IntegrationEngine

engine = IntegrationEngine(seed=0, round_samples=8192)
client = IntegrationClient(engine)

# -- client A: harmonic modes; client B: an overlapping grid scan ----------
template.reset_launch_count()
res_a = client.integrate([harmonic_family(50, 4), gaussian_family(10, 3)],
                         n_samples=32768)
res_b = client.integrate([harmonic_family(50, 4)],   # same integrals as A!
                         n_samples=32768)
print(f"cold: {template.launch_count()} launches for both clients "
      f"(B deduped onto A's cache entry: from_cache={res_b.served_from_cache})")

exact = harmonic_analytic(50, 4)
print("first three harmonic modes (estimate +- stderr vs analytic):")
for i in range(3):
    print(f"  F_{i+1:<3d} = {res_a.means[i]:+.5f} "
          f"+- {res_a.stderrs[i]:.1e}   exact {exact[i]:+.5f}")

# -- warm cache: zero launches -------------------------------------------
template.reset_launch_count()
res_c = client.integrate([harmonic_family(50, 4)], n_samples=32768)
assert template.launch_count() == 0 and res_c.served_from_cache
np.testing.assert_array_equal(res_c.means, res_b.means)
print("warm: 0 launches, identical result")

# -- top-up: resume the stream instead of recomputing ---------------------
# the 4 delta rounds arrive in ONE multi-round fused launch (R x B -> B)
template.reset_launch_count()
res_d = client.integrate([harmonic_family(50, 4)], n_samples=65536)
assert template.launch_count() == 1
print(f"top-up to 2x budget: {template.launch_count()} launch, "
      f"stderr {res_b.stderrs.max():.2e} -> {res_d.stderrs.max():.2e}")

# -- or ask for precision directly ----------------------------------------
res_e = client.integrate([harmonic_family(50, 4)], target_stderr=2.5e-3)
print(f"to-precision: max stderr {res_e.stderrs.max():.2e} "
      f"after {res_e.n_per_family[0]} samples")

# -- infinite domains ride the same fused path -----------------------------
# a gaussian over R^3: canonicalization compactifies it (tangent
# transform, Jacobian folded in-kernel), so the request buckets into the
# SAME fused launches as finite boxes — no chunked fallback — and lands
# on the analytic value (sigma sqrt(2 pi))^3
template.reset_launch_count()
res_inf = client.integrate([gaussian_family(10, 3, lo=-np.inf, hi=np.inf)],
                           n_samples=32768)
exact_inf = gaussian_analytic(10, 3)
assert template.launch_count() == 1 and engine.batcher.fallback_rounds == 0
assert np.all(np.abs(res_inf.means - exact_inf) <= 6 * res_inf.stderrs + 1e-3)
print(f"infinite domain: gaussian over R^3 in {template.launch_count()} "
      f"fused launch, max error "
      f"{np.abs(res_inf.means - exact_inf).max():.2e} "
      f"(stderr {res_inf.stderrs.max():.2e})")
print(f"engine stats: {engine.stats}")

# -- durability: the cache survives process death -------------------------
# pass state_dir= and every round deposit is journaled to disk
# (crash-safe: fsynced + checksummed, compacted to npz on close).  A new
# process pointing at the same dir resumes every stream at its exact
# counter offset — zero launches for work already done, bit-identical
# results.  `serve_integrals --state-dir` exposes the same thing on the
# CLI; `benchmarks/persistence_bench.py` proves it under real SIGKILLs.
import tempfile
with tempfile.TemporaryDirectory(prefix="zmc-state-") as state_dir:
    with IntegrationEngine(seed=1, round_samples=8192,
                           state_dir=state_dir) as eng1:
        res_cold = IntegrationClient(eng1).integrate(
            [harmonic_family(50, 4)], n_samples=32768)
    # eng1 is gone — "the process died".  Boot a fresh engine on its state:
    with IntegrationEngine(seed=1, round_samples=8192,
                           state_dir=state_dir) as eng2:
        template.reset_launch_count()
        res_warm = IntegrationClient(eng2).integrate(
            [harmonic_family(50, 4)], n_samples=32768)
        assert template.launch_count() == 0 and res_warm.served_from_cache
        np.testing.assert_array_equal(res_warm.means, res_cold.means)
print("restart: 0 launches, bit-identical result from persisted state")

# -- telemetry: watch the engine work --------------------------------------
# Observability.enabled() turns on tracing + convergence recording; the
# trace file loads in Perfetto (ui.perfetto.dev) or chrome://tracing,
# the metrics registry renders a Prometheus exposition, and every
# stream's stderr-vs-rounds trajectory is queryable by its id from
# ``result.stream_ids``.  Disabled (the default) costs almost nothing.
from repro.obs import Observability

with tempfile.TemporaryDirectory(prefix="zmc-obs-") as tmp:
    trace_path = os.path.join(tmp, "trace_wave_pipeline.json")
    obs = Observability.enabled(trace_path=trace_path)
    eng = IntegrationEngine(seed=2, round_samples=8192, obs=obs)
    res = IntegrationClient(eng).integrate([harmonic_family(50, 4)],
                                           n_samples=65536)
    (sid,) = res.stream_ids
    traj = eng.stderr_trajectory(sid)
    print(f"telemetry: stream {sid[:16]}... converged "
          f"{traj[0].stderr_max:.2e} -> {traj[-1].stderr_max:.2e} "
          f"over {traj[-1].rounds_done} rounds; "
          f"{int(obs.m['launches'].value())} launches, "
          f"{int(obs.m['waves'].value())} waves recorded")
    obs.close()
    from repro.obs.trace import load_trace, span_totals
    totals = span_totals(load_trace(trace_path))
    print("per-stage wall time: " +
          ", ".join(f"{k} {v * 1e3:.1f}ms" for k, v in totals.items()))


# -- parameter sweeps: scan a template over a grid in one request ----------
# A Boltzmann-style scan: one integrand family, evaluated over a 2-D
# (amplitude, offset) parameter grid.  client.sweep() submits ONE
# request; the engine slices the grid into swept families (64 points
# each by default) and serves them on the fused kernel path — the
# per-point parameters substitute *inside* the kernel, so a 64-point
# grid costs one launch per (dim, sampler) bucket per wave, not 64.
eng = IntegrationEngine(seed=3, round_samples=8192)
sweeper = IntegrationClient(eng)
a_axis = np.linspace(0.5, 2.0, 8)      # amplitude scan
b_axis = np.linspace(-1.0, 1.0, 8)     # offset scan
template.reset_launch_count()
sweep = sweeper.sweep(harmonic_family(1, 3), {"a": a_axis, "b": b_axis},
                      n_samples=16384)
surface = sweep.means.reshape(sweep.grid_shape)  # indexed by (a_i, b_j)
print(f"sweep: {sweep.n_points} grid points over axes {sweep.axis_names} "
      f"in {template.launch_count()} fused launch(es); "
      f"surface shape {surface.shape}")

# warm-restart top-up: the same grid at a bigger budget resumes every
# slice's counter stream (only the delta rounds run), and a verbatim
# re-ask is a pure cache hit — same STR semantics as any other stream.
template.reset_launch_count()
finer = sweeper.sweep(harmonic_family(1, 3), {"a": a_axis, "b": b_axis},
                      n_samples=65536)
delta_launches = template.launch_count()
again = sweeper.sweep(harmonic_family(1, 3), {"a": a_axis, "b": b_axis},
                      n_samples=65536)
assert again.served_from_cache
np.testing.assert_array_equal(again.means, finer.means)
print(f"sweep top-up: {delta_launches} launch(es) for the extra rounds, "
      f"re-ask free; max stderr {sweep.stderrs.max():.2e} -> "
      f"{finer.stderrs.max():.2e}")
