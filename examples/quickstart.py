"""Quickstart: integrate many different functions at once (ZMC-v5.1 API).

    PYTHONPATH=src python examples/quickstart.py

The multi-function solver takes *families* — one traced function + stacked
parameters — which is how 10^3-10^4 integrands stay a handful of fused XLA
programs instead of 10^4 separate kernels (see DESIGN.md §2).
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import (IntegrandFamily, MultiFunctionSpec,
                        ZMCMultiFunctions, harmonic_analytic,
                        harmonic_family)

# -- family 1: the paper's harmonic series (Eq. 1), 50 integrands, dim 4 --
harmonics = harmonic_family(50, 4)

# -- family 2: your own integrands: f_c(x) = exp(-c |x|^2) over [-1, 2]^3 --
cs = jnp.linspace(0.5, 4.0, 20)
gauss = IntegrandFamily(
    fn=lambda x, p: jnp.exp(-p["c"] * jnp.sum(jnp.square(x), -1)),
    params={"c": cs},
    domains=jnp.broadcast_to(jnp.asarray([-1.0, 2.0]), (20, 3, 2)),
    name="gauss3d",
).validate()

spec = MultiFunctionSpec.from_families([harmonics, gauss])
zmc = ZMCMultiFunctions(spec, n_samples=100_000, seed=0)
result = zmc.evaluate(num_trials=5)        # 5 independent evaluations

exact = harmonic_analytic(50, 4)
print("first five harmonic modes (estimate +- spread vs analytic):")
for i in range(5):
    print(f"  F_{i+1:<3d} = {result.trial_mean[i]:+.5f} "
          f"+- {result.trial_std[i]:.1e}   exact {exact[i]:+.5f}")

cover = np.mean(np.abs(result.trial_mean[:50] - exact)
                <= 2 * np.maximum(result.trial_std[:50], 1e-12))
print(f"harmonics inside 2-sigma band: {100 * cover:.0f}%")
print(f"gauss3d first/last: {result.trial_mean[50]:.5f} / "
      f"{result.trial_mean[-1]:.5f}")
