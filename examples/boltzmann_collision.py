"""The paper's physics motivation: batches of collision integrals.

Solving a Boltzmann equation with radiation requires, per energy beam and
per Feynman graph, a collision integral of the form

    C(p) = Int d^3q  W(p, q) [ f(q) (1 - f(p)) - f(p) (1 - f(q)) ]

Here we evaluate a (simplified, Maxwell-Juttner-weighted, 2->2 scattering)
gain-term kernel for MANY beam energies p and TWO "graphs" (s-channel-like
and t-channel-like angular weights) simultaneously — one
ZMCMultiFunctions call, exactly the workload class v5.1 was built for.

    PYTHONPATH=src python examples/boltzmann_collision.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import IntegrandFamily, MultiFunctionSpec, ZMCMultiFunctions

T = 1.0            # temperature (natural units)
N_BEAMS = 32       # energy beams -> one integrand per beam per graph
beam_p = np.linspace(0.2, 6.0, N_BEAMS).astype(np.float32)


def _thermal(e):
    return jnp.exp(-e / T)


def gain_s_channel(x, prm):
    """x = (|q|, cos(theta), phi); s-channel-ish |M|^2 ~ s^2/(s^2+1)."""
    q, ct, _ = x[..., 0], x[..., 1], x[..., 2]
    p = prm["p"]
    s_mand = 2 * p * q * (1 - ct) + 0.5          # massless-ish invariant
    m2 = jnp.square(s_mand) / (jnp.square(s_mand) + 1.0)
    flux = q * q / (jnp.maximum(p, 1e-3))
    return m2 * flux * _thermal(q) * (1 - 0.2 * _thermal(p))


def gain_t_channel(x, prm):
    """t-channel-ish: forward-peaked angular weight 1/(1 + (1-ct))^2."""
    q, ct, _ = x[..., 0], x[..., 1], x[..., 2]
    p = prm["p"]
    w = 1.0 / jnp.square(2.0 - ct)
    flux = q * q / (jnp.maximum(p, 1e-3))
    return w * flux * _thermal(q) * (1 - 0.2 * _thermal(p))


# domain: |q| in [0, 8T] (thermal support), cos(theta) in [-1,1], phi in [0,2pi]
dom = np.array([[0.0, 8.0], [-1.0, 1.0], [0.0, 2 * np.pi]], np.float32)
domains = np.broadcast_to(dom, (N_BEAMS, 3, 2)).copy()

spec = MultiFunctionSpec.from_families([
    IntegrandFamily(fn=gain_s_channel, params={"p": jnp.asarray(beam_p)},
                    domains=jnp.asarray(domains), name="graph_s").validate(),
    IntegrandFamily(fn=gain_t_channel, params={"p": jnp.asarray(beam_p)},
                    domains=jnp.asarray(domains), name="graph_t").validate(),
])

zmc = ZMCMultiFunctions(spec, n_samples=200_000, seed=1)
r = zmc.evaluate(num_trials=3)

cs = r.trial_mean[:N_BEAMS]
ct_ = r.trial_mean[N_BEAMS:]
print("beam p,   C_s-channel,   C_t-channel,   (rel stderr)")
for i in range(0, N_BEAMS, 4):
    rel = r.trial_std[i] / max(abs(cs[i]), 1e-9)
    print(f"{beam_p[i]:6.2f}   {cs[i]:12.5f}   {ct_[i]:12.5f}   ({rel:.1e})")

# physics sanity: gain terms positive and decaying with beam energy at tail
assert np.all(cs > 0) and np.all(ct_ > 0)
assert cs[-1] < cs[N_BEAMS // 2]
print("OK: per-graph collision terms evaluated for all beams in one call")
