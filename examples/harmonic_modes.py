"""Paper Fig. 1 end-to-end: harmonic-mode decomposition with error band.

    PYTHONPATH=src python examples/harmonic_modes.py [--full]

Evaluates F_n = Int_{[0,1]^4} cos(k_n.x) + sin(k_n.x) dx for n = 1..100
over independent trials and prints an ASCII version of the paper's figure:
the +-dF band around F_bar with the analytic curve overlaid.
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import numpy as np

from repro.core import (ZMCMultiFunctions, harmonic_analytic,
                        harmonic_family)

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true", help="1e6 samples, 10 trials")
ap.add_argument("--use-kernel", action="store_true")
args = ap.parse_args()

samples = 10**6 if args.full else 10**5
trials = 10 if args.full else 6

zmc = ZMCMultiFunctions([harmonic_family(100, 4)], n_samples=samples,
                        seed=0, use_kernel=args.use_kernel)
r = zmc.evaluate(num_trials=trials)
exact = harmonic_analytic(100, 4)
fbar, dfn = r.trial_mean, np.maximum(r.trial_std, 1e-12)

lo, hi = (fbar - dfn).min(), (fbar + dfn).max()
width = 64
print(f"F_n for n=1..100 ({samples:.0e} samples x {trials} trials); "
      f"band = [F-dF, F+dF], * = analytic")
for i in range(0, 100, 2):
    a = int((fbar[i] - dfn[i] - lo) / (hi - lo) * (width - 1))
    b = int((fbar[i] + dfn[i] - lo) / (hi - lo) * (width - 1))
    e = int((exact[i] - lo) / (hi - lo) * (width - 1))
    row = [" "] * width
    for j in range(a, b + 1):
        row[j] = "-"
    row[max(0, min(width - 1, e))] = "*"
    print(f"n={i+1:3d} |{''.join(row)}|")

pull = np.abs(fbar - exact) / dfn
print(f"\nmax pull: {pull.max():.2f} sigma at n={pull.argmax()+1}; "
      f"2-sigma coverage {(pull <= 2).mean():.2f}")
