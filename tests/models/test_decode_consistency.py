"""Serving correctness: decode step == extended prefill (cache integrity)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, reduced
from repro.models.model import Model

DECODABLE = [a for a in ARCH_NAMES if a != "hubert_xlarge"]


@pytest.mark.parametrize("arch", DECODABLE)
def test_decode_matches_prefill(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    seq, cap = 12, 16
    toks = jax.random.randint(jax.random.key(1), (2, seq), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    if cfg.family == "vlm":
        pytest.skip("vlm prefill oracle needs vision splice bookkeeping")
    _, cache = model.prefill(params, {"tokens": toks}, seq_cap=cap)
    new = jnp.array([[5], [7]], jnp.int32)
    dec, cache2 = model.decode_step(params, cache, new, jnp.int32(seq))
    ext, _ = model.prefill(
        params, {"tokens": jnp.concatenate([toks, new], axis=1)},
        seq_cap=cap)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ext), atol=2e-4)


@pytest.mark.parametrize("arch", ["stablelm_3b", "mamba2_130m", "zamba2_7b"])
def test_multi_step_decode(arch):
    """Three consecutive decode steps == prefill over the full string."""
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    seq, cap = 8, 12
    toks = jax.random.randint(jax.random.key(2), (1, seq), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    extra = jnp.array([[3, 9, 11]], jnp.int32)
    _, cache = model.prefill(params, {"tokens": toks}, seq_cap=cap)
    outs = []
    for i in range(3):
        logits, cache = model.decode_step(params, cache, extra[:, i:i + 1],
                                          jnp.int32(seq + i))
        outs.append(logits)
    full, _ = model.prefill(
        params, {"tokens": jnp.concatenate([toks, extra], axis=1)},
        seq_cap=cap)
    np.testing.assert_allclose(np.asarray(outs[-1]), np.asarray(full),
                               atol=5e-4)


def test_prefill_logits_are_last_position():
    cfg = reduced(get_config("stablelm_3b"))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(3), (2, 10), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    logits, _ = model.prefill(params, {"tokens": toks}, seq_cap=10)
    assert logits.shape == (2, cfg.vocab_padded)
    full = model.forward(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, -1]),
                               atol=1e-5)
