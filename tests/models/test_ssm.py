"""Mamba-2 SSD: chunked scan vs naive recurrence oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import _causal_conv, _ssd_chunked


def _naive_ssd(x, dt, a_log, bmat, cmat):
    """Token-by-token recurrence: h = dA h + dt B x ; y = C h."""
    b, l, h, p = x.shape
    n = bmat.shape[-1]
    a = -np.exp(np.asarray(a_log, np.float64))
    state = np.zeros((b, h, p, n))
    ys = np.zeros((b, l, h, p))
    xf = np.asarray(x, np.float64)
    dtf = np.asarray(dt, np.float64)
    bf = np.asarray(bmat, np.float64)
    cf = np.asarray(cmat, np.float64)
    for t in range(l):
        da = np.exp(dtf[:, t] * a)                      # (B,H)
        contrib = np.einsum("bhp,bn,bh->bhpn", xf[:, t], bf[:, t], dtf[:, t])
        state = state * da[:, :, None, None] + contrib
        ys[:, t] = np.einsum("bhpn,bn->bhp", state, cf[:, t])
    return ys, state


@pytest.mark.parametrize("chunk", [2, 4, 8])
def test_chunked_matches_naive(chunk):
    key = jax.random.key(0)
    b, l, h, p, n = 2, 16, 3, 4, 5
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    bmat = jax.random.normal(ks[3], (b, l, n))
    cmat = jax.random.normal(ks[4], (b, l, n))
    y, s = _ssd_chunked(x, dt, a_log, bmat, cmat, chunk)
    y_ref, s_ref = _naive_ssd(x, dt, a_log, bmat, cmat)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), s_ref, atol=2e-4)


def test_chunk_size_invariance():
    key = jax.random.key(1)
    b, l, h, p, n = 1, 24, 2, 4, 3
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a_log = jnp.zeros((h,))
    bmat = jax.random.normal(ks[3], (b, l, n))
    cmat = jax.random.normal(ks[4], (b, l, n))
    y3, s3 = _ssd_chunked(x, dt, a_log, bmat, cmat, 3)
    y8, s8 = _ssd_chunked(x, dt, a_log, bmat, cmat, 8)
    np.testing.assert_allclose(np.asarray(y3), np.asarray(y8), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s3), np.asarray(s8), atol=2e-4)


def test_causal_conv_matches_numpy():
    key = jax.random.key(2)
    u = jax.random.normal(key, (2, 10, 4))
    w = jax.random.normal(jax.random.key(3), (4, 4)) * 0.3
    y, cache = _causal_conv(u, w)
    un = np.asarray(u)
    wn = np.asarray(w)
    pad = np.concatenate([np.zeros((2, 3, 4)), un], axis=1)
    ref = sum(pad[:, i:i + 10] * wn[i] for i in range(4))
    ref = np.asarray(jax.nn.silu(jnp.asarray(ref)))
    np.testing.assert_allclose(np.asarray(y), ref, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cache), un[:, -3:], atol=1e-6)


def test_conv_cache_streaming():
    """conv over [u1; u2] == conv(u1) then conv(u2, cache)."""
    key = jax.random.key(4)
    u = jax.random.normal(key, (1, 12, 3))
    w = jax.random.normal(jax.random.key(5), (4, 3)) * 0.3
    y_full, _ = _causal_conv(u, w)
    y1, c1 = _causal_conv(u[:, :7], w)
    y2, _ = _causal_conv(u[:, 7:], w, c1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-5)
