"""MoE routing/dispatch semantics."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe
from repro.models.config import ModelConfig, init_params


def _cfg(**kw):
    base = dict(name="m", family="moe", n_layers=1, d_model=16, n_heads=2,
                n_kv_heads=2, head_dim=8, d_ff=32, vocab_size=64,
                n_experts=4, top_k=2, moe_d_ff=8, n_shared_experts=0,
                capacity_factor=2.0,  # = E/k -> dropless
                param_dtype="float32", compute_dtype="float32", remat="none")
    base.update(kw)
    return ModelConfig(**base)


def _dense_oracle(x, params, cfg):
    """Evaluate ALL experts densely, weight by renormalised top-k gates."""
    t = x.shape[0]
    logits = x @ np.asarray(params["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    order = np.argsort(-probs, axis=-1)[:, :cfg.top_k]
    out = np.zeros_like(x)
    for i in range(t):
        w = probs[i, order[i]]
        w = w / w.sum()
        for k, e in enumerate(order[i]):
            g = x[i] @ np.asarray(params["wg"][e])
            u = x[i] @ np.asarray(params["wu"][e])
            h = (g / (1 + np.exp(-g))) * u
            out[i] += w[k] * (h @ np.asarray(params["wd"][e]))
    return out


def test_routed_matches_dense_oracle():
    cfg = _cfg()
    params = init_params(moe.moe_defs(cfg), jax.random.key(0), jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 5, cfg.d_model)) * 0.7
    got = moe.moe_ffn(x, params, cfg)
    ref = _dense_oracle(np.asarray(x).reshape(10, cfg.d_model), params, cfg)
    np.testing.assert_allclose(np.asarray(got).reshape(10, -1), ref,
                               atol=2e-5)


def test_capacity_drops_reduce_output_norm():
    """Tiny capacity drops tokens -> output shrinks, never NaNs."""
    params = init_params(moe.moe_defs(_cfg()), jax.random.key(0), jnp.float32)
    x = jax.random.normal(jax.random.key(2), (1, 64, 16))
    full = moe.moe_ffn(x, params, _cfg(capacity_factor=2.0))
    tight = moe.moe_ffn(x, params, _cfg(capacity_factor=0.25))
    assert bool(jnp.all(jnp.isfinite(tight)))
    assert float(jnp.linalg.norm(tight)) <= float(jnp.linalg.norm(full)) + 1e-3


def test_shared_experts_added():
    cfg0 = _cfg()
    cfg2 = _cfg(n_shared_experts=2)
    p2 = init_params(moe.moe_defs(cfg2), jax.random.key(0), jnp.float32)
    p0 = {k: v for k, v in p2.items() if k != "shared"}
    x = jax.random.normal(jax.random.key(3), (1, 4, 16))
    base = moe.moe_ffn(x, p0, cfg0)
    both = moe.moe_ffn(x, p2, cfg2)
    from repro.models import layers
    shared = layers.mlp(x, p2["shared"], cfg2)
    np.testing.assert_allclose(np.asarray(both), np.asarray(base + shared),
                               atol=1e-5)


def test_route_renormalises():
    cfg = _cfg()
    rw = jax.random.normal(jax.random.key(4), (16, 4))
    x = jax.random.normal(jax.random.key(5), (7, 16))
    w, idx = moe._route(x, rw, cfg)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert int(idx.max()) < 4 and int(idx.min()) >= 0
