"""REQUIRED per-arch smoke tests: reduced same-family config, one forward
and one optimizer train step on CPU, assert output shapes + no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, reduced
from repro.launch.specs import concrete_batch
from repro.launch.train import TrainHParams, make_train_state, make_train_step
from repro.models.model import Model


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    batch = concrete_batch(cfg, 2, 16, train=True)

    # forward: logits shape + finite
    params = model.init(jax.random.key(0))
    logits = model.forward(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits))), arch

    # one full train step (grads + AdamW) moves the loss and stays finite
    hp = TrainHParams(lr=1e-3, warmup_steps=1, total_steps=10, grad_accum=2)
    state = make_train_state(model, hp, jax.random.key(1))
    step = jax.jit(make_train_step(model, hp))
    state, m1 = step(state, batch)
    state, m2 = step(state, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"]) + 0.5  # no blow-up
    assert int(state["step"]) == 2
    # params actually changed
    l0 = jax.tree.leaves(state["params"])[0]
    assert bool(jnp.any(l0 != jax.tree.leaves(params)[0]))


@pytest.mark.parametrize("arch", ["zamba2_7b", "deepseek_v3_671b"])
def test_full_config_structure(arch):
    """FULL configs build abstract params only (no allocation)."""
    cfg = get_config(arch)
    model = Model(cfg)
    abstract = model.abstract()
    n = sum(np.prod(l.shape) for l in jax.tree.leaves(abstract))
    if arch == "deepseek_v3_671b":
        assert 6.3e11 < n < 7.3e11, n   # ~671B params
    specs = model.specs()
    assert (jax.tree.structure(jax.tree.map(lambda x: 0, abstract))
            == jax.tree.structure(jax.tree.map(lambda x: 0, specs,
                                               is_leaf=lambda s: isinstance(s, tuple))))
