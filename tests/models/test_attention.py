"""Attention: chunked==full, GQA grouping, RoPE properties, MLA absorption."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.config import ModelConfig


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=1, d_model=32, n_heads=4,
                n_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64,
                param_dtype="float32", compute_dtype="float32", remat="none")
    base.update(kw)
    return ModelConfig(**base)


def test_chunked_equals_full(monkeypatch):
    cfg = _cfg()
    key = jax.random.key(0)
    q = jax.random.normal(key, (2, 32, 4, 8))
    k = jax.random.normal(jax.random.key(1), (2, 32, 2, 8))
    v = jax.random.normal(jax.random.key(2), (2, 32, 2, 8))
    full = layers.sdpa(q, k, v, cfg, causal=True)
    monkeypatch.setattr(layers, "Q_CHUNK_THRESHOLD", 16)
    monkeypatch.setattr(layers, "Q_CHUNK", 8)
    chunked = layers.sdpa(q, k, v, cfg, causal=True)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               atol=1e-5)


def test_gqa_equals_repeated_kv():
    cfg = _cfg()
    q = jax.random.normal(jax.random.key(0), (1, 8, 4, 8))
    k = jax.random.normal(jax.random.key(1), (1, 8, 2, 8))
    v = jax.random.normal(jax.random.key(2), (1, 8, 2, 8))
    out = layers.sdpa(q, k, v, cfg, causal=True)
    # oracle: repeat kv heads to 4 and run MHA
    k4 = jnp.repeat(k, 2, axis=2)
    v4 = jnp.repeat(v, 2, axis=2)
    cfg4 = _cfg(n_kv_heads=4)
    # heads interleave as (kv, group): head h uses kv h//2
    ref = layers.sdpa(q, k4, v4, cfg4, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_causal_mask():
    """Changing future tokens never changes past outputs."""
    cfg = _cfg()
    q = jax.random.normal(jax.random.key(0), (1, 8, 4, 8))
    k = jax.random.normal(jax.random.key(1), (1, 8, 2, 8))
    v = jax.random.normal(jax.random.key(2), (1, 8, 2, 8))
    out1 = layers.sdpa(q, k, v, cfg, causal=True)
    k2 = k.at[:, 5:].set(99.0)
    v2 = v.at[:, 5:].set(-99.0)
    out2 = layers.sdpa(q, k2, v2, cfg, causal=True)
    np.testing.assert_allclose(np.asarray(out1[:, :5]),
                               np.asarray(out2[:, :5]), atol=1e-5)


def test_rope_preserves_norm_and_relativity():
    angles = layers.rope_angles(jnp.arange(16)[None], 8, 10000.0)
    x = jax.random.normal(jax.random.key(0), (1, 16, 2, 8))
    r = layers.apply_rope(x, angles)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(r), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(jax.random.key(1), (1, 1, 1, 8))
    k = jax.random.normal(jax.random.key(2), (1, 1, 1, 8))
    def dot_at(p, d):
        aq = layers.rope_angles(jnp.array([[p]]), 8, 10000.0)
        ak = layers.rope_angles(jnp.array([[p + d]]), 8, 10000.0)
        return float(jnp.sum(layers.apply_rope(q, aq)
                             * layers.apply_rope(k, ak)))
    assert abs(dot_at(0, 3) - dot_at(7, 3)) < 1e-4


def test_partial_rope_2d_leaves_tail():
    cfg = _cfg(rope_style="2d", head_dim=8)
    angles = layers.rope_for(cfg, jnp.arange(4)[None])
    assert angles.shape[-1] == 2          # rotates first half of the dims
    x = jnp.ones((1, 4, 1, 8))
    r = layers.apply_rope(x, angles)
    np.testing.assert_allclose(np.asarray(r[..., 4:]), 1.0, atol=1e-6)


def test_mrope_sections():
    cfg = _cfg(rope_style="mrope", head_dim=16)
    pos = jnp.broadcast_to(jnp.arange(6)[None, None], (3, 1, 6))
    angles = layers.rope_for(cfg, pos)
    assert angles.shape == (1, 6, 8)
    # identical t/h/w positions must reduce to standard rope
    std = layers.rope_angles(jnp.arange(6)[None], 16, cfg.rope_theta)
    np.testing.assert_allclose(np.asarray(angles), np.asarray(std),
                               rtol=1e-6)


def test_mla_absorbed_equals_expanded():
    from repro.models import mla
    from repro.models.config import init_params
    cfg = _cfg(attn_type="mla", n_heads=4, n_kv_heads=4, head_dim=12,
               q_lora_rank=16, kv_lora_rank=16, qk_nope_dim=8, qk_rope_dim=4,
               v_head_dim=8)
    params = init_params(mla.mla_defs(cfg), jax.random.key(0), jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 6, 32)) * 0.5
    positions = jnp.broadcast_to(jnp.arange(6), (2, 6))
    out_exp, (c_kv, k_rope) = mla.mla_attention(x, params, cfg, positions)
    # decode the last token with the absorbed path against the cache of the
    # first 5
    cache = {"c_kv": jnp.pad(c_kv[:, :5], ((0, 0), (0, 3), (0, 0))),
             "k_rope": jnp.pad(k_rope[:, :5], ((0, 0), (0, 3), (0, 0)))}
    out_dec, _ = mla.mla_decode(x[:, 5:6], params, cfg, cache, jnp.int32(5))
    np.testing.assert_allclose(np.asarray(out_dec[:, 0]),
                               np.asarray(out_exp[:, 5]), atol=2e-4)
