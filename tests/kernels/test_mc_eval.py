"""Pallas mc_eval kernel: shape/dtype sweep vs the pure-jnp oracle."""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import family_sums, finalize, harmonic_family
from repro.core import rng
from repro.kernels.mc_eval.kernel import S_BLK
from repro.kernels.mc_eval.ops import mc_eval_harmonic
from repro.kernels.mc_eval.ref import mc_harmonic_ref

KEY = rng.fold_key(31, 0)


def _ref(fam, n_samples, key, fn_offset=0, sample_offset=0):
    n_fn, dim = fam.n_fn, fam.dim
    scalars = jnp.array([key[0], key[1], sample_offset, n_samples],
                        jnp.uint32)
    return mc_harmonic_ref(
        scalars,
        jnp.uint32(fn_offset) + jnp.arange(n_fn, dtype=jnp.uint32),
        jnp.asarray(fam.params["a"]).reshape(n_fn, 1),
        jnp.asarray(fam.params["b"]).reshape(n_fn, 1),
        jnp.asarray(fam.params["k"]),
        fam.domains[..., 0], fam.domains[..., 1],
        dim=dim, n_sample_blocks=max(1, math.ceil(n_samples / S_BLK)))


@pytest.mark.parametrize("n_fn", [1, 5, 16, 33])
@pytest.mark.parametrize("dim", [1, 4])
def test_kernel_vs_ref_shapes(n_fn, dim):
    fam = harmonic_family(n_fn, dim)
    n = S_BLK + 777   # exercises the tail mask
    got = mc_eval_harmonic(fam, n, KEY)
    ref = _ref(fam, n, KEY)
    np.testing.assert_allclose(np.asarray(got.s1), np.asarray(ref[:, 0]),
                               rtol=5e-5, atol=5e-3)
    np.testing.assert_allclose(np.asarray(got.s2), np.asarray(ref[:, 1]),
                               rtol=5e-5, atol=5e-3)


@pytest.mark.parametrize("n_samples", [100, S_BLK, 3 * S_BLK + 13])
def test_kernel_sample_counts(n_samples):
    fam = harmonic_family(4, 3)
    got = mc_eval_harmonic(fam, n_samples, KEY)
    ref = _ref(fam, n_samples, KEY)
    np.testing.assert_allclose(np.asarray(got.s1), np.asarray(ref[:, 0]),
                               rtol=5e-5, atol=5e-3)
    assert float(got.n) == n_samples


def test_kernel_vs_engine_estimates():
    """Kernel fast path and pure-JAX engine agree statistically exactly
    (same Threefry counters)."""
    fam = harmonic_family(10, 4)
    n = 2 * S_BLK
    rk = finalize(fam, mc_eval_harmonic(fam, n, KEY))
    rj = finalize(fam, family_sums(fam, n, KEY, chunk=S_BLK))
    np.testing.assert_allclose(np.asarray(rk.mean), np.asarray(rj.mean),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(rk.stderr), np.asarray(rj.stderr),
                               rtol=1e-3)


def test_kernel_offsets_match_engine():
    """fn_offset / sample_offset address the same counter space."""
    fam = harmonic_family(6, 2)
    got = mc_eval_harmonic(fam, S_BLK, KEY, fn_offset=100,
                           sample_offset=12345)
    eng = family_sums(fam, S_BLK, KEY, fn_offset=100, sample_offset=12345,
                      chunk=S_BLK)
    np.testing.assert_allclose(np.asarray(got.s1), np.asarray(eng.s1),
                               rtol=5e-5, atol=5e-3)


def test_registry_dispatch():
    from repro.kernels import registry
    fam = harmonic_family(3, 4)
    assert fam.kernel == "mc_eval_harmonic"
    impl = registry.get("mc_eval_harmonic")
    out = impl(fam, 1000, KEY)
    eng = family_sums(fam, 1000, KEY, use_kernel=True)
    np.testing.assert_allclose(np.asarray(out.s1), np.asarray(eng.s1),
                               rtol=1e-6)


def test_kernel_output_dtypes():
    fam = harmonic_family(2, 2)
    out = mc_eval_harmonic(fam, 500, KEY)
    assert out.s1.dtype == jnp.float32
    assert out.s2.dtype == jnp.float32


@pytest.mark.parametrize("n_fn,dim", [(3, 2), (16, 4), (20, 7)])
def test_sobol_kernel_vs_engine(n_fn, dim):
    """Fused RQMC kernel == pure-JAX sobol path (same shifts, same points)."""
    fam = harmonic_family(n_fn, dim)
    n = S_BLK + 321
    kq = family_sums(fam, n, KEY, use_kernel=True, sampler="sobol")
    eq = family_sums(fam, n, KEY, use_kernel=False, sampler="sobol",
                     chunk=S_BLK)
    np.testing.assert_allclose(np.asarray(kq.s1), np.asarray(eq.s1),
                               rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(np.asarray(kq.s2), np.asarray(eq.s2),
                               rtol=1e-4, atol=1e-2)


@pytest.mark.parametrize("name", ["oscillatory", "corner_peak"])
@pytest.mark.parametrize("dim", [2, 4])
def test_genz_kernel_forms_vs_engine(name, dim):
    """Registered Genz forms run the fused kernel == chunked JAX path."""
    from repro.core import genz
    fam, _ = genz.ALL[name](6, dim)
    assert fam.kernel is not None
    n = S_BLK + 100
    kq = family_sums(fam, n, KEY, use_kernel=True)
    eq = family_sums(fam, n, KEY, use_kernel=False, chunk=S_BLK)
    np.testing.assert_allclose(np.asarray(kq.s1), np.asarray(eq.s1),
                               rtol=5e-5, atol=5e-3)
    np.testing.assert_allclose(np.asarray(kq.s2), np.asarray(eq.s2),
                               rtol=5e-5, atol=5e-3)


@pytest.mark.parametrize("name", ["oscillatory", "corner_peak"])
def test_genz_kernel_estimates_accurate(name):
    """Kernel-path Genz estimates hit the known closed forms."""
    from repro.core import genz
    fam, exact = genz.ALL[name](6, 3)
    res = finalize(fam, family_sums(fam, 8 * S_BLK, KEY, use_kernel=True))
    assert np.all(np.abs(np.asarray(res.mean) - exact)
                  <= 5 * np.asarray(res.stderr) + 1e-4)


def test_genz_families_fuse_into_buckets():
    """Grid-scan service workloads stay on the fused kernel path."""
    from repro.core import genz
    from repro.core.integrand import MultiFunctionSpec
    from repro.kernels.mc_eval import multi
    fams = [genz.oscillatory(5, 3)[0], genz.corner_peak(4, 3)[0],
            harmonic_family(3, 3)]
    plan = multi.plan_spec(MultiFunctionSpec.from_families(fams))
    assert not plan.unfused
    assert plan.n_launches == 1       # one dim -> one fused launch


def test_sobol_kernel_estimates_accurate():
    from repro.core import harmonic_analytic
    fam = harmonic_family(8, 4)
    res = finalize(fam, family_sums(fam, 4 * S_BLK, KEY, use_kernel=True,
                                    sampler="sobol"))
    exact = harmonic_analytic(8, 4)
    # RQMC at 8k samples is far tighter than the MC stderr formula (which
    # still upper-bounds the error)
    assert np.all(np.abs(np.asarray(res.mean) - exact)
                  <= 5 * np.asarray(res.stderr) + 1e-4)
