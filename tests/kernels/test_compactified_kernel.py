"""Compactified families on the fused kernel path: cross-path parity.

Infinite-domain integrands reach the kernels through a static per-axis
transform (kind + shift packed as parameter columns) applied by a
wrapper stage around the registered eval body
(``template.compactified_body``).  The invariants asserted here:

* **parity** — fused kernel sums match the chunked JAX path (both apply
  the identical ``domains.apply_transform``; only f32 fold order
  differs) for fully-infinite and half-infinite boxes, mc and sobol,
  single-device and mesh;
* **accuracy** — kernel-path estimates hit the analytic Gaussian values
  over R^d and [0, inf)^d within their reported stderr;
* **no fallback** — a mixed finite/infinite batch buckets into fused
  launches with zero families left to the chunked path, at the planner
  level (``plan.unfused``) and through the live service engine
  (launch count == buckets, ``RoundBatcher.fallback_rounds == 0``).
"""

import jax
import numpy as np
import pytest

from repro.core import (MultiFunctionSpec, family_sums, finalize,
                        gaussian_analytic, gaussian_family, harmonic_family)
from repro.core import rng as rng_lib
from repro.kernels import template
from repro.kernels.mc_eval import multi

KEY = rng_lib.fold_key(11, 0)
N = 4096 + 321   # off a block multiple: exercises the tail mask
R = 4096


def gaussian_inf(n, dim):
    return gaussian_family(n, dim, lo=-np.inf, hi=np.inf)


def gaussian_half(n, dim):
    return gaussian_family(n, dim, lo=0.0, hi=np.inf)


def harmonic_half(n, dim):
    return harmonic_family(n, dim, lo=0.0, hi=np.inf)


# -- fused vs chunked parity --------------------------------------------------

@pytest.mark.parametrize("sampler", ["mc", "sobol"])
@pytest.mark.parametrize("maker", [gaussian_inf, gaussian_half])
def test_fused_matches_chunked(maker, sampler):
    """Kernel and chunked paths draw the same counters and apply the
    same transform — sums agree up to f32 association order."""
    fam = maker(5, 3).compactified()
    assert fam.compact and fam.kernel is not None
    template.reset_launch_count()
    k = family_sums(fam, N, KEY, use_kernel=True, sampler=sampler)
    assert template.launch_count() == 1, "compactified family fell back"
    c = family_sums(fam, N, KEY, use_kernel=False, sampler=sampler,
                    chunk=1024)
    np.testing.assert_allclose(np.asarray(k.s1), np.asarray(c.s1),
                               rtol=5e-3, atol=1e-2)
    np.testing.assert_allclose(np.asarray(k.s2), np.asarray(c.s2),
                               rtol=5e-3, atol=1e-2)


@pytest.mark.parametrize("sampler", ["mc", "sobol"])
def test_harmonic_half_infinite_same_transform(sampler):
    """Harmonic over [0, inf)^d: both paths apply the same transform.

    The integral diverges and the dominant samples evaluate cos at
    phases ~1e6, where f32 phase accumulation error alone is O(0.1 rad)
    — so *any* two f32 evaluation orders disagree at O(10%) on the sums
    and elementwise parity is ill-posed.  What IS well-posed: the
    Jacobian-amplified magnitude.  A missing or wrong transform moves s2
    by orders of magnitude; same-order agreement pins the wrapper stage
    without asserting meaningless digits.
    """
    fam = harmonic_half(5, 3).compactified()
    template.reset_launch_count()
    k = family_sums(fam, N, KEY, use_kernel=True, sampler=sampler)
    assert template.launch_count() == 1, "compactified family fell back"
    c = family_sums(fam, N, KEY, use_kernel=False, sampler=sampler,
                    chunk=1024)
    ks2, cs2 = np.asarray(k.s2), np.asarray(c.s2)
    assert np.all(ks2 > 0) and np.all(cs2 > 0)
    np.testing.assert_allclose(np.log10(ks2), np.log10(cs2), atol=0.5)


def test_compactified_offsets_match_chunked():
    """fn_offset / sample_offset address the same counter space on the
    wrapped body (the service cache's resume invariant)."""
    fam = gaussian_inf(4, 2).compactified()
    k = family_sums(fam, R, KEY, fn_offset=37, sample_offset=5 * R,
                    use_kernel=True)
    c = family_sums(fam, R, KEY, fn_offset=37, sample_offset=5 * R,
                    use_kernel=False, chunk=1024)
    np.testing.assert_allclose(np.asarray(k.s1), np.asarray(c.s1),
                               rtol=1e-4, atol=1e-3)


# -- analytic accuracy --------------------------------------------------------

@pytest.mark.parametrize("sampler", ["mc", "sobol"])
@pytest.mark.parametrize("half", [False, True])
def test_gaussian_analytic_values(half, sampler):
    """int exp(-|x|^2 / 2 sigma^2) over R^d (and its positive orthant)
    lands on (sigma sqrt(2 pi))^d within the reported stderr."""
    maker = gaussian_half if half else gaussian_inf
    fam = maker(3, 3).compactified()
    res = finalize(fam, family_sums(fam, 16 * R, KEY, use_kernel=True,
                                    sampler=sampler))
    exact = gaussian_analytic(3, 3, half=half)
    assert np.all(np.abs(np.asarray(res.mean) - exact)
                  <= 6 * np.asarray(res.stderr) + 1e-3), (res.mean, exact)


# -- fusion: mixed finite / infinite buckets ----------------------------------

def _mixed_spec():
    return MultiFunctionSpec.from_families([
        harmonic_family(4, 3),
        gaussian_inf(3, 3).compactified(),
        gaussian_half(2, 3).compactified(),
    ])


def test_mixed_bucket_no_fallback():
    """Finite and compactified families of one dim share ONE launch."""
    spec = _mixed_spec()
    plan = multi.plan_spec(spec)
    assert plan.unfused == ()
    assert plan.n_launches == 1
    # the wrapper gives the compactified gaussians a distinct switch body
    assert len(plan.buckets[0].bodies) == 2
    out = multi.eval_plan(plan, N, KEY)
    offs = spec.offsets()
    for i, fam in enumerate(spec.families):
        ref = family_sums(fam, N, KEY, fn_offset=offs[i], use_kernel=False,
                          chunk=1024)
        np.testing.assert_allclose(np.asarray(out[i].s1),
                                   np.asarray(ref.s1), rtol=1e-4, atol=1e-2)


def test_compactified_wrapper_identity_is_shared():
    """Two plans of the same compactified form reuse ONE wrapped body, so
    buckets dedupe bodies and the jit compile cache keys stay stable."""
    a = multi.plan_spec(MultiFunctionSpec.from_families(
        [gaussian_inf(3, 3).compactified()]))
    b = multi.plan_spec(MultiFunctionSpec.from_families(
        [gaussian_half(2, 3).compactified()]))
    assert a.buckets[0].bodies == b.buckets[0].bodies


def test_multiround_compactified_bit_identical():
    """R rounds of a mixed finite/infinite bucket in one launch: each
    round bit-identical to its own single-round launch."""
    plan = multi.plan_spec(_mixed_spec())
    fused = multi.eval_plan_rounds(plan, R, 3, KEY,
                                   start_rounds={0: 0, 1: 0, 2: 0})
    for r in range(3):
        single = multi.eval_plan(plan, R, KEY, sample_offset=r * R)
        for fam in single:
            np.testing.assert_array_equal(np.asarray(fused[fam][r].s1),
                                          np.asarray(single[fam].s1))
            np.testing.assert_array_equal(np.asarray(fused[fam][r].s2),
                                          np.asarray(single[fam].s2))


def test_sharded_compactified_matches_single_device():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    plan = multi.plan_spec(_mixed_spec())
    single = multi.eval_plan(plan, R, KEY)
    sharded = multi.sharded_eval_plan(plan, R, KEY, mesh)
    for i in single:
        np.testing.assert_array_equal(np.asarray(single[i].s1),
                                      np.asarray(sharded[i].s1))
        np.testing.assert_array_equal(np.asarray(single[i].s2),
                                      np.asarray(sharded[i].s2))
    starts = {0: 2, 1: 0, 2: 1}
    fused = multi.eval_plan_rounds(plan, R, 2, KEY, start_rounds=starts)
    shr = multi.sharded_eval_plan_rounds(plan, R, 2, KEY, mesh,
                                         start_rounds=starts)
    for i in fused:
        for r in range(2):
            np.testing.assert_array_equal(np.asarray(fused[i][r].s1),
                                          np.asarray(shr[i][r].s1))


def test_unregistered_compactified_family_still_falls_back():
    """A compactified family without a registered form keeps the chunked
    path (capability miss, not a crash)."""
    import jax.numpy as jnp
    from repro.core.integrand import IntegrandFamily
    fam = IntegrandFamily(
        fn=lambda x, p: p["s"] * jnp.exp(-jnp.sum(jnp.abs(x), -1)),
        params={"s": jnp.ones(3)},
        domains=jnp.asarray(np.broadcast_to([0.0, np.inf],
                                            (3, 2, 2)).copy()),
        name="exp").validate().compactified()
    plan = multi.plan_spec(MultiFunctionSpec.from_families([fam]))
    assert plan.unfused == (0,)
    template.reset_launch_count()
    sums = family_sums(fam, R, KEY, use_kernel=True)
    assert template.launch_count() == 0
    assert np.all(np.isfinite(np.asarray(sums.s1)))


# -- service engine: infinite-domain requests stay fused ----------------------

def test_service_mixed_batch_entirely_fused():
    """A mixed batch of finite and infinite-domain requests is served by
    fused kernels only: launches == (dim, sampler) buckets, zero chunked
    fallbacks, and the infinite-domain answers are right."""
    from repro.service import IntegrationEngine, IntegrationRequest
    engine = IntegrationEngine(seed=0, round_samples=R,
                               max_rounds_per_wave=8)
    reqs = [
        IntegrationRequest.make([gaussian_family(4, 3)], n_samples=2 * R),
        IntegrationRequest.make([gaussian_inf(4, 3)], n_samples=2 * R),
        IntegrationRequest.make([gaussian_half(3, 2)], n_samples=2 * R),
        IntegrationRequest.make([harmonic_family(4, 2)], n_samples=2 * R),
    ]
    tickets = [engine.submit(r) for r in reqs]
    template.reset_launch_count()
    while engine.step():
        pass
    assert template.launch_count() == 2          # dims {2, 3} -> 2 buckets
    assert engine.batcher.fallback_rounds == 0
    results = [engine.poll(t) for t in tickets]
    assert all(r is not None for r in results)
    exact = gaussian_analytic(4, 3)
    assert np.all(np.abs(results[1].means - exact)
                  <= 6 * results[1].stderrs + 1e-3)


def test_service_infinite_domain_warm_restart_bit_identical(tmp_path):
    """An infinite-domain stream journals, restarts and tops up exactly
    like a finite one now that it runs on the kernel path."""
    from repro.service import IntegrationClient, IntegrationEngine
    fams = [gaussian_inf(4, 3)]
    e1 = IntegrationEngine(seed=0, round_samples=R,
                           state_dir=str(tmp_path))
    first = IntegrationClient(e1).integrate(fams, n_samples=2 * R)
    # no close(): the journal is all that survives the "SIGKILL"
    e2 = IntegrationEngine(seed=0, round_samples=R,
                           state_dir=str(tmp_path))
    template.reset_launch_count()
    again = IntegrationClient(e2).integrate(fams, n_samples=2 * R)
    assert template.launch_count() == 0 and again.served_from_cache
    np.testing.assert_array_equal(first.means, again.means)
    # top-up pays only the delta round, still fused
    topped = IntegrationClient(e2).integrate(fams, n_samples=3 * R)
    assert template.launch_count() == 1
    clean = IntegrationClient(
        IntegrationEngine(seed=0, round_samples=R)).integrate(
            fams, n_samples=3 * R)
    np.testing.assert_array_equal(topped.means, clean.means)
