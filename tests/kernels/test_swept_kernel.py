"""Swept families on the fused kernel path: cross-path parity.

A parameter-grid sweep reaches the kernels as ONE swept family: the
template's packed row plus per-point table columns, substituted into
the effective parameter block in-kernel by a wrapper stage around the
registered eval body (``template.swept_body`` — the sweep analogue of
``template.compactified_body``).  The invariants asserted here:

* **bit-identity** — the fused swept family's per-round sums are byte
  identical to evaluating each grid point as its own single-function
  family at the matching global function id, for mc and sobol and for
  finite and compactified (infinite-domain) templates: same effective
  parameters, and counters depend only on (global fn id, sample id);
* **chunked parity** — the swept family evaluates on the chunked JAX
  path (table merged into the base params) to the same sums up to f32
  fold order;
* **layout** — ``sweep_col_map`` / ``packed_cols`` describe the
  ``[base][sweep][transform]`` column layout consistently, and reject
  un-sweepable parameters and width mismatches at build time;
* **construction** — ``IntegrandFamily.swept_over`` validates its
  table eagerly (single-function templates only, sweep before
  compactify, axes must agree on the point count and per-point shape).
"""

import numpy as np
import pytest

from repro.core import family_sums, gaussian_family, genz, harmonic_family
from repro.core import rng as rng_lib
from repro.kernels import registry, template

KEY = rng_lib.fold_key(23, 0)
N = 4096 + 321   # off a block multiple: exercises the tail mask
DIM = 3

A = np.linspace(0.5, 2.0, 6).astype(np.float32)
B = np.linspace(-1.0, 1.0, 6).astype(np.float32)
SIGMA = np.linspace(0.6, 1.8, 6).astype(np.float32)


def _swept(maker, **table):
    return maker(1, DIM).swept_over(table)


def _points(maker, **table):
    n_pts = len(next(iter(table.values())))
    return [maker(1, DIM, **{k: np.asarray(v[j:j + 1]) for k, v in
                             table.items()})
            for j in range(n_pts)]


def harmonic_half(n, dim, **kw):
    return harmonic_family(n, dim, lo=0.0, hi=np.inf, **kw)


# -- fused swept family vs per-point launches ---------------------------------

@pytest.mark.parametrize("sampler", ["mc", "sobol"])
def test_swept_bit_identical_to_per_point(sampler):
    """One fused launch over the grid == one launch per point, byte for
    byte, when the global function ids line up."""
    sw = _swept(harmonic_family, a=A, b=B)
    assert sw.n_fn == len(A) and sw.swept == ("a", "b")
    template.reset_launch_count()
    fused = family_sums(sw, N, KEY, use_kernel=True, sampler=sampler)
    assert template.launch_count() == 1, "swept family fell back"
    for j, pt in enumerate(_points(harmonic_family, a=A, b=B)):
        one = family_sums(pt, N, KEY, fn_offset=j, use_kernel=True,
                          sampler=sampler)
        np.testing.assert_array_equal(np.asarray(fused.s1)[j],
                                      np.asarray(one.s1)[0])
        np.testing.assert_array_equal(np.asarray(fused.s2)[j],
                                      np.asarray(one.s2)[0])


@pytest.mark.parametrize("sampler", ["mc", "sobol"])
@pytest.mark.parametrize("lo", [-np.inf, 0.0])
def test_compactified_swept_bit_identical(lo, sampler):
    """Sweep composes with compactification — the kernel wraps
    ``compactified_body(swept_body(body))`` over a
    ``[base][sweep][transform]`` packed row — without breaking
    bit-identity on fully- and half-infinite domains."""
    def maker(n, dim, **kw):
        return gaussian_family(n, dim, lo=lo, hi=np.inf, **kw)
    sw = _swept(maker, sigma=SIGMA).compactified()
    assert sw.compact and sw.swept == ("sigma",)
    template.reset_launch_count()
    fused = family_sums(sw, N, KEY, use_kernel=True, sampler=sampler)
    assert template.launch_count() == 1, "compactified sweep fell back"
    for j, pt in enumerate(_points(maker, sigma=SIGMA)):
        one = family_sums(pt.compactified(), N, KEY, fn_offset=j,
                          use_kernel=True, sampler=sampler)
        np.testing.assert_array_equal(np.asarray(fused.s1)[j],
                                      np.asarray(one.s1)[0])
        np.testing.assert_array_equal(np.asarray(fused.s2)[j],
                                      np.asarray(one.s2)[0])


def test_harmonic_half_infinite_swept_same_magnitude():
    """Harmonic over [0, inf)^d: the integral diverges and the dominant
    samples evaluate cos at phases ~1e8, where transcendental expansion
    differences between two compiled programs alone move individual
    sample values — elementwise bit-parity across program boundaries is
    ill-posed for it (same caveat as the non-swept compactified test).
    What IS well-posed: the Jacobian-amplified magnitude, which pins
    the composed sweep+transform stages to ~1e-7 of the per-point path
    without asserting meaningless digits."""
    sw = _swept(harmonic_half, a=A).compactified()
    fused = family_sums(sw, N, KEY, use_kernel=True)
    for j, pt in enumerate(_points(harmonic_half, a=A)):
        one = family_sums(pt.compactified(), N, KEY, fn_offset=j,
                          use_kernel=True)
        np.testing.assert_allclose(np.asarray(fused.s1)[j],
                                   np.asarray(one.s1)[0], rtol=1e-5)


def test_vector_valued_axis_bit_identical():
    """A dim-wide axis (harmonic's k) packs one table column per
    component and still substitutes bit-identically."""
    k = np.stack([np.full(DIM, 7.0 + j, np.float32) for j in range(4)])
    sw = _swept(harmonic_family, k=k)
    fused = family_sums(sw, N, KEY, use_kernel=True)
    for j in range(4):
        pt = harmonic_family(1, DIM, k=k[j:j + 1])
        one = family_sums(pt, N, KEY, fn_offset=j, use_kernel=True)
        np.testing.assert_array_equal(np.asarray(fused.s1)[j],
                                      np.asarray(one.s1)[0])


def test_swept_kernel_matches_chunked():
    """The chunked path merges the table into the base params in plain
    JAX — both paths draw the same counters, so sums agree up to f32
    association order."""
    sw = _swept(harmonic_family, a=A, b=B)
    k = family_sums(sw, N, KEY, use_kernel=True)
    c = family_sums(sw, N, KEY, use_kernel=False, chunk=1024)
    np.testing.assert_allclose(np.asarray(k.s1), np.asarray(c.s1),
                               rtol=5e-3, atol=1e-2)
    np.testing.assert_allclose(np.asarray(k.s2), np.asarray(c.s2),
                               rtol=5e-3, atol=1e-2)


def test_swept_body_identity_is_shared():
    """Same (body, base_cols, col_map) -> the same wrapped body object,
    so fused buckets dedupe and jit cache keys stay stable."""
    a = template.body_and_packed(registry.form("mc_eval_harmonic"),
                                 _swept(harmonic_family, a=A))
    b = template.body_and_packed(registry.form("mc_eval_harmonic"),
                                 _swept(harmonic_family, a=2 * A))
    assert a[0] is b[0]


# -- column layout ------------------------------------------------------------

def test_sweep_col_map_and_packed_cols():
    form = registry.form("mc_eval_harmonic")
    sw = _swept(harmonic_family, a=A, b=B)
    assert template.sweep_col_map(form, sw) == (0, 1)
    assert template.packed_cols(form, sw) == form.n_cols(DIM) + 2
    csw = _swept(harmonic_half, a=A).compactified()
    assert template.packed_cols(form, csw) == form.n_cols(DIM) + 1 + 2 * DIM
    _, packed = template.body_and_packed(form, csw)
    assert packed.shape == (len(A), template.packed_cols(form, csw))


def test_sweep_col_map_rejects_unsweepable_name():
    """genz_osc's "u" enters the packed row only through u[:, :1] — the
    form excludes it from sweep_cols, and the layout builder says so."""
    fam, _ = genz.oscillatory(1, DIM)
    sw = fam.swept_over({"u": np.linspace(0.1, 0.9, 4)[:, None]
                         * np.ones(DIM, np.float32)})
    with pytest.raises(ValueError, match="cannot sweep parameter 'u'"):
        template.sweep_col_map(registry.form("mc_eval_genz_osc"), sw)


def test_sweep_col_map_rejects_width_mismatch():
    """A table leaf whose per-point width disagrees with the form's
    column map fails at build time, not inside the kernel."""
    import dataclasses
    form = registry.form("mc_eval_harmonic")
    bad = dataclasses.replace(form, name="bad",
                              sweep_cols=lambda dim: {"a": (0, 1)})
    sw = _swept(harmonic_family, a=A)
    with pytest.raises(ValueError, match="packs 1 column"):
        template.sweep_col_map(bad, sw)


def test_sweep_col_map_requires_sweepable_form():
    import dataclasses
    form = registry.form("mc_eval_harmonic")
    none = dataclasses.replace(form, name="none", sweep_cols=None)
    with pytest.raises(ValueError, match="does not support swept"):
        template.sweep_col_map(none, _swept(harmonic_family, a=A))


# -- swept_over construction --------------------------------------------------

def test_swept_over_validates():
    tmpl = harmonic_family(1, DIM)
    with pytest.raises(ValueError, match="at least one parameter"):
        tmpl.swept_over({})
    with pytest.raises(ValueError, match="not in template params"):
        tmpl.swept_over({"nope": A})
    with pytest.raises(ValueError, match="single function"):
        harmonic_family(2, DIM).swept_over({"a": A})
    with pytest.raises(ValueError, match="before compactifying"):
        harmonic_half(1, DIM).compactified().swept_over({"a": A})
    with pytest.raises(ValueError, match="disagree on n_points"):
        tmpl.swept_over({"a": A, "b": B[:3]})
    with pytest.raises(ValueError, match="per-point shape"):
        tmpl.swept_over({"k": np.ones((4, DIM + 1), np.float32)})


def test_swept_over_chunked_semantics():
    """Row j of the swept family IS the template with table[j] merged
    over its params — checked on plain eval, no kernel involved."""
    sw = _swept(harmonic_family, a=A)
    x = np.random.default_rng(0).random((len(A), 5, DIM)).astype(np.float32)
    got = np.asarray(sw.eval_batch(x))
    for j, pt in enumerate(_points(harmonic_family, a=A)):
        want = np.asarray(pt.eval_batch(x[j:j + 1]))[0]
        np.testing.assert_allclose(got[j], want, rtol=1e-6)
