"""Stratum-moments kernel sweep vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.moments.kernel import C_BLK, R_BLK
from repro.kernels.moments.ops import stratum_moments
from repro.kernels.moments.ref import moments_ref


@pytest.mark.parametrize("rows", [1, R_BLK, 13, 2 * R_BLK + 3])
@pytest.mark.parametrize("cols", [C_BLK, 4 * C_BLK])
def test_sweep_vs_ref(rows, cols):
    x = jax.random.normal(jax.random.key(rows * 100 + cols), (rows, cols))
    x = x * jnp.arange(1, rows + 1)[:, None] + jnp.arange(rows)[:, None]
    got = stratum_moments(x)
    ref = moments_ref(x)
    np.testing.assert_allclose(np.asarray(got.count), np.asarray(ref[:, 0]))
    np.testing.assert_allclose(np.asarray(got.mean), np.asarray(ref[:, 1]),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(got.m2), np.asarray(ref[:, 2]),
                               rtol=1e-4)


def test_variance_matches_numpy():
    x = jax.random.normal(jax.random.key(0), (5, 2 * C_BLK)) * 3.0 + 7.0
    got = stratum_moments(x)
    np.testing.assert_allclose(np.asarray(got.variance),
                               np.var(np.asarray(x), axis=1, ddof=1),
                               rtol=1e-4)


def test_rejects_ragged_columns():
    with pytest.raises(ValueError):
        stratum_moments(jnp.zeros((4, C_BLK + 1)))
