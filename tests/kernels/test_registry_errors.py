"""Registry miss diagnostics: lookups name what they wanted and what is.

``registry.lookup`` historically returned ``None`` on any capability
miss so callers could fall back to the chunked path; ``required=True``
callers (the sweep planner, which has no fallback worth hiding) instead
get a ValueError that names the form, the requested capability combo
and the nearest combo the registry *does* serve — not a bare KeyError.
"""

import pytest

from repro.kernels import registry


def test_lookup_miss_returns_none_by_default():
    assert registry.lookup("no_such_form", dim=3) is None
    assert registry.lookup("mc_eval_harmonic", dim=3,
                           sweep=("nope",)) is None


def test_lookup_hit_with_sweep_capability():
    impl = registry.lookup("mc_eval_harmonic", dim=3, sweep=("a", "b"))
    assert callable(impl)
    assert callable(registry.lookup("mc_eval_harmonic", dim=3,
                                    sampler="sobol", compactified=True,
                                    sweep=("a",)))


def test_required_unknown_form_names_registry():
    with pytest.raises(ValueError) as ei:
        registry.lookup("no_such_form", dim=3, required=True)
    msg = str(ei.value)
    assert "no_such_form" in msg
    assert "mc_eval_harmonic" in msg          # lists what IS registered


def test_required_unsweepable_param_names_sweepable_set():
    with pytest.raises(ValueError) as ei:
        registry.lookup("mc_eval_harmonic", dim=3, sweep=("sigma",),
                        required=True)
    msg = str(ei.value)
    assert "mc_eval_harmonic" in msg
    assert "sigma" in msg and "not sweepable" in msg
    # the nearest-supported hint names what the form CAN sweep
    assert "nearest supported" in msg and "'a'" in msg and "'b'" in msg


def test_required_bad_sampler_states_request_and_support():
    with pytest.raises(ValueError) as ei:
        registry.lookup("mc_eval_harmonic", dim=3, sampler="qmc",
                        required=True)
    msg = str(ei.value)
    assert "'qmc'" in msg and "dim=3" in msg
    assert "nearest supported" in msg


def test_required_dim_overflow_reports_max_dim():
    form = registry.form("mc_eval_harmonic")
    with pytest.raises(ValueError) as ei:
        registry.lookup("mc_eval_harmonic", dim=form.max_dim + 1,
                        required=True)
    assert f"max_dim {form.max_dim}" in str(ei.value)


def test_impl_keyerror_lists_registry_and_sampler_naming():
    with pytest.raises(KeyError) as ei:
        registry.impl("no_such_impl")
    msg = str(ei.value)
    assert "no_such_impl" in msg
    assert "<form>@<sampler>" in msg          # the naming-scheme hint
