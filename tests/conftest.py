import os
import sys

# tests must see ONE cpu device (the dry-run sets its own 512-device flag
# in a separate process); never inherit a stray XLA_FLAGS
os.environ.pop("XLA_FLAGS", None)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
