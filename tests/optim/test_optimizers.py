"""AdamW / Adafactor from scratch: convergence + state spec shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adafactor, adamw, constant, make_optimizer,
                         opt_state_specs, warmup_cosine)


def _quadratic_target():
    a = jnp.asarray(np.random.default_rng(0).standard_normal((6, 6)),
                    jnp.float32)
    target = {"w": jnp.ones((6, 6)) * 2.0, "b": jnp.full((6,), -1.0)}

    def loss(p):
        return (jnp.sum(jnp.square(p["w"] - target["w"]))
                + jnp.sum(jnp.square(p["b"] - target["b"])))
    return loss, target


@pytest.mark.parametrize("kind,lr", [("adamw", 0.05), ("adafactor", 0.1)])
def test_converges_on_quadratic(kind, lr):
    loss, target = _quadratic_target()
    opt = make_optimizer(kind, lr, weight_decay=0.0)
    params = {"w": jnp.zeros((6, 6)), "b": jnp.zeros((6,))}
    state = opt.init(params)
    for step in range(200):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, jnp.int32(step))
    assert float(loss(params)) < 1e-2, (kind, float(loss(params)))


def test_adafactor_factored_path_converges():
    opt = adafactor(0.1, min_dim_size_to_factor=4)
    target = jnp.asarray(np.random.default_rng(1).standard_normal((8, 16)),
                         jnp.float32)
    loss = lambda p: jnp.sum(jnp.square(p["w"] - target))
    params = {"w": jnp.zeros((8, 16))}
    state = opt.init(params)
    assert set(state["w"]) == {"vr", "vc"}   # actually factored
    for step in range(300):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, jnp.int32(step))
    assert float(loss(params)) < 1e-2


def test_adamw_weight_decay_shrinks():
    opt = adamw(0.1, weight_decay=0.5)
    params = {"w": jnp.full((4, 4), 10.0)}
    state = opt.init(params)
    zeros = {"w": jnp.zeros((4, 4))}
    p2, _ = opt.update(zeros, state, params, jnp.int32(0))
    assert float(jnp.abs(p2["w"]).max()) < 10.0


def test_adamw_moment_dtype():
    opt = adamw(0.1, moment_dtype=jnp.bfloat16)
    st = opt.init({"w": jnp.zeros((2, 2))})
    assert st["mu"]["w"].dtype == jnp.bfloat16


def test_adafactor_factored_state_memory():
    opt = adafactor(0.1)
    p = {"big": jnp.zeros((512, 256)), "small": jnp.zeros((8,))}
    st = opt.init(p)
    assert set(st["big"]) == {"vr", "vc"}
    assert st["big"]["vr"].shape == (512,)
    assert st["big"]["vc"].shape == (256,)
    assert set(st["small"]) == {"v"}


def test_opt_state_specs_match_init():
    ab = {"big": jax.ShapeDtypeStruct((512, 256), jnp.float32),
          "small": jax.ShapeDtypeStruct((8,), jnp.float32)}
    sp = {"big": ("embed", "mlp"), "small": ("embed",)}
    s_ada = opt_state_specs("adafactor", ab, sp)
    assert s_ada["big"] == {"vr": ("embed",), "vc": ("mlp",)}
    assert s_ada["small"] == {"v": ("embed",)}
    s_adam = opt_state_specs("adamw", ab, sp)
    assert s_adam["mu"]["big"] == ("embed", "mlp")


def test_warmup_cosine_shape():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(100)) < float(s(50)) < float(s(10))
    assert float(s(200)) >= 0.1 - 1e-6   # floor
