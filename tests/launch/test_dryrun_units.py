"""Dry-run machinery units (no 512-device init in this process)."""

import numpy as np

from repro.configs import get_config
from repro.configs.shapes import SHAPES, cell_status, runnable_cells


def test_cell_skips():
    ok, why = cell_status(get_config("hubert-xlarge"), SHAPES["decode_32k"])
    assert not ok and "encoder" in why
    ok, why = cell_status(get_config("qwen2.5-32b"), SHAPES["long_500k"])
    assert not ok and "sub-quadratic" in why
    ok, _ = cell_status(get_config("zamba2-7b"), SHAPES["long_500k"])
    assert ok
    ok, _ = cell_status(get_config("mamba2-130m"), SHAPES["long_500k"])
    assert ok


def test_runnable_cell_count():
    from repro.configs import all_configs
    cells = runnable_cells(all_configs())
    # 40 - 7 full-attn long_500k - 2 hubert decode shapes = 31
    assert len(cells) == 31, len(cells)


def test_parse_collectives():
    from repro.launch.dryrun import _shape_bytes, parse_collectives
    hlo = """
  %ar = bf16[8,128]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = f32[16,4]{1,0} all-gather(%y), dimensions={0}
  %cp = u32[4]{0} collective-permute(%z)
  %a2a = bf16[2,2]{1,0} all-to-all(%w)
  %ars = bf16[8,128]{1,0} all-reduce-start(%x)
  %other = f32[9999]{0} add(%a, %b)
"""
    out = parse_collectives(hlo)
    assert out["all-reduce"]["count"] == 2
    assert out["all-reduce"]["bytes"] == 2 * 8 * 128 * 2
    assert out["all-gather"]["count"] == 1
    assert out["collective-permute"]["count"] == 1
    assert out["all-to-all"]["count"] == 1
    assert _shape_bytes("(f32[2,2], bf16[4])") == 16 + 8


def test_model_flops_estimate_dsv3_active_params():
    from repro.launch.dryrun import model_flops_estimate
    est = model_flops_estimate(get_config("deepseek-v3-671b"),
                               SHAPES["train_4k"])
    assert 6.3e11 < est["n_params"] < 7.3e11
    assert 3.0e10 < est["n_active"] < 5.5e10     # ~37B active
    assert est["model_flops"] == 6.0 * est["n_active"] * est["tokens"]


def test_input_specs_shapes():
    from repro.launch.specs import batch_logical_axes, input_specs
    cfg = get_config("qwen2-vl-7b")
    sp = input_specs(cfg, SHAPES["train_4k"])
    assert sp["tokens"].shape == (256, 4096)
    assert sp["positions"].shape == (3, 256, 4096)
    ax = batch_logical_axes(cfg, SHAPES["train_4k"])
    assert ax["positions"][1] == "batch"
    dec = input_specs(cfg, SHAPES["decode_32k"])
    assert dec["tokens"].shape == (128, 1)
    assert dec["pos"].shape == ()


def test_roofline_terms_sane():
    from benchmarks.roofline import analytic_terms
    from repro.launch.dryrun import model_flops_estimate
    cfg = get_config("qwen2.5-32b")
    m = model_flops_estimate(cfg, SHAPES["train_4k"])
    t = analytic_terms("qwen2.5-32b", "train_4k", 256, m)
    assert t["compute_s"] > 0 and t["memory_s"] > 0 and t["collective_s"] > 0
    assert t["dominant"] in ("compute", "memory", "collective")
    assert 0 < t["roofline_fraction"] <= 1.0
    assert 0 < t["useful_ratio"] <= 1.2
