"""Multi-host launcher helpers (single-process semantics)."""

import os

from repro.launch import multihost


def test_initialize_noop_without_env(monkeypatch):
    for var in ("REPRO_COORD", "REPRO_NUM_PROCS", "REPRO_PROC_ID",
                "TPU_WORKER_HOSTNAMES", "MEGASCALE_COORDINATOR_ADDRESS"):
        monkeypatch.delenv(var, raising=False)
    assert multihost.initialize_if_needed(verbose=False) is False


def test_host_batch_rows_single_process():
    s = multihost.host_batch_rows(256)
    assert (s.start, s.stop) == (0, 256)


def test_scripts_exist_and_are_executable_shell():
    base = os.path.join(os.path.dirname(multihost.__file__), "scripts")
    for name in ("train_pod.sh", "integrate_pod.sh"):
        path = os.path.join(base, name)
        assert os.path.exists(path), path
        head = open(path).readline()
        assert head.startswith("#!"), path
