"""Fault tolerance of the integration driver: resume == uninterrupted."""

import os

import numpy as np
import pytest

from repro.core import ZMCMultiFunctions, harmonic_family


@pytest.fixture
def zmc():
    return ZMCMultiFunctions([harmonic_family(6, 3)], n_samples=60_000,
                             seed=11)


def test_resume_equals_uninterrupted(zmc, tmp_path):
    try:
        zmc.evaluate_resumable(rounds=6, checkpoint_dir=str(tmp_path),
                               fail_after_round=2)
        raise AssertionError("injected failure did not raise")
    except RuntimeError as e:
        assert "injected" in str(e)
    resumed = zmc.evaluate_resumable(rounds=6, checkpoint_dir=str(tmp_path))
    clean = zmc.evaluate_resumable(rounds=6, checkpoint_dir=None)
    np.testing.assert_allclose(resumed.means, clean.means, rtol=1e-6)
    np.testing.assert_allclose(resumed.stderrs, clean.stderrs, rtol=1e-6)


def test_rounds_equals_single_shot(zmc):
    """Round-splitting never changes the estimate (counter addressing)."""
    split = zmc.evaluate_resumable(rounds=5)
    single = zmc.evaluate_resumable(rounds=1)
    np.testing.assert_allclose(split.means, single.means, rtol=1e-4,
                               atol=1e-5)


def test_checkpoint_files_atomic(zmc, tmp_path):
    try:
        zmc.evaluate_resumable(rounds=4, checkpoint_dir=str(tmp_path),
                               fail_after_round=1)
    except RuntimeError:
        pass
    files = os.listdir(tmp_path)
    assert any(f.endswith(".npz") for f in files)
    assert not any(f.endswith(".tmp.npz") for f in files), files


@pytest.mark.parametrize("use_kernel", [False, True])
def test_cache_topup_bit_identical(use_kernel):
    """Resuming from cached (s1, s2, n) == uninterrupted run, bitwise.

    The service cache quantizes budgets into fixed rounds and left-folds
    deposits in order, so a topped-up stream and an uninterrupted stream
    perform the *same* f32 additions — not merely statistically equal.
    """
    from repro.service import IntegrationClient, IntegrationEngine

    def engine():
        return IntegrationEngine(seed=7, round_samples=4096,
                                 use_kernel=use_kernel)

    warm = IntegrationClient(engine())
    first = warm.integrate([harmonic_family(6, 3)], n_samples=4096)
    topped = warm.integrate([harmonic_family(6, 3)], n_samples=3 * 4096)
    assert topped.n_per_family == (3 * 4096,)

    cold = IntegrationClient(engine()).integrate(
        [harmonic_family(6, 3)], n_samples=3 * 4096)
    np.testing.assert_array_equal(topped.means, cold.means)
    np.testing.assert_array_equal(topped.stderrs, cold.stderrs)
    # and the first answer really was served from the shared stream
    assert not np.array_equal(first.means, topped.means)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_cache_topup_matches_resumable_driver(use_kernel):
    """Service accumulation == the checkpointed evaluate_resumable fold.

    Both paths left-fold identical per-round sums (same counters, same
    round boundaries), so the service's topped-up stream is bit-identical
    to the fault-tolerant driver's checkpoint/restart stream.
    """
    from repro.service import IntegrationClient, IntegrationEngine

    cli = IntegrationClient(IntegrationEngine(seed=7, round_samples=4096,
                                              use_kernel=use_kernel))
    cli.integrate([harmonic_family(6, 3)], n_samples=4096)
    topped = cli.integrate([harmonic_family(6, 3)], n_samples=3 * 4096)

    zmc = ZMCMultiFunctions([harmonic_family(6, 3)], n_samples=3 * 4096,
                            seed=7, use_kernel=use_kernel)
    driver = zmc.evaluate_resumable(rounds=3)
    np.testing.assert_array_equal(topped.means, driver.means[0])
    np.testing.assert_array_equal(topped.stderrs, driver.stderrs[0])


def test_adaptive_resume_bit_identical(tmp_path):
    """An adapted run killed mid-flight resumes to the same bytes.

    The grid epoch chain is journaled (grid record before child alloc)
    and the refit trigger reads only durable per-stream state, so an
    engine restarted from the state dir re-adopts the recorded grid —
    never refits a new one — and the finished result is *bit-identical*
    to an uninterrupted run: same means, stderrs, sample counts and
    epoch stream ids.
    """
    from repro.core import gaussian_family
    from repro.service import (IntegrationClient, IntegrationEngine,
                               IntegrationRequest)

    fams = [gaussian_family(2, 2, sigma=np.asarray([0.15, 0.25]))]
    target = 5e-4

    def engine(state_dir):
        return IntegrationEngine(seed=3, round_samples=4096,
                                 state_dir=str(state_dir),
                                 adapt_rounds_per_epoch=1,
                                 adapt_max_epochs=3,
                                 adapt_pilot_samples=1024)

    eng = engine(tmp_path / "uninterrupted")
    clean = IntegrationClient(eng).integrate(
        fams, target_stderr=target, adaptive=True)
    eng.close()

    eng = engine(tmp_path / "interrupted")
    eng.submit(IntegrationRequest.make(
        fams, target_stderr=target, adaptive=True))
    for _ in range(2):
        eng.step()
    del eng             # abandoned mid-wave: journal only, no snapshot

    eng = engine(tmp_path / "interrupted")
    resumed = IntegrationClient(eng).integrate(
        fams, target_stderr=target, adaptive=True)
    eng.close()

    assert resumed.means.tobytes() == clean.means.tobytes()
    assert resumed.stderrs.tobytes() == clean.stderrs.tobytes()
    assert resumed.n_per_family == clean.n_per_family
    assert resumed.stream_ids == clean.stream_ids
    assert np.all(resumed.stderrs <= target)


def test_work_queue_reissue():
    from repro.distributed.fault_tolerance import WorkQueue
    q = WorkQueue(total_samples=100, chunk=30)
    t1, c1 = q.take()
    t2, c2 = q.take()
    q.fail(t1)           # worker died -> chunk back to pending
    q.complete(t2)
    seen = [c2]
    while (item := q.take()) is not None:
        t, c = item
        q.complete(t)
        seen.append(c)
    assert q.finished
    covered = sorted(seen)
    assert covered == [(0, 30), (30, 30), (60, 30), (90, 10)]
