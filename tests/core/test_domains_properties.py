"""Hypothesis properties of the compactification transform.

The service's dedupe assumption (``canonical.py``) and the fused kernel
path both lean on the same facts about ``repro.core.domains``:

* finite boxes pass through ``compactify`` untouched, and
  ``apply_transform`` with kind ``TRANSFORM_NONE`` is the exact
  identity with unit Jacobian (the "Jacobian-weighted transform of a
  finite box is the identity" round-trip);
* compactification is **idempotent** — ``family.compactified()`` of an
  already-compact family is the same object, so a raw infinite-domain
  ask and its pre-compactified twin canonicalize (and hash) alike;
* the static ``transform_params`` metadata is faithful: kinds match the
  infinity pattern of the box, shifts anchor half-infinite edges, the
  new box is finite with [0, 1] on transformed axes;
* the traced transform matches its own calculus: ``jac`` is the
  numerical derivative dx/du, and quadrature of a known integrand
  through the transform recovers the analytic improper integral.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason=("property tests need hypothesis (pip install "
            "hypothesis); the rest of the suite runs without it"))
from hypothesis import given, settings, strategies as st

from repro.core import gaussian_family
from repro.core.domains import (TRANSFORM_LOWER, TRANSFORM_NONE,
                                TRANSFORM_TAN, TRANSFORM_UPPER,
                                apply_transform, compactify, is_finite_box,
                                transform_params)
from repro.core.integrand import IntegrandFamily

SETTINGS = dict(max_examples=50, deadline=None)

finite_edge = st.floats(min_value=-100.0, max_value=100.0,
                        allow_nan=False, width=32)
unit = st.floats(min_value=1e-6, max_value=1.0 - 1e-6,
                 allow_nan=False, width=32)


@st.composite
def boxes(draw, min_fn=1, max_fn=3, min_dim=1, max_dim=3,
          allow_infinite=True):
    """(n_fn, dim, 2) boxes with a random finite/infinite edge pattern."""
    n_fn = draw(st.integers(min_fn, max_fn))
    dim = draw(st.integers(min_dim, max_dim))
    out = np.empty((n_fn, dim, 2), np.float64)
    kinds = ["finite", "upper", "lower", "both"] if allow_infinite \
        else ["finite"]
    for i in range(n_fn):
        for d in range(dim):
            kind = draw(st.sampled_from(kinds))
            a = draw(finite_edge)
            b = a + abs(draw(finite_edge)) + 1e-3
            out[i, d] = {
                "finite": (a, b),
                "upper": (a, np.inf),
                "lower": (-np.inf, b),
                "both": (-np.inf, np.inf),
            }[kind]
    return out


# -- finite boxes: the transform is the identity ------------------------------

@settings(**SETTINGS)
@given(boxes(allow_infinite=False))
def test_compactify_finite_box_is_identity(dom):
    fn = lambda x, p: jnp.sum(x, -1)
    out = compactify(fn, dom)
    assert len(out) == 2                      # no aux: nothing to transform
    fn2, new_dom = out
    assert fn2 is fn
    np.testing.assert_array_equal(np.asarray(new_dom),
                                  dom.astype(np.float32))


@settings(**SETTINGS)
@given(st.lists(unit, min_size=1, max_size=8))
def test_apply_transform_none_is_exact_identity(us):
    u = jnp.asarray(us, jnp.float32)
    x, jac = apply_transform(u, jnp.int32(TRANSFORM_NONE), jnp.float32(0))
    assert np.asarray(x).tobytes() == np.asarray(u).tobytes()
    np.testing.assert_array_equal(np.asarray(jac), np.ones(len(us)))


# -- static metadata is faithful ----------------------------------------------

@settings(**SETTINGS)
@given(boxes())
def test_transform_params_faithful(dom):
    kind, shift, new_dom = transform_params(dom)
    lo_inf = ~np.isfinite(dom[..., 0])
    hi_inf = ~np.isfinite(dom[..., 1])
    np.testing.assert_array_equal(kind == TRANSFORM_TAN, lo_inf & hi_inf)
    np.testing.assert_array_equal(kind == TRANSFORM_UPPER,
                                  ~lo_inf & hi_inf)
    np.testing.assert_array_equal(kind == TRANSFORM_LOWER,
                                  lo_inf & ~hi_inf)
    assert np.all(np.isfinite(new_dom))
    transformed = kind != TRANSFORM_NONE
    np.testing.assert_array_equal(new_dom[..., 0][transformed], 0.0)
    np.testing.assert_array_equal(new_dom[..., 1][transformed], 1.0)
    np.testing.assert_array_equal(new_dom[..., 0][~transformed],
                                  dom[..., 0][~transformed].astype(
                                      np.float32))
    # shifts anchor the finite edge of half-infinite axes
    up = kind == TRANSFORM_UPPER
    lw = kind == TRANSFORM_LOWER
    np.testing.assert_array_equal(shift[up],
                                  dom[..., 0][up].astype(np.float32))
    np.testing.assert_array_equal(shift[lw],
                                  dom[..., 1][lw].astype(np.float32))


# -- the transform matches its own calculus -----------------------------------

def _np_x(u, kind, shift):
    """f64 numpy mirror of apply_transform's coordinate map."""
    uc = np.clip(np.float64(u), 1e-7, 1.0 - 1e-7)
    if kind == TRANSFORM_TAN:
        return np.tan(np.pi * (uc - 0.5))
    if kind == TRANSFORM_UPPER:
        return np.float64(shift) + uc / (1.0 - uc)
    return np.float64(shift) - uc / (1.0 - uc)


@settings(**SETTINGS)
@given(st.sampled_from([TRANSFORM_TAN, TRANSFORM_UPPER, TRANSFORM_LOWER]),
       finite_edge,
       st.floats(min_value=0.05, max_value=0.95, allow_nan=False, width=32))
def test_jacobian_is_dx_du(kind, shift, u):
    """The traced jac equals |dx/du| of the documented coordinate map
    (central difference on an f64 reference)."""
    _, jac = apply_transform(jnp.float32(u), jnp.int32(kind),
                             jnp.float32(shift))
    h = 1e-6
    num = (_np_x(u + h, kind, shift) - _np_x(u - h, kind, shift)) / (2 * h)
    np.testing.assert_allclose(abs(num), float(jac), rtol=1e-3)


@settings(**SETTINGS)
@given(finite_edge, st.floats(min_value=0.2, max_value=3.0,
                              allow_nan=False, width=32))
def test_halfinfinite_quadrature_roundtrip(a, rate):
    """Midpoint quadrature of exp(-rate (x - a)) through the [a, inf)
    transform recovers 1/rate — the Jacobian-weighted round-trip."""
    n = 20001
    u = (np.arange(n, dtype=np.float64) + 0.5) / n
    x, jac = apply_transform(jnp.asarray(u, jnp.float32),
                             jnp.int32(TRANSFORM_UPPER), jnp.float32(a))
    x64 = np.asarray(x, np.float64)
    vals = np.exp(-rate * (x64 - np.float32(a))) * np.asarray(jac,
                                                              np.float64)
    np.testing.assert_allclose(vals.mean(), 1.0 / rate, rtol=5e-3)


# -- idempotence: the canonicalizer's dedupe assumption -----------------------

@settings(**SETTINGS)
@given(boxes(min_dim=2, max_dim=2))
def test_compactified_idempotent(dom):
    fam = IntegrandFamily(
        fn=lambda x, p: jnp.exp(-jnp.sum(jnp.square(x), -1)) * p["c"],
        params={"c": jnp.ones(dom.shape[0])},
        domains=jnp.asarray(dom.astype(np.float32)),
    ).validate()
    once = fam.compactified()
    assert is_finite_box(once.domains)
    assert once.compactified() is once
    if is_finite_box(dom):
        assert once is fam
    else:
        assert once.compact


def test_compactified_keeps_kernel_and_hash_dedupes():
    """The canonical form of an infinite-domain registered family keeps
    its fused-kernel name, and raw vs pre-compactified asks hash alike."""
    from repro.service.canonical import family_hash
    raw = gaussian_family(3, 2, lo=-np.inf, hi=np.inf)
    canon = raw.compactified()
    assert canon.kernel == raw.kernel == "mc_eval_gaussian"
    assert canon.compact
    assert family_hash(raw) == family_hash(canon)
    assert family_hash(canon) == family_hash(canon.compactified())
