"""Stratified sampling + heuristic tree search."""

import jax.numpy as jnp
import numpy as np

from repro.core import rng, tree_search
from repro.core.stratified import (StratumTable, initial_grid,
                                   stratum_volumes, table_estimate)

KEY = rng.fold_key(17, 0)


def _peaked(x):
    # sharp bump in one corner: adaptive refinement should win here
    return jnp.exp(-50.0 * jnp.sum(jnp.square(x - 0.9), axis=-1))


def test_initial_grid_partition():
    t = initial_grid(np.array([[0, 1], [0, 2]], np.float32), 3, capacity=16)
    vols = np.asarray(stratum_volumes(t))
    act = np.asarray(t.active)
    assert act.sum() == 9
    np.testing.assert_allclose(vols[act].sum(), 2.0, rtol=1e-6)


def test_tree_search_converges():
    res = tree_search.integrate(_peaked, [[0, 1], [0, 1]], KEY,
                                splits_per_dim=4, n_per=512, depth=6,
                                k_split=8)
    # exact: product of 1-d gaussians integrals
    from math import erf, pi, sqrt
    one_d = sqrt(pi / 50) / 2 * (erf(sqrt(50) * 0.9) + erf(sqrt(50) * 0.1))
    exact = one_d ** 2
    assert abs(float(res.integral) - exact) < 4 * float(res.stderr) + 1e-3


def test_refinement_reduces_stderr():
    shallow = tree_search.integrate(_peaked, [[0, 1], [0, 1]], KEY,
                                    splits_per_dim=4, n_per=512, depth=0,
                                    k_split=8)
    deep = tree_search.integrate(_peaked, [[0, 1], [0, 1]], KEY,
                                 splits_per_dim=4, n_per=512, depth=8,
                                 k_split=8)
    assert float(deep.stderr) < float(shallow.stderr)


def test_splits_preserve_volume():
    res = tree_search.integrate(_peaked, [[0, 1], [0, 1]], KEY,
                                splits_per_dim=4, n_per=256, depth=5,
                                k_split=4)
    t = res.table
    vols = np.asarray(stratum_volumes(t))
    act = np.asarray(t.active)
    np.testing.assert_allclose(vols[act].sum(), 1.0, rtol=1e-5)


def test_capacity_bound_respected():
    res = tree_search.integrate(_peaked, [[0, 1], [0, 1]], KEY,
                                splits_per_dim=4, n_per=128, depth=3,
                                k_split=4)
    assert res.table.capacity == 16 + 3 * 4
    assert int(np.asarray(res.table.active).sum()) == 16 + 3 * 4
