"""Hypothesis properties of the VEGAS importance grid (``repro.core.adaptive``).

The adaptive service path (``docs/adaptive.md``) leans on four facts
about the grid, asserted here over generated boxes and pilot weights:

* the inverse-CDF map is **monotone and bijective** on [0, 1) per axis —
  it spans the box exactly and never folds, so adapted sampling stays an
  unbiased reparametrization;
* the returned Jacobian equals the analytic **bin-width product**
  ``prod_d n_bins * width(selected bin)`` — the unbiasedness weight the
  in-kernel ``adapted_body`` stage must reproduce;
* an **un-refined grid is plain uniform sampling**: uniform edges give
  the affine box map with constant Jacobian = box volume;
* **refinement is deterministic and total** — same pilot data, same new
  edges (the resume contract refits grids from journaled state and
  requires bit-identical results), strictly increasing with the box
  endpoints pinned, and degenerate pilots leave the grid unchanged.
"""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason=("property tests need hypothesis (pip install "
            "hypothesis); the rest of the suite runs without it"))
from hypothesis import given, settings, strategies as st

from repro.core import harmonic_family, rng
from repro.core.adaptive import (apply_map, initial_edges, pilot_weights,
                                 refine_edges)

SETTINGS = dict(max_examples=50, deadline=None)

# bin widths bounded well away from 0 so f32 interpolation inside a bin
# stays strictly monotone at the test's u spacing
width = st.floats(min_value=0.01, max_value=10.0,
                  allow_nan=False, width=32)


@st.composite
def grids(draw, min_dim=1, max_dim=3, min_bins=2, max_bins=8):
    """(1, dim, n_bins + 1) strictly increasing edges over a random box."""
    dim = draw(st.integers(min_dim, max_dim))
    n_bins = draw(st.integers(min_bins, max_bins))
    lo = draw(st.floats(min_value=-50.0, max_value=50.0,
                        allow_nan=False, width=32))
    widths = np.asarray(
        [[draw(width) for _ in range(n_bins)] for _ in range(dim)],
        np.float64)
    edges = lo + np.concatenate(
        [np.zeros((dim, 1)), np.cumsum(widths, axis=1)], axis=1)
    return edges.astype(np.float32)[None]


def _u_grid(dim, n=65):
    """(n, dim) probe uniforms: the same [0, 1) ramp on every axis."""
    return np.tile(np.linspace(0.0, 1.0 - 1e-6, n,
                               dtype=np.float32)[:, None], (1, dim))


@given(edges=grids())
@settings(**SETTINGS)
def test_map_is_monotone_and_spans_the_box(edges):
    e = edges[0]
    u = _u_grid(e.shape[0])
    x, _ = apply_map(u, e)
    x = np.asarray(x)
    assert np.all(np.diff(x, axis=0) > 0), "inverse-CDF map folded"
    np.testing.assert_array_equal(x[0], e[:, 0])      # u=0 -> lo exactly
    assert np.all(x <= e[:, -1])                      # never exits the box


@given(edges=grids())
@settings(**SETTINGS)
def test_jacobian_is_the_bin_width_product(edges):
    e = edges[0]
    dim, n_bins = e.shape[0], e.shape[1] - 1
    u = _u_grid(dim)
    _, jac = apply_map(u, e)
    idx = np.minimum((u * n_bins).astype(np.int32), n_bins - 1)
    widths = np.take_along_axis(e.T, idx + 1, axis=0) \
        - np.take_along_axis(e.T, idx, axis=0)
    analytic = np.prod(n_bins * widths.astype(np.float64), axis=-1)
    np.testing.assert_allclose(np.asarray(jac, np.float64), analytic,
                               rtol=1e-4)


@given(dim=st.integers(1, 3), n_bins=st.integers(2, 16))
@settings(**SETTINGS)
def test_uniform_grid_is_plain_uniform_sampling(dim, n_bins):
    domains = np.stack([-np.ones(dim), 3 * np.ones(dim)],
                       axis=-1)[None].astype(np.float32)
    e = initial_edges(domains, n_bins)[0]
    u = _u_grid(dim)
    x, jac = apply_map(u, e)
    np.testing.assert_allclose(np.asarray(x), -1.0 + 4.0 * u, atol=1e-5)
    np.testing.assert_allclose(np.asarray(jac), 4.0 ** dim, rtol=1e-5)


@given(edges=grids(),
       data=st.data())
@settings(**SETTINGS)
def test_refine_is_deterministic_increasing_endpoint_preserving(
        edges, data):
    n_fn, dim, n_edges = edges.shape
    w = data.draw(st.lists(
        st.floats(min_value=0.0, max_value=1e6, allow_nan=False,
                  width=32),
        min_size=n_fn * dim * (n_edges - 1),
        max_size=n_fn * dim * (n_edges - 1)))
    weights = np.asarray(w, np.float64).reshape(n_fn, dim, n_edges - 1)
    new = refine_edges(edges, weights)
    np.testing.assert_array_equal(new, refine_edges(edges, weights))
    assert new.shape == edges.shape and new.dtype == np.float32
    assert np.all(np.diff(new, axis=-1) > 0), "refit collapsed a bin"
    np.testing.assert_array_equal(new[..., 0], edges[..., 0])
    np.testing.assert_array_equal(new[..., -1], edges[..., -1])


def test_degenerate_pilots_leave_the_grid_unchanged():
    edges = initial_edges(np.asarray([[[0.0, 1.0], [0.0, 2.0]]]), 4)
    for bad in (np.zeros((1, 2, 4)),
                np.full((1, 2, 4), np.nan),
                np.asarray([[[1.0, np.inf, 1.0, 1.0]] * 2])):
        np.testing.assert_array_equal(refine_edges(edges, bad), edges)
    with pytest.raises(ValueError, match="do not match"):
        refine_edges(edges, np.ones((1, 2, 5)))


def test_pilot_and_refit_are_deterministic():
    """Same (family, edges, key) -> identical weights and refit edges.

    This is the resume contract's load-bearing half: a crashed planner
    re-runs the pilot from the journaled seed and must land on the very
    grid the dead engine journaled."""
    fam = harmonic_family(3, 2)
    edges = initial_edges(np.asarray(fam.domains), 8)
    key = rng.fold_key(7, 12345)
    w1 = pilot_weights(fam, edges, key, 1024)
    w2 = pilot_weights(fam, edges, key, 1024)
    np.testing.assert_array_equal(w1, w2)
    assert w1.shape == (3, 2, 8) and np.all(w1 >= 0)
    np.testing.assert_array_equal(refine_edges(edges, w1),
                                  refine_edges(edges, w2))
    # a different key is a different pilot (the fold is not a no-op)
    w3 = pilot_weights(fam, edges, rng.fold_key(7, 54321), 1024)
    assert not np.array_equal(w1, w3)
