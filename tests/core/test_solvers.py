"""The three public solvers vs analytic oracles."""

import numpy as np
import jax.numpy as jnp

import pytest

from repro.core import (MultiFunctionSpec, ZMCFunctional, ZMCMultiFunctions,
                        ZMCNormal, abs_sum_family, gaussian_family,
                        harmonic_analytic, harmonic_family)


def test_multifunctions_paper_fig1_small():
    """Fig.-1 workload at reduced sample count: band brackets the exact."""
    z = ZMCMultiFunctions([harmonic_family(25, 4)], n_samples=100_000, seed=3)
    r = z.evaluate(num_trials=4)
    exact = harmonic_analytic(25, 4)
    band = 3 * np.maximum(r.trial_std, 1e-12)
    within = np.abs(r.trial_mean - exact) <= band
    assert within.mean() >= 0.9, (r.trial_mean - exact) / band


def test_multifunctions_heterogeneous_spec():
    """Eq.(1)+Eq.(2) together: different dims and forms in one evaluate."""
    spec = MultiFunctionSpec.from_families([
        harmonic_family(6, 4),
        abs_sum_family(3, 2, np.ones(3)),
        abs_sum_family(3, 3, np.ones(3), sign_last=-1.0),
    ])
    assert spec.n_fn_total == 12
    assert spec.offsets() == [0, 6, 9]
    z = ZMCMultiFunctions(spec, n_samples=50_000, seed=1)
    r = z.evaluate(num_trials=2)
    assert r.means.shape == (2, 12)
    np.testing.assert_allclose(r.trial_mean[6:9], 1.0, atol=0.02)


def test_normal_separable_oracle():
    f = lambda x: jnp.sin(x[..., 0]) * jnp.cos(x[..., 1]) * x[..., 2]
    dom = [[0, np.pi], [0, np.pi / 2], [0, 2.0]]
    exact = 2.0 * 1.0 * 2.0
    z = ZMCNormal(f, dom, seed=5, splits_per_dim=3, n_per_stratum=1024,
                  depth=4, k_split=16)
    res = z.evaluate(num_trials=3)
    assert abs(res.integral - exact) < 0.02, res


def test_normal_rejects_infinite_domain():
    with pytest.raises(ValueError):
        ZMCNormal(lambda x: x[..., 0], [[0, np.inf]])


def test_functional_parameter_scan():
    """I(a) = int_0^1 exp(-a x) dx = (1 - e^-a)/a."""
    grid = {"a": jnp.linspace(0.5, 3.0, 8)}
    z = ZMCFunctional(lambda x, t: jnp.exp(-t["a"] * x[..., 0]),
                      grid, [[0.0, 1.0]], n_samples=100_000, seed=2)
    r = z.evaluate()
    a = np.linspace(0.5, 3.0, 8)
    exact = (1 - np.exp(-a)) / a
    np.testing.assert_allclose(r.means[0], exact, atol=5e-3)


def test_infinite_domain_gaussians():
    g = gaussian_family(3, 2, lo=-np.inf, hi=np.inf)
    z = ZMCMultiFunctions([g], n_samples=300_000, seed=7)
    r = z.evaluate()
    exact = 2 * np.pi * np.linspace(0.5, 2.0, 3) ** 2
    np.testing.assert_allclose(r.means[0], exact, rtol=0.05)


def test_semi_infinite_domain():
    """int_0^inf e^-x dx = 1 per function."""
    import jax
    from repro.core.integrand import IntegrandFamily
    n = 3
    fam = IntegrandFamily(
        fn=lambda x, p: p["s"] * jnp.exp(-jnp.sum(x, -1)),
        params={"s": jnp.asarray([1.0, 2.0, 3.0])},
        domains=jnp.asarray(np.broadcast_to([0.0, np.inf], (n, 1, 2)).copy()),
        name="exp").validate()
    z = ZMCMultiFunctions([fam], n_samples=200_000, seed=9)
    r = z.evaluate()
    np.testing.assert_allclose(r.means[0], [1.0, 2.0, 3.0], rtol=0.03)
