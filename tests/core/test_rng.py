"""Counter-based RNG: determinism, independence, uniformity."""

import jax.numpy as jnp
import numpy as np

from repro.core import rng


def test_deterministic():
    k0, k1 = rng.fold_key(123, 0)
    a = rng.uniforms_for(k0, k1, jnp.arange(3), jnp.arange(100, dtype=jnp.uint32), 4)
    b = rng.uniforms_for(k0, k1, jnp.arange(3), jnp.arange(100, dtype=jnp.uint32), 4)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_range_and_dtype():
    k0, k1 = rng.fold_key(7, 0)
    u = rng.uniforms_for(k0, k1, jnp.arange(2), jnp.arange(4096, dtype=jnp.uint32), 3)
    u = np.asarray(u)
    assert u.dtype == np.float32
    assert u.min() >= 0.0 and u.max() < 1.0


def test_streams_differ():
    a0 = rng.fold_key(5, 0)
    a1 = rng.fold_key(5, 1)
    b = rng.fold_key(6, 0)
    assert a0 != a1 and a0 != b
    u0 = rng.uniforms_for(*a0, jnp.arange(1), jnp.arange(512, dtype=jnp.uint32), 2)
    u1 = rng.uniforms_for(*a1, jnp.arange(1), jnp.arange(512, dtype=jnp.uint32), 2)
    assert np.abs(np.asarray(u0) - np.asarray(u1)).max() > 1e-3


def test_functions_and_dims_independent():
    """Different fn ids / dims give uncorrelated streams."""
    k0, k1 = rng.fold_key(11, 0)
    u = np.asarray(rng.uniforms_for(k0, k1, jnp.arange(4),
                                    jnp.arange(4096, dtype=jnp.uint32), 3))
    # pairwise correlations across (fn, dim) slots should be ~0
    flat = u.reshape(4 * 4096 // 4096, -1) if False else u
    for i in range(4):
        for d in range(3):
            for j in range(4):
                for e in range(3):
                    if (i, d) >= (j, e):
                        continue
                    c = np.corrcoef(flat[i, :, d], flat[j, :, e])[0, 1]
                    assert abs(c) < 0.06, (i, d, j, e, c)


def test_avalanche():
    """Flipping one counter bit flips ~half the output bits."""
    k0 = np.uint32(0xDEADBEEF)
    k1 = np.uint32(0x12345678)
    c0 = jnp.arange(256, dtype=jnp.uint32)
    c1 = jnp.zeros(256, jnp.uint32)
    base = np.asarray(rng.random_bits(k0, k1, c0, c1))
    flipped = np.asarray(rng.random_bits(k0, k1, c0 ^ np.uint32(1 << 7), c1))
    diff = np.unpackbits((base ^ flipped).view(np.uint8)).mean()
    assert 0.4 < diff < 0.6


def test_uniform_moments():
    k0, k1 = rng.fold_key(99, 3)
    u = np.asarray(rng.uniforms_for(k0, k1, jnp.arange(1),
                                    jnp.arange(1 << 16, dtype=jnp.uint32), 1))
    assert abs(u.mean() - 0.5) < 0.005
    assert abs(u.var() - 1 / 12) < 0.002
