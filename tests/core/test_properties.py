"""Hypothesis property tests on the MC engine's invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the optional 'test' extra (pip install "
           "hypothesis); the rest of the suite runs without it")
from hypothesis import given, settings, strategies as st

from repro.core import IntegrandFamily, family_sums, finalize, merge_sums
from repro.core import rng
from repro.core.domains import affine_from_unit, box_volume, compactify
from repro.core.reduction import (Moments, kahan_add, kahan_zero,
                                  moments_combine, moments_from_sums,
                                  pairwise_sum)

KEY = rng.fold_key(23, 0)
SETTINGS = dict(max_examples=20, deadline=None)


def _poly_family(coeffs, dim, lo, hi):
    n = len(coeffs)
    dom = np.broadcast_to(np.asarray([lo, hi], np.float32),
                          (n, dim, 2)).copy()
    return IntegrandFamily(
        fn=lambda x, p: p["c"] * jnp.sum(x, -1) + p["c"] ** 2,
        params={"c": jnp.asarray(np.asarray(coeffs, np.float32))},
        domains=jnp.asarray(dom), name="poly").validate()


@settings(**SETTINGS)
@given(st.floats(-3, 3), st.floats(-3, 3),
       st.integers(1, 4), st.integers(1, 3))
def test_linearity_in_integrand_scale(a, b, dim, n_fn):
    """sums of (a*f) == a * sums of f (same counters, exact fp scaling
    within tolerance)."""
    fam1 = _poly_family([1.0] * n_fn, dim, 0.0, 1.0)
    fam_a = IntegrandFamily(
        fn=lambda x, p, a=a, b=b: a * fam1.fn(x, p) + b,
        params=fam1.params, domains=fam1.domains, name="lin").validate()
    s1 = family_sums(fam1, 4096, KEY, chunk=2048)
    sa = family_sums(fam_a, 4096, KEY, chunk=2048)
    np.testing.assert_allclose(np.asarray(sa.s1),
                               a * np.asarray(s1.s1) + b * 4096,
                               rtol=1e-4, atol=1e-2)


@settings(**SETTINGS)
@given(st.floats(-5, 2), st.floats(0.1, 7), st.integers(1, 3))
def test_affine_domain_invariance(lo, width, dim):
    """I over [lo,hi] == vol * mean; estimates transform affinely."""
    hi = lo + width
    fam = _poly_family([1.0, -0.5], dim, lo, hi)
    res = finalize(fam, family_sums(fam, 32_768, KEY, chunk=4096))
    # analytic: int (c*sum(x) + c^2) = vol*(c*dim*(lo+hi)/2 + c^2)
    vol = width ** dim
    for i, c in enumerate([1.0, -0.5]):
        exact = vol * (c * dim * (lo + hi) / 2 + c * c)
        err = abs(float(res.mean[i]) - exact)
        tol = 5 * float(res.stderr[i]) + 1e-3 * max(1.0, abs(exact))
        assert err < tol, (lo, width, dim, c, err, tol)


@settings(**SETTINGS)
@given(st.integers(1, 6))
def test_volume_positive(dim):
    dom = np.zeros((3, dim, 2), np.float32)
    dom[..., 1] = np.arange(1, dim + 1, dtype=np.float32)
    v = np.asarray(box_volume(jnp.asarray(dom)))
    assert np.all(v > 0)
    np.testing.assert_allclose(v, np.prod(np.arange(1, dim + 1)), rtol=1e-5)


@settings(**SETTINGS)
@given(st.lists(st.integers(100, 5000), min_size=2, max_size=5))
def test_merge_associativity(chunks):
    """Any partition of the sample range merges to the same sums."""
    fam = _poly_family([2.0], 2, 0.0, 1.0)
    total = sum(chunks)
    whole = family_sums(fam, total, KEY, chunk=8192)
    parts = []
    off = 0
    for c in chunks:
        parts.append(family_sums(fam, c, KEY, sample_offset=off, chunk=8192))
        off += c
    acc = parts[0]
    for p in parts[1:]:
        acc = merge_sums(acc, p)
    np.testing.assert_allclose(np.asarray(acc.s1), np.asarray(whole.s1),
                               rtol=1e-4, atol=1e-2)
    assert float(acc.n) == float(whole.n)


@settings(**SETTINGS)
@given(st.integers(2, 200), st.integers(2, 200))
def test_moments_combine_matches_direct(n1, n2):
    rng_np = np.random.default_rng(n1 * 1000 + n2)
    x = rng_np.standard_normal(n1 + n2).astype(np.float32) * 3 + 1
    a, b = x[:n1], x[n1:]

    def mom(v):
        return Moments(count=jnp.float32(len(v)),
                       mean=jnp.float32(v.mean()),
                       m2=jnp.float32(((v - v.mean()) ** 2).sum()))

    m = moments_combine(mom(a), mom(b))
    assert abs(float(m.mean) - x.mean()) < 1e-4
    np.testing.assert_allclose(float(m.m2), ((x - x.mean()) ** 2).sum(),
                               rtol=1e-4)


def test_kahan_beats_naive():
    vals = np.array([1e8] + [0.1] * 10000, np.float32)
    naive = np.float32(0)
    acc = kahan_zero(())
    for v in vals:
        naive = np.float32(naive + np.float32(v))
        acc = kahan_add(acc, jnp.float32(v))
    exact = 1e8 + 0.1 * 10000
    assert abs(float(acc.total) - exact) < abs(float(naive) - exact)


@settings(**SETTINGS)
@given(st.integers(1, 64))
def test_pairwise_sum_matches(n):
    x = np.random.default_rng(n).standard_normal(n).astype(np.float32)
    np.testing.assert_allclose(float(pairwise_sum(jnp.asarray(x))),
                               x.sum(dtype=np.float64), rtol=1e-5, atol=1e-5)


def test_compactify_produces_finite_box():
    dom = np.array([[[0.0, np.inf]], [[-np.inf, np.inf]]], np.float64)
    fn2, new_dom, aux = compactify(lambda x, p: jnp.sum(x, -1), dom)
    assert np.all(np.isfinite(np.asarray(new_dom)))
