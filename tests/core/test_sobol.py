"""Randomised Sobol QMC sampler (beyond-paper upgrade, §Perf iteration 9)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ZMCMultiFunctions, gaussian_family, harmonic_family
from repro.core.sobol import direction_vectors, sobol_bits, sobol_uniforms_for
from repro.core import rng


def test_canonical_first_points():
    """Unshifted points match the standard Joe-Kuo Sobol sequence."""
    pts = np.asarray(sobol_bits(jnp.arange(8, dtype=jnp.uint32), 2)) / 2.0**32
    expect_d1 = [0.0, 0.5, 0.75, 0.25, 0.375, 0.875, 0.625, 0.125]
    expect_d2 = [0.0, 0.5, 0.25, 0.75, 0.375, 0.875, 0.125, 0.625]
    np.testing.assert_allclose(pts[:, 0], expect_d1, atol=1e-9)
    np.testing.assert_allclose(pts[:, 1], expect_d2, atol=1e-9)


def test_direction_vectors_shape_and_first_bits():
    v = direction_vectors(8)
    assert v.shape == (8, 32) and v.dtype == np.uint32
    assert v[0, 0] == 1 << 31              # van der Corput
    assert np.all(v[:, 0] == 1 << 31)      # m_1 = 1 for all dims


def test_low_discrepancy_stratification():
    """First 2^k points hit every dyadic row/column exactly once."""
    n = 64
    pts = np.asarray(sobol_bits(jnp.arange(n, dtype=jnp.uint32), 2)) / 2.0**32
    for d in range(2):
        cells = np.floor(pts[:, d] * n).astype(int)
        assert len(np.unique(cells)) == n   # one point per 1/64 stratum


def test_shift_randomisation_differs_by_function_and_trial():
    k0a, k1a = rng.fold_key(1, 0)
    k0b, k1b = rng.fold_key(1, 1)
    ua = sobol_uniforms_for(k0a, k1a, jnp.arange(2),
                            jnp.arange(16, dtype=jnp.uint32), 3)
    ub = sobol_uniforms_for(k0b, k1b, jnp.arange(2),
                            jnp.arange(16, dtype=jnp.uint32), 3)
    assert not np.allclose(np.asarray(ua), np.asarray(ub))
    assert not np.allclose(np.asarray(ua[0]), np.asarray(ua[1]))
    u = np.asarray(ua)
    assert u.min() >= 0.0 and u.max() < 1.0


def test_dim_cap():
    with pytest.raises(ValueError):
        direction_vectors(9)


def test_rqmc_beats_mc_on_smooth_integrand():
    g = gaussian_family(4, 3, lo=-2.0, hi=2.0)
    z_mc = ZMCMultiFunctions([g], n_samples=16384, seed=3, sampler="mc")
    z_qmc = ZMCMultiFunctions([g], n_samples=16384, seed=3, sampler="sobol")
    r_mc = z_mc.evaluate(num_trials=4)
    r_qmc = z_qmc.evaluate(num_trials=4)
    gain = np.median(r_mc.trial_std) / max(np.median(r_qmc.trial_std), 1e-12)
    assert gain > 20.0, gain
    # and unbiased: QMC mean agrees with MC mean within MC's error
    assert np.all(np.abs(r_qmc.trial_mean - r_mc.trial_mean)
                  <= 5 * np.maximum(r_mc.trial_std, 1e-9))


def test_rqmc_helps_on_paper_family():
    fam = harmonic_family(8, 4)
    r_mc = ZMCMultiFunctions([fam], n_samples=32768, seed=5,
                             sampler="mc").evaluate(num_trials=4)
    r_qmc = ZMCMultiFunctions([fam], n_samples=32768, seed=5,
                              sampler="sobol").evaluate(num_trials=4)
    gain = np.median(r_mc.trial_std) / max(np.median(r_qmc.trial_std), 1e-12)
    assert gain > 1.5, gain
