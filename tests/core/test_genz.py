"""Genz suite: every family's estimate matches its closed form."""

import numpy as np
import pytest

from repro.core import family_sums, finalize, rng
from repro.core import genz

KEY = rng.fold_key(7, 0)


@pytest.mark.parametrize("name", sorted(genz.ALL))
def test_family_vs_closed_form(name):
    dim = 3 if name == "corner_peak" else 4
    fam, exact = genz.ALL[name](6, dim)
    res = finalize(fam, family_sums(fam, 100_000, KEY))
    pulls = np.abs(np.asarray(res.mean) - exact) / \
        np.maximum(np.asarray(res.stderr), 1e-12)
    assert np.all(pulls < 5.0), (name, pulls)


def test_params_reproducible():
    f1, e1 = genz.oscillatory(4, 3, seed=9)
    f2, e2 = genz.oscillatory(4, 3, seed=9)
    np.testing.assert_array_equal(np.asarray(f1.params["a"]),
                                  np.asarray(f2.params["a"]))
    np.testing.assert_array_equal(e1, e2)
    f3, _ = genz.oscillatory(4, 3, seed=10)
    assert not np.allclose(np.asarray(f1.params["a"]),
                           np.asarray(f3.params["a"]))


def test_corner_peak_d1_closed_form():
    """d=1 sanity: int (1+ax)^-2 = 1/(1+a)."""
    fam, exact = genz.corner_peak(3, 1)
    a = np.asarray(fam.params["a"])[:, 0]
    np.testing.assert_allclose(exact, 1.0 / (1.0 + a), rtol=1e-5)


def test_rqmc_gains_on_smooth_families():
    from repro.core import ZMCMultiFunctions
    fam, _ = genz.gaussian_peak(4, 3)
    r_mc = ZMCMultiFunctions([fam], n_samples=16384, seed=1,
                             sampler="mc").evaluate(num_trials=3)
    r_q = ZMCMultiFunctions([fam], n_samples=16384, seed=1,
                            sampler="sobol").evaluate(num_trials=3)
    gain = np.median(r_mc.trial_std) / max(np.median(r_q.trial_std), 1e-15)
    assert gain > 3.0, gain
