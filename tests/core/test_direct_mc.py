"""Direct-MC engine: accuracy vs analytic, chunking invariance, merging."""

import jax.numpy as jnp
import numpy as np

from repro.core import (IntegrandFamily, abs_sum_family, family_sums,
                        finalize, gaussian_family, harmonic_analytic,
                        harmonic_family, merge_sums)
from repro.core import rng

KEY = rng.fold_key(42, 0)


def test_harmonic_vs_analytic():
    fam = harmonic_family(20, 4)
    res = finalize(fam, family_sums(fam, 200_000, KEY))
    exact = harmonic_analytic(20, 4)
    pulls = np.abs(np.asarray(res.mean) - exact) / np.asarray(res.stderr)
    assert np.all(pulls < 5.0), pulls


def test_abs_sum_eq2_families():
    """The paper's Eq.(2): numeric quadrature oracle."""
    # |x1 + x2| on [0,1]^2 == x1 + x2 -> integral = 1
    f2 = abs_sum_family(3, 2, [1.0, 2.0, 0.5])
    r2 = finalize(f2, family_sums(f2, 100_000, KEY))
    np.testing.assert_allclose(np.asarray(r2.mean),
                               np.array([1.0, 2.0, 0.5]), atol=0.01)
    # |x1 + x2 - x3| on [0,1]^3: dense-grid oracle
    g = np.linspace(0, 1, 201)
    xs, ys, zs = np.meshgrid(g, g, g, indexing="ij")
    oracle = np.trapezoid(np.trapezoid(np.trapezoid(
        np.abs(xs + ys - zs), g, axis=2), g, axis=1), g, axis=0)
    f3 = abs_sum_family(2, 3, [1.0, 3.0], sign_last=-1.0)
    r3 = finalize(f3, family_sums(f3, 200_000, KEY))
    np.testing.assert_allclose(np.asarray(r3.mean),
                               oracle * np.array([1.0, 3.0]), atol=0.02)


def test_chunk_size_invariance():
    """Same counters regardless of chunking -> near-identical sums."""
    fam = harmonic_family(5, 3)
    a = family_sums(fam, 30_000, KEY, chunk=1024)
    b = family_sums(fam, 30_000, KEY, chunk=7000)
    np.testing.assert_allclose(np.asarray(a.s1), np.asarray(b.s1),
                               rtol=2e-4, atol=2e-3)
    np.testing.assert_allclose(np.asarray(a.s2), np.asarray(b.s2), rtol=2e-4)


def test_fn_chunk_matches_unblocked():
    fam = gaussian_family(10, 3)
    a = family_sums(fam, 20_000, KEY)
    b = family_sums(fam, 20_000, KEY, fn_chunk=4)
    np.testing.assert_allclose(np.asarray(a.s1), np.asarray(b.s1), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(a.s2), np.asarray(b.s2), rtol=1e-5)


def test_merge_equals_single_run():
    """[0,N) == [0,N/2) + [N/2,N): counter-addressed restartability."""
    fam = harmonic_family(4, 2)
    whole = family_sums(fam, 40_000, KEY)
    h1 = family_sums(fam, 20_000, KEY, sample_offset=0)
    h2 = family_sums(fam, 20_000, KEY, sample_offset=20_000)
    merged = merge_sums(h1, h2)
    np.testing.assert_allclose(np.asarray(whole.s1), np.asarray(merged.s1),
                               rtol=1e-5, atol=1e-4)
    assert float(merged.n) == float(whole.n)


def test_sample_offset_disjoint():
    fam = harmonic_family(2, 2)
    a = family_sums(fam, 10_000, KEY, sample_offset=0)
    b = family_sums(fam, 10_000, KEY, sample_offset=10_000)
    assert not np.allclose(np.asarray(a.s1), np.asarray(b.s1))


def test_stderr_scaling():
    fam = harmonic_family(8, 4)
    r1 = finalize(fam, family_sums(fam, 20_000, KEY))
    r2 = finalize(fam, family_sums(fam, 80_000, KEY))
    ratio = np.asarray(r1.stderr) / np.asarray(r2.stderr)
    assert np.all(ratio > 1.6) and np.all(ratio < 2.6), ratio
