"""Hypothesis property: arbitrary deposit sequences round-trip exactly.

For any interleaving of entry allocations, f32 round deposits and
snapshot compactions, reloading the store must reproduce every stream's
``(s1, s2, n, rounds_done)`` *bit-for-bit* plus the allocator's
high-water mark — the invariant that makes a warm restart
indistinguishable from never having died.
"""

import tempfile

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason=("property tests need hypothesis (pip install "
            "hypothesis); the rest of the suite runs without it"))
from hypothesis import given, settings, strategies as st

from repro.core import harmonic_family
from repro.core.direct_mc import SumsState
from repro.service import ResultCache
from repro.service.store import DurableStore

f32 = st.floats(min_value=-1e6, max_value=1e6, allow_nan=False, width=32)


@st.composite
def deposit_scenarios(draw):
    """Entries with shapes, an interleaved deposit order, f32 round sums,
    and compaction points sprinkled anywhere in the sequence."""
    n_entries = draw(st.integers(1, 3))
    n_fns = [draw(st.integers(1, 4)) for _ in range(n_entries)]
    rounds = [draw(st.integers(0, 3)) for _ in range(n_entries)]
    order = draw(st.permutations(
        [i for i, k in enumerate(rounds) for _ in range(k)]))
    deposits = [(i, tuple(draw(st.lists(f32, min_size=n_fns[i],
                                        max_size=n_fns[i]))),
                 tuple(draw(st.lists(f32, min_size=n_fns[i],
                                     max_size=n_fns[i]))),
                 draw(st.integers(1, 10_000)))
                for i in order]
    compact_after = draw(st.sets(st.integers(0, max(len(deposits), 1))))
    return n_fns, deposits, compact_after


@given(deposit_scenarios())
@settings(max_examples=25, deadline=None)
def test_journal_replay_roundtrip_exact(scenario):
    n_fns, deposits, compact_after = scenario
    with tempfile.TemporaryDirectory() as root:
        store = DurableStore(root)
        cache = ResultCache(round_samples=64, store=store)
        entries = [cache.get_or_allocate(f"e{i}", harmonic_family(n_fn, 2))
                   for i, n_fn in enumerate(n_fns)]
        if 0 in compact_after:
            cache.snapshot_to_store()
        for step, (i, s1, s2, n) in enumerate(deposits, start=1):
            cache.deposit(entries[i], entries[i].rounds_done, SumsState(
                s1=np.asarray(s1, np.float32),
                s2=np.asarray(s2, np.float32), n=n))
            if step in compact_after:
                cache.snapshot_to_store()
        expected = {e.chash: e.snapshot() for e in entries}
        next_id = cache.stats()["function_ids_allocated"]
        store.close()

        cache2 = ResultCache(round_samples=64, store=DurableStore(root))
        assert cache2.stats()["function_ids_allocated"] == next_id
        for i, entry in enumerate(entries):
            revived = cache2.get(entry.chash, harmonic_family(n_fns[i], 2))
            assert revived is not None
            assert revived.fn_offset == entry.fn_offset
            s1, s2, n, done = expected[entry.chash]
            assert revived.s1.tobytes() == s1.tobytes()     # exact bits
            assert revived.s2.tobytes() == s2.tobytes()
            assert (revived.n, revived.rounds_done) == (n, done)
