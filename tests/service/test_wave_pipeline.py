"""Wave pipeline: multi-round fused launches, group commit, fairness.

The tentpole invariant is **bit-identity**: an R-round wave evaluated by
one fused multi-round launch per bucket deposits per-round sums that are
bit-for-bit the sums of R single-round launches — so the cache's
in-order fold, resume and persistence guarantees are untouched while the
launch count drops from R x B to B.  These tests assert that digest
equality end to end (kernel, chunked and sharded paths, the pipelined
worker, and crash replay through the group-committed WAL), plus the
planner's round-robin fairness and the batcher's LRU plan cache.

Engine construction and the bit-identity assertion come from the shared
``tests/service/conftest.py`` fixtures (``make_engine`` defaults to
rounds of ``R`` samples).
"""

import threading

import jax
import numpy as np
import pytest

from repro.core import gaussian_family, harmonic_family
from repro.core import rng as rng_lib
from repro.kernels import template
from repro.kernels.mc_eval import multi
from repro.service import (IntegrationClient, IntegrationRequest)

R = 4096   # = conftest.R, the make_engine fixture's round quantum


# -- kernel layer: one launch == R launches, bit for bit ----------------------

@pytest.mark.parametrize("sampler", ["mc", "sobol"])
def test_eval_plan_rounds_bit_identical(sampler):
    from repro.core import MultiFunctionSpec
    spec = MultiFunctionSpec.from_families(
        [harmonic_family(6, 3), gaussian_family(4, 3)])
    plan = multi.plan_spec(spec, sampler=sampler)
    key = rng_lib.fold_key(4, 0)
    fused = multi.eval_plan_rounds(plan, R, 3, key,
                                   start_rounds={0: 0, 1: 0})
    for r in range(3):
        single = multi.eval_plan(plan, R, key, sample_offset=r * R)
        for fam in single:
            np.testing.assert_array_equal(np.asarray(fused[fam][r].s1),
                                          np.asarray(single[fam].s1))
            np.testing.assert_array_equal(np.asarray(fused[fam][r].s2),
                                          np.asarray(single[fam].s2))


def test_eval_plan_rounds_heterogeneous_starts():
    """Streams parked at different depths share one launch."""
    from repro.core import MultiFunctionSpec
    spec = MultiFunctionSpec.from_families(
        [harmonic_family(6, 3), gaussian_family(4, 3)])
    plan = multi.plan_spec(spec)
    key = rng_lib.fold_key(4, 0)
    fused = multi.eval_plan_rounds(plan, R, 2, key,
                                   start_rounds={0: 2, 1: 0})
    for fam, start in ((0, 2), (1, 0)):
        for r in range(2):
            single = multi.eval_plan(plan, R, key,
                                     sample_offset=(start + r) * R)
            np.testing.assert_array_equal(np.asarray(fused[fam][r].s1),
                                          np.asarray(single[fam].s1))


def test_sharded_eval_plan_rounds_bit_identical():
    from repro.core import MultiFunctionSpec
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = MultiFunctionSpec.from_families(
        [harmonic_family(6, 3), gaussian_family(4, 3)])
    plan = multi.plan_spec(spec)
    key = rng_lib.fold_key(4, 0)
    starts = {0: 1, 1: 0}
    sharded = multi.sharded_eval_plan_rounds(plan, R, 2, key, mesh,
                                             start_rounds=starts)
    fused = multi.eval_plan_rounds(plan, R, 2, key, start_rounds=starts)
    for fam in fused:
        for r in range(2):
            np.testing.assert_array_equal(np.asarray(sharded[fam][r].s1),
                                          np.asarray(fused[fam][r].s1))
            np.testing.assert_array_equal(np.asarray(sharded[fam][r].s2),
                                          np.asarray(fused[fam][r].s2))


# -- engine layer: multi-round waves == single-round waves --------------------

@pytest.mark.parametrize("use_kernel", [True, False])
def test_multiround_wave_matches_per_round_waves(make_engine, bit_identical,
                                                 use_kernel):
    """R rounds in one wave (one launch) == R single-round waves."""
    fams = [harmonic_family(4, 3), gaussian_family(3, 2)]
    fused_engine = make_engine(use_kernel=use_kernel, max_rounds_per_wave=8)
    template.reset_launch_count()
    fused = IntegrationClient(fused_engine).integrate(fams, n_samples=4 * R)
    fused_launches = template.launch_count()

    per_engine = make_engine(use_kernel=use_kernel, max_rounds_per_wave=1)
    template.reset_launch_count()
    per = IntegrationClient(per_engine).integrate(fams, n_samples=4 * R)
    per_launches = template.launch_count()

    bit_identical(fused, per)
    if use_kernel:
        # 4 rounds x 2 dim buckets: 8 launches -> 2
        assert fused_launches == 2
        assert per_launches == 8
    assert fused_engine.stats.waves == 1
    assert per_engine.stats.waves == 4


def test_multiround_wave_on_mesh_bit_identical(make_engine, bit_identical):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    fams = [harmonic_family(4, 3)]
    fused = IntegrationClient(make_engine(mesh=mesh,
                                          max_rounds_per_wave=8)).integrate(
        fams, n_samples=3 * R)
    per = IntegrationClient(make_engine(mesh=mesh,
                                        max_rounds_per_wave=1)).integrate(
        fams, n_samples=3 * R)
    bit_identical(fused, per)


def test_mixed_depth_streams_fuse_into_one_launch(make_engine,
                                                  bit_identical):
    """A top-up and a cold stream with equal round counts share a launch."""
    engine = make_engine(max_rounds_per_wave=8)
    cli = IntegrationClient(engine)
    cli.integrate([harmonic_family(4, 3)], n_samples=R)    # depth 1
    t1 = engine.submit(IntegrationRequest.make(
        [harmonic_family(4, 3)], n_samples=3 * R))         # rounds [1, 3)
    t2 = engine.submit(IntegrationRequest.make(
        [gaussian_family(4, 3)], n_samples=2 * R))         # rounds [0, 2)
    template.reset_launch_count()
    while engine.step():
        pass
    # same count, same dim, different stream depths -> ONE launch
    assert template.launch_count() == 1
    res_h, res_g = engine.poll(t1), engine.poll(t2)

    clean = make_engine(max_rounds_per_wave=8)
    ref_h = IntegrationClient(clean).integrate([harmonic_family(4, 3)],
                                               n_samples=3 * R)
    ref_g = IntegrationClient(clean).integrate([gaussian_family(4, 3)],
                                               n_samples=2 * R)
    bit_identical(res_h, ref_h)
    bit_identical(res_g, ref_g)


def test_pipelined_worker_bit_identical_to_sync(make_engine, bit_identical):
    """Double-buffered waves deposit exactly what serial waves deposit."""
    fams = [harmonic_family(4, 3), gaussian_family(3, 2)]
    piped = make_engine(max_rounds_per_wave=2, pipeline_waves=True)
    piped.start()
    try:
        cli = IntegrationClient(piped)
        res = cli.wait(cli.submit(fams, n_samples=6 * R), timeout=300.0)
    finally:
        piped.stop()
    assert piped.stats.waves >= 2          # the budget spans several waves

    sync = make_engine(max_rounds_per_wave=2)
    ref = IntegrationClient(sync).integrate(fams, n_samples=6 * R)
    bit_identical(res, ref)


def test_pipelined_worker_many_clients(make_engine):
    """Concurrent submitters against the pipelined worker: all served,
    overlapping asks deduped onto shared streams, estimates sane."""
    from repro.core import harmonic_analytic
    engine = make_engine(max_rounds_per_wave=2, pipeline_waves=True)
    engine.start()
    results = {}

    def client(i):
        results[i] = IntegrationClient(engine).integrate(
            [harmonic_family(4, 2 + i % 2)], n_samples=4 * R)

    try:
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300.0)
    finally:
        engine.stop()
    assert len(results) == 6
    assert engine.cache.n_entries == 2         # dims 2 and 3 shared
    # clients sharing a stream get the identical fold
    for i in (0, 1):
        np.testing.assert_array_equal(results[i].means,
                                      results[i + 2].means)
        np.testing.assert_array_equal(results[i].means,
                                      results[i + 4].means)
        exact = harmonic_analytic(4, 2 + i)
        assert np.all(np.abs(results[i].means - exact)
                      <= 6 * results[i].stderrs + 1e-6)


# -- group commit + crash replay ----------------------------------------------

def test_group_commit_one_journal_write_per_wave(make_engine, tmp_path):
    """A 4-round wave journals its deposits in ONE write+fsync."""
    from repro.service.store import DurableStore
    writes = []
    orig = DurableStore._write

    def counting_write(self, record):
        writes.append(len(record))
        return orig(self, record)

    DurableStore._write = counting_write
    try:
        engine = make_engine(state_dir=str(tmp_path), max_rounds_per_wave=8)
        IntegrationClient(engine).integrate([harmonic_family(4, 3)],
                                            n_samples=4 * R)
    finally:
        DurableStore._write = orig
    # one alloc record + one group-committed batch of 4 deposit records
    assert len(writes) == 2
    assert engine.cache.get(
        next(iter(engine.cache._entries))).rounds_done == 4


def test_torn_group_commit_replays_prefix(make_engine, bit_identical,
                                          tmp_path):
    """A crash tearing the wave's batch write loses only a round suffix;
    the restart tops up bit-identically."""
    from repro.service.store import _MAGIC, DurableStore
    engine = make_engine(state_dir=str(tmp_path), max_rounds_per_wave=8)
    IntegrationClient(engine).integrate([harmonic_family(6, 3)],
                                        n_samples=3 * R)
    # no close(): the journal is all that survives the "SIGKILL"; tear
    # the batch at the last record boundary (drop deposit r2)
    import os
    journal = os.path.join(str(tmp_path), DurableStore.JOURNAL)
    with open(journal, "rb") as f:
        data = f.read()
    starts = []
    pos = 0
    while (pos := data.find(_MAGIC, pos)) != -1:
        starts.append(pos)
        pos += len(_MAGIC)
    assert len(starts) == 4                  # alloc + 3 deposits
    with open(journal, "wb") as f:
        f.write(data[:starts[3] + 7])        # torn mid-record

    e2 = make_engine(state_dir=str(tmp_path), max_rounds_per_wave=8)
    assert e2.cache.recovered.truncated_bytes > 0
    template.reset_launch_count()
    res = IntegrationClient(e2).integrate([harmonic_family(6, 3)],
                                          n_samples=3 * R)
    assert e2.stats.items_executed == 1      # only the torn round re-paid
    assert template.launch_count() == 1
    clean = IntegrationClient(
        make_engine(max_rounds_per_wave=8)).integrate(
            [harmonic_family(6, 3)], n_samples=3 * R)
    bit_identical(res, clean)


def test_transient_deposit_failure_replays_wave(make_engine, bit_identical,
                                                tmp_path):
    """A wave whose group commit dies mid-write is replayed identically
    (journaled prefix replays as exact no-ops on the retry)."""
    engine = make_engine(state_dir=str(tmp_path), max_rounds_per_wave=8)
    store = engine.store
    orig = store.append_deposits
    fails = {"left": 1}

    def flaky(payloads):
        payloads = list(payloads)
        if fails["left"]:
            fails["left"] -= 1
            orig(payloads[:1])               # half the batch hits disk...
            raise OSError("injected torn group commit")
        return orig(payloads)

    store.append_deposits = flaky
    res = IntegrationClient(engine).integrate([harmonic_family(4, 3)],
                                              n_samples=3 * R)
    assert engine.stats.restarts == 1
    clean = IntegrationClient(
        make_engine(max_rounds_per_wave=8)).integrate(
            [harmonic_family(4, 3)], n_samples=3 * R)
    bit_identical(res, clean)


def test_deposit_wave_skips_ahead_of_frontier_rounds():
    """A wave carrying rounds whose predecessors are still in another
    driver's in-flight wave folds (and journals) nothing for them; the
    planner re-schedules once the frontier catches up.  The single-round
    deposit keeps its strict gap-raise contract."""
    from repro.core.direct_mc import SumsState
    from repro.service import ResultCache
    cache = ResultCache(round_samples=R)
    entry = cache.get_or_allocate("x:mc", harmonic_family(4, 3))
    ones = SumsState(s1=np.ones(4, np.float32),
                     s2=np.ones(4, np.float32), n=np.float32(R))
    assert cache.deposit_wave([(entry, 1, ones)]) == 0   # round 0 missing
    assert entry.rounds_done == 0
    assert cache.deposit_wave([(entry, 0, ones), (entry, 1, ones)]) == 2
    assert entry.rounds_done == 2
    assert cache.deposit_wave([(entry, 1, ones)]) == 0   # replay: skipped
    with pytest.raises(ValueError, match="deposit gap"):
        cache.deposit(entry, 3, ones)


# -- fairness -----------------------------------------------------------------

def test_small_request_not_starved_by_heavy(make_engine):
    """Round-robin wave budget: the small ask completes in wave 1 even
    though a heavy ask arrived first and wants far more than the wave."""
    engine = make_engine(max_rounds_per_wave=4, max_items_per_wave=4)
    heavy = engine.submit(IntegrationRequest.make(
        [harmonic_family(4, 3)], n_samples=16 * R))
    small = engine.submit(IntegrationRequest.make(
        [gaussian_family(4, 2)], n_samples=R))
    assert engine.step()
    assert engine.poll(small) is not None, "small request starved"
    assert engine.poll(heavy) is None
    while engine.step():
        pass
    assert engine.poll(heavy) is not None


def test_greedy_allocation_would_starve_rr_does_not(make_engine):
    """With many heavy streams saturating the budget, every stream still
    progresses every wave (one round each, round-robin)."""
    engine = make_engine(max_rounds_per_wave=8, max_items_per_wave=3)
    tickets = [engine.submit(IntegrationRequest.make(
        [harmonic_family(2, 2 + i % 3)], n_samples=2 * R)) for i in range(3)]
    engine.step()
    done = [e.rounds_done for pend in engine._pending.values()
            for e in pend.entries]
    # budget 3 over 3 streams -> exactly one round each, nobody at 2
    assert len(done) == 3 and all(d == 1 for d in done)
    while engine.step():
        pass
    assert all(engine.poll(t) is not None for t in tickets)


# -- plan cache ---------------------------------------------------------------

def test_plan_cache_lru_eviction(make_engine):
    engine = make_engine()
    batcher = engine.batcher
    batcher.plan_cache_size = 2
    cli = IntegrationClient(engine)
    fams = [harmonic_family(4, d) for d in (2, 3, 4)]
    for f in fams:
        cli.integrate([f], n_samples=R)
    assert len(batcher._plans) == 2          # oldest mix evicted
    keys = list(batcher._plans)
    # a warm re-ask costs no launches, so the plan table is untouched
    cli.integrate([fams[2]], n_samples=R)
    assert list(batcher._plans) == keys
    # re-planning the evicted mix displaces the least recently used
    cli.integrate([fams[0]], n_samples=2 * R)
    assert len(batcher._plans) == 2
    assert keys[0] not in batcher._plans


def test_plan_reused_across_waves(make_engine):
    """A topped-up stream re-uses its cached plan object (LRU hit)."""
    engine = make_engine(max_rounds_per_wave=1)
    cli = IntegrationClient(engine)
    cli.integrate([harmonic_family(4, 3)], n_samples=2 * R)  # two waves
    assert len(engine.batcher._plans) == 1
