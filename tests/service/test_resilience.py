"""Chaos-harness and policy tests: deterministic fault injection,
the unified retry/deadline policy, poison-stream quarantine, state-dir
leases, and idempotent shutdown.

The contract under test is the PR-9 resilience story: transient
injected faults are retried and leave results bit-identical to a
fault-free run; permanent failures *complete* their tickets with a
structured ``RequestFailed`` instead of hanging; NaN-poisoned streams
quarantine themselves without taking the request's siblings down; and
a state dir admits exactly one live writer.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import harmonic_family
from repro.obs import clock
from repro.service import (Deadline, DeadlineExceeded, FaultPlan,
                           IntegrationClient, IntegrationEngine,
                           IntegrationRequest, LeaseHeld, LeaseLost,
                           NullFaultPlan, RequestError, RequestFailed,
                           RetryExhausted, RetryPolicy, run_with_policy)
from repro.service.faults import (FAULT_POINTS, InjectedCrash,
                                  InjectedDeviceError, InjectedIOError)
from repro.service.store import DurableStore

R = 4096
FAMS = [harmonic_family(4, 2)]


@pytest.fixture
def fake_clock():
    """Install a controllable monotonic/wall clock; yields advance(dt)."""
    state = {"t": 1000.0}
    clock.set_clock(lambda: state["t"])

    def advance(dt):
        state["t"] += dt

    yield advance
    clock.set_clock(None)


def drive(engine, ticket, max_steps=200):
    """Step-drive the engine until ``ticket`` completes; permanent wave
    failures surface as exceptions from step() for sync drivers but the
    ticket still completes — keep stepping through them."""
    for _ in range(max_steps):
        res = engine.poll(ticket)
        if res is not None:
            return res
        try:
            engine.step()
        except (RetryExhausted, DeadlineExceeded):
            continue
    raise AssertionError(f"ticket {ticket} did not complete "
                         f"in {max_steps} steps")


# -- RetryPolicy ---------------------------------------------------------------
class TestRetryPolicy:
    def test_backoff_monotone_and_capped(self):
        pol = RetryPolicy(base_delay=0.05, multiplier=2.0, max_delay=0.4)
        delays = [pol.backoff(k) for k in range(1, 12)]
        assert delays == sorted(delays)
        assert max(delays) == 0.4
        assert delays[0] == 0.05

    def test_delay_within_jitter_band(self):
        pol = RetryPolicy(base_delay=0.1, multiplier=3.0, max_delay=5.0,
                          jitter=0.25, seed=3)
        for attempt in range(1, 8):
            b = pol.backoff(attempt)
            for counter in range(6):
                d = pol.delay(attempt, counter)
                assert b * (1.0 - pol.jitter) <= d <= b

    def test_delay_deterministic_per_seed_counter_attempt(self):
        a = RetryPolicy(seed=7)
        b = RetryPolicy(seed=7)
        assert [a.delay(k, 5) for k in range(1, 6)] == \
               [b.delay(k, 5) for k in range(1, 6)]
        # the counter actually participates (different waves de-sync)
        assert len({a.delay(2, c) for c in range(16)}) > 1

    def test_zero_jitter_is_pure_backoff(self):
        pol = RetryPolicy(jitter=0.0)
        assert pol.delay(3, counter=9) == pol.backoff(3)

    @pytest.mark.parametrize("kw", [
        {"max_attempts": 0}, {"multiplier": 0.5}, {"jitter": 1.5},
        {"jitter": -0.1}, {"base_delay": -1.0}])
    def test_invalid_policy_rejected(self, kw):
        with pytest.raises(ValueError):
            RetryPolicy(**kw)

    def test_backoff_attempt_is_one_based(self):
        with pytest.raises(ValueError, match="1-based"):
            RetryPolicy().backoff(0)


# -- Deadline + run_with_policy ------------------------------------------------
class TestRunWithPolicy:
    def test_success_passes_value_through(self, fake_clock):
        out = run_with_policy(lambda attempt: ("ok", attempt),
                              RetryPolicy(max_attempts=3))
        assert out == ("ok", 0)

    def test_retries_then_succeeds(self, fake_clock):
        calls, retries = [], []

        def body(attempt):
            calls.append(attempt)
            if attempt < 2:
                raise ValueError(f"transient {attempt}")
            return attempt

        out = run_with_policy(
            body, RetryPolicy(max_attempts=4), stage="launch",
            on_retry=lambda a, exc: retries.append((a, str(exc))))
        assert out == 2
        assert calls == [0, 1, 2]
        assert [a for a, _ in retries] == [0, 1]

    def test_exhaustion_raises_retry_exhausted(self, fake_clock):
        retries = []

        def body(attempt):
            raise ValueError("permanent boom")

        with pytest.raises(RetryExhausted) as ei:
            run_with_policy(body, RetryPolicy(max_attempts=3),
                            stage="deposit",
                            on_retry=lambda a, e: retries.append(a))
        exc = ei.value
        assert isinstance(exc, RuntimeError)
        assert exc.stage == "deposit" and exc.attempts == 3
        assert isinstance(exc.last, ValueError)
        assert exc.__cause__ is exc.last
        assert "permanent boom" in str(exc)
        # the hook fires for EVERY failed attempt, final included
        assert retries == [0, 1, 2]

    def test_deadline_stops_attempt_loop(self, fake_clock):
        deadline = Deadline(10.0)
        calls = []

        def body(attempt):
            calls.append(attempt)
            fake_clock(6.0)
            raise ValueError("slow failure")

        with pytest.raises(DeadlineExceeded) as ei:
            run_with_policy(body, RetryPolicy(max_attempts=8, base_delay=0),
                            stage="wave", deadline=deadline)
        # attempt 0 at t=0, attempt 1 at t=6 (<10); attempt 2 would
        # start at t=12 — the pre-attempt check stops it there
        assert calls == [0, 1]
        assert isinstance(ei.value.__cause__, ValueError)
        assert "budget 10" in str(ei.value)

    def test_started_attempt_is_never_interrupted(self, fake_clock):
        deadline = Deadline(1.0)

        def body(attempt):
            fake_clock(50.0)  # blows way past the budget mid-attempt
            return "done"

        assert run_with_policy(body, RetryPolicy(max_attempts=2),
                               deadline=deadline) == "done"

    def test_unbounded_deadline(self, fake_clock):
        d = Deadline(None)
        assert d.remaining() == float("inf")
        fake_clock(1e9)
        assert not d.expired

    def test_deadline_expiry_and_validation(self, fake_clock):
        d = Deadline(5.0)
        assert not d.expired and d.remaining() == pytest.approx(5.0)
        fake_clock(5.5)
        assert d.expired and d.remaining() == pytest.approx(-0.5)
        with pytest.raises(ValueError):
            Deadline(0.0)


# -- FaultPlan -----------------------------------------------------------------
class TestFaultPlan:
    def test_from_seed_is_deterministic(self):
        a = FaultPlan.from_seed(17, FAULT_POINTS)
        b = FaultPlan.from_seed(17, FAULT_POINTS)
        assert a.spec() == b.spec()
        assert set(a.spec()) == set(FAULT_POINTS)
        json.dumps(a.spec())  # bench artifacts embed the spec

    def test_unknown_point_and_negative_index_rejected(self):
        with pytest.raises(ValueError, match="unknown fault point"):
            FaultPlan({"warp_core": 0})
        with pytest.raises(ValueError, match=">= 0"):
            FaultPlan({"launch": -1})

    def test_counted_down_trigger(self):
        plan = FaultPlan({"launch": 2})
        assert [plan.fire("launch") for _ in range(5)] == \
               [False, False, True, False, False]
        assert plan.fired == [("launch", 2)]
        assert plan.exhausted

    def test_multiple_trigger_indices(self):
        plan = FaultPlan({"deposit": [0, 2]})
        assert not plan.exhausted
        assert [plan.fire("deposit") for _ in range(4)] == \
               [True, False, True, False]
        assert plan.exhausted

    def test_exception_types_per_point(self):
        plan = FaultPlan({"wal_fsync": 0, "device_error": 0, "launch": 0})
        with pytest.raises(InjectedIOError) as ei:
            plan.check("wal_fsync")
        assert isinstance(ei.value, OSError)
        with pytest.raises(InjectedDeviceError):
            plan.check("device_error")
        with pytest.raises(InjectedCrash):
            plan.check("launch")
        # untriggered / exhausted points are silent
        plan.check("wal_fsync")
        plan.check("transfer")

    def test_null_plan_is_inert(self):
        null = NullFaultPlan()
        assert not null.enabled
        assert null.bind(object()) is null
        assert not null.fire("launch")
        assert null.check("wal_fsync") is None

    def test_fired_faults_counted_into_metrics(self, make_engine):
        eng = make_engine(faults=FaultPlan({"launch": 0}), use_kernel=False,
                          max_restarts=2)
        t = eng.submit(IntegrationRequest.make(FAMS, n_samples=R))
        res = drive(eng, t)
        assert not res.failed
        m = eng.obs.m
        assert m["faults_injected"].value(stage="launch") == 1.0
        assert eng.faults.exhausted


# -- engine-level chaos --------------------------------------------------------
class TestEngineChaos:
    def test_transient_faults_leave_results_bit_identical(
            self, make_engine, bit_identical):
        req = IntegrationRequest.make(FAMS, n_samples=2 * R)
        clean = make_engine(use_kernel=False)
        want = drive(clean, clean.submit(req))

        plan = FaultPlan({"launch": 0, "deposit": 0})
        eng = make_engine(use_kernel=False, faults=plan,
                          retry_policy=RetryPolicy(max_attempts=3,
                                                   base_delay=0.001))
        got = drive(eng, eng.submit(req))
        assert not got.failed
        bit_identical(want, got)
        assert eng.stats.restarts >= 2
        assert plan.exhausted
        # counter contract: sum over stages == EngineStats.restarts
        retries = eng.obs.m["retries"]
        total = sum(retries.value(stage=s)
                    for s in ("wave", "launch", "deposit"))
        assert total == eng.stats.restarts

    def test_retry_exhaustion_completes_ticket_with_failure(
            self, make_engine):
        eng = make_engine(use_kernel=False, max_restarts=1,
                          faults=FaultPlan({"launch": [0, 1]}))
        t = eng.submit(IntegrationRequest.make(FAMS, n_samples=R))
        with pytest.raises(RetryExhausted):
            while eng.poll(t) is None:
                eng.step()
        res = eng.poll(t)
        assert isinstance(res, RequestFailed) and res.failed
        assert res.reason == "retry_exhausted"
        assert res.stage == "wave" and res.attempts == 2
        assert res.ticket == t
        assert eng.stats.failed == 1
        # the ticket COMPLETED: result() returns the failure, no hang
        assert eng.result(t, timeout=1.0) is res

    def test_client_wait_raises_request_error(self, make_engine):
        eng = make_engine(use_kernel=False, max_restarts=0,
                          faults=FaultPlan({"launch": 0}))
        client = IntegrationClient(eng)
        t = client.submit(FAMS, n_samples=R)
        with pytest.raises(RequestError) as ei:
            client.wait(t, timeout=30.0)
        assert ei.value.failure.reason == "retry_exhausted"
        assert "retry_exhausted" in str(ei.value)

    def test_deadline_expiry_fails_ticket_not_hangs(self, make_engine):
        eng = make_engine(use_kernel=False, max_rounds_per_wave=1)
        req = IntegrationRequest.make(FAMS, n_samples=4 * R,
                                      deadline=0.001)
        res = drive(eng, eng.submit(req))
        assert isinstance(res, RequestFailed)
        assert res.reason == "deadline"
        assert eng.stats.deadline_expirations >= 1
        assert eng.obs.m["deadline_expirations"].value() >= 1.0

    def test_deadline_validation(self):
        with pytest.raises(ValueError, match="deadline"):
            IntegrationRequest.make(FAMS, n_samples=R, deadline=-1.0)

    def test_nan_stream_quarantined(self, make_engine):
        plan = FaultPlan({"transfer_nan": [0, 1, 2]})
        eng = make_engine(use_kernel=False, faults=plan)
        t = eng.submit(IntegrationRequest.make(FAMS, n_samples=R))
        res = drive(eng, t)
        assert isinstance(res, RequestFailed)
        assert res.reason == "quarantined"
        quarantined = eng.cache.quarantined_streams()
        assert len(quarantined) == 1
        assert quarantined[0][:16] in res.message
        assert eng.obs.m["quarantined_streams"].value() == 1.0
        # strikes 1-2 only reject+reschedule; strike 2 degrades the
        # stream off the fused path before strike 3 quarantines it
        entry = eng.cache.get(quarantined[0])
        assert entry.quarantined and entry.degraded
        assert entry.poison_strikes == 3
        # poison was never journaled and never folded
        assert entry.rounds_done == 0

    def test_quarantine_spares_healthy_siblings(self, make_engine):
        from repro.core import gaussian_family
        plan = FaultPlan({"transfer_nan": [0, 1, 2, 3, 4]})
        eng = make_engine(use_kernel=False, faults=plan)
        poisoned = eng.submit(IntegrationRequest.make(FAMS, n_samples=R))
        healthy = eng.submit(IntegrationRequest.make(
            [gaussian_family(4, 3)], n_samples=R))
        res_p = drive(eng, poisoned)
        res_h = drive(eng, healthy)
        assert isinstance(res_p, RequestFailed)
        assert res_p.reason == "quarantined"
        assert not res_h.failed
        assert np.isfinite(res_h.means).all()

    def test_worker_crash_is_salvaged_by_step_driver(
            self, make_engine, bit_identical):
        req = IntegrationRequest.make(FAMS, n_samples=2 * R)
        clean = make_engine(use_kernel=False)
        want = drive(clean, clean.submit(req))

        eng = make_engine(use_kernel=False,
                          faults=FaultPlan({"worker_crash": 0}))
        eng.start()
        t = eng.submit(req)
        eng._worker.join(timeout=30.0)
        assert not eng.running  # the injected crash killed the worker
        got = drive(eng, t)  # a sync driver salvages the pending work
        assert not got.failed
        bit_identical(want, got)


# -- idempotent shutdown -------------------------------------------------------
class TestShutdownIdempotency:
    def test_stop_twice_snapshots_once(self, make_engine, tmp_path,
                                       monkeypatch):
        eng = make_engine(state_dir=str(tmp_path / "state"))
        eng.start()
        drive_res = eng.result(
            eng.submit(IntegrationRequest.make(FAMS, n_samples=R)),
            timeout=60.0)
        assert not drive_res.failed
        calls = []
        real = eng.cache.snapshot_to_store
        monkeypatch.setattr(eng.cache, "snapshot_to_store",
                            lambda: calls.append(1) or real())
        eng.stop()
        eng.stop()  # second call: no-op, no double snapshot
        assert calls == [1]
        assert not eng.running

    def test_close_after_stop_and_restart(self, make_engine, tmp_path):
        eng = make_engine(state_dir=str(tmp_path / "state"))
        eng.start()
        eng.stop()
        eng.close()
        eng.close()  # idempotent
        # a fresh start() re-arms the engine after a completed stop()
        eng2 = make_engine(state_dir=str(tmp_path / "state"))
        eng2.start()
        res = eng2.result(
            eng2.submit(IntegrationRequest.make(FAMS, n_samples=R)),
            timeout=60.0)
        assert not res.failed
        eng2.close()

    def test_result_timeout_message_names_state(self, make_engine):
        eng = make_engine(use_kernel=False)  # no worker running
        t = eng.submit(IntegrationRequest.make(FAMS, n_samples=R))
        with pytest.raises(TimeoutError) as ei:
            eng.result(t, timeout=0.01)
        msg = str(ei.value)
        assert "still pending" in msg
        assert "NOT running" in msg
        assert "rounds folded per stream" in msg


# -- state-dir leases ----------------------------------------------------------
class TestLeases:
    def test_acquire_writes_fsynced_lease(self, tmp_path):
        store = DurableStore(str(tmp_path), lease_ttl=30.0)
        with open(store.lease_path, encoding="utf-8") as f:
            rec = json.load(f)
        assert rec["pid"] == os.getpid()
        assert rec["token"] == store._lease_token
        assert rec["expires"] > rec["acquired"]
        store.close()
        assert not os.path.exists(store.lease_path)  # released

    def test_live_foreign_holder_blocks(self, tmp_path):
        store = DurableStore(str(tmp_path), lease_ttl=30.0)
        # forge a live foreign holder: pid 1 is always alive and never us
        rec = {"token": "not-ours", "pid": 1,
               "acquired": clock.wall(), "expires": clock.wall() + 3600}
        with open(store.lease_path, "w", encoding="utf-8") as f:
            json.dump(rec, f)
        with pytest.raises(LeaseHeld, match="leased to pid 1"):
            DurableStore(str(tmp_path), lease_ttl=30.0)

    def test_expired_lease_is_taken_over(self, tmp_path):
        rec = {"token": "stale", "pid": 1,
               "acquired": clock.wall() - 7200,
               "expires": clock.wall() - 3600}
        lease = tmp_path / "lease.json"
        lease.write_text(json.dumps(rec))
        store = DurableStore(str(tmp_path), lease_ttl=30.0)
        assert json.loads(lease.read_text())["pid"] == os.getpid()
        store.close()

    def test_dead_holder_is_taken_over(self, tmp_path):
        # a reaped child is a guaranteed-dead pid (SIGKILL crash model)
        proc = subprocess.Popen([sys.executable, "-c", "pass"])
        proc.wait()
        rec = {"token": "dead-holder", "pid": proc.pid,
               "acquired": clock.wall(), "expires": clock.wall() + 3600}
        (tmp_path / "lease.json").write_text(json.dumps(rec))
        store = DurableStore(str(tmp_path), lease_ttl=30.0)
        assert json.loads(
            (tmp_path / "lease.json").read_text())["pid"] == os.getpid()
        store.close()

    def test_same_process_handle_is_taken_over(self, tmp_path):
        a = DurableStore(str(tmp_path), lease_ttl=30.0)
        # an abandoned handle in this very process must not deadlock a
        # warm reopen (the engine-restart-same-dir pattern)
        b = DurableStore(str(tmp_path), lease_ttl=30.0)
        b.close()
        a.close()

    def test_heartbeat_fencing_detects_usurper(self, tmp_path):
        store = DurableStore(str(tmp_path), lease_ttl=30.0)
        rec = {"token": "usurper", "pid": 1,
               "acquired": clock.wall(), "expires": clock.wall() + 3600}
        with open(store.lease_path, "w", encoding="utf-8") as f:
            json.dump(rec, f)
        with pytest.raises(LeaseLost, match="must stop"):
            store.heartbeat(force=True)

    def test_lease_disabled_with_none_ttl(self, tmp_path):
        store = DurableStore(str(tmp_path), lease_ttl=None)
        assert not os.path.exists(store.lease_path)
        store.heartbeat(force=True)  # no-op, no file, no error
        store.close()
