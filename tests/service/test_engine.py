"""Engine behavior: batching, dedupe, warm cache, backpressure, faults.

Engine construction and the mixed-dimension request stream come from the
shared ``tests/service/conftest.py`` fixtures.
"""

import threading

import numpy as np
import pytest

from repro.core import gaussian_family, harmonic_analytic, harmonic_family
from repro.kernels import template
from repro.service import (Backpressure, IntegrationClient,
                           IntegrationRequest)

R = 4096   # = conftest.R, the make_engine fixture's round quantum


def test_batched_fewer_launches_than_sequential(make_engine, mixed_requests):
    reqs = mixed_requests(8)
    engine = make_engine()
    template.reset_launch_count()
    tickets = [engine.submit(r) for r in reqs]
    while engine.step():
        pass
    batched = template.launch_count()
    results = [engine.poll(t) for t in tickets]
    assert all(r is not None for r in results)
    # 8 single-family requests over dims {2,3,4} coalesce to 3 buckets
    assert batched < len(reqs)
    assert batched == 3
    # estimates are real: harmonic requests match the closed form
    for req, res in zip(reqs, results):
        if "harmonic" in res.names[0]:
            exact = harmonic_analytic(req.families[0].n_fn,
                                      req.families[0].dim)
            assert np.all(np.abs(res.means - exact)
                          <= 6 * res.stderrs + 1e-6)


def test_dedupe_across_clients(make_engine):
    engine = make_engine()
    fams = lambda: [harmonic_family(4, 3)]
    t1 = engine.submit(IntegrationRequest.make(fams(), n_samples=2 * R))
    t2 = engine.submit(IntegrationRequest.make(fams(), n_samples=2 * R))
    while engine.step():
        pass
    r1, r2 = engine.poll(t1), engine.poll(t2)
    np.testing.assert_array_equal(r1.means, r2.means)
    assert engine.stats.items_requested > engine.stats.items_executed
    assert engine.cache.n_entries == 1


def test_warm_cache_zero_launches(make_engine):
    engine = make_engine()
    cli = IntegrationClient(engine)
    cli.integrate([harmonic_family(4, 3)], n_samples=R)
    template.reset_launch_count()
    res = cli.integrate([harmonic_family(4, 3)], n_samples=R)
    assert template.launch_count() == 0
    assert res.served_from_cache
    # looser precision is also a pure hit
    res2 = cli.integrate([harmonic_family(4, 3)],
                         target_stderr=float(res.stderrs.max()) * 2)
    assert template.launch_count() == 0 and res2.served_from_cache


def test_topup_resumes_stream(make_engine):
    engine = make_engine()
    cli = IntegrationClient(engine)
    cli.integrate([harmonic_family(4, 3)], n_samples=R)
    template.reset_launch_count()
    before = engine.stats.items_executed
    res = cli.integrate([harmonic_family(4, 3)], n_samples=3 * R)
    # only the two delta rounds are computed — in ONE multi-round launch
    assert engine.stats.items_executed - before == 2
    assert template.launch_count() == 1
    assert res.n_per_family == (3 * R,)
    assert not res.served_from_cache


def test_samplers_use_distinct_streams(make_engine):
    engine = make_engine()
    cli = IntegrationClient(engine)
    a = cli.integrate([harmonic_family(4, 3)], n_samples=R, sampler="mc")
    b = cli.integrate([harmonic_family(4, 3)], n_samples=R, sampler="sobol")
    assert engine.cache.n_entries == 2
    assert not np.array_equal(a.means, b.means)


def test_backpressure(make_engine):
    engine = make_engine(max_pending=1)
    engine.submit(IntegrationRequest.make([harmonic_family(4, 3)],
                                          n_samples=R))
    with pytest.raises(Backpressure):
        engine.submit(IntegrationRequest.make([gaussian_family(4, 3)],
                                              n_samples=R), block=False)
    with pytest.raises(Backpressure):
        engine.submit(IntegrationRequest.make([gaussian_family(4, 3)],
                                              n_samples=R), timeout=0.05)


def test_async_worker_thread(make_engine, mixed_requests):
    engine = make_engine()
    engine.start()
    try:
        tickets = [engine.submit(r) for r in mixed_requests(4)]
        results = [engine.result(t, timeout=120.0) for t in tickets]
        assert all(r.n_per_family[0] >= R for r in results)
        engine.drain(timeout=10.0)
    finally:
        engine.stop()
    assert not engine.running


def test_wave_restart_on_transient_failure(make_engine):
    """A crashed wave replays identically (counter-addressed work)."""
    engine = make_engine()
    fails = {"left": 1}
    orig = engine.batcher.execute

    def flaky(items):
        if fails["left"]:
            fails["left"] -= 1
            raise RuntimeError("injected wave failure")
        return orig(items)

    engine.batcher.execute = flaky
    res = IntegrationClient(engine).integrate([harmonic_family(4, 3)],
                                              n_samples=2 * R)
    assert engine.stats.restarts == 1
    # bit-identical to an undisturbed engine
    clean = IntegrationClient(make_engine()).integrate(
        [harmonic_family(4, 3)], n_samples=2 * R)
    np.testing.assert_array_equal(res.means, clean.means)


def test_exhausted_restart_budget_raises(make_engine):
    engine = make_engine(max_restarts=1)

    def always_fail(items):
        raise RuntimeError("permanent failure")

    engine.batcher.execute = always_fail
    engine.submit(IntegrationRequest.make([harmonic_family(4, 3)],
                                          n_samples=R))
    with pytest.raises(RuntimeError, match="permanent"):
        engine.step()


def test_multifamily_request_order_preserved(make_engine):
    engine = make_engine()
    res = IntegrationClient(engine).integrate(
        [gaussian_family(3, 2), harmonic_family(5, 4)], n_samples=R)
    assert res.names == ("gaussian[3x2d]", "harmonic[5x4d]")
    assert res.means.shape == (8,)
    exact = harmonic_analytic(5, 4)
    assert np.all(np.abs(res.means[3:] - exact) <= 6 * res.stderrs[3:] + 1e-6)


def test_concurrent_step_drivers(make_engine):
    """Two blocking clients driving step() themselves race their waves:
    duplicate rounds are skipped as exact replays, both get answers."""
    engine = make_engine()
    results = {}

    def client(i):
        results[i] = IntegrationClient(engine).integrate(
            [harmonic_family(4, 3)], n_samples=2 * R)

    threads = [threading.Thread(target=client, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=180.0)
    assert len(results) == 2
    np.testing.assert_array_equal(results[0].means, results[1].means)
    clean = IntegrationClient(make_engine()).integrate(
        [harmonic_family(4, 3)], n_samples=2 * R)
    np.testing.assert_array_equal(results[0].means, clean.means)


def test_rejected_submit_allocates_nothing(make_engine):
    engine = make_engine(max_pending=1)
    engine.submit(IntegrationRequest.make([harmonic_family(4, 3)],
                                          n_samples=R))
    before = engine.cache.stats()["function_ids_allocated"]
    with pytest.raises(Backpressure):
        engine.submit(IntegrationRequest.make([gaussian_family(4, 3)],
                                              n_samples=R), block=False)
    assert engine.cache.stats()["function_ids_allocated"] == before
    assert engine.cache.n_entries == 1


def test_result_retention_bounded(make_engine):
    engine = make_engine(max_retained_results=2)
    tickets = []
    for n in (1, 2, 3):
        tickets.append(engine.submit(IntegrationRequest.make(
            [harmonic_family(4, 3)], n_samples=n * R)))
        while engine.step():
            pass
    assert engine.poll(tickets[0]) is None     # evicted FIFO
    assert engine.poll(tickets[2]) is not None
    engine.release(tickets[2])
    assert engine.poll(tickets[2]) is None


def test_concurrent_submitters_against_worker(make_engine):
    """Many client threads against the running worker: all served, shared
    entries deduped."""
    engine = make_engine()
    engine.start()
    results = {}

    def client(i):
        cli = IntegrationClient(engine)
        results[i] = cli.integrate([harmonic_family(4, 2 + i % 2)],
                                   n_samples=2 * R)

    try:
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=180.0)
    finally:
        engine.stop()
    assert len(results) == 6
    assert engine.cache.n_entries == 2         # dims 2 and 3 only
    np.testing.assert_array_equal(results[0].means, results[2].means)
