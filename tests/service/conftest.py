"""Shared fixtures for the service test modules.

Every module in this directory drives the same ``IntegrationEngine``
surface with the same round quantum; the engine factory, bit-identity
assertion and the mixed-dimension request maker live here once instead
of being re-declared per module.  ``R`` is the shared round quantum —
the factory's ``round_samples`` default — and modules that spell it in
sample-budget arithmetic keep a local ``R = 4096`` alias for
readability.
"""

import numpy as np
import pytest

from repro.service import IntegrationEngine, IntegrationRequest

R = 4096


@pytest.fixture
def make_engine():
    """Factory for engines with the suite's defaults (seed 0, rounds of
    ``R`` samples).  Keyword overrides pass straight through — including
    ``state_dir`` for durable-store tests.  Engines whose worker thread
    is still running at teardown are stopped so a failing test cannot
    leak a live worker into the next one.
    """
    made = []

    def make(**kw):
        kw.setdefault("seed", 0)
        kw.setdefault("round_samples", R)
        eng = IntegrationEngine(**kw)
        made.append(eng)
        return eng

    yield make
    for eng in made:
        if eng.running:
            eng.stop()


@pytest.fixture
def bit_identical():
    """Assert two IntegrationResults carry byte-identical estimates."""

    def check(a, b):
        np.testing.assert_array_equal(a.means, b.means)
        np.testing.assert_array_equal(a.stderrs, b.stderrs)
        assert a.means.tobytes() == b.means.tobytes()

    return check


@pytest.fixture
def mixed_requests():
    """Factory for a mixed-form, mixed-dimension request stream (the
    canonical batching workload: forms cycle, dims span 2-4)."""
    from repro.core import abs_sum_family, gaussian_family, harmonic_family

    def make(n=8, n_fn=4, budget=R):
        makers = [lambda d: harmonic_family(n_fn, d),
                  lambda d: gaussian_family(n_fn, d),
                  lambda d: abs_sum_family(n_fn, d, np.ones(n_fn))]
        return [IntegrationRequest.make([makers[i % 3](2 + i % 3)],
                                        n_samples=budget)
                for i in range(n)]

    return make
