"""Fused-bucket dispatch on a mesh (single-device mesh; the multi-device
semantics run in tests/distributed/progs/prog_sharded_mc.py)."""

import jax
import numpy as np
import pytest

from repro.core import (MultiFunctionSpec, ZMCMultiFunctions, gaussian_family,
                        harmonic_family)
from repro.core import genz
from repro.core import rng as rng_lib
from repro.kernels import template
from repro.kernels.mc_eval import multi

R = 4096


@pytest.fixture
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _spec():
    return MultiFunctionSpec.from_families([
        harmonic_family(6, 3), gaussian_family(4, 3),
        genz.corner_peak(5, 2)[0]])


def test_sharded_eval_plan_matches_single_device(mesh):
    spec = _spec()
    plan = multi.plan_spec(spec)
    key = rng_lib.fold_key(4, 0)
    single = multi.eval_plan(plan, R, key)
    sharded = multi.sharded_eval_plan(plan, R, key, mesh)
    assert set(single) == set(sharded)
    for idx in single:
        np.testing.assert_array_equal(np.asarray(single[idx].s1),
                                      np.asarray(sharded[idx].s1))
        np.testing.assert_array_equal(np.asarray(single[idx].s2),
                                      np.asarray(sharded[idx].s2))


def test_mesh_solver_uses_fused_buckets(mesh):
    spec = _spec()
    template.reset_launch_count()
    rm = ZMCMultiFunctions(spec, n_samples=R, seed=3, mesh=mesh,
                           use_kernel=True).evaluate(1)
    mesh_launches = template.launch_count()
    rs = ZMCMultiFunctions(spec, n_samples=R, seed=3,
                           use_kernel=True).evaluate(1)
    # one launch per dim bucket, not one per family
    assert mesh_launches == 2
    np.testing.assert_allclose(rm.means, rs.means, rtol=1e-6, atol=1e-7)


def test_service_engine_on_mesh(mesh):
    from repro.service import IntegrationClient, IntegrationEngine
    engine = IntegrationEngine(seed=0, round_samples=R, mesh=mesh)
    res = IntegrationClient(engine).integrate(
        [harmonic_family(4, 3), genz.oscillatory(4, 2)[0]], n_samples=R)
    ref_engine = IntegrationEngine(seed=0, round_samples=R)
    ref = IntegrationClient(ref_engine).integrate(
        [harmonic_family(4, 3), genz.oscillatory(4, 2)[0]], n_samples=R)
    np.testing.assert_allclose(res.means, ref.means, rtol=1e-6, atol=1e-7)
