"""Durable store: journal round-trips, corruption recovery, warm restarts.

The acceptance property of the persistence layer is that process death
is invisible to correctness: a request satisfied before a SIGKILL is
served after restart with zero kernel launches and bit-identical
``(s1, s2, n)``, and a partially-met request tops up from its persisted
``sample_offset`` bit-identically to an uninterrupted run.  Abandoning
an engine *without* ``close()`` models the SIGKILL here (the journal is
the only surviving state — snapshot-on-shutdown never ran); the real
cross-process SIGKILL is exercised by ``benchmarks/persistence_bench.py``
and the ``persistence`` CI job.
"""

import os

import numpy as np
import pytest

from repro.core import harmonic_family
from repro.core.direct_mc import SumsState
from repro.kernels import template
from repro.service import (IntegrationClient, IntegrationEngine, ResultCache,
                           canonical_family, family_hash)
from repro.service.store import _MAGIC, DurableStore

R = 4096
FAMS = [harmonic_family(6, 3)]


def make_engine(tmp_path, **kw):
    kw.setdefault("seed", 7)
    kw.setdefault("round_samples", R)
    return IntegrationEngine(state_dir=str(tmp_path), **kw)


def entry_of(engine, family, sampler="mc"):
    """The engine's cache entry for ``family`` (rehydrating if dormant)."""
    canon = canonical_family(family)
    chash = f"{family_hash(canon, canonicalize=False)}:{sampler}"
    return engine.cache.get(chash, canon)


# -- cross-"process" warm starts (acceptance criteria) ------------------------

def test_warm_restart_zero_launches_bit_identical(tmp_path):
    """Satisfied before the kill -> served after restart for free."""
    e1 = make_engine(tmp_path)
    first = IntegrationClient(e1).integrate(FAMS, n_samples=2 * R)
    state1 = entry_of(e1, FAMS[0]).snapshot()
    # no close(): the journal is all that survives the "SIGKILL"

    e2 = make_engine(tmp_path)
    template.reset_launch_count()
    again = IntegrationClient(e2).integrate(FAMS, n_samples=2 * R)
    assert template.launch_count() == 0
    assert again.served_from_cache
    np.testing.assert_array_equal(first.means, again.means)
    np.testing.assert_array_equal(first.stderrs, again.stderrs)
    # the accumulators themselves came back bit-for-bit
    s1a, s2a, na, ra = state1
    s1b, s2b, nb, rb = entry_of(e2, FAMS[0]).snapshot()
    assert s1a.tobytes() == s1b.tobytes()
    assert s2a.tobytes() == s2b.tobytes()
    assert (na, ra) == (nb, rb) == (2 * R, 2)


def test_partial_topup_bit_identical_to_uninterrupted(tmp_path):
    """Partially met before the kill -> only the delta rounds are paid."""
    e1 = make_engine(tmp_path)
    IntegrationClient(e1).integrate(FAMS, n_samples=R)     # 1 of 3 rounds

    e2 = make_engine(tmp_path)
    template.reset_launch_count()
    topped = IntegrationClient(e2).integrate(FAMS, n_samples=3 * R)
    resumed_launches = template.launch_count()

    cold_engine = IntegrationEngine(seed=7, round_samples=R)
    cold = IntegrationClient(cold_engine).integrate(FAMS, n_samples=3 * R)

    np.testing.assert_array_equal(topped.means, cold.means)
    np.testing.assert_array_equal(topped.stderrs, cold.stderrs)
    # the resume pays only the two delta rounds (one fused multi-round
    # launch), never the persisted first round
    assert resumed_launches > 0
    assert e2.stats.items_executed == 2
    assert cold_engine.stats.items_executed == 3
    ea, eb = entry_of(e2, FAMS[0]), entry_of(cold_engine, FAMS[0])
    assert ea.s1.tobytes() == eb.s1.tobytes()
    assert ea.s2.tobytes() == eb.s2.tobytes()
    assert ea.n == eb.n == 3 * R


def test_snapshot_on_shutdown_compacts_journal(tmp_path):
    with make_engine(tmp_path) as e1:
        IntegrationClient(e1).integrate(FAMS, n_samples=2 * R)
        assert e1.store.journal_size() > 0
    assert e1.store.journal_size() == 0          # compacted on close
    assert os.path.exists(os.path.join(str(tmp_path), "snapshot.npz"))

    e2 = make_engine(tmp_path, compact_on_start=True)
    template.reset_launch_count()
    res = IntegrationClient(e2).integrate(FAMS, n_samples=2 * R)
    assert template.launch_count() == 0 and res.served_from_cache


def test_allocator_high_water_mark_survives(tmp_path):
    fam_a, fam_b = harmonic_family(6, 3), harmonic_family(10, 2)
    e1 = make_engine(tmp_path)
    cli = IntegrationClient(e1)
    cli.integrate([fam_a], n_samples=R)
    cli.integrate([fam_b], n_samples=R)
    offsets1 = (entry_of(e1, fam_a).fn_offset, entry_of(e1, fam_b).fn_offset)
    next_id1 = e1.cache.stats()["function_ids_allocated"]

    e2 = make_engine(tmp_path)
    assert e2.cache.stats()["function_ids_allocated"] == next_id1
    assert (entry_of(e2, fam_a).fn_offset,
            entry_of(e2, fam_b).fn_offset) == offsets1
    # a brand-new family lands beyond every persisted counter range
    fam_c = harmonic_family(4, 4)
    IntegrationClient(e2).integrate([fam_c], n_samples=R)
    assert entry_of(e2, fam_c).fn_offset >= next_id1


def test_dormant_streams_survive_compaction(tmp_path):
    e1 = make_engine(tmp_path)
    IntegrationClient(e1).integrate(FAMS, n_samples=2 * R)

    # restart twice, never re-asking; checkpoint in between — a dormant
    # stream must ride through snapshot compaction untouched
    e2 = make_engine(tmp_path)
    assert e2.cache.stats()["dormant"] == 1
    e2.checkpoint()
    e3 = make_engine(tmp_path)
    template.reset_launch_count()
    res = IntegrationClient(e3).integrate(FAMS, n_samples=2 * R)
    assert template.launch_count() == 0 and res.served_from_cache


def test_config_mismatch_refused(tmp_path):
    e1 = make_engine(tmp_path)
    IntegrationClient(e1).integrate(FAMS, n_samples=R)
    with pytest.raises(ValueError, match="seed"):
        make_engine(tmp_path, seed=8)
    with pytest.raises(ValueError, match="round_samples"):
        make_engine(tmp_path, round_samples=2 * R)


# -- journal corruption: truncate the tail, never crash -----------------------

def _seed_store(tmp_path, rounds=3):
    store = DurableStore(str(tmp_path))
    cache = ResultCache(round_samples=R, store=store)
    entry = cache.get_or_allocate("e0", harmonic_family(4, 2))
    rng = np.random.default_rng(0)
    for r in range(rounds):
        cache.deposit(entry, r, SumsState(
            s1=rng.standard_normal(4).astype(np.float32),
            s2=rng.random(4).astype(np.float32), n=R))
    store.close()
    return entry


def _reload(tmp_path):
    store = DurableStore(str(tmp_path))
    cache = ResultCache(round_samples=R, store=store)
    return cache, cache.get("e0", harmonic_family(4, 2))


def test_partial_tail_write_truncated(tmp_path):
    _seed_store(tmp_path, rounds=3)
    journal = os.path.join(str(tmp_path), DurableStore.JOURNAL)
    size = os.path.getsize(journal)
    with open(journal, "r+b") as f:
        f.truncate(size - 5)                     # torn final record
    cache, entry = _reload(tmp_path)
    assert entry.rounds_done == 2                # last deposit lost, rest kept
    assert cache.recovered.truncated_bytes > 0
    assert os.path.getsize(journal) < size - 5   # bad tail dropped on disk
    # the journal keeps working after recovery truncation
    cache.deposit(entry, 2, SumsState(s1=np.ones(4, np.float32),
                                      s2=np.ones(4, np.float32), n=R))
    _, entry2 = _reload(tmp_path)
    assert entry2.rounds_done == 3


def test_garbage_tail_truncated(tmp_path):
    ref = _seed_store(tmp_path, rounds=2)
    journal = os.path.join(str(tmp_path), DurableStore.JOURNAL)
    with open(journal, "ab") as f:
        f.write(b"\x00garbage-that-is-not-a-record" * 4)
    cache, entry = _reload(tmp_path)
    assert entry.rounds_done == 2
    assert entry.s1.tobytes() == ref.s1.tobytes()
    assert cache.recovered.truncated_bytes > 0


def test_corrupt_record_drops_suffix(tmp_path):
    _seed_store(tmp_path, rounds=3)
    journal = os.path.join(str(tmp_path), DurableStore.JOURNAL)
    with open(journal, "rb") as f:
        data = f.read()
    # records: alloc, dep r0, dep r1, dep r2 — flip one payload byte of
    # dep r1, so the journal is valid up to and including dep r0
    starts, pos = [], 0
    while (pos := data.find(_MAGIC, pos)) != -1:
        starts.append(pos)
        pos += len(_MAGIC)
    assert len(starts) == 4
    pos = starts[3] - 3                          # tail of dep r1's payload
    data = data[:pos] + bytes([data[pos] ^ 0xFF]) + data[pos + 1:]
    with open(journal, "wb") as f:
        f.write(data)
    _, entry = _reload(tmp_path)
    # everything from the corrupt record on is gone; the prefix survives
    assert entry is not None and entry.rounds_done == 1


def test_snapshot_journal_overlap_is_idempotent(tmp_path):
    """Crash between snapshot commit and journal reset: replay skips."""
    ref = _seed_store(tmp_path, rounds=3)
    journal = os.path.join(str(tmp_path), DurableStore.JOURNAL)
    with open(journal, "rb") as f:
        saved = f.read()
    cache, entry = _reload(tmp_path)
    cache.snapshot_to_store()                    # journal reset to empty
    with open(journal, "wb") as f:
        f.write(saved)                           # ...crash un-reset it
    _, entry2 = _reload(tmp_path)
    assert entry2.rounds_done == 3               # not 6: overlap skipped
    assert entry2.s1.tobytes() == ref.s1.tobytes()
    assert entry2.n == ref.n


# The hypothesis round-trip property (arbitrary deposit sequences ->
# exact replay) lives in test_store_properties.py so this module still
# runs where hypothesis is not installed.


# -- fail-closed WAL under injected faults (chaos regression) ------------------

def _clean_result(tmp_path, n_samples):
    eng = IntegrationEngine(state_dir=str(tmp_path / "clean"), seed=7,
                            round_samples=R, use_kernel=False)
    return IntegrationClient(eng).integrate(FAMS, n_samples=n_samples)


@pytest.mark.parametrize("point", ["wal_fsync", "wal_torn_write"])
def test_injected_wal_fault_retried_bit_identical(tmp_path, point):
    """A journal write that dies mid-wave (failed fsync / torn write)
    must not ack any of the wave's deposits: the wave retries whole and
    the final answer is bit-identical to a fault-free run, with no torn
    middle left in the journal."""
    from repro.service import FaultPlan
    from repro.service.store import read_journal

    want = _clean_result(tmp_path, 2 * R)
    # journal hit 0 is the stream's alloc record at submit time; the
    # wave's deposit group-commit is hit 1 — fail THAT one
    eng = make_engine(tmp_path / "chaos", use_kernel=False,
                      faults=FaultPlan({point: 1}))
    got = IntegrationClient(eng).integrate(FAMS, n_samples=2 * R)
    assert eng.stats.restarts >= 1           # the fault really fired
    np.testing.assert_array_equal(want.means, got.means)
    np.testing.assert_array_equal(want.stderrs, got.stderrs)
    assert want.means.tobytes() == got.means.tobytes()
    # the failed append rewound to the last good boundary: every frame
    # on disk parses, nothing torn survives mid-file
    journal = os.path.join(str(tmp_path / "chaos"), DurableStore.JOURNAL)
    _, bad_tail = read_journal(journal)
    assert bad_tail == 0
    # and the journal replays to the same accumulators (kill -9 model)
    e2 = make_engine(tmp_path / "chaos", use_kernel=False)
    template.reset_launch_count()
    again = IntegrationClient(e2).integrate(FAMS, n_samples=2 * R)
    assert template.launch_count() == 0 and again.served_from_cache
    assert again.means.tobytes() == want.means.tobytes()


def test_wal_oserror_never_acks_unjournaled_deposits(tmp_path):
    """The satellite regression: an OSError inside append_deposits must
    leave the cache exactly as before the wave — no folded rounds whose
    journal frames never hit the disk."""
    from repro.service import FaultPlan
    from repro.service.faults import InjectedIOError

    # hit 0 is the alloc record; fail the wave group-commit (hit 1)
    store = DurableStore(str(tmp_path), faults=FaultPlan({"wal_fsync": 1}))
    cache = ResultCache(round_samples=R, store=store)
    entry = cache.get_or_allocate("e0", harmonic_family(4, 2))
    rng = np.random.default_rng(0)
    wave = [(entry, r, SumsState(
        s1=rng.standard_normal(4).astype(np.float32),
        s2=rng.random(4).astype(np.float32), n=R)) for r in range(3)]
    with pytest.raises(InjectedIOError):
        cache.deposit_wave(wave)
    assert entry.rounds_done == 0            # nothing acked, fail closed
    # the handle survives the error: the retried wave commits cleanly
    assert cache.deposit_wave(wave) == 3
    assert entry.rounds_done == 3
    store.close()
    _, entry2 = _reload(tmp_path)
    assert entry2.rounds_done == 3
    assert entry2.s1.tobytes() == entry.s1.tobytes()
