"""Canonicalization: equivalent requests hash together, distinct don't."""

import jax.numpy as jnp
import numpy as np

from repro.core import abs_sum_family, gaussian_family, harmonic_family
from repro.core.integrand import IntegrandFamily
from repro.service.canonical import family_hash, spec_hash


def test_independent_constructions_dedupe():
    # two clients building "the same integral" from scratch
    assert family_hash(harmonic_family(8, 3)) == family_hash(harmonic_family(8, 3))
    assert (family_hash(gaussian_family(4, 2))
            == family_hash(gaussian_family(4, 2)))


def test_name_is_cosmetic():
    a = harmonic_family(5, 2)
    b = harmonic_family(5, 2)
    b.name = "client-7-scan"
    assert family_hash(a) == family_hash(b)


def test_content_changes_hash():
    base = harmonic_family(8, 3)
    assert family_hash(base) != family_hash(harmonic_family(9, 3))   # size
    assert family_hash(base) != family_hash(harmonic_family(8, 4))   # dim
    assert family_hash(base) != family_hash(
        harmonic_family(8, 3, a=2 * np.ones(8, np.float32)))         # params
    assert family_hash(base) != family_hash(
        harmonic_family(8, 3, lo=-1.0))                              # domain


def test_dtype_normalized_to_engine_precision():
    c32 = np.linspace(0.5, 2.0, 6).astype(np.float32)
    a = abs_sum_family(6, 2, c32)
    b = abs_sum_family(6, 2, c32.astype(np.float64))
    assert family_hash(a) == family_hash(b)


def test_closure_values_participate():
    def make(scale):
        return IntegrandFamily(
            fn=lambda x, p: scale * jnp.sum(x * p["w"], -1),
            params={"w": jnp.ones((3, 2))},
            domains=jnp.broadcast_to(jnp.asarray([0.0, 1.0]), (3, 2, 2)),
        ).validate()

    assert family_hash(make(1.0)) == family_hash(make(1.0))
    assert family_hash(make(1.0)) != family_hash(make(2.0))


def test_compactification_canonical():
    # an infinite-domain ask and its pre-compactified twin are one integral
    inf_dom = np.broadcast_to(
        np.asarray([-np.inf, np.inf], np.float32), (2, 2, 2)).copy()
    fam = IntegrandFamily(
        fn=lambda x, p: jnp.exp(-jnp.sum(jnp.square(x), -1)) * p["c"],
        params={"c": jnp.ones(2)},
        domains=jnp.asarray(inf_dom),
    ).validate()
    assert family_hash(fam) == family_hash(fam.compactified())


def test_spec_hash_order_sensitive():
    a, b = harmonic_family(4, 2), gaussian_family(3, 2)
    assert spec_hash([a, b]) != spec_hash([b, a])
    assert spec_hash([a, b]) == spec_hash([a, b])
    assert spec_hash([a, b], sampler="sobol") != spec_hash([a, b])
