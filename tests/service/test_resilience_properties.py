"""Property tests for the retry/deadline policy (hypothesis).

Three properties the resilience layer stakes its determinism claims on:
backoff is monotone and capped for EVERY parameterization, jittered
delays stay inside the documented band and replay bit-identically from
``(seed, counter, attempt)``, and no attempt ever *starts* after its
deadline expired — even across nested retry loops sharing one budget.
"""

import pytest

pytest.importorskip(
    "hypothesis",
    reason=("property tests need hypothesis; the deterministic "
            "counterparts in test_resilience.py cover the same "
            "contracts with fixed examples"))

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.obs import clock  # noqa: E402
from repro.service.resilience import (Deadline, DeadlineExceeded,  # noqa: E402
                                      RetryExhausted, RetryPolicy,
                                      run_with_policy)

policies = st.builds(
    RetryPolicy,
    max_attempts=st.integers(1, 8),
    base_delay=st.floats(0.0, 5.0, allow_nan=False),
    max_delay=st.floats(0.0, 10.0, allow_nan=False),
    multiplier=st.floats(1.0, 4.0, allow_nan=False),
    jitter=st.floats(0.0, 1.0, allow_nan=False),
    seed=st.integers(0, 2**31))


@given(policy=policies)
@settings(max_examples=200, deadline=None)
def test_backoff_monotone_nondecreasing_and_capped(policy):
    delays = [policy.backoff(k) for k in range(1, 16)]
    assert all(a <= b for a, b in zip(delays, delays[1:]))
    assert all(d <= policy.max_delay for d in delays)


@given(policy=policies, attempt=st.integers(1, 12),
       counter=st.integers(0, 2**31))
@settings(max_examples=200, deadline=None)
def test_jitter_bounded_and_seed_deterministic(policy, attempt, counter):
    b = policy.backoff(attempt)
    d = policy.delay(attempt, counter)
    # the jitter only ever SHRINKS the capped backoff, by at most the
    # jitter fraction — a retry storm can never exceed the cap
    assert b * (1.0 - policy.jitter) <= d <= b
    twin = RetryPolicy(max_attempts=policy.max_attempts,
                       base_delay=policy.base_delay,
                       max_delay=policy.max_delay,
                       multiplier=policy.multiplier,
                       jitter=policy.jitter, seed=policy.seed)
    assert twin.delay(attempt, counter) == d


@given(budget=st.floats(0.5, 50.0, allow_nan=False),
       costs=st.lists(st.floats(0.01, 20.0, allow_nan=False),
                      min_size=1, max_size=6),
       inner_attempts=st.integers(1, 4),
       outer_attempts=st.integers(1, 4))
@settings(max_examples=150, deadline=None)
def test_deadline_never_exceeded_across_nested_retries(
        budget, costs, inner_attempts, outer_attempts):
    """No attempt starts after the shared deadline expired, however the
    outer and inner retry loops interleave."""
    state = {"t": 0.0}
    clock.set_clock(lambda: state["t"])
    try:
        deadline = Deadline(budget)
        starts = []

        def inner_body(attempt):
            starts.append(state["t"])
            state["t"] += costs[len(starts) % len(costs)]
            raise ValueError("inner always fails")

        def outer_body(attempt):
            return run_with_policy(
                inner_body, RetryPolicy(max_attempts=inner_attempts),
                stage="inner", deadline=deadline)

        with pytest.raises((RetryExhausted, DeadlineExceeded)):
            run_with_policy(
                outer_body, RetryPolicy(max_attempts=outer_attempts),
                stage="outer", deadline=deadline)
        assert all(t0 < budget for t0 in starts)
    finally:
        clock.set_clock(None)
