"""Result cache: allocation, precision logic, in-order deposits."""

import numpy as np
import pytest

from repro.core import family_sums, harmonic_family
from repro.core import rng as rng_lib
from repro.service.cache import ResultCache

KEY = rng_lib.fold_key(3, 0)
R = 4096


@pytest.fixture
def cache():
    return ResultCache(round_samples=R)


def _round(entry, idx):
    return family_sums(entry.family, R, KEY, fn_offset=entry.fn_offset,
                       sample_offset=idx * R)


def test_allocator_disjoint_counter_ranges(cache):
    a = cache.get_or_allocate("a", harmonic_family(10, 3))
    b = cache.get_or_allocate("b", harmonic_family(7, 2))
    c = cache.get_or_allocate("a", harmonic_family(10, 3))
    assert c is a                      # same hash -> same entry
    ra = range(a.fn_offset, a.fn_offset + a.n_fn)
    rb = range(b.fn_offset, b.fn_offset + b.n_fn)
    assert not set(ra) & set(rb)


def test_empty_entry_never_meets(cache):
    e = cache.get_or_allocate("x", harmonic_family(4, 2))
    assert np.all(np.isinf(e.stderr()))
    assert not cache.meets(e, target_stderr=None, n_samples=1)
    assert not cache.meets(e, target_stderr=1e9, n_samples=None)
    # stderr target with no variance estimate -> one bootstrap round
    assert cache.rounds_needed(e, target_stderr=1e-3, n_samples=None) == 1


def test_budget_quantized_up(cache):
    e = cache.get_or_allocate("x", harmonic_family(4, 2))
    assert cache.rounds_needed(e, target_stderr=None, n_samples=1) == 1
    assert cache.rounds_needed(e, target_stderr=None, n_samples=R + 1) == 2
    cache.deposit(e, 0, _round(e, 0))
    assert cache.meets(e, target_stderr=None, n_samples=R)
    assert not cache.meets(e, target_stderr=None, n_samples=R + 1)


def test_stderr_prediction_converges(cache):
    e = cache.get_or_allocate("x", harmonic_family(4, 2))
    cache.deposit(e, 0, _round(e, 0))
    target = float(e.stderr().max()) / 2.0
    # stderr ~ 1/sqrt(n): halving needs ~4x the samples
    need = cache.rounds_needed(e, target_stderr=target, n_samples=None)
    assert 2 <= need <= 6
    for r in range(1, 1 + need):
        cache.deposit(e, r, _round(e, r))
    assert cache.meets(e, target_stderr=1.1 * target, n_samples=None)


def test_deposit_ordering(cache):
    e = cache.get_or_allocate("x", harmonic_family(4, 2))
    sums = _round(e, 0)
    with pytest.raises(ValueError, match="gap"):
        cache.deposit(e, 1, sums)          # skipping samples is a bug
    assert cache.deposit(e, 0, sums)
    # replay of a folded round (restarted wave / racing driver): exact
    # no-op, because a recomputed round is bit-identical by counters
    assert not cache.deposit(e, 0, sums)
    assert e.n == R and e.rounds_done == 1


def test_topup_equals_single_shot_estimate(cache):
    """Two deposited rounds == one family_sums call over both windows."""
    e = cache.get_or_allocate("x", harmonic_family(6, 3))
    cache.deposit(e, 0, _round(e, 0))
    cache.deposit(e, 1, _round(e, 1))
    ref = family_sums(e.family, 2 * R, KEY, fn_offset=e.fn_offset)
    np.testing.assert_allclose(e.s1, np.asarray(ref.s1), rtol=1e-6)
    np.testing.assert_allclose(e.s2, np.asarray(ref.s2), rtol=1e-6)
    assert e.n == 2 * R
